"""Resident graph serving: a ProgramServer answering a multi-tenant
stream of BFS/SSSP queries over resident graphs.

Walks the whole serving path end to end on 8 fake host devices:

1. register resident graphs and pre-warm every (program, graph, width)
   compile-cache shape class;
2. serve a mixed-tenant stream — many roots fused into tenant-column
   batches, one shard_map launch per batch, zero re-traces;
3. demonstrate admission control: an undersized per-tenant budget gets
   a retriable rejection, not a silent drop, and succeeds on retry
   after the tenant's queued work drains;
4. print the per-tenant / aggregate serving stats snapshot.

  PYTHONPATH=src python examples/serve_graph.py [--requests 24]
"""
import argparse
import json
import os

if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8"
                               ).strip()

import numpy as np

from repro.core.compat import make_mesh
from repro.core.queues import QueueConfig
from repro.serve import ProgramServer, Request, STATUS_OK
from repro.sparse import datasets


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--width", type=int, default=4)
    args = ap.parse_args()

    mesh = make_mesh((8,), ("data",))
    graphs = {"wiki": datasets.wiki_like(256, avg_degree=6, seed=3),
              "road": datasets.erdos_renyi(256, avg_degree=4, seed=7)}
    server = ProgramServer(mesh, graphs, batch_width=args.width)

    print("== pre-warm ==")
    for (prog, gname), keys in server.prewarm(("bfs", "sssp")).items():
        print(f"  {prog}/{gname}: {len(keys)} compile-cache key(s)")

    print(f"== serving {args.requests} mixed-tenant requests ==")
    rng = np.random.default_rng(0)
    tenants = ["acme", "globex", "initech", "umbrella"]
    stream = [Request(req_id=i, tenant=tenants[(i // 4) % len(tenants)],
                      program=("bfs", "sssp")[i % 2],
                      graph=("wiki", "road")[(i // 2) % 2],
                      root=int(rng.integers(256)))
              for i in range(args.requests)]
    responses = server.run(stream)
    ok = sum(r.status == STATUS_OK for r in responses)
    print(f"  {ok}/{len(responses)} ok; "
          f"{server.stats.launches} fused launches; "
          f"cache hit rate {server.stats.cache_hit_rate:.2f}")

    print("== admission control (undersized budget) ==")
    # budget = cap x n_dev; size it to fit exactly ONE wiki query's
    # worst-case per-round demand (its edge count), not two
    one_req = QueueConfig.from_cap(graphs["wiki"].nnz // 8 + 1, "serve")
    tiny = ProgramServer(mesh, graphs, batch_width=args.width,
                         default_queues=one_req)
    first = tiny.submit(Request(req_id=0, tenant="acme", program="bfs",
                                graph="wiki", root=1))
    print(f"  submit #1 -> {'admitted' if first is None else first.status}")
    second = tiny.submit(Request(req_id=1, tenant="acme", program="bfs",
                                 graph="wiki", root=2))
    print(f"  submit #2 -> {second.status} (retriable={second.retriable}): "
          f"{second.reason}")
    tiny.drain()
    retry = tiny.submit(Request(req_id=1, tenant="acme", program="bfs",
                                graph="wiki", root=2))
    print(f"  retry after drain -> "
          f"{'admitted' if retry is None else retry.status}")
    tiny.drain()

    server.stats.verify()
    print("== stats snapshot ==")
    print(json.dumps(server.stats.snapshot(), indent=2, default=float))


if __name__ == "__main__":
    main()
