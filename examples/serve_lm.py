"""Batched-serving example (deliverable b): prefill + greedy decode for a
reduced Mixtral (MoE) and a reduced RWKV6 (attention-free state serving).

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model


def generate(model, params, prompts, gen):
    B, P = prompts.shape
    cache = model.init_cache(B, P + gen, jnp.float32)
    decode = jax.jit(model.decode_step)
    tok = prompts[:, :1]
    outs = []
    for t in range(P + gen - 1):
        logits, cache = decode(params, cache, tok, jnp.array(t, jnp.int32))
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        tok = prompts[:, t + 1:t + 2] if t + 1 < P else nxt
        if t >= P - 1:
            outs.append(tok)
    return jnp.concatenate(outs, axis=1)


def main():
    for arch in ("mixtral-8x22b", "rwkv6-7b"):
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        B, P, G = 4, 24, 12
        prompts = jax.random.randint(jax.random.key(1), (B, P), 0,
                                     cfg.vocab_size)
        t0 = time.time()
        out = generate(model, params, prompts, G)
        dt = time.time() - t0
        assert out.shape == (B, G)
        assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
        print(f"{arch:16s} generated {B}x{G} tokens in {dt:.1f}s "
              f"({B * G / dt:.1f} tok/s, cache type: "
              f"{'state' if cfg.attn_free else 'KV ring'})")


if __name__ == "__main__":
    main()
