"""Graph analytics on the DCRA task engine: all seven apps (the paper's
six + k-core) on one dataset, with the paper's target metrics (TEPS,
TEPS/W, TEPS/$) and the design-space comparison the paper advocates
(SRAM-only vs HBM packaging).

``--distributed`` additionally runs every app on the REAL distributed
shard_map path (8 fake host devices) as a TaskProgram through the shared
owner-routed NoC layer in ``repro.core.routing``, validating each against
its numpy oracle and printing per-app rounds / routed messages / IQ
drops.

  PYTHONPATH=src python examples/graph_analytics.py [--scale 12]
      [--distributed]
"""
import argparse
import os
import sys

if (any(a.startswith("--dist") for a in sys.argv)  # argparse abbreviations
        and "host_platform_device_count" not in os.environ.get("XLA_FLAGS",
                                                               "")):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8"
                               ).strip()

import numpy as np

from repro.core import EngineConfig, TaskEngine, TileGrid
from repro.core.cache import DRAMConfig, SRAMConfig
from repro.costmodel import run_energy, run_perf
from repro.sparse import apps, datasets, ref

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import config_cost, evaluate, APPS  # noqa: E402


def run_distributed(g, scale):
    """All seven apps on the shard_map path; oracle-checked, stats
    printed."""
    from repro.core.compat import make_mesh
    from repro.sparse.jax_apps import (dcra_bfs, dcra_histogram,
                                       dcra_kcore, dcra_pagerank,
                                       dcra_spmv, dcra_sssp, dcra_wcc)
    mesh = make_mesh((8,), ("data",))
    x = np.random.default_rng(0).random(g.n)
    els = datasets.histogram_data(1 << 14, 256)
    hdr = f"{'app':10s} {'rounds':>7s} {'messages':>10s} {'drops':>7s} " \
          f"{'max_err':>10s}"
    print("distributed path (8 devices, owner-routed rounds)")
    print(hdr)
    print("-" * len(hdr))

    def row(name, got, want, stats):
        err = float(np.max(np.abs(np.asarray(got, np.float64) -
                                  np.asarray(want, np.float64))))
        print(f"{name:10s} {stats.rounds:7d} {stats.total_messages:10d} "
              f"{stats.total_drops:7d} {err:10.2e}")

    from repro.sparse.jax_apps import AppStats
    y, drops = dcra_spmv(g, x, mesh, capacity_factor=3.0)
    one = AppStats(1, np.array([g.nnz]), np.array([int(drops)]))
    row("spmv", y, ref.spmv_ref(g, x), one)
    h, drops = dcra_histogram(els, 256, mesh, capacity_factor=3.0)
    one = AppStats(1, np.array([len(els)]), np.array([int(drops)]))
    row("histogram", h, ref.histogram_ref(els, 256), one)
    d, st = dcra_bfs(g, 0, mesh)
    row("bfs", d, ref.bfs_ref(g, 0), st)
    s, st = dcra_sssp(g, 0, mesh)
    row("sssp", np.where(np.isfinite(s), s, -1),
        np.where(np.isfinite(ref.sssp_ref(g, 0)), ref.sssp_ref(g, 0), -1),
        st)
    p, st = dcra_pagerank(g, mesh)
    row("pagerank", p, ref.pagerank_ref(g), st)
    w, st = dcra_wcc(g, mesh)
    row("wcc", w, ref.wcc_ref(g), st)
    k, st = dcra_kcore(g, 16, mesh)
    row("kcore", k, ref.kcore_ref(g, 16), st)
    print()

    # Pareto-guided launch: pick the deployment from the tracked frontier
    # instead of hand-tuning capacity_factor (repro.dse.autoconfig)
    from repro.dse.autoconfig import autoconfigure
    lc = autoconfigure(g, "bfs")
    print(f"auto-config (bfs, objective=teps): {lc.point.point_id} "
          f"[{lc.source}]")
    d, st = dcra_bfs(g, 0, mesh, config=lc)   # reuse the resolved config
    row("bfs[auto]", d, ref.bfs_ref(g, 0), st)
    print()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--distributed", action="store_true",
                    help="also run the six apps on the shard_map path")
    args = ap.parse_args()

    g = datasets.rmat(args.scale, edge_factor=16)
    print(f"RMAT-{args.scale}: V={g.n} E={g.nnz} "
          f"({g.memory_bytes() / 2**20:.1f} MB CSR)\n")

    if args.distributed:
        run_distributed(g, args.scale)

    packagings = {
        "DCRA-HBM (32x32)": EngineConfig(
            grid=TileGrid(32, 32, "hier_torus", die_rows=16, die_cols=16),
            sram=SRAMConfig(kb_per_tile=512), dram=DRAMConfig(present=True)),
        "DCRA-SRAM (64x64)": EngineConfig(
            grid=TileGrid(64, 64, "hier_torus", die_rows=16, die_cols=16),
            sram=SRAMConfig(kb_per_tile=512), dram=DRAMConfig(present=False)),
    }
    hdr = f"{'packaging':20s} {'app':10s} {'TEPS':>10s} {'TEPS/W':>10s} " \
          f"{'TEPS/$':>10s}"
    print(hdr)
    print("-" * len(hdr))
    for pname, cfg in packagings.items():
        for app in APPS:
            r = evaluate(cfg, g, app)
            print(f"{pname:20s} {app:10s} {r.teps:10.2e} "
                  f"{r.teps_per_watt:10.2e} {r.teps_per_dollar:10.2e}")
        print()


if __name__ == "__main__":
    main()
