"""Graph analytics on the DCRA task engine: all six paper apps on one
dataset, with the paper's target metrics (TEPS, TEPS/W, TEPS/$) and the
design-space comparison the paper advocates (SRAM-only vs HBM packaging).

  PYTHONPATH=src python examples/graph_analytics.py [--scale 12]
"""
import argparse
import os
import sys

import numpy as np

from repro.core import EngineConfig, TaskEngine, TileGrid
from repro.core.cache import DRAMConfig, SRAMConfig
from repro.costmodel import run_energy, run_perf
from repro.sparse import apps, datasets, ref

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import config_cost, evaluate, APPS  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    args = ap.parse_args()

    g = datasets.rmat(args.scale, edge_factor=16)
    print(f"RMAT-{args.scale}: V={g.n} E={g.nnz} "
          f"({g.memory_bytes() / 2**20:.1f} MB CSR)\n")

    packagings = {
        "DCRA-HBM (32x32)": EngineConfig(
            grid=TileGrid(32, 32, "hier_torus", die_rows=16, die_cols=16),
            sram=SRAMConfig(kb_per_tile=512), dram=DRAMConfig(present=True)),
        "DCRA-SRAM (64x64)": EngineConfig(
            grid=TileGrid(64, 64, "hier_torus", die_rows=16, die_cols=16),
            sram=SRAMConfig(kb_per_tile=512), dram=DRAMConfig(present=False)),
    }
    hdr = f"{'packaging':20s} {'app':10s} {'TEPS':>10s} {'TEPS/W':>10s} " \
          f"{'TEPS/$':>10s}"
    print(hdr)
    print("-" * len(hdr))
    for pname, cfg in packagings.items():
        for app in APPS:
            r = evaluate(cfg, g, app)
            print(f"{pname:20s} {app:10s} {r.teps:10.2e} "
                  f"{r.teps_per_watt:10.2e} {r.teps_per_dollar:10.2e}")
        print()


if __name__ == "__main__":
    main()
