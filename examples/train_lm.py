"""End-to-end driver (deliverable b): train a ~100M-param decoder LM for a
few hundred steps with checkpointing + fault-tolerant loop.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]

~100M params: 8 layers x d_model 512 x d_ff 2048, vocab 32000 (granite
family scaled). Loss should drop from ~10.4 to well under 8 on the
synthetic zipf stream.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import synth_batch
from repro.models import build_model
from repro.optim.adamw import AdamW, cosine_schedule
from repro.runtime.fault_tolerance import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("granite-8b"),
        name="granite-100m", num_layers=8, d_model=512, num_heads=8,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
        scan_layers=True)
    model = build_model(cfg)
    print(f"model: {cfg.param_count() / 1e6:.0f}M params")
    opt = AdamW(lr=cosine_schedule(peak_lr=6e-4, warmup=30,
                                   total=args.steps))
    shape = ShapeConfig("train", args.seq, args.batch, "train")

    @jax.jit
    def step_fn(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, metrics

    def init_state():
        params = model.init(jax.random.key(0))
        return params, opt.init(params)

    def batch_fn(step):
        raw = synth_batch(cfg, shape, step)
        return {k: jnp.asarray(np.minimum(v, cfg.vocab_size - 1)
                               if k in ("tokens", "labels") else v)
                for k, v in raw.items()}

    t0 = time.time()
    res = run_training(step_fn, init_state, batch_fn, args.steps,
                       args.ckpt_dir, ckpt_every=100)
    dt = time.time() - t0
    first = res.metrics_history[0]["ce"]
    last = np.mean([m["ce"] for m in res.metrics_history[-10:]])
    print(f"CE {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s)")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
