"""Quickstart: the DCRA framework in five acts, all on CPU in ~a minute.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import EngineConfig, TaskEngine, TileGrid
from repro.costmodel import run_energy, run_perf
from repro.models import build_model
from repro.sparse import apps, datasets, ref

# -- 1. a graph + the DCRA task engine (the paper's execution model) -------
g = datasets.rmat(10, edge_factor=8)
grid = TileGrid(8, 8, topology="hier_torus", die_rows=4, die_cols=4)
engine = TaskEngine(EngineConfig(grid=grid), g.n)
dist, stats = apps.bfs(engine, g, root=0)
assert np.array_equal(dist, ref.bfs_ref(g, 0))
print(f"BFS on RMAT-10: {stats.total_messages} task messages, "
      f"{stats.total_hops} NoC hops over a {grid.topology} grid")

# -- 2. performance / energy / cost from the paper's models ----------------
perf = run_perf(stats, engine.cfg, g.nnz, dataset_bytes=g.memory_bytes())
en = run_energy(stats, engine.cfg, dataset_bytes=g.memory_bytes())
print(f"model: {perf.teps:.2e} TEPS, {en.total_j * 1e6:.1f} uJ "
      f"(NoC {en.noc_j / en.total_j:.0%}, mem {en.memory_j / en.total_j:.0%},"
      f" PU {en.pu_j / en.total_j:.0%})")

# -- 3. a Pallas TPU kernel (interpret mode on CPU) -------------------------
from repro.kernels.ops import histogram
els = jax.random.randint(jax.random.key(0), (4096,), 0, 256)
print("histogram kernel ok:", bool((histogram(els, 256)
                                    == jnp.bincount(els, length=256)).all()))

# -- 4. an assigned architecture, reduced, one train step -------------------
cfg = get_config("mixtral-8x22b").reduced()
model = build_model(cfg)
params = model.init(jax.random.key(0))
tok = jax.random.randint(jax.random.key(1), (2, 64), 0, cfg.vocab_size)
loss, metrics = model.loss(params, {"tokens": tok, "labels": tok})
print(f"mixtral-8x22b (reduced) loss: {float(loss):.3f} "
      f"(aux {float(metrics['aux']):.3f})")

# -- 5. one greedy decode step with a KV cache ------------------------------
cache = model.init_cache(2, 64, jnp.float32)
logits, cache = model.decode_step(params, cache, tok[:, :1],
                                  jnp.array(0, jnp.int32))
print("decode step ok:", logits.shape)
