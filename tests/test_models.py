"""Model-layer unit + property tests: chunked==scan oracles for RWKV6 and
Mamba2 SSD, SWA ring cache, M-RoPE, attention equivalences."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import build_model
from repro.models.attention import (_cache_positions, _chunked_attend,
                                    _direct_attend)
from repro.models.mamba2 import ssd_chunked, ssd_scan
from repro.models.rwkv6 import wkv_chunked, wkv_scan


def test_rwkv_chunked_equals_scan():
    B, T, H, hd = 2, 128, 2, 16
    ks = jax.random.split(jax.random.key(0), 5)
    r, k, v = (jax.random.normal(kk, (B, T, H, hd)) for kk in ks[:3])
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    s0 = jnp.zeros((B, H, hd, hd))
    y1, s1 = wkv_scan(r, k, v, w, u, s0)
    y2, s2 = wkv_chunked(r, k, v, w, u, s0, chunk=32)
    assert jnp.max(jnp.abs(y1 - y2)) < 1e-4
    assert jnp.max(jnp.abs(s1 - s2)) < 1e-4


def test_mamba_chunked_equals_scan():
    B, T, H, P, N = 2, 128, 3, 8, 4
    ks = jax.random.split(jax.random.key(1), 4)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, N))
    Cm = jax.random.normal(jax.random.key(5), (B, T, N))
    h0 = jnp.zeros((B, H, N, P))
    y1, h1 = ssd_scan(x, dt, A, Bm, Cm, h0)
    y2, h2 = ssd_chunked(x, dt, A, Bm, Cm, h0, chunk=32)
    assert jnp.max(jnp.abs(y1 - y2)) < 1e-3
    assert jnp.max(jnp.abs(h1 - h2)) < 1e-3


def test_chunked_attention_equals_direct():
    B, S, Hq, Hkv, hd = 1, 256, 4, 2, 16
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    pos = jnp.arange(S, dtype=jnp.int32)
    d = _direct_attend(q, k, v, pos[None], pos, True, 0)
    c = _chunked_attend(q, k, v, pos[None], pos, True, 0, chunk=64)
    assert jnp.max(jnp.abs(d - c)) < 1e-4
    # with sliding window
    d = _direct_attend(q, k, v, pos[None], pos, True, 32)
    c = _chunked_attend(q, k, v, pos[None], pos, True, 32, chunk=64)
    assert jnp.max(jnp.abs(d - c)) < 1e-4


@settings(max_examples=15, deadline=None)
@given(pos=st.integers(0, 300), cap=st.sampled_from([16, 32, 64]))
def test_ring_cache_positions_property(pos, cap):
    """Slot positions cover exactly the last min(pos+1, cap) positions."""
    got = np.asarray(_cache_positions(jnp.array(pos), cap))
    valid = got[got != np.iinfo(np.int32).max]
    expect = set(range(max(0, pos - cap + 1), pos + 1))
    assert set(valid.tolist()) == expect
    assert len(valid) == min(pos + 1, cap)


@pytest.mark.parametrize("arch", ["h2o-danube-3-4b", "mixtral-8x22b"])
def test_swa_decode_matches_forward(arch):
    """SWA ring buffer: teacher-forced decode equals full forward even past
    the window wrap-around."""
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        # capacity drops are batch-size dependent (standard MoE train/serve
        # discrepancy) -> raise capacity so routing is drop-free both ways
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
    assert cfg.sliding_window > 0
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 1, cfg.sliding_window * 2 + 8   # wraps the ring
    tok = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    logits, _ = model.forward(params, {"tokens": tok, "labels": tok})
    cache = model.init_cache(B, S, jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, tok[:, t:t + 1],
                                      jnp.array(t, jnp.int32))
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    assert jnp.max(jnp.abs(dec - logits)) < 1e-3


def test_mrope_position_dependence():
    """M-RoPE: changing the spatial position streams changes attention."""
    cfg = get_config("qwen2-vl-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, P, St = 1, 16, 16
    S = P + St
    tok = jax.random.randint(jax.random.key(1), (B, St), 0, cfg.vocab_size)
    pe = jax.random.normal(jax.random.key(2), (B, P, cfg.d_model))
    pos1 = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, 3, S))
    pos2 = pos1.at[:, 1].set(pos1[:, 1][:, ::-1])   # flip height stream
    l1, _ = model.forward(params, {"tokens": tok, "labels": tok,
                                   "patch_embeds": pe, "positions": pos1})
    l2, _ = model.forward(params, {"tokens": tok, "labels": tok,
                                   "patch_embeds": pe, "positions": pos2})
    assert not jnp.allclose(l1, l2, atol=1e-4)


def test_encdec_cross_attention_uses_encoder():
    cfg = get_config("seamless-m4t-large-v2").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, Ss, St = 1, 16, 16
    tok = jax.random.randint(jax.random.key(1), (B, St), 0, cfg.vocab_size)
    src1 = jax.random.normal(jax.random.key(2), (B, Ss, cfg.d_model))
    src2 = src1 + 1.0
    l1, _ = model.forward(params, {"src_embeds": src1, "tokens": tok,
                                   "labels": tok})
    l2, _ = model.forward(params, {"src_embeds": src2, "tokens": tok,
                                   "labels": tok})
    assert not jnp.allclose(l1, l2, atol=1e-4)
