"""The resident serving runtime (:mod:`repro.serve`).

Part A — host-side pieces: tenant-graph expansion, column split, batch
padding, QueueConfig round budgets.

Part B (subprocess, 8 fake host devices) — the serving contract:

* a mixed stream of 4 tenants x 2 programs completes with every
  per-tenant result **bit-identical** to the equivalent standalone
  ``run_program`` launch;
* pre-warm populates exactly one compile-cache key per (program, graph,
  batch-width) shape class, and the whole request stream afterwards is
  cache hits only — zero new jit traces under serving load (the
  ``cache_stats``/``_cached`` serving-load coverage);
* admission control: an undersized per-tenant budget rejects with a
  retriable status (never a silent drop), accounting balances, and a
  drained tenant's retry is admitted; a request whose demand alone
  exceeds its budget is rejected NON-retriable (no futile retry loop);
* undersized *launch* queues produce NoC drops that are attributed to
  responses and stats, never swallowed;
* the MoE lane serves batched token blocks through one warm jitted
  dispatch (no re-trace after warm-up) and matches the einsum oracle;
* **continuous serving**: for every ``inflight_depth`` in {1, 2, 4} (and
  the DRR former, and donated buffers) the responses, per-tenant ledger
  and cache keys are bit-identical to the synchronous drain with zero
  extra re-traces; a poisoned batch at window position 2 of 3 fails only
  its own riders while earlier/later inflight batches complete.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# Part A: host-side pieces
# ---------------------------------------------------------------------------

def test_tenant_graph_expansion_blocked_layout():
    from repro.serve.batching import split_tenant_states, tenant_graph
    from repro.sparse import datasets
    g = datasets.erdos_renyi(48, avg_degree=4, seed=2)
    T = 3
    tg = tenant_graph(g, T)
    assert tg.n == g.n * T and tg.nnz == g.nnz * T
    rows, cols = tg.row_of(), tg.col_idx.astype(np.int64)
    # every edge stays inside its tenant column (blocked ids: t*n + v)
    assert np.array_equal(rows // g.n, cols // g.n)
    # each column holds exactly the base edge set
    base = set(zip(g.row_of().tolist(), g.col_idx.tolist()))
    for t in range(T):
        sel = rows // g.n == t
        col_edges = set(zip((rows[sel] - t * g.n).tolist(),
                            (cols[sel] - t * g.n).tolist()))
        assert col_edges == base
    # memoized by identity
    assert tenant_graph(g, T) is tg
    # split is the exact inverse of the blocked packing
    state = np.arange(g.n * T, dtype=np.float64)
    parts = split_tenant_states(state, g.n, T)
    for t in range(T):
        assert np.array_equal(parts[t], state[t * g.n:(t + 1) * g.n])


def test_tenant_batch_padding():
    from repro.serve.batching import TenantBatch
    b = TenantBatch(program="bfs", graph="g", width=4, roots=(5, 9),
                    tenants=["a", "b"], req_ids=[1, 2]).padded()
    assert b.roots == (5, 9, 0, 0) and b.n_real == 2
    assert b.req_ids == [1, 2, None, None]
    with pytest.raises(ValueError):
        TenantBatch(program="bfs", graph="g", width=1, roots=(1, 2),
                    tenants=["a", "b"], req_ids=[1, 2]).padded()


def test_queueconfig_round_budget():
    from repro.core.queues import QueueConfig
    assert QueueConfig.from_cap(5, "serve").round_budget("serve", 100, 4) \
        == 20
    # factor sizing: per-channel cap is lane-aligned, budget scales by it
    q = QueueConfig.from_factor(1.0, "serve")
    cap = q.channel_cap("serve", 100, 4)
    assert q.round_budget("serve", 100, 4) == cap * 4
    # unbounded -> no admission limit
    assert QueueConfig.unbounded().round_budget("serve", 100, 4) is None


def test_batched_program_registry():
    from repro.serve.batching import batched_program
    assert batched_program("bfs").init_only == ("roots",)
    assert batched_program("sssp").reduce_op == "min"
    with pytest.raises(KeyError):
        batched_program("pagerank")   # add-reduce: no exact batching


def test_tenant_graph_memo_purges_dead_graphs():
    """The memo must not pin garbage-collected base graphs (unbounded
    growth) — a dead referent's entry disappears with the graph."""
    import gc
    from repro.serve import batching
    from repro.sparse import datasets
    n0 = len(batching._TENANT_GRAPHS)
    g = datasets.erdos_renyi(32, avg_degree=3, seed=4)
    tg = batching.tenant_graph(g, 2)
    assert batching.tenant_graph(g, 2) is tg        # memo hit while alive
    assert len(batching._TENANT_GRAPHS) == n0 + 1
    del g
    gc.collect()
    assert len(batching._TENANT_GRAPHS) == n0


def test_tenant_graph_memo_not_fooled_by_id_reuse():
    """Regression: the memo keyed (id(g), T) alone — once a base CSR was
    collected and a new one landed at the same id, the stale expansion of
    a DIFFERENT graph came back. Simulate the id collision directly: a
    stale entry under g's id whose recorded referent is dead must be
    recomputed, not served."""
    import weakref
    from repro.serve import batching
    from repro.sparse import datasets
    g = datasets.erdos_renyi(32, avg_degree=3, seed=5)
    other = datasets.erdos_renyi(8, avg_degree=2, seed=6)
    stale = batching.tenant_graph(other, 2)

    class _Dead:
        pass

    d = _Dead()
    batching._TENANT_GRAPHS[(id(g), 2)] = (weakref.ref(d), stale)
    del d
    tg = batching.tenant_graph(g, 2)
    assert tg is not stale
    assert tg.n == g.n * 2 and tg.nnz == g.nnz * 2


class _FakeMesh:
    """Just enough mesh for submit-time admission tests (no launches)."""
    devices = np.zeros(4)


def test_submit_rejects_out_of_range_root():
    """Regression: an unvalidated root r >= n (or negative) wraps into
    ANOTHER tenant's column in _multi_root_init, silently corrupting that
    tenant's result. submit() must fail such requests loudly."""
    from repro.serve import ProgramServer, Request, STATUS_FAILED
    from repro.sparse import datasets
    g = datasets.erdos_renyi(32, avg_degree=3, seed=7)
    srv = ProgramServer(_FakeMesh(), {"g": g}, batch_width=2)
    for bad in (g.n, g.n + 5, -1):
        resp = srv.submit(Request(0, "acme", "bfs", "g", root=bad))
        assert resp is not None and resp.status == STATUS_FAILED
        assert "root" in resp.reason and not resp.retriable
    assert srv.queue_depth == 0
    srv.stats.verify()                  # failed roots are all accounted
    assert srv.stats.tenant("acme").failed == 3
    # boundary roots are still admitted
    srv2 = ProgramServer(_FakeMesh(), {"g": g}, batch_width=2)
    assert srv2.submit(Request(1, "acme", "bfs", "g", root=g.n - 1)) is None
    assert srv2.submit(Request(2, "bee", "bfs", "g", root=0)) is None
    assert srv2.queue_depth == 2


def test_multi_root_init_rejects_out_of_range_root():
    """Defense in depth: the init rule itself refuses roots that would
    seed distance 0 outside the request's own tenant column."""
    from repro.serve.batching import tenant_graph
    from repro.sparse import datasets
    from repro.sparse.jax_apps import BATCHED_BFS
    g = datasets.erdos_renyi(16, avg_degree=3, seed=8)
    tg = tenant_graph(g, 2)
    (dist,), _ = BATCHED_BFS.init(tg, {"roots": (0, g.n - 1)})
    assert dist[0] == 0.0 and dist[2 * g.n - 1] == 0.0
    for bad in (g.n, -1):
        with pytest.raises(ValueError, match="out of range"):
            BATCHED_BFS.init(tg, {"roots": (0, bad)})


def test_submit_moe_without_service_fails_accounted():
    """Regression: a 'moe' request on a server with no MoEService raised
    ValueError out of submit(), leaving the request counted as submitted
    but never served/rejected/failed — breaking the stats ledger."""
    from repro.serve import ProgramServer, Request, STATUS_FAILED
    srv = ProgramServer(_FakeMesh(), {})
    resp = srv.submit(Request(0, "acme", "moe",
                              payload=np.zeros((16, 8), np.float32)))
    assert resp is not None and resp.status == STATUS_FAILED
    assert "MoEService" in resp.reason and not resp.retriable
    srv.stats.verify()
    assert srv.stats.tenant("acme").failed == 1


def test_oversized_demand_rejected_nonretriable():
    """Regression: a request whose demand alone exceeds the tenant budget
    was rejected retriable=True with a 'resubmit after drain' reason, so
    a well-behaved retrying client looped forever."""
    from repro.core.queues import QueueConfig
    from repro.serve import ProgramServer, Request, STATUS_REJECTED
    from repro.sparse import datasets
    g = datasets.erdos_renyi(32, avg_degree=3, seed=9)
    srv = ProgramServer(
        _FakeMesh(), {"g": g}, batch_width=2,
        default_queues=QueueConfig.from_cap(2, "serve"))   # budget 8 << nnz
    resp = srv.submit(Request(0, "acme", "bfs", "g", root=0))
    assert resp is not None and resp.status == STATUS_REJECTED
    assert resp.retriable is False
    assert "never" in resp.reason
    srv.stats.verify()
    assert srv.stats.tenant("acme").rejected == 1


def test_serve_options_validation():
    from repro.serve import ServeOptions
    assert ServeOptions().resolve().inflight_depth == 1
    assert ServeOptions(inflight_depth=4, fairness="drr",
                        drr_quantum=100).resolve().fairness == "drr"
    with pytest.raises(ValueError, match="inflight_depth"):
        ServeOptions(inflight_depth=0).resolve()
    with pytest.raises(ValueError, match="fairness"):
        ServeOptions(fairness="lifo").resolve()
    with pytest.raises(ValueError, match="drr_quantum"):
        ServeOptions(drr_quantum=0).resolve()


class _Entry:
    """Former-protocol stub: tenant / klass / demand (+ a test tag)."""

    def __init__(self, tenant, klass, demand=1, tag=0):
        self.tenant, self.klass = tenant, klass
        self.demand, self.tag = demand, tag


def test_fifo_former_head_of_line_scan():
    """FifoFormer is the pre-former serving loop verbatim: the oldest
    request fixes the class, same-class requests from distinct tenants
    ride, everything else keeps arrival order."""
    from repro.serve.batching import FifoFormer
    f = FifoFormer()
    for tenant, klass in [("a", "A"), ("b", "B"), ("c", "A"),
                          ("a", "A"), ("d", "A")]:
        f.push(_Entry(tenant, klass))
    got = f.form(lambda e: 3)
    assert [(e.tenant, e.klass) for e in got] == \
        [("a", "A"), ("c", "A"), ("d", "A")]
    # the duplicate-tenant entry and the off-class entry stay, in order
    assert len(f) == 2 and f.pending_tenants() == ["b", "a"]
    assert [(e.tenant, e.klass) for e in f.form(lambda e: 3)] == [("b", "B")]
    assert [(e.tenant, e.klass) for e in f.form(lambda e: 3)] == [("a", "A")]
    assert f.form(lambda e: 3) == []


def test_drr_former_unstarves_light_tenants():
    """The 1-vs-many skew FIFO gets wrong: a hog with a deep backlog of
    one class vs three light tenants of another. FIFO would serve the
    entire hog backlog first; DRR lets every light tenant set or ride a
    batch within n_tenants formations of arriving."""
    from repro.serve.batching import DrrFormer
    f = DrrFormer()
    for i in range(16):
        f.push(_Entry("hog", ("bfs", "g"), demand=5, tag=i))
    for t in ("lark", "wren", "finch"):
        f.push(_Entry(t, ("sssp", "g"), demand=3))
    batches = []
    while len(f):
        batches.append(f.form(lambda e: 4))
    # formation 1: hog sets (no same-class riders pending); formation 2:
    # the light class launches fused — not after 16 hog batches
    assert [e.tenant for e in batches[0]] == ["hog"]
    assert sorted(e.tenant for e in batches[1]) == ["finch", "lark", "wren"]
    # intra-tenant FIFO: the hog backlog drains in admission order
    hog_tags = [e.tag for b in batches for e in b if e.tenant == "hog"]
    assert hog_tags == list(range(16))


def test_drr_starvation_bound_and_intra_tenant_order():
    """Property pin (the ISSUE acceptance bound): under random mixed
    streams with per-tenant backlog <= batch_width, every admitted
    request launches within ``batch_width * n_tenants`` formations of
    its admission, and each tenant's requests pop in admission order."""
    from repro.serve.batching import DrrFormer
    width = 4
    for seed in range(8):
        rng = np.random.default_rng(seed)
        n_tenants = int(rng.integers(2, 6))
        tenants = [f"t{i}" for i in range(n_tenants)]
        classes = [("bfs", "g"), ("sssp", "g"), ("bfs", "h")]
        f = DrrFormer()
        formations, tag = 0, 0
        admitted_at = {}
        pending = {t: 0 for t in tenants}
        pushed = {t: [] for t in tenants}
        popped = {t: [] for t in tenants}

        def push_some():
            nonlocal tag
            for t in tenants:
                for _ in range(int(rng.integers(0, width + 1 - pending[t]))):
                    f.push(_Entry(t, classes[int(rng.integers(0, 3))],
                                  demand=int(rng.integers(1, 9)), tag=tag))
                    admitted_at[tag] = formations
                    pushed[t].append(tag)
                    pending[t] += 1
                    tag += 1

        push_some()
        while len(f):
            batch = f.form(lambda e: width)
            formations += 1
            assert batch and len({e.tenant for e in batch}) == len(batch)
            assert len({e.klass for e in batch}) == 1
            for e in batch:
                popped[e.tenant].append(e.tag)
                pending[e.tenant] -= 1
                wait = formations - admitted_at[e.tag]
                assert wait <= width * n_tenants, (seed, e.tag, wait)
            if rng.random() < 0.3:
                push_some()
        for t in tenants:
            assert popped[t] == pushed[t], (seed, t)


def test_stats_reservoirs_bounded():
    """A resident server runs for days: every per-event reservoir is a
    bounded deque so host memory stays O(STATS_WINDOW) — this test pins
    the cap and the over-the-window eviction behavior."""
    from repro.serve.stats import STATS_WINDOW, ServingStats, TenantStats
    assert STATS_WINDOW == 4096                   # the documented cap
    ts = TenantStats()
    for i in range(STATS_WINDOW + 123):
        ts.latencies.append(float(i))
        ts.queue_waits.append(float(i))
        ts.device_times.append(float(i))
    assert ts.latencies.maxlen == STATS_WINDOW
    assert len(ts.latencies) == len(ts.queue_waits) \
        == len(ts.device_times) == STATS_WINDOW
    # quantiles cover the most recent window only (oldest 123 evicted)
    assert ts.snapshot()["p50_latency_s"] >= 123
    ss = ServingStats()
    for d in range(STATS_WINDOW + 7):
        ss.observe_queue_depth(d)
        ss.round_latencies.append(float(d))
    assert len(ss.queue_depth_samples) == len(ss.round_latencies) \
        == STATS_WINDOW
    assert min(ss.queue_depth_samples) == 7       # eviction really happened
    # ... but the running max survives the window
    assert ss.max_queue_depth == STATS_WINDOW + 6
    assert ss.snapshot()["max_queue_depth"] == STATS_WINDOW + 6


# ---------------------------------------------------------------------------
# Part B: the serving contract under shard_map (subprocess)
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import dataclasses
import json
import numpy as np
import jax
from repro.core.compat import make_mesh
from repro.core.queues import QueueConfig
from repro.sparse import datasets, program
from repro.sparse.jax_apps import BFS, SSSP
from repro.sparse.program import run_program
from repro.serve import (MoEService, ProgramServer, Request,
                         STATUS_OK, STATUS_REJECTED)

res = {}
g = datasets.wiki_like(192, avg_degree=6, seed=3)
mesh = make_mesh((4,), ('data',))
WIDTH = 4

# ---- pre-warm populates exactly the expected keys ----------------------
program.clear_cache()
srv = ProgramServer(mesh, {'wiki': g}, batch_width=WIDTH)
warm = srv.prewarm(('bfs', 'sssp'))
res['warm'] = {'keys_per_class': {f'{p}/{gn}': len(ks)
                                  for (p, gn), ks in warm.items()},
               'cache': program.cache_stats(),
               'n_cache_keys': len(program.cache_keys())}
warm2 = srv.prewarm(('bfs', 'sssp'))      # idempotent: nothing new
res['warm_again'] = {'new_keys': sum(len(k) for k in warm2.values()),
                     'cache': program.cache_stats()}

# ---- mixed 4-tenant x 2-program stream under serving load --------------
TENANTS = ['acme', 'globex', 'initech', 'umbrella']
reqs = [Request(i, TENANTS[i % 4], 'bfs' if i % 2 == 0 else 'sssp',
                'wiki', root=(i * 13) % g.n) for i in range(16)]
c0 = program.cache_stats()
resps = srv.run(reqs)
c1 = program.cache_stats()
res['stream'] = {
    'statuses': [r.status for r in resps],
    'new_hits': c1['hits'] - c0['hits'],
    'new_misses': c1['misses'] - c0['misses'],
    'new_traces': c1['kernel_traces'] - c0['kernel_traces'],
    'identical': [], 'drops': sum(r.batch_drops for r in resps)}
for r, resp in zip(reqs, resps):
    prog = BFS if r.program == 'bfs' else SSSP
    (d,), _ = run_program(prog, g, mesh, params={'root': r.root})
    res['stream']['identical'].append(
        bool(np.array_equal(d, resp.result)))
srv.stats.verify()
res['stats'] = srv.stats.snapshot()

# ---- continuous serving: depth sweep bit-identity + zero re-traces -----
from repro.serve import ServeOptions

def _sig(rs):
    return [(r.req_id, r.tenant, r.status, r.retriable, r.reason,
             None if r.result is None else r.result.tobytes(),
             r.batch_drops, r.batch_messages, r.rounds, r.batch_width)
            for r in sorted(rs, key=lambda r: r.req_id)]

def _ledger(s):
    return {t: (v.submitted, v.served, v.rejected, v.failed)
            for t, v in s.stats.tenants.items()}

base_sig = _sig(resps)          # the depth-1 FIFO synchronous drain
base_ledger = _ledger(srv)
res['depths'] = {}
for depth, fairness in [(1, 'fifo'), (2, 'fifo'), (4, 'fifo'), (3, 'drr')]:
    c0 = program.cache_stats()
    srv_d = ProgramServer(mesh, {'wiki': g}, batch_width=WIDTH,
                          serve_options=ServeOptions(inflight_depth=depth,
                                                     fairness=fairness))
    rs = srv_d.run(reqs)
    c1 = program.cache_stats()
    srv_d.stats.verify()
    res['depths'][f'{fairness}{depth}'] = {
        'sig_equal': _sig(rs) == base_sig,
        'ledger_equal': _ledger(srv_d) == base_ledger,
        'new_misses': c1['misses'] - c0['misses'],
        'new_traces': c1['kernel_traces'] - c0['kernel_traces'],
        'launches': srv_d.stats.launches}

# ---- donated buffers: own key class, still bit-identical ---------------
srv_don = ProgramServer(mesh, {'wiki': g}, batch_width=WIDTH,
                        serve_options=ServeOptions(inflight_depth=3,
                                                   donate_buffers=True))
k0 = len(program.cache_keys())
srv_don.prewarm(('bfs', 'sssp'))
k1 = len(program.cache_keys())
c0 = program.cache_stats()
rs_don = srv_don.run(reqs)
c1 = program.cache_stats()
srv_don.stats.verify()
res['donate'] = {'sig_equal': _sig(rs_don) == base_sig,
                 'new_keys_prewarm': k1 - k0,
                 'new_misses_under_load': c1['misses'] - c0['misses'],
                 'new_traces_under_load':
                     c1['kernel_traces'] - c0['kernel_traces']}

# ---- failure in flight: poisoned batch at window position 2 of 3 -------
POISON_ROOT = g.n - 1
real_launch = program.launch_program
window_at_launch = []
def _poisoned(prog, data, fabric, **kw):
    window_at_launch.append(srv_f.inflight_depth)
    roots = tuple((kw.get('params') or {}).get('roots') or ())
    if POISON_ROOT in roots:
        raise RuntimeError('injected launch failure')
    return real_launch(prog, data, fabric, **kw)
program.launch_program = _poisoned
try:
    srv_f = ProgramServer(mesh, {'wiki': g}, batch_width=WIDTH,
                          serve_options=ServeOptions(inflight_depth=3))
    f_reqs = (
        [Request(i, f'a{i}', 'bfs', 'wiki', root=1) for i in range(4)]
        + [Request(4 + i, f'b{i}', 'bfs', 'wiki',
                   root=POISON_ROOT if i == 0 else 2) for i in range(4)]
        + [Request(8 + i, f'c{i}', 'bfs', 'wiki', root=3) for i in range(4)])
    f_resps = srv_f.run(f_reqs)
    srv_f.stats.verify()
finally:
    program.launch_program = real_launch
(ok1,), _ = run_program(BFS, g, mesh, params={'root': 1})
(ok3,), _ = run_program(BFS, g, mesh, params={'root': 3})
res['failure'] = {
    'n_responses': len(f_resps),
    'statuses': [r.status for r in f_resps],
    'retriable': [r.retriable for r in f_resps],
    'reasons_failed': [r.reason for r in f_resps if r.status != STATUS_OK],
    'survivors_identical': bool(
        np.array_equal(f_resps[0].result, ok1)
        and np.array_equal(f_resps[8].result, ok3)),
    'max_window_at_launch': max(window_at_launch),
    'ledger': _ledger(srv_f)}

# ---- admission control: undersized per-tenant budget -------------------
n_dev = 4
one_req = QueueConfig.from_cap(g.nnz // n_dev + 1, 'serve')
tiny = QueueConfig.from_cap(2, 'serve')
srv2 = ProgramServer(mesh, {'wiki': g}, batch_width=WIDTH,
                     tenant_queues={'acme': one_req, 'globex': tiny})
r_ok = srv2.submit(Request(0, 'acme', 'bfs', 'wiki', root=1))
r_over = srv2.submit(Request(1, 'acme', 'bfs', 'wiki', root=2))
r_tiny = srv2.submit(Request(2, 'globex', 'bfs', 'wiki', root=3))
drained = srv2.drain()
r_retry = srv2.submit(Request(3, 'acme', 'bfs', 'wiki', root=2))
drained += srv2.drain()
srv2.stats.verify()
res['admission'] = {
    'first_admitted': r_ok is None,
    'over_budget': None if r_over is None else
        {'status': r_over.status, 'retriable': r_over.retriable},
    'tiny_budget': None if r_tiny is None else
        {'status': r_tiny.status, 'retriable': r_tiny.retriable},
    'retry_after_drain_admitted': r_retry is None,
    'served': [r.status for r in drained],
    'tenant_stats': srv2.stats.snapshot()['tenants']}

# ---- undersized LAUNCH queues: drops are attributed, never silent ------
srv3 = ProgramServer(mesh, {'wiki': g}, batch_width=WIDTH,
                     launch_queues=QueueConfig.from_cap(2, 'T3'))
resp3 = srv3.run([Request(i, f't{i}', 'bfs', 'wiki', root=i)
                  for i in range(2)])
srv3.stats.verify()
res['drops'] = {'batch_drops': [r.batch_drops for r in resp3],
                'stats_drops': srv3.stats.noc_drops,
                'statuses': [r.status for r in resp3]}

# ---- MoE lane: batched dispatch, warm after one trace ------------------
from repro.configs import get_config
from repro.core.dispatch import MeshInfo
from repro.models.moe import init_moe, moe_einsum
cfg = get_config('olmoe-1b-7b').reduced()
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, capacity_factor=8.0))
params = init_moe(jax.random.key(0), cfg)
mesh2 = make_mesh((2, 2, 2), ('data', 'expert', 'tp'))
moe = MoEService(cfg, params, MeshInfo(mesh2, pod_axis=None),
                 batch=4, seq=16)
srv4 = ProgramServer(mesh2, {}, moe=moe)
srv4.prewarm(('moe',))
traces_after_warm = moe.traces
rng = np.random.default_rng(0)
blocks = [rng.normal(size=(16, cfg.d_model)).astype(np.float32)
          for _ in range(6)]
mreqs = [Request(i, f'm{i % 3}', 'moe', payload=b)
         for i, b in enumerate(blocks)]
mresps = srv4.run(mreqs)
srv4.stats.verify()
x = np.zeros((4, 16, cfg.d_model), np.float32)
for i in range(4):
    x[i] = blocks[i]
oracle, _ = moe_einsum(params, x, cfg)
err = max(float(np.max(np.abs(np.asarray(oracle)[i] - mresps[i].result)))
          for i in range(4))
res['moe'] = {'statuses': [r.status for r in mresps],
              'traces_after_warm': traces_after_warm,
              'traces_final': moe.traces, 'calls': moe.calls,
              'oracle_err': err,
              'cache_hits': srv4.stats.cache_hits,
              'cache_misses': srv4.stats.cache_misses}
print('RESULT ' + json.dumps(res))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1500)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_prewarm_populates_exactly_the_expected_keys(results):
    w = results["warm"]
    # one shape class per (program, graph, batch width) -> one key each
    assert w["keys_per_class"] == {"bfs/wiki": 1, "sssp/wiki": 1}
    assert w["n_cache_keys"] == 2
    assert w["cache"]["misses"] == 2
    assert w["cache"]["kernel_traces"] == 2
    # idempotent: a second pre-warm adds nothing and re-traces nothing
    again = results["warm_again"]
    assert again["new_keys"] == 0
    assert again["cache"]["misses"] == 2
    assert again["cache"]["kernel_traces"] == 2


def test_stream_serves_all_tenants_ok(results):
    s = results["stream"]
    assert s["statuses"] == ["ok"] * 16
    assert s["drops"] == 0


def test_results_bit_identical_to_standalone_runs(results):
    assert all(results["stream"]["identical"])


def test_serving_load_is_cache_hits_only(results):
    """The cache_stats()/_cached contract under a mixed request stream:
    after pre-warm, repeated mixed-program batches must be hits — no new
    misses and, critically, zero new jit traces."""
    s = results["stream"]
    assert s["new_hits"] >= 4          # 16 reqs / width 4 = 4+ launches
    assert s["new_misses"] == 0
    assert s["new_traces"] == 0
    stats = results["stats"]
    assert stats["cache_hit_rate"] == 1.0
    assert stats["launches"] >= 4
    assert stats["batched_requests"] == 16


def test_stats_snapshot_shape(results):
    stats = results["stats"]
    assert set(stats["tenants"]) == {"acme", "globex", "initech",
                                     "umbrella"}
    for ts in stats["tenants"].values():
        assert ts["submitted"] == ts["served"] == 4
        assert ts["p50_latency_s"] <= ts["p99_latency_s"]
        assert ts["rounds"] > 0 and ts["messages"] > 0
    assert stats["max_queue_depth"] >= 1
    assert stats["p50_round_latency_s"] <= stats["p99_round_latency_s"]
    assert stats["noc_drops"] == 0


def test_admission_rejects_retriably_not_silently(results):
    a = results["admission"]
    assert a["first_admitted"]
    assert a["over_budget"] == {"status": "rejected", "retriable": True}
    # globex's budget can't fit the request even when idle: rejecting it
    # retriable would send a well-behaved client into a futile retry loop
    assert a["tiny_budget"] == {"status": "rejected", "retriable": False}
    assert a["retry_after_drain_admitted"]
    assert a["served"] == ["ok", "ok"]
    # the ledger balances: every submit is served or rejected
    acme = a["tenant_stats"]["acme"]
    assert acme["submitted"] == 3 and acme["served"] == 2
    assert acme["rejected"] == 1
    globex = a["tenant_stats"]["globex"]
    assert globex["submitted"] == 1 and globex["rejected"] == 1


def test_launch_queue_drops_are_attributed(results):
    d = results["drops"]
    assert d["statuses"] == ["ok", "ok"]
    assert d["stats_drops"] > 0                    # tight cap really drops
    assert all(b == d["stats_drops"] for b in d["batch_drops"])


def test_moe_lane_warm_after_one_trace(results):
    m = results["moe"]
    assert m["statuses"] == ["ok"] * 6
    assert m["traces_after_warm"] == 1
    assert m["traces_final"] == 1                  # no re-trace under load
    assert m["calls"] == 3                         # warm + 2 batches
    assert m["oracle_err"] < 1e-5
    assert m["cache_hits"] == 2 and m["cache_misses"] == 0


def test_moe_lane_batches_by_fixed_width(results):
    # 6 single-block requests from 3 tenants -> two fused launches of the
    # fixed [4, 16, D] shape class (max one request per tenant per batch)
    assert results["moe"]["calls"] - 1 == 2


def test_depth_sweep_bit_identical_to_sync_drain(results):
    """The ISSUE acceptance gate: for inflight_depth in {1, 2, 4} (FIFO)
    and depth 3 under DRR, the full response signature (results, statuses,
    reasons, batch attribution) and the per-tenant ledger are bit-identical
    to the synchronous drain — and the overlapped window re-uses the very
    same compile-cache entries: zero new misses, zero new jit traces."""
    depths = results["depths"]
    assert set(depths) == {"fifo1", "fifo2", "fifo4", "drr3"}
    for name, leg in depths.items():
        assert leg["sig_equal"], name
        assert leg["ledger_equal"], name
        assert leg["new_misses"] == 0, name      # byte-compatible keys
        assert leg["new_traces"] == 0, name
        assert leg["launches"] >= 4, name


def test_donated_buffers_own_key_class_same_responses(results):
    """donate_argnums changes lowering, so donation joins the cache key —
    exactly one new key per pre-warmed shape class, none for the default
    path — and the donated pipeline still serves bit-identical responses
    with zero re-traces after its pre-warm."""
    d = results["donate"]
    assert d["new_keys_prewarm"] == 2            # donated bfs + sssp
    assert d["sig_equal"]
    assert d["new_misses_under_load"] == 0
    assert d["new_traces_under_load"] == 0


def test_failure_in_flight_poisons_only_its_batch(results):
    """A launch failure at window position 2 of 3 (inflight_depth=3)
    fails only its own riders — non-retriably — while the earlier and
    later inflight batches complete bit-identically; every response is
    delivered exactly once and the ledger balances."""
    f = results["failure"]
    assert f["n_responses"] == 12                # nothing dropped/doubled
    assert f["statuses"] == ["ok"] * 4 + ["failed"] * 4 + ["ok"] * 4
    assert f["retriable"] == [False] * 12
    assert len(f["reasons_failed"]) == 4
    assert all("injected launch failure" in r for r in f["reasons_failed"])
    assert f["survivors_identical"]
    # the poisoned launch really was issued with 2 batches already in
    # flight (window positions fill 0, 1, 2 before any harvest)
    assert f["max_window_at_launch"] == 2
    # ledger rows are (submitted, served, rejected, failed)
    for tenant, row in f["ledger"].items():
        want = [1, 0, 0, 1] if tenant.startswith("b") else [1, 1, 0, 0]
        assert row == want, (tenant, row)
