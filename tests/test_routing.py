"""Property tests for the shared owner-routed NoC collective layer
(:mod:`repro.core.routing`).

Part A — in-process properties of the pure bucketing primitives (hypothesis
or its seeded-examples shim).

Part B — the distributed round under shard_map on 1/2/4/8 host devices
(subprocess so XLA_FLAGS doesn't leak): random dest/vals/capacity, ops
add/min; the routed result must equal a numpy oracle applying the same
first-``cap``-per-(source shard, owner) keep rule, and the drop count must
equal the analytic IQ-overflow count computed by ``TaskEngine.route`` for
the same task stream.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.routing import (bucket, pack_wire, positions_by_dest,
                                round8, unpack_wire)


# ---------------------------------------------------------------------------
# Part A: bucketing primitives
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_buckets=st.sampled_from([1, 3, 8]))
def test_positions_by_dest_is_stable_cumcount(seed, n_buckets):
    rng = np.random.default_rng(seed)
    n = 128
    dest = rng.integers(0, n_buckets, n)
    valid = rng.random(n) < 0.8
    pos = np.asarray(positions_by_dest(jnp.asarray(dest),
                                       jnp.asarray(valid), n_buckets))
    counts = np.zeros(n_buckets, np.int64)
    for i in range(n):
        if valid[i]:
            assert pos[i] == counts[dest[i]]
            counts[dest[i]] += 1


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), cap=st.sampled_from([8, 16, 32]),
       n_buckets=st.sampled_from([2, 4, 8]))
def test_bucket_drop_count_matches_overflow(seed, cap, n_buckets):
    rng = np.random.default_rng(seed)
    n = 256
    dest = rng.integers(0, n_buckets, n)
    valid = rng.random(n) < 0.9
    vals = rng.integers(0, 100, n).astype(np.float32)
    xb, (got_vals,), task_slot, n_drop = bucket(
        jnp.asarray(vals)[:, None], jnp.asarray(dest), jnp.asarray(valid),
        [jnp.asarray(vals).astype(jnp.int32)], n_buckets, cap)
    per_bucket = np.bincount(dest[valid], minlength=n_buckets)
    want_drop = int(np.maximum(per_bucket - cap, 0).sum())
    assert int(n_drop) == want_drop
    # kept tasks land in their own slot, dropped tasks get slot -1
    slots = np.asarray(task_slot)
    assert int((slots >= 0).sum()) == int(valid.sum()) - want_drop
    kept = slots >= 0
    assert np.array_equal(np.asarray(xb)[slots[kept], 0], vals[kept])


@settings(max_examples=10, deadline=None)
@given(v=st.integers(0, 10**6))
def test_round8(v):
    r = round8(v)
    assert r % 8 == 0 and r >= max(v, 8) and r - v < 8 or v < 8


# ---------------------------------------------------------------------------
# Part B: the distributed round on 1/2/4/8 devices
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import json
import numpy as np
import jax
from repro.core import EngineConfig, QueueConfig, TaskEngine, TileGrid
from repro.core.compat import make_mesh
from repro.sparse.jax_apps import dcra_scatter, from_owner_layout

def oracle(dest, vals, n, n_dev, cap, op):
    '''First-cap-per-(source shard, owner) keep rule + reduction.'''
    e_local = len(dest) // n_dev
    y = np.zeros(n) if op == 'add' else np.full(n, np.inf)
    drops = 0
    for d in range(n_dev):
        counts = np.zeros(n_dev, np.int64)
        for i in range(d * e_local, (d + 1) * e_local):
            if dest[i] < 0:
                continue
            o = dest[i] % n_dev
            if counts[o] < cap:
                counts[o] += 1
                if op == 'add':
                    y[dest[i]] += vals[i]
                else:
                    y[dest[i]] = min(y[dest[i]], vals[i])
            else:
                drops += 1
    return y, drops

cases = []
for n_dev in (1, 2, 4, 8):
    mesh = make_mesh((n_dev,), ('data',))
    for op in ('add', 'min'):
        for seed in (0, 1, 2):
            rng = np.random.default_rng(seed * 31 + n_dev * 7 +
                                        (op == 'min'))
            n = int(rng.integers(16, 200))
            e_local = int(rng.integers(4, 80))
            E = e_local * n_dev
            dest = rng.integers(0, n, E)
            dest[rng.random(E) < 0.1] = -1        # padding / no-task
            vals = rng.integers(0, 100, E).astype(np.float32)
            cf = float(rng.choice([0.25, 1.0, 4.0]))  # tight queues DO drop
            cap = max(8, -(-int(e_local * cf / n_dev) // 8) * 8)
            y_sh, dropped = dcra_scatter(
                jax.numpy.asarray(dest, jax.numpy.int32),
                jax.numpy.asarray(vals), n, mesh, 'data', op=op,
                capacity_factor=cf)
            y = np.asarray(from_owner_layout(y_sh, n, n_dev), np.float64)
            want, want_drops = oracle(dest, vals, n, n_dev, cap, op)
            # analytic twin: same stream through TaskEngine.route, the
            # capacity flowing through QueueConfig (the only IQ source)
            engine = TaskEngine(EngineConfig(
                grid=TileGrid(1, n_dev),
                queues=QueueConfig(default_iq=cap)), n)
            valid = dest >= 0
            shard_of = np.repeat(np.arange(n_dev), e_local)
            rs = engine.route('T3', src_idx=shard_of[valid],
                              dst_idx=dest[valid])
            cases.append({
                'desc': f'n_dev={n_dev} op={op} seed={seed} cf={cf}',
                'max_err': float(np.max(np.abs(np.where(
                    np.isfinite(want), y - want,
                    (~np.isfinite(y)).astype(float) - 1)))),
                'drops': int(dropped),
                'oracle_drops': int(want_drops),
                'engine_drops': int(rs.drops),
            })
print('RESULT ' + json.dumps(cases))
"""


@pytest.fixture(scope="module")
def cases():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_covers_all_device_counts(cases):
    assert len(cases) == 4 * 2 * 3


def test_routed_result_matches_numpy_oracle(cases):
    bad = [c for c in cases if c["max_err"] > 1e-5]
    assert not bad, bad


def test_drop_count_matches_oracle_and_task_engine(cases):
    bad = [c for c in cases
           if not (c["drops"] == c["oracle_drops"] == c["engine_drops"])]
    assert not bad, bad


def test_some_case_actually_dropped(cases):
    """The grid must exercise the overflow path, not just the happy path."""
    assert any(c["drops"] > 0 for c in cases)


# ---------------------------------------------------------------------------
# Part C: fused-payload wire packing (what fused_all_to_all puts on the NoC)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(1, 9),
       n_int=st.integers(0, 3),
       dtype=st.sampled_from(["bfloat16", "float16"]))
def test_half_width_packing_round_trips_exactly(seed, d, n_int, dtype):
    """bf16/f16 payloads with any D (odd included) round-trip bitwise and
    ride two-per-f32-lane: the wire never inflates beyond
    ceil(D/2) + n_int columns."""
    rng = np.random.default_rng(seed)
    n = 16
    # raw bit patterns (not just round numbers): bitcast must be exact
    vals = jnp.asarray(rng.random((n, d)) * 100 - 50).astype(dtype)
    ints = [jnp.asarray(rng.integers(-2**31, 2**31 - 1, n), jnp.int32)
            for _ in range(n_int)]
    packed, meta = pack_wire(vals, ints)
    assert packed.dtype == jnp.float32
    assert packed.shape == (n, -(-d // 2) + n_int)      # never inflates
    v_out, ints_out = unpack_wire(packed, meta)
    assert v_out.dtype == vals.dtype
    assert jnp.array_equal(
        jax.lax.bitcast_convert_type(v_out, jnp.uint16),
        jax.lax.bitcast_convert_type(vals, jnp.uint16))
    for a, b in zip(ints, ints_out):
        assert jnp.array_equal(a, b)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(1, 5))
def test_f32_and_1d_packing_round_trip(seed, d):
    rng = np.random.default_rng(seed)
    n = 8
    vals2 = jnp.asarray(rng.random((n, d)), jnp.float32)
    ints = [jnp.asarray(rng.integers(0, 100, n), jnp.int32)]
    packed, meta = pack_wire(vals2, ints)
    assert packed.shape == (n, d + 1)
    v_out, (i_out,) = unpack_wire(packed, meta)
    assert jnp.array_equal(v_out, vals2) and jnp.array_equal(i_out, ints[0])
    vals1 = jnp.asarray(rng.random(n), jnp.float32)      # [N] squeeze path
    packed, meta = pack_wire(vals1, [])
    v_out, empty = unpack_wire(packed, meta)
    assert v_out.shape == (n,) and jnp.array_equal(v_out, vals1)
    assert empty == []
