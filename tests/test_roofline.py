"""Roofline accounting tests: HLO collective parser, the scan-once
cost_analysis calibration (the measured XLA behaviour our §Dry-run
methodology is built on), and analytic cost sanity."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.core.compat import cost_analysis
from repro.launch.analytic import forward_flops, step_cost
from repro.launch.roofline import _shape_bytes, collective_bytes


def test_collective_parser_counts_result_bytes():
    hlo = """
  %ag = f32[8,128]{1,0} all-gather(%x), replica_groups={{0,1}}
  %ar = bf16[16]{0} all-reduce(%y), to_apply=%add
  %a2a = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%a, %b)
  %cp = u8[32]{0} collective-permute(%z)
  %dot = f32[999]{0} dot(%p, %q)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 4
    assert out["all-reduce"] == 16 * 2 * 2          # x2: RS+AG phases
    assert out["all-to-all"] == 2 * 4 * 4 * 4
    assert out["collective-permute"] == 32
    assert "dot" not in out


def test_shape_bytes_tuple():
    assert _shape_bytes("(f32[2,3]{1,0}, s8[5]{0})") == 2 * 3 * 4 + 5


def test_xla_cost_analysis_counts_scan_once():
    """The measured XLA behaviour that motivates analytic accounting
    (EXPERIMENTS.md §Dry-run): scan bodies are costed once."""
    a = jnp.zeros((128, 128))
    single = jax.jit(lambda a: a @ a).lower(a).compile()
    f1 = cost_analysis(single)["flops"]

    def scanned(a):
        x, _ = jax.lax.scan(lambda x, _: (x @ a, None), a, None, length=10)
        return x
    f10 = cost_analysis(jax.jit(scanned).lower(a).compile())["flops"]
    assert f10 == pytest.approx(f1, rel=0.01)   # NOT 10x


@pytest.mark.parametrize("arch", ["granite-8b", "mixtral-8x22b", "rwkv6-7b",
                                  "zamba2-7b", "seamless-m4t-large-v2"])
def test_analytic_costs_sane(arch):
    cfg = get_config(arch)
    tr = step_cost(cfg, SHAPES["train_4k"])
    pf = step_cost(cfg, SHAPES["prefill_32k"])
    dc = step_cost(cfg, SHAPES["decode_32k"])
    assert tr.flops > 0 and tr.hbm_bytes > 0
    # train does fwd+bwd(+remat) on 1M tokens vs prefill fwd on 1M tokens
    assert tr.flops > 2.0 * pf.flops
    # decode processes 128 tokens, prefill 1M -> orders of magnitude apart
    assert dc.flops < pf.flops / 100
    # train flops near the 6ND floor (enc-dec tokens traverse only their
    # half of the stack, so the conventional 6ND overestimates there)
    floor = 6.0 * cfg.active_param_count() * 256 * 4096
    lo = 0.5 if cfg.family == "encdec" else 0.8
    assert lo * floor < tr.flops < 6 * floor


def test_moe_capacity_padding_shows_in_flops():
    """The einsum dispatch pays capacity-factor dead compute; DCRA does not
    — the MODEL_FLOPS ratio gap the §Perf tables show."""
    import dataclasses
    cfg = get_config("mixtral-8x22b")
    cfg_e = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch_impl="einsum"))
    f_dcra = forward_flops(cfg, 8, 4096)
    f_einsum = forward_flops(cfg_e, 8, 4096)
    assert f_einsum > f_dcra * 1.1
