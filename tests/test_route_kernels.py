"""Differential tests for the Pallas routing fast path
(:mod:`repro.kernels.route` + the ``route_impl`` knob).

Part A — in-process: the raw interpret-mode kernels (bucket-rank, fused
bucket-scatter, receive-reduce) and both XLA renderings must agree
bit-exactly with the legacy one-hot primitives on awkward (prime) sizes.

Part B — distributed (subprocess, 8 host devices): all three impls must
produce *identical* recv/drop streams on 1/2/4/8 devices, flat and
pod/portal, under tight caps that actually drop — which is what keeps
the analytic twins exact no matter which impl a launch resolves.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.routing import bucket, positions_by_dest, reduce_received
from repro.kernels.route import (bucket_rank_pallas, bucket_rank_xla,
                                 bucket_scatter_pallas,
                                 reduce_received_pallas, resolve_route_impl)
from repro.sparse.program import cache_stats, clear_cache


# ---------------------------------------------------------------------------
# Part A: kernels vs the one-hot oracle primitives
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.sampled_from([1, 7, 61, 127, 509]),
       n_buckets=st.sampled_from([1, 3, 8, 37, 64]))
def test_rank_kernels_match_onehot(seed, n, n_buckets):
    rng = np.random.default_rng(seed)
    dest = jnp.asarray(rng.integers(0, n_buckets, n), jnp.int32)
    valid = jnp.asarray(rng.random(n) < 0.8)
    want = positions_by_dest(dest, valid, n_buckets, impl="onehot")
    for name, got in [
            ("pallas-interpret", bucket_rank_pallas(dest, valid, n_buckets)),
            ("xla-tilescan", bucket_rank_xla(dest, valid, n_buckets)),
            ("sort", positions_by_dest(dest, valid, n_buckets, impl="sort"))]:
        assert bool(jnp.all(jnp.where(valid, got == want, True))), name


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.sampled_from([5, 127, 509]),
       n_buckets=st.sampled_from([2, 7, 32]),
       cap=st.sampled_from([1, 3, 8]))
def test_bucket_impls_bit_identical(seed, n, n_buckets, cap):
    """(xb, ints, task_slot, n_drop) must agree elementwise across the
    one-hot / sort / tile-scan impls AND the fused interpret kernel."""
    rng = np.random.default_rng(seed)
    dest = jnp.asarray(rng.integers(0, n_buckets, n), jnp.int32)
    valid = jnp.asarray(rng.random(n) < 0.85)
    x = jnp.asarray(rng.random((n, 2)), jnp.float32)
    aux = [jnp.asarray(rng.integers(0, 1000, n), jnp.int32),
           jnp.asarray(rng.integers(0, 50, n), jnp.int32)]
    outs = {impl: bucket(x, dest, valid, aux, n_buckets, cap, impl=impl)
            for impl in ("onehot", "sort", "pallas")}
    outs["fused-kernel"] = bucket_scatter_pallas(x, dest, valid, aux,
                                                 n_buckets, cap)
    ref = outs.pop("onehot")
    for name, got in outs.items():
        assert jnp.array_equal(ref[0], got[0]), name
        for a, b in zip(ref[1], got[1]):
            assert jnp.array_equal(a, b), name
        assert jnp.array_equal(ref[2], got[2]), name
        assert int(ref[3]) == int(got[3]), name


def test_bucket_sort_gather_matches_onehot():
    """The gather-based sort bucketing: ``xb``/aux come straight off the
    stable argsort (slot (b, p) gathers sorted position start[b] + p)
    instead of a second segment-sum scatter. Must be bit-identical to the
    one-hot reference on prime sizes — including 1-D payload squeeze,
    aux columns, task_slot and the first-cap-per-channel drop count."""
    from repro.kernels.route import bucket_sort_gather
    for seed, n, n_buckets, cap in [(0, 7, 3, 2), (1, 101, 13, 3),
                                    (2, 499, 31, 1), (3, 17, 5, 8)]:
        rng = np.random.default_rng(seed)
        dest = jnp.asarray(rng.integers(0, n_buckets, n), jnp.int32)
        valid = jnp.asarray(rng.random(n) < 0.8)
        aux = [jnp.asarray(rng.integers(0, 999, n), jnp.int32)]
        for shape in ((n, 3), (n,)):
            x = jnp.asarray(rng.random(shape), jnp.float32)
            want = bucket(x, dest, valid, aux, n_buckets, cap,
                          impl="onehot")
            got = bucket_sort_gather(x, dest, valid, aux, n_buckets, cap)
            assert got[0].shape == want[0].shape
            assert jnp.array_equal(want[0], got[0]), (seed, shape)
            assert jnp.array_equal(want[1][0], got[1][0]), (seed, shape)
            assert jnp.array_equal(want[2], got[2]), (seed, shape)
            assert int(want[3]) == int(got[3]), (seed, shape)
    # empty stream: identity outputs, no zero-size sort
    e_i = jnp.zeros((0,), jnp.int32)
    xb, ints, slot, nd = bucket_sort_gather(
        jnp.zeros((0, 2), jnp.float32), e_i, jnp.zeros((0,), bool),
        [e_i], 4, 2)
    assert xb.shape == (8, 2) and ints[0].shape == (8,)
    assert slot.shape == (0,) and int(nd) == 0


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), op=st.sampled_from(["add", "min",
                                                           "store"]))
def test_reduce_kernel_matches_segment_ops(seed, op):
    rng = np.random.default_rng(seed)
    n, m = int(rng.integers(3, 400)), int(rng.integers(2, 60))
    slots = jnp.asarray(rng.integers(-1, m, n), jnp.int32)
    vals = jnp.asarray(rng.random(n) * 20 - 10, jnp.float32)
    want = reduce_received(slots, vals, m, op)
    got = reduce_received_pallas(slots, vals, m, op)
    assert jnp.array_equal(want, got), op


def test_empty_streams_are_safe():
    """N=0 must not build a zero-size pallas grid (regression): every
    kernel wrapper early-returns its identity, matching the XLA paths."""
    from repro.kernels.histogram import histogram_pallas
    empty_i = jnp.zeros((0,), jnp.int32)
    empty_b = jnp.zeros((0,), bool)
    assert bucket_rank_pallas(empty_i, empty_b, 4).shape == (0,)
    xb, ints, slot, nd = bucket_scatter_pallas(
        jnp.zeros((0, 1), jnp.float32), empty_i, empty_b, [empty_i], 4, 2)
    want_xb, want_ints, want_slot, want_nd = bucket(
        jnp.zeros((0, 1), jnp.float32), empty_i, empty_b, [empty_i], 4, 2,
        impl="onehot")
    assert jnp.array_equal(xb, want_xb)
    assert jnp.array_equal(ints[0], want_ints[0])
    assert slot.shape == (0,) and int(nd) == int(want_nd) == 0
    for op in ("add", "min", "store"):
        got = reduce_received_pallas(empty_i, jnp.zeros((0,)), 5, op)
        want = reduce_received(empty_i, jnp.zeros((0,)), 5, op)
        assert jnp.array_equal(got, want), op
    assert histogram_pallas(empty_i, 5).tolist() == [0] * 5


def test_resolve_route_impl():
    assert resolve_route_impl(None) == "pallas"
    assert resolve_route_impl("auto") == "pallas"
    assert resolve_route_impl("sort") == "sort"
    with pytest.raises(ValueError):
        resolve_route_impl("quantum")


def test_histogram_kernel_matches_reduce_received():
    """The single-shard local-reduce glue: the MXU histogram kernel must
    equal the routed receive-reduce over the same task stream."""
    from repro.kernels import ops
    rng = np.random.default_rng(3)
    n, bins = 997, 61                            # primes: tail-pad path
    dest = rng.integers(0, bins, n)
    dest[rng.random(n) < 0.1] = -1               # padding no-tasks
    slots = jnp.asarray(dest, jnp.int32)
    want = reduce_received(slots, jnp.ones(n, jnp.float32), bins, "add")
    got = ops.histogram(slots, bins).astype(jnp.float32)
    assert jnp.array_equal(want, got)


def test_histogram_local_reduce_end_to_end():
    """Single-shard ``dcra_histogram`` engages the kernel local reduce
    (no-drop guard holds: default factor 2.0 can never drop on one
    shard) and must equal the routed path bit-for-bit."""
    from repro.core.compat import make_mesh
    from repro.sparse.jax_apps import dcra_histogram
    rng = np.random.default_rng(7)
    els = rng.integers(0, 53, 811)               # primes: off-tile tails
    mesh = make_mesh((1,), ("data",))
    clear_cache()
    y_kernel, d_kernel = dcra_histogram(els, 53, mesh)
    assert cache_stats()["misses"] == 0          # no scatter compiled: the
    #                                            # kernel path really ran
    y_routed, d_routed = dcra_histogram(els, 53, mesh, route_impl="onehot",
                                        capacity_factor=2.0)
    assert cache_stats()["misses"] == 1          # explicit impl: routed
    assert d_kernel == 0 and d_routed == 0
    assert np.array_equal(np.asarray(y_kernel), np.asarray(y_routed))
    assert int(np.asarray(y_kernel).sum()) == 811


def test_route_compare_gate():
    """The CI trajectory gate: speedup-relative (machine-portable),
    >tol relative drop or silent coverage loss fails."""
    from repro.dse.route_compare import compare
    cell = {"n": 65536, "s": 64, "cap": 2048,
            "ms": {"onehot": 50.0, "sort": 25.0, "pallas": 10.0},
            "speedup_vs_onehot": {"onehot": 1.0, "sort": 2.0,
                                  "pallas": 5.0}}
    old = {"schema": "dcra-route-bench/v1", "cells": [cell]}
    f, _ = compare(old, old)
    assert not f
    worse = json.loads(json.dumps(old))
    worse["cells"][0]["speedup_vs_onehot"]["pallas"] = 3.9   # -22%
    f, _ = compare(old, worse)
    assert f and "REGRESSED" in f[0]
    f, _ = compare(old, worse, tol=0.25)                     # within 25%
    assert not f
    gone = {"schema": "dcra-route-bench/v1", "cells": []}
    f, _ = compare(old, gone)
    assert f


def test_route_impl_is_part_of_compile_cache_key():
    from repro.core.compat import make_mesh
    from repro.sparse.jax_apps import dcra_scatter
    mesh = make_mesh((1,), ("data",))
    dest = jnp.asarray(np.arange(16) % 4, jnp.int32)
    vals = jnp.ones(16, jnp.float32)
    clear_cache()
    ys = {}
    for impl in ("onehot", "sort", "pallas"):
        y, _ = dcra_scatter(dest, vals, 4, mesh, route_impl=impl)
        ys[impl] = np.asarray(y)
    assert cache_stats()["misses"] == 3          # one compile per impl
    y, _ = dcra_scatter(dest, vals, 4, mesh, route_impl="sort")
    assert cache_stats()["hits"] == 1            # repeat launch: no re-trace
    assert np.array_equal(ys["onehot"], ys["sort"])
    assert np.array_equal(ys["onehot"], ys["pallas"])


# ---------------------------------------------------------------------------
# Part B: identical recv/drop streams on 1/2/4/8 devices, flat + pod/portal
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import json
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.compat import make_mesh, shard_map_unchecked
from repro.core.routing import owner_route, owner_route_hier
from repro.sparse.program import run_program
from repro.sparse.jax_apps import BFS, HISTOGRAM

IMPLS = ('onehot', 'sort', 'pallas')
results = []

# --- flat: raw recv/drop streams from owner_route, elementwise ----------
for n_dev in (1, 2, 4, 8):
    mesh = make_mesh((n_dev,), ('data',))
    rng = np.random.default_rng(n_dev)
    e_local = 64
    E = e_local * n_dev
    n = 40
    dest = rng.integers(0, n, E).astype(np.int32)
    dest[rng.random(E) < 0.15] = -1
    vals = rng.random(E).astype(np.float32)
    cap = 8                                       # tight: forces drops
    streams = {}
    for impl in IMPLS:
        def k(d_b, v_b, impl=impl):
            valid = d_b >= 0
            d_c = jnp.maximum(d_b, 0)
            rs, rv, nd = owner_route(v_b, d_c // n_dev, d_c % n_dev,
                                     valid, n_dev, cap, 'data', impl=impl)
            return rs, rv, jax.lax.psum(nd, 'data')
        f = jax.jit(shard_map_unchecked(k, mesh=mesh,
                                        in_specs=(P('data'), P('data')),
                                        out_specs=(P('data'), P('data'),
                                                   P())))
        rs, rv, nd = f(jnp.asarray(dest), jnp.asarray(vals))
        streams[impl] = (np.asarray(rs), np.asarray(rv), int(nd))
    ref = streams['onehot']
    ok = all(np.array_equal(ref[0], s[0]) and np.array_equal(ref[1], s[1])
             and ref[2] == s[2] for s in streams.values())
    results.append({'case': f'flat n_dev={n_dev}', 'identical': ok,
                    'drops': ref[2]})

# --- pod/portal: app-level states + per-round stats, tight caps ---------
from repro.sparse.datasets import rmat
g = rmat(7, edge_factor=4, seed=5)
for shape, axes in [((2, 2), ('pod', 'data')), ((2, 4), ('pod', 'data'))]:
    mesh = make_mesh(shape, axes)
    outs = {}
    for impl in IMPLS:
        (d,), stats = run_program(BFS, g, mesh, axis='data',
                                  pod_axis='pod', capacity_factor=0.5,
                                  params={'root': 0}, route_impl=impl)
        outs[impl] = (d, stats.messages.tolist(), stats.drops.tolist())
    ref = outs['onehot']
    ok = all(np.array_equal(ref[0], o[0]) and ref[1] == o[1]
             and ref[2] == o[2] for o in outs.values())
    results.append({'case': f'hier {shape}', 'identical': ok,
                    'drops': int(sum(ref[2]))})

print('RESULT ' + json.dumps(results))
"""


@pytest.fixture(scope="module")
def dist_cases():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_all_impls_identical_streams(dist_cases):
    bad = [c for c in dist_cases if not c["identical"]]
    assert not bad, bad


def test_distributed_cases_cover_drops(dist_cases):
    """Tight caps must actually exercise the overflow path."""
    assert any(c["drops"] > 0 for c in dist_cases)
    assert len(dist_cases) == 4 + 2
