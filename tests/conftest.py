"""Test-tier bootstrap: make ``import hypothesis`` always work.

Must run before test modules are collected — conftest import order
guarantees that. See tests/_hypothesis_compat.py for the fallback
semantics when real hypothesis isn't installed.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

import _hypothesis_compat  # noqa: E402,F401
