"""Pareto-guided launch auto-configuration (:mod:`repro.dse.autoconfig`).

Part A — selection properties against the committed ``BENCH_dse.json``:
deterministic for a fixed file, objective ordering respected, and the
acceptance bar: ``config="auto"`` never picks a point whose analytic TEPS
on the quick datasets is below the all-defaults baseline.

Part B — the executable path (subprocess, 8 fake host devices):
``dcra_bfs(g, root, mesh, config="auto")`` selects a frontier point, still
matches the numpy oracle, and the auto-resolved ``QueueConfig`` sizing
stays drop-free at emulation granularity.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.dse.autoconfig import (BASELINE, MINISWEEP_THRESHOLD,
                                  DatasetSignature, autoconfigure,
                                  bench_signatures, interpolate_record,
                                  launch_for, load_bench, objective_score,
                                  objective_weights, select_from_frontier,
                                  signature_distance, signature_of)
from repro.dse.evaluate import evaluate, load_datasets
from repro.sparse import datasets

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def bench():
    b = load_bench()
    assert b is not None, "committed BENCH_dse.json missing"
    return b


@pytest.fixture(scope="module")
def quick_data(bench):
    return load_datasets(int(bench["dataset_scale"]))


# ---------------------------------------------------------------------------
# Part A: signatures
# ---------------------------------------------------------------------------

def test_signature_distance_is_a_premetric():
    a = DatasetSignature(n=256, nnz=4096, skew=1.2)
    assert signature_distance(a, a) == 0.0
    b = DatasetSignature(n=4096, nnz=65536, skew=1.2)
    assert signature_distance(a, b) == signature_distance(b, a) > 0.9


def test_bench_signatures_recompute_matches_recorded(bench):
    if "dataset_signatures" not in bench:
        pytest.skip("bench predates recorded signatures")
    recorded = bench_signatures(bench)
    stripped = {k: v for k, v in bench.items()
                if k != "dataset_signatures"}
    recomputed = bench_signatures(stripped)
    assert set(recorded) == set(recomputed)
    for name in recorded:
        assert recorded[name].n == recomputed[name].n
        assert recorded[name].nnz == recomputed[name].nnz
        assert recorded[name].skew == pytest.approx(recomputed[name].skew)


# ---------------------------------------------------------------------------
# Part A: frontier selection
# ---------------------------------------------------------------------------

def test_selection_is_deterministic_for_a_fixed_bench(bench, quick_data):
    g = quick_data[sorted(quick_data)[0]]
    picks = [autoconfigure(g, "bfs", bench=bench) for _ in range(2)]
    assert picks[0].point == picks[1].point
    assert picks[0].source == picks[1].source == "frontier"
    assert picks[0].score == picks[1].score


def test_selection_respects_the_objective_ordering(bench, quick_data):
    """The frontier argmax really is the argmax of the interpolated
    objective over the app's frontier slice, for every supported
    objective."""
    g = quick_data[sorted(quick_data)[0]]
    sig = signature_of(g)
    sigs = bench_signatures(bench)
    dists = {d: signature_distance(sig, s) for d, s in sigs.items()}
    from repro.dse.autoconfig import frontier_records
    records = frontier_records(bench, "bfs")
    assert records
    for objective in ("teps", "watts", "usd", {"teps": 0.7, "watts": 0.3}):
        weights = objective_weights(objective)
        point, score, _ = select_from_frontier(bench, sig, "bfs", weights)
        scores = [objective_score(weights,
                                  *interpolate_record(r, "bfs", dists))
                  for r in records]
        assert score == pytest.approx(max(scores))


def test_selection_ranks_on_the_app_frontier_slice():
    """Schema v2: when the bench records app-specific Pareto slices, the
    selection for an app considers ONLY that app's slice — a globally
    Pareto point excluded from the slice must not win."""
    sig = DatasetSignature(n=256, nnz=4096, skew=1.0)
    from repro.dse.space import DesignPoint

    def rec(pid, iq, teps):
        return {"point_id": pid, "pareto": True,
                "config": DesignPoint(iq_capacity=iq).to_dict(),
                "metrics": {"teps_geomean": teps, "watts_geomean": 1.0,
                            "system_usd": 100.0},
                "per_cell": {f"{app}:D": {"teps": teps, "seconds": 1.0,
                                          "energy_j": 1.0}
                             for app in ("bfs", "spmv")}}

    bench = {
        "schema": "dcra-dse-bench/v2",
        "dataset_signatures": {"D": sig.to_dict()},
        "datasets": ["D"],
        "points": [rec("slow_bfs_ok", 12, 50.0),
                   rec("fast_global", 48, 100.0)],
        "app_frontiers": {"bfs": ["slow_bfs_ok"],
                          "spmv": ["slow_bfs_ok", "fast_global"]},
    }
    w = objective_weights("teps")
    point, _, dist = select_from_frontier(bench, sig, "bfs", w)
    assert dist == 0.0 and point.iq_capacity == 12   # slice-restricted
    point, _, _ = select_from_frontier(bench, sig, "spmv", w)
    assert point.iq_capacity == 48                   # full slice, argmax
    # an app without a slice falls back to the global frontier
    point, _, _ = select_from_frontier(bench, sig, "wcc", w)
    assert point.iq_capacity == 48


def test_committed_bench_carries_per_app_slices(bench):
    """The regenerated BENCH_dse.json is schema v2 with a non-empty
    frontier slice per swept app (incl. the seventh app, kcore)."""
    assert bench["schema"] == "dcra-dse-bench/v2"
    fronts = bench["app_frontiers"]
    assert set(bench["apps"]) <= set(fronts)
    assert "kcore" in fronts
    ids = {r["point_id"] for r in bench["points"]}
    for app, pids in fronts.items():
        assert pids and set(pids) <= ids


def test_objectives_can_disagree_on_a_synthetic_tradeoff():
    """A fast-but-expensive point vs a cheap-but-slow one: "teps" and
    "usd" must pick different winners."""
    sig = DatasetSignature(n=256, nnz=4096, skew=1.0)
    def point_cfg(iq):
        from repro.dse.space import DesignPoint
        return DesignPoint(iq_capacity=iq).to_dict()
    bench = {
        "dataset_signatures": {"D": sig.to_dict()},
        "datasets": ["D"],
        "points": [
            {"point_id": "fast", "pareto": True, "config": point_cfg(48),
             "metrics": {"teps_geomean": 100.0, "watts_geomean": 10.0,
                         "system_usd": 1000.0},
             "per_cell": {"bfs:D": {"teps": 100.0, "seconds": 1.0,
                                    "energy_j": 10.0}}},
            {"point_id": "cheap", "pareto": True, "config": point_cfg(12),
             "metrics": {"teps_geomean": 50.0, "watts_geomean": 2.0,
                         "system_usd": 100.0},
             "per_cell": {"bfs:D": {"teps": 50.0, "seconds": 1.0,
                                    "energy_j": 2.0}}},
        ],
    }
    pick = {}
    for objective in ("teps", "watts", "usd"):
        w = objective_weights(objective)
        point, _, dist = select_from_frontier(bench, sig, "bfs", w)
        assert dist == 0.0
        pick[objective] = point.iq_capacity
    assert pick["teps"] == 48          # throughput winner
    assert pick["usd"] == 12           # teps/$ winner
    assert pick["watts"] == 12         # power winner


def test_unknown_objective_rejected():
    with pytest.raises(ValueError):
        objective_weights("joules")
    with pytest.raises(ValueError):
        objective_weights({"latency": 1.0})


# ---------------------------------------------------------------------------
# Part A: the acceptance bar — auto never below the all-defaults baseline
# ---------------------------------------------------------------------------

def test_auto_teps_at_least_baseline_on_quick_datasets(bench, quick_data):
    """`config="auto"` (objective teps) must select a frontier point whose
    analytic TEPS on each quick dataset is >= the hand-tuned all-defaults
    deployment the benchmarks launch with."""
    for dname, g in quick_data.items():
        for app in ("bfs", "spmv"):
            lc = autoconfigure(g, app, bench=bench)
            auto = evaluate(lc.point.engine_config(), g, app).teps
            base = evaluate(BASELINE.engine_config(), g, app).teps
            assert auto >= base * (1 - 1e-9), (dname, app, auto, base)


def test_minisweep_fallback_for_faraway_datasets(bench):
    tiny = datasets.erdos_renyi(16, 4, seed=3)
    sig = signature_of(tiny)
    sigs = bench_signatures(bench)
    assert min(signature_distance(sig, s)
               for s in sigs.values()) > MINISWEEP_THRESHOLD
    lc = autoconfigure(tiny, "bfs", bench=bench)
    assert lc.source == "mini-sweep"
    # the baseline is a candidate, so the winner can never score below it
    auto = evaluate(lc.point.engine_config(), tiny, "bfs").teps
    base = evaluate(BASELINE.engine_config(), tiny, "bfs").teps
    assert auto >= base * (1 - 1e-9)


def test_baseline_survives_mini_candidate_truncation():
    """A large frontier (full-space nightly: 10+ Pareto points) must not
    push the all-defaults baseline out of the mini-sweep candidate list —
    it is what anchors the never-below-baseline guarantee."""
    from repro.dse.autoconfig import _mini_candidates
    frontier = [BASELINE.with_(iq_capacity=8 * i) for i in range(2, 16)]
    cands = _mini_candidates(frontier)
    assert len(cands) <= 10
    assert BASELINE in cands


def test_element_stream_signature_lives_in_bin_space():
    """Histogram streams are signatured as (bins, tasks), like the sweep's
    histogram cells — not (len, len), which could never be near any
    recorded graph signature."""
    els = datasets.histogram_data(1 << 12, 64, seed=4)
    sig = signature_of(els)
    assert sig.n == 64 and sig.nnz == len(els)


def test_config_conflicts_with_explicit_sizing_kwargs(quick_data):
    from repro.sparse.jax_apps import dcra_bfs, dcra_spmv
    g = quick_data[sorted(quick_data)[0]]
    with pytest.raises(ValueError, match="conflicts"):
        dcra_bfs(g, 0, mesh=None, capacity_factor=2.0, config="auto")
    with pytest.raises(ValueError, match="conflicts"):
        dcra_spmv(g, np.ones(g.n), mesh=None, cap=4, config="auto")


# ---------------------------------------------------------------------------
# Part A: MoE dispatch auto-configuration (capacity factor from load)
# ---------------------------------------------------------------------------

def test_moe_autoconfig_uniform_load_picks_the_smallest_factor():
    from repro.core.queues import QueueConfig
    from repro.dse.autoconfig import (MOE_FACTOR_LADDER, autoconfigure_moe,
                                      moe_dispatch_signature)
    E, shards = 16, 8
    # block-cyclic assignment: every (sender, owner-shard) channel carries
    # exactly tasks_per_sender / n_shards tasks — the f=1.0 capacity
    ids = (np.arange(4096) // shards) % E
    sig = moe_dispatch_signature(ids, E)
    assert sig.peak_frac == pytest.approx(1.0 / E)
    f, q = autoconfigure_moe(ids, E, shards)
    assert f == MOE_FACTOR_LADDER[0]
    # the returned QueueConfig IS the dispatch sizing (single source)
    ref_q = QueueConfig.for_moe_dispatch(f)
    for task in ("dispatch", "portal", "expert"):
        assert q.channel_cap(task, 4096, 8) == ref_q.channel_cap(task,
                                                                 4096, 8)


def test_moe_autoconfig_skewed_load_needs_a_larger_factor():
    from repro.dse.autoconfig import autoconfigure_moe, moe_dispatch_signature
    rng = np.random.default_rng(1)
    E, shards, T = 16, 8, 4096
    uniform = rng.integers(0, E, T)
    hot = np.where(rng.random(T) < 0.8, 0, uniform)       # 80% on expert 0
    sig_u = moe_dispatch_signature(uniform, E)
    sig_h = moe_dispatch_signature(hot, E)
    assert sig_h.peak_frac > sig_u.peak_frac
    assert sig_h.cv > sig_u.cv
    f_u, _ = autoconfigure_moe(uniform, E, shards)
    f_h, _ = autoconfigure_moe(hot, E, shards)
    assert f_h > f_u


def test_moe_autoconfig_models_contiguous_token_sharding():
    """moe_dcra shards tokens as contiguous blocks, so a locally
    correlated run (one shard's whole block routed to one expert) must
    raise the factor even when the GLOBAL expert histogram looks mild —
    a round-robin sender model would hide exactly that hotspot."""
    from repro.dse.autoconfig import autoconfigure_moe
    E, shards, T = 16, 8, 4096
    uniform = (np.arange(T) // shards) % E
    correlated = uniform.copy()
    correlated[:T // shards] = 0          # sender 0's block: all expert 0
    f_u, _ = autoconfigure_moe(uniform, E, shards)
    f_c, _ = autoconfigure_moe(correlated, E, shards)
    assert f_c > f_u


def test_moe_autoconfig_is_deterministic_and_handles_empty():
    from repro.dse.autoconfig import MOE_FACTOR_LADDER, autoconfigure_moe
    ids = np.arange(64) % 7
    assert autoconfigure_moe(ids, 8, 4) == autoconfigure_moe(ids, 8, 4)
    f, _ = autoconfigure_moe(np.array([], np.int64), 8, 4)
    assert f == MOE_FACTOR_LADDER[0]


def test_launch_for_wraps_an_explicit_point(quick_data):
    g = quick_data[sorted(quick_data)[0]]
    lc = launch_for(BASELINE, g)
    assert lc.source == "explicit" and lc.point == BASELINE
    assert lc.queues.iq("T3") == BASELINE.iq_capacity
    # device folding: per-shard capacity clamps at the local slice
    q = lc.device_queues(n_dev=8, e_local=500)
    assert q.channel_cap("T3", 500, 8) == 500


# ---------------------------------------------------------------------------
# Part B: the executable path under shard_map (subprocess)
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import json
import numpy as np
from repro.core.compat import make_mesh
from repro.dse.autoconfig import autoconfigure
from repro.sparse import datasets, ref
from repro.sparse.jax_apps import dcra_bfs, dcra_spmv

mesh = make_mesh((8,), ('data',))
g = datasets.rmat(8, edge_factor=16, seed=1)      # a quick-bench dataset
res = {}

lc = autoconfigure(g, 'bfs')
res['source'] = lc.source
res['point_id'] = lc.point.point_id

d, stats = dcra_bfs(g, 0, mesh, config='auto')
res['bfs_err'] = float(np.max(np.abs(d - ref.bfs_ref(g, 0))))
res['bfs_drops'] = stats.total_drops
res['bfs_rounds'] = stats.rounds

x = np.random.default_rng(0).random(g.n)
y, drops = dcra_spmv(g, x, mesh, config='auto')
want = ref.spmv_ref(g, x)
res['spmv_err'] = float(np.max(np.abs(np.asarray(y) - want))
                        / max(1.0, float(np.abs(want).max())))
res['spmv_drops'] = int(drops)
print('RESULT ' + json.dumps(res))
"""


@pytest.fixture(scope="module")
def exe_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_auto_config_selects_a_frontier_point_for_bench_data(exe_results):
    assert exe_results["source"] == "frontier"


def test_auto_configured_bfs_matches_oracle(exe_results):
    assert exe_results["bfs_err"] == 0.0
    assert exe_results["bfs_drops"] == 0      # device-folded IQ is lossless
    assert 0 < exe_results["bfs_rounds"] < 128


def test_auto_configured_spmv_matches_oracle(exe_results):
    assert exe_results["spmv_err"] < 1e-4
    assert exe_results["spmv_drops"] == 0
