"""int8 error-feedback gradient compression under shard_map on 8 fake
devices: compressed-DP training must track uncompressed training."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.compat import make_mesh, set_mesh, shard_map_unchecked
from repro.optim.adamw import AdamW
from repro.optim.compression import compress_psum, init_ef, EFState

mesh = make_mesh((8,), ('data',))

# toy regression: y = X w*, grads sharded over data
rng = np.random.default_rng(0)
w_star = jnp.asarray(rng.normal(0, 1, (16,)).astype(np.float32))
X = jnp.asarray(rng.normal(0, 1, (64, 16)).astype(np.float32))
y = X @ w_star

opt = AdamW(lr=lambda s: 0.05, weight_decay=0.0, clip_norm=0.0)

def local_grad(w, xb, yb):
    return jax.grad(lambda w: jnp.mean((xb @ w - yb) ** 2))(w)

def make_step(compress):
    def step(w, opt_state, ef, X, y):
        def shard_fn2(w, ef_res, xb, yb):
            g = local_grad(w, xb, yb)
            if compress:
                gs, ef2 = compress_psum({'g': g}, EFState({'g': ef_res}),
                                        ('data',))
                return gs['g'], ef2.residual['g']
            return jax.lax.pmean(g, 'data'), ef_res
        g, new_ef = shard_map_unchecked(
            shard_fn2, mesh=mesh,
            in_specs=(P(), P(), P('data'), P('data')),
            out_specs=(P(), P()))(w, ef, X, y)
        w2, opt_state2 = opt.update({'w': g}, opt_state, {'w': w})
        return w2['w'], opt_state2, new_ef
    return jax.jit(step)

results = {}
for compress in (False, True):
    w = jnp.zeros(16)
    state = opt.init({'w': w})
    ef = jnp.zeros(16)
    step = make_step(compress)
    with set_mesh(mesh):
        for i in range(150):
            w, state, ef = step(w, state, ef, X, y)
    results['compressed' if compress else 'exact'] = float(
        jnp.max(jnp.abs(w - w_star)))
print('RESULT ' + json.dumps(results))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_exact_dp_converges(results):
    assert results["exact"] < 0.05


def test_compressed_dp_converges(results):
    """EF-int8 compression preserves convergence (within 3x of exact)."""
    assert results["compressed"] < 0.15
