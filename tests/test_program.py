"""The TaskProgram runtime (:mod:`repro.sparse.program`).

Part A — in-process properties: the vectorised edge packer matches a
per-device reference, the owner layout round-trips.

Part B (subprocess, 8 fake host devices) — the analytic-twin contract:
for EVERY program (all seven apps) on 1/2/4/8 devices, the executable's
per-round message/drop trajectory must equal the twin's
(``program_app_stats`` replaying the generated task stream through
``TaskEngine.route``), with tight explicit caps actually dropping; the
pod/portal path agrees against the two-stage channel mirror; k-core (the
seventh app, a pure program definition) matches its numpy oracle with a
partial peel; and repeated same-shape launches hit the compile cache
without re-tracing.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

APPS = ("bfs", "sssp", "wcc", "pagerank", "kcore", "spmv", "histogram")
DEVS = (1, 2, 4, 8)


# ---------------------------------------------------------------------------
# Part A: host-side pieces
# ---------------------------------------------------------------------------

def _pack_edges_reference(rows, cols, wts, n_dev, seed=0):
    """The pre-vectorisation per-device packer (kept as the oracle)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(rows))
    rows, cols, wts = rows[perm], cols[perm], wts[perm]
    own = (rows % n_dev).astype(np.int64)
    counts = np.bincount(own, minlength=n_dev)
    E_max = max(8, int(counts.max()))
    src_slot = np.zeros((n_dev, E_max), np.int32)
    dst = np.full((n_dev, E_max), -1, np.int32)
    w = np.zeros((n_dev, E_max), np.float32)
    for d in range(n_dev):
        sel = own == d
        k = int(counts[d])
        src_slot[d, :k] = (rows[sel] // n_dev).astype(np.int32)
        dst[d, :k] = cols[sel].astype(np.int32)
        w[d, :k] = wts[sel]
    return (src_slot.reshape(-1), dst.reshape(-1), w.reshape(-1), E_max)


@pytest.mark.parametrize("n_dev", [1, 3, 8])
@pytest.mark.parametrize("seed", [0, 7])
def test_pack_edges_matches_per_device_reference(n_dev, seed):
    from repro.sparse.program import _pack_edges
    rng = np.random.default_rng(seed + 100)
    E, n = 500, 64
    rows = rng.integers(0, n, E)
    cols = rng.integers(0, n, E)
    wts = rng.random(E).astype(np.float32)
    got = _pack_edges(rows, cols, wts, n_dev, seed)
    want = _pack_edges_reference(rows, cols, wts, n_dev, seed)
    assert got[3] == want[3]
    for g_arr, w_arr in zip(got[:3], want[:3]):
        assert np.array_equal(np.asarray(g_arr), w_arr)


def test_pack_edges_empty():
    from repro.sparse.program import _pack_edges
    e = np.array([], np.int64)
    src_slot, dst, w, E_max = _pack_edges(e, e, e.astype(np.float32), 4)
    assert E_max == 8 and (np.asarray(dst) == -1).all()


def test_owner_layout_round_trips():
    from repro.sparse.program import from_owner_layout, owner_layout
    rng = np.random.default_rng(3)
    for n, n_dev in ((17, 4), (32, 8), (5, 8)):
        arr = rng.random(n)
        packed, valid = owner_layout(arr, n_dev)
        assert int(np.asarray(valid).sum()) == n
        back = np.asarray(from_owner_layout(packed, n, n_dev))
        assert np.allclose(back, arr)


# ---------------------------------------------------------------------------
# Part B: the analytic-twin contract under shard_map (subprocess)
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import json
import numpy as np
from repro.core.compat import make_mesh
from repro.sparse import datasets, program, ref
from repro.sparse.jax_apps import PROGRAMS, dcra_bfs, dcra_kcore
from repro.sparse.program import program_app_stats, run_program

g = datasets.wiki_like(256, avg_degree=8, seed=7)
x = np.random.default_rng(0).random(g.n)
els = datasets.histogram_data(1 << 11, 64, seed=4)
PARAMS = {'bfs': {'root': 0}, 'sssp': {'root': 0}, 'wcc': {},
          'pagerank': {'damping': 0.85, 'iters': 4}, 'kcore': {'k': 8.0},
          'spmv': {}, 'histogram': {}}
DATA = {'spmv': (g, x), 'histogram': (els, 64)}

res = {'parity': [], 'pod': [], 'cache': {}, 'results': {}}

def parity_case(app, n_dev, tag, stats, twin):
    return {'app': app, 'n_dev': n_dev, 'cap': tag,
            'ok': (stats.rounds == twin.rounds
                   and np.array_equal(stats.messages, twin.messages)
                   and np.array_equal(stats.drops, twin.drops)),
            'rounds': stats.rounds, 'msgs': stats.total_messages,
            'drops': stats.total_drops,
            'twin_drops': twin.total_drops}

for n_dev in (1, 2, 4, 8):
    mesh = make_mesh((n_dev,), ('data',))
    for app, prog in PROGRAMS.items():
        data = DATA.get(app, g)
        caps = (2, 96) if n_dev in (1, 8) else (2,)
        for cap in caps:
            _, stats = run_program(prog, data, mesh, cap=cap,
                                   params=PARAMS[app])
            twin = program_app_stats(prog, data, n_dev, cap=cap,
                                     params=PARAMS[app])
            res['parity'].append(parity_case(app, n_dev, cap, stats, twin))

# ---- pod/portal path: two-stage channel mirror (every program) ----
hier = make_mesh((2, 4), ('pod', 'data'))
for app, prog in PROGRAMS.items():
    data = DATA.get(app, g)
    for cf in (0.25, 4.0):
        _, stats = run_program(prog, data, hier, pod_axis='pod',
                               capacity_factor=cf, params=PARAMS[app])
        twin = program_app_stats(prog, data, 8, capacity_factor=cf,
                                 params=PARAMS[app], pods=(4, 2))
        res['pod'].append(parity_case(app, 8, f'cf{cf}', stats, twin))

# ---- the seventh app vs its oracle (flat + pod, drop-free sizing) ----
mesh8 = make_mesh((8,), ('data',))
k_, st = dcra_kcore(g, 8, mesh8)
want = ref.kcore_ref(g, 8)
res['results']['kcore'] = {
    'err': int(np.abs(k_ - want).max()),
    'drops': st.total_drops, 'rounds': st.rounds,
    'partial_peel': bool(0 < int((k_ >= 0).sum()) < g.n)}
k2, _ = dcra_kcore(g, 8, hier, pod_axis='pod')
res['results']['kcore_pod_err'] = int(np.abs(k2 - want).max())
d_, st = dcra_bfs(g, 0, hier, pod_axis='pod')
res['results']['bfs_pod'] = {
    'err': int(np.abs(d_ - ref.bfs_ref(g, 0)).max()),
    'drops': st.total_drops}

# ---- compile cache: repeated same-shape launches must not re-trace ----
program.clear_cache()
dcra_bfs(g, 0, mesh8)
s1 = program.cache_stats()
dcra_bfs(g, 0, mesh8)
s2 = program.cache_stats()
dcra_bfs(g, 0, make_mesh((4,), ('data',)))
s3 = program.cache_stats()
res['cache'] = {'first': s1, 'repeat': s2, 'other_mesh': s3}
print('RESULT ' + json.dumps(res))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1500)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_parity_covers_every_app_and_device_count(results):
    seen = {(c["app"], c["n_dev"]) for c in results["parity"]}
    assert seen == {(a, d) for a in APPS for d in DEVS}


@pytest.mark.parametrize("app", APPS)
def test_analytic_twin_matches_executable(results, app):
    cases = [c for c in results["parity"] if c["app"] == app]
    bad = [c for c in cases if not c["ok"]]
    assert not bad, bad


@pytest.mark.parametrize("app", APPS)
def test_tight_caps_actually_drop(results, app):
    """cap=2 must overflow for every app, or the agreement is vacuous."""
    tight = [c for c in results["parity"]
             if c["app"] == app and c["cap"] == 2]
    assert any(c["drops"] > 0 for c in tight), tight


def test_pod_portal_path_agrees_with_two_stage_mirror(results):
    assert {c["app"] for c in results["pod"]} == set(APPS)
    bad = [c for c in results["pod"] if not c["ok"]]
    assert not bad, bad
    assert any(c["drops"] > 0 for c in results["pod"])   # tight factor
    assert any(c["drops"] == 0 for c in results["pod"])  # roomy factor


def test_kcore_matches_oracle_with_partial_peel(results):
    r = results["results"]["kcore"]
    assert r["err"] == 0 and r["drops"] == 0
    assert r["partial_peel"] and r["rounds"] > 1
    assert results["results"]["kcore_pod_err"] == 0


def test_iterative_app_runs_hierarchically(results):
    r = results["results"]["bfs_pod"]
    assert r["err"] == 0 and r["drops"] == 0


def test_repeated_launches_hit_the_compile_cache(results):
    first = results["cache"]["first"]
    repeat = results["cache"]["repeat"]
    other = results["cache"]["other_mesh"]
    assert repeat["hits"] == first["hits"] + 1
    assert repeat["misses"] == first["misses"]
    # no re-trace on the cache hit
    assert repeat["kernel_traces"] == first["kernel_traces"]
    # a different deployment is a genuine miss, not a stale reuse
    assert other["misses"] == repeat["misses"] + 1
