"""Task engine / topology / queue / cache model unit + property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import EngineConfig, TaskEngine, TileGrid
from repro.core.cache import CacheModel, DRAMConfig, SRAMConfig
from repro.costmodel import murphy_yield, die_cost_usd, dcra_die_area_mm2
from repro.costmodel.silicon import package_cost


# ---------------------------------------------------------------------------
# topology
# ---------------------------------------------------------------------------

def test_torus_halves_worst_case_hops():
    g_mesh = TileGrid(8, 8, "mesh")
    g_torus = TileGrid(8, 8, "torus")
    src = np.array([0])
    dst = np.array([63])   # opposite corner
    assert g_mesh.hops(src, dst)[0] == 14
    assert g_torus.hops(src, dst)[0] == 2   # wraps both axes


def test_torus_bisection_doubles_mesh():
    m = TileGrid(16, 16, "mesh")
    t = TileGrid(16, 16, "torus")
    assert t.bisection_links() == 2 * m.bisection_links()


def test_hier_reduces_long_distance_hops():
    flat = TileGrid(64, 64, "torus", die_rows=16, die_cols=16)
    hier = TileGrid(64, 64, "hier_torus", die_rows=16, die_cols=16)
    assert hier.avg_uniform_hops() < flat.avg_uniform_hops()


@pytest.mark.parametrize("topology", ["mesh", "torus"])
@pytest.mark.parametrize("shape", [(4, 6), (5, 5), (8, 8), (7, 3)])
def test_avg_uniform_hops_closed_form_is_exact(topology, shape):
    """The flat-topology closed form equals the exhaustive mean over ALL
    (src, dst) pairs — including odd torus extents and src == dst."""
    r, c = shape
    g = TileGrid(r, c, topology, die_rows=max(r // 2, 1),
                 die_cols=max(c // 2, 1))
    s = np.repeat(np.arange(g.n_tiles), g.n_tiles)
    d = np.tile(np.arange(g.n_tiles), g.n_tiles)
    assert g.avg_uniform_hops() == pytest.approx(float(g.hops(s, d).mean()))


@settings(max_examples=25, deadline=None)
@given(r=st.sampled_from([4, 8, 16]), c=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 1000))
def test_hops_symmetric_and_bounded(r, c, seed):
    g = TileGrid(r, c, "torus", die_rows=max(r // 2, 1),
                 die_cols=max(c // 2, 1))
    rng = np.random.default_rng(seed)
    s = rng.integers(0, g.n_tiles, 32)
    d = rng.integers(0, g.n_tiles, 32)
    h1, h2 = g.hops(s, d), g.hops(d, s)
    assert np.array_equal(h1, h2)                  # symmetric
    assert (h1 <= r // 2 + c // 2).all()           # torus diameter
    assert (g.hops(s, s) == 0).all()


# ---------------------------------------------------------------------------
# engine routing + reductions
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), op=st.sampled_from(["add", "min"]))
def test_route_reduction_matches_numpy(seed, op):
    rng = np.random.default_rng(seed)
    n = 256
    eng = TaskEngine(EngineConfig(grid=TileGrid(4, 4, die_rows=2,
                                                die_cols=2)), n)
    src = rng.integers(0, n, 500)
    dst = rng.integers(0, n, 500)
    vals = rng.random(500)
    if op == "add":
        target = np.zeros(n)
        want = np.bincount(dst, weights=vals, minlength=n)
    else:
        target = np.full(n, np.inf)
        want = np.full(n, np.inf)
        np.minimum.at(want, dst, vals)
    eng.route("T", src, dst, vals, target, op)
    assert np.allclose(target, want)
    rs = eng.stats.rounds[-1]
    assert rs.messages + rs.local_msgs == 500
    assert rs.hops >= rs.messages            # >= 1 hop per remote message


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_store_tiebreak_is_order_independent_and_matches_shardmap(seed):
    """op='store' with duplicate destinations: the max value wins,
    whatever order the tasks arrive in — and the shard_map-side
    ``reduce_received`` picks the same winner for the same stream
    (the two paths agree by construction, not by input order)."""
    import jax.numpy as jnp
    from repro.core.routing import reduce_received

    rng = np.random.default_rng(seed)
    n = 32
    dst = rng.integers(0, n, 200)                 # dense duplicates
    vals = rng.random(200)
    perm = rng.permutation(200)                   # a second arrival order

    t1, t2 = np.zeros(n), np.zeros(n)
    TaskEngine._reduce(dst, vals, t1, "store")
    TaskEngine._reduce(dst[perm], vals[perm], t2, "store")
    assert np.array_equal(t1, t2)                 # order-independent

    want = np.zeros(n)
    np.maximum.at(want, dst, vals)                # oracle: max per dest
    touched = np.zeros(n, bool)
    touched[dst] = True
    assert np.allclose(t1[touched], want[touched])
    assert np.all(t1[~touched] == 0)              # untouched slots keep 0

    y = np.asarray(reduce_received(jnp.asarray(dst, jnp.int32),
                                   jnp.asarray(vals, jnp.float32),
                                   n, "store"))
    assert np.allclose(y[touched], t1[touched], atol=1e-6)
    assert np.all(y[~touched] == 0)


def test_queue_stats_recorded():
    eng = TaskEngine(EngineConfig(grid=TileGrid(4, 4)), 64)
    dst = np.zeros(100, np.int64)            # all to tile 0 -> hotspot
    eng.route("T3", np.arange(100) % 64, dst, np.ones(100),
              np.zeros(64), "add")
    assert eng.stats.queue.peak_iq["T3"] == 100
    assert eng.stats.rounds[-1].tasks_per_tile_peak == 100


# ---------------------------------------------------------------------------
# cache model
# ---------------------------------------------------------------------------

def test_cache_hit_rate_monotone_in_sram():
    dram = DRAMConfig(present=True)
    foot = 4 * 2**20                           # 4MB/tile footprint
    hits = [CacheModel(SRAMConfig(kb_per_tile=kb), dram)
            .random_hit_rate(foot) for kb in (64, 128, 256, 512)]
    assert all(a < b for a, b in zip(hits, hits[1:]))


def test_effective_bw_formula():
    cm = CacheModel(SRAMConfig(kb_per_tile=512), DRAMConfig(present=True))
    full = cm.effective_bw(1.0)
    none = cm.effective_bw(0.0)
    assert full == pytest.approx(cm.sram_bw_bytes_per_ns())
    assert none == pytest.approx(cm.dram_bw_per_tile_bytes_per_ns())


def test_scratchpad_mode_always_hits():
    cm = CacheModel(SRAMConfig(kb_per_tile=512), DRAMConfig(present=False))
    assert cm.random_hit_rate(10 * 2**30) == 1.0   # dataset fits by layout


# ---------------------------------------------------------------------------
# silicon cost model
# ---------------------------------------------------------------------------

def test_murphy_yield_decreases_with_area():
    ys = [murphy_yield(a, 0.0007) for a in (50, 100, 200, 400)]
    assert all(a > b for a, b in zip(ys, ys[1:]))
    assert 0 < ys[-1] < ys[0] <= 1


def test_die_cost_scales_superlinearly():
    c100 = die_cost_usd(100)
    c400 = die_cost_usd(400)
    assert c400 > 4 * c100     # yield loss makes big dies extra expensive


def test_paper_die_area_sane():
    # paper §V-B: 32x32-tile die with 512KB/tile ~ 255mm^2 "still good yield"
    area = dcra_die_area_mm2(1024, 512)
    assert 150 < area < 350
    assert murphy_yield(area, 0.0007) > 0.5


def test_package_cost_components():
    pc = package_cost(4, 200.0, hbm_gb_total=32.0)
    assert pc.hbm_usd == pytest.approx(32 * 7.5)
    assert pc.total > pc.dcra_dies_usd + pc.hbm_usd
