"""Executable JAX sparse apps: single-device jnp versions vs numpy oracles,
and the distributed owner-routed round on 8 fake devices (subprocess)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sparse import datasets, ref
from repro.sparse.jax_apps import bfs_jnp, histogram_jnp, spmv_jnp


@pytest.fixture(scope="module")
def graph():
    return datasets.rmat(9, edge_factor=8, seed=3)


def test_spmv_jnp(graph):
    x = np.random.default_rng(0).random(graph.n)
    y = spmv_jnp(jnp.asarray(graph.row_of()), jnp.asarray(graph.col_idx),
                 jnp.asarray(graph.values), jnp.asarray(x), graph.n)
    assert np.allclose(np.asarray(y), ref.spmv_ref(graph, x), rtol=1e-5,
                       atol=1e-3)


def test_bfs_jnp(graph):
    d = bfs_jnp(jnp.asarray(graph.row_of()), jnp.asarray(graph.col_idx),
                graph.n, 0, max_levels=64)
    want = ref.bfs_ref(graph, 0).astype(float)
    got = np.where(np.isinf(np.asarray(d)), -1, np.asarray(d))
    assert np.array_equal(got, want)


def test_histogram_jnp():
    els = datasets.histogram_data(1 << 12, 128)
    h = histogram_jnp(jnp.asarray(els), 128)
    assert np.array_equal(np.asarray(h), ref.histogram_ref(els, 128))


SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import json
import jax, numpy as np
from repro.core.compat import make_mesh, set_mesh
from repro.sparse import datasets, ref
from repro.sparse.jax_apps import dcra_histogram, dcra_spmv

mesh = make_mesh((8,), ('data',))
g = datasets.rmat(9, edge_factor=8, seed=3)
x = np.random.default_rng(0).random(g.n)
res = {}
with set_mesh(mesh):
    y, dropped = dcra_spmv(g, x, mesh)
    res['spmv_err'] = float(np.max(np.abs(np.asarray(y) - ref.spmv_ref(g, x))))
    res['spmv_dropped'] = int(dropped)
    els = datasets.histogram_data(1 << 12, 128)
    h, d2 = dcra_histogram(els, 128, mesh)
    res['hist_exact'] = bool(
        np.array_equal(np.asarray(h), ref.histogram_ref(els, 128)))
    res['hist_dropped'] = int(d2)
    # tight queues DO drop (the paper's overflow semantics)
    _, d3 = dcra_histogram(els, 128, mesh, capacity_factor=0.2)
    res['tight_queue_drops'] = int(d3)
print('RESULT ' + json.dumps(res))
"""


@pytest.fixture(scope="module")
def dist():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_distributed_spmv_exact(dist):
    assert dist["spmv_dropped"] == 0
    assert dist["spmv_err"] < 1e-2


def test_distributed_histogram_exact(dist):
    assert dist["hist_exact"] and dist["hist_dropped"] == 0


def test_queue_overflow_drops_when_undersized(dist):
    assert dist["tight_queue_drops"] > 0
