"""Substrate tests: optimizer, gradient compression, checkpoint/restore,
fault-tolerant loop with injected failures + straggler watchdog, elastic."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import checkpoint as ckpt
from repro.optim.adamw import AdamW, cosine_schedule, global_norm
from repro.optim.compression import (EFState, dequantize, init_ef,
                                     quantize_int8)
from repro.runtime.fault_tolerance import (FailurePlan, InjectedFailure,
                                           StragglerWatchdog, run_training)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    opt = AdamW(lr=lambda s: 0.1, weight_decay=0.0, clip_norm=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clipping():
    opt = AdamW(lr=lambda s: 1e-3, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    big = {"w": jnp.full(3, 1e6)}
    new, state = opt.update(big, state, params)
    assert float(global_norm(state.mu)) <= 0.11   # clipped before moments


def test_cosine_schedule_shape():
    lr = cosine_schedule(peak_lr=1.0, warmup=10, total=100)
    assert float(lr(jnp.array(0))) == 0.0
    assert float(lr(jnp.array(10))) == pytest.approx(1.0, abs=1e-2)
    assert float(lr(jnp.array(100))) == pytest.approx(0.1, abs=1e-2)


# ---------------------------------------------------------------------------
# int8 error-feedback compression
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_quantize_roundtrip_bounded(seed):
    x = jax.random.normal(jax.random.key(seed), (128,))
    q, scale = quantize_int8(x)
    err = jnp.abs(dequantize(q, scale) - x)
    assert float(err.max()) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_preserves_signal():
    """With EF, the *accumulated* compressed signal tracks the true sum."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(0, 1, (64,)).astype(np.float32))
    residual = jnp.zeros(64)
    acc = jnp.zeros(64)
    for _ in range(50):
        q, s = quantize_int8(g_true + residual)
        sent = dequantize(q, s)
        residual = (g_true + residual) - sent
        acc = acc + sent
    # mean of sent converges to g_true
    assert float(jnp.abs(acc / 50 - g_true).max()) < 0.02


# ---------------------------------------------------------------------------
# checkpoint / restore / elastic
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones(5, jnp.int32)}}
    ckpt.save(str(tmp_path), 3, tree)
    assert ckpt.latest_step(str(tmp_path)) == 3
    out = ckpt.restore(str(tmp_path), 3, jax.tree.map(jnp.zeros_like, tree))
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert (np.asarray(x) == np.asarray(y)).all()


def test_checkpoint_retention(tmp_path):
    tree = {"a": jnp.zeros(2)}
    for s in range(5):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2 and steps[-1] == "step_00000004"


def test_checkpoint_atomicity_no_tmp_left(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(2)})
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


# ---------------------------------------------------------------------------
# fault-tolerant loop
# ---------------------------------------------------------------------------

def _toy_problem():
    opt = AdamW(lr=lambda s: 0.05, weight_decay=0.0)

    def init_state():
        params = {"w": jnp.array([4.0])}
        return params, opt.init(params)

    def step_fn(params, opt_state, batch):
        (loss), g = jax.value_and_grad(
            lambda p: jnp.sum((p["w"] - batch) ** 2))(params)
        params, opt_state = opt.update(g, opt_state, params)
        return params, opt_state, {"loss": loss}

    return init_state, step_fn


def test_training_recovers_from_injected_failures(tmp_path):
    init_state, step_fn = _toy_problem()
    plan = FailurePlan(at_steps={7: "ici-timeout", 13: "preemption"})
    res = run_training(step_fn, init_state, lambda s: jnp.array(1.0),
                       total_steps=20, ckpt_dir=str(tmp_path),
                       ckpt_every=5, failure_plan=plan)
    assert res.final_step == 20
    assert res.restarts == 2
    assert res.metrics_history[-1]["loss"] < res.metrics_history[0]["loss"]


def test_training_gives_up_after_max_restarts(tmp_path):
    init_state, step_fn = _toy_problem()
    plan = FailurePlan(at_steps={i: "crash" for i in range(0, 50)})
    with pytest.raises(InjectedFailure):
        run_training(step_fn, init_state, lambda s: jnp.array(1.0),
                     total_steps=20, ckpt_dir=str(tmp_path),
                     max_restarts=2, failure_plan=plan)


def test_straggler_watchdog_flags_slow_steps():
    wd = StragglerWatchdog(factor=2.0, window=8)
    for i in range(8):
        wd.observe(i, 0.01)
    wd.observe(8, 0.5)
    assert 8 in wd.flagged


def test_restart_does_not_double_count_replayed_steps(tmp_path):
    """Regression: a mid-interval rollback re-runs the steps after the
    last checkpoint; metrics_history and the watchdog must keep exactly
    one entry per step (pre-fix they kept the pre-failure entries too)."""
    init_state, step_fn = _toy_problem()

    def step_fn_tagged(params, opt_state, batch):
        params, opt_state, metrics = step_fn(params, opt_state,
                                             jnp.array(1.0))
        return params, opt_state, {**metrics, "step": batch}

    wd = StragglerWatchdog()
    # ckpt_every=4 -> checkpoint after step 3; failing at 6 rolls back to
    # step 4, so steps 4 and 5 replay (a mid-interval rollback)
    plan = FailurePlan(at_steps={6: "ici-timeout"})
    res = run_training(step_fn_tagged, init_state,
                       lambda s: jnp.array(float(s)), total_steps=12,
                       ckpt_dir=str(tmp_path), ckpt_every=4,
                       failure_plan=plan, watchdog=wd)
    assert res.restarts == 1
    steps = [int(m["step"]) for m in res.metrics_history]
    assert steps == list(range(12))          # no duplicates, no holes
    assert len(wd.history) == 12             # watchdog deduped too
    assert wd.steps == list(range(12))


def test_watchdog_rollback_drops_flags_of_replayed_steps():
    wd = StragglerWatchdog(factor=2.0, window=8)
    for i in range(8):
        wd.observe(i, 0.01)
    wd.observe(8, 0.5)
    assert 8 in wd.flagged
    wd.rollback(8)
    assert wd.flagged == [] and len(wd.history) == 8


def test_watchdog_median_is_true_median_for_even_windows():
    """Regression: sorted(hist)[len//2] is the UPPER-mid element — with a
    bimodal even window it biased the threshold high and masked a real
    straggler. The true median (mean of the middle two) must flag it."""
    wd = StragglerWatchdog(factor=3.0, window=4)
    for i, dt in enumerate([0.001, 0.001, 0.1, 0.1]):
        wd.observe(i, dt)
    # true median = 0.0505 -> threshold 0.1515; upper-mid would have set
    # the threshold at 0.3 and let this 0.2s step through unflagged
    wd.observe(4, 0.2)
    assert 4 in wd.flagged


def test_resume_continues_not_restarts(tmp_path):
    """Second call resumes from the checkpoint (optimizer momentum kept)."""
    init_state, step_fn = _toy_problem()
    run_training(step_fn, init_state, lambda s: jnp.array(1.0),
                 total_steps=10, ckpt_dir=str(tmp_path), ckpt_every=5)
    res2 = run_training(step_fn, init_state, lambda s: jnp.array(1.0),
                        total_steps=12, ckpt_dir=str(tmp_path), ckpt_every=5)
    assert res2.final_step == 12
    assert len(res2.metrics_history) == 2   # only steps 10, 11 re-run
