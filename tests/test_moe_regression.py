"""Refactor-regression guard for the owner-routed MoE dispatch.

Golden fingerprints of seeded ``moe_dcra`` outputs were captured BEFORE the
routing machinery was extracted into :mod:`repro.core.routing`; this test
pins the refactored dispatch to those values at fp32 tolerance, for every
packaging the dispatch plan can pick: single-pod fused-tp, single-pod with a
tp-sharded FFN (partial-F psum), and the multi-pod hierarchical two-stage
path.

Regenerate (only when the *semantics* intentionally change)::

    PYTHONPATH=src python tests/test_moe_regression.py --regen
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "moe_dispatch.json")

SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import json, dataclasses
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.core.compat import make_mesh, set_mesh
from repro.core.dispatch import MeshInfo, moe_dcra
from repro.models.moe import init_moe

def fingerprint(out):
    f = jnp.ravel(out).astype(jnp.float32)
    step = max(1, f.shape[0] // 256)
    return {
        'sample': [float(v) for v in f[::step][:256]],
        'sum': float(f.sum()),
        'abs_sum': float(jnp.abs(f).sum()),
        'shape': list(out.shape),
    }

cfg = get_config('olmoe-1b-7b').reduced()
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, capacity_factor=8.0))
cfg8 = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, num_experts=8, capacity_factor=8.0))
params = init_moe(jax.random.key(0), cfg)
params8 = init_moe(jax.random.key(2), cfg8)
x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model))

res = {}
mesh = make_mesh((2, 2, 2), ('data', 'expert', 'tp'))
with set_mesh(mesh):
    out, _ = jax.jit(lambda p, x: moe_dcra(
        p, x, cfg, MeshInfo(mesh, pod_axis=None)))(params, x)
    res['single_pod_fused'] = fingerprint(out)
    out, _ = jax.jit(lambda p, x: moe_dcra(
        p, x, cfg, MeshInfo(mesh, pod_axis=None, fuse_tp=False)))(params, x)
    res['tp_sharded_ffn'] = fingerprint(out)

mesh2 = make_mesh((2, 1, 2, 2), ('pod', 'data', 'expert', 'tp'))
with set_mesh(mesh2):
    out, _ = jax.jit(lambda p, x: moe_dcra(
        p, x, cfg8, MeshInfo(mesh2, pod_axis='pod')))(params8, x)
    res['multi_pod_hier'] = fingerprint(out)
print('RESULT ' + json.dumps(res))
"""


def _run_current():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


@pytest.fixture(scope="module")
def current():
    return _run_current()


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


@pytest.mark.parametrize("case", ["single_pod_fused", "tp_sharded_ffn",
                                  "multi_pod_hier"])
def test_matches_pre_refactor_golden(current, golden, case):
    got, want = current[case], golden[case]
    assert got["shape"] == want["shape"]
    assert np.allclose(got["sample"], want["sample"], rtol=1e-5, atol=1e-5), \
        np.max(np.abs(np.array(got["sample"]) - np.array(want["sample"])))
    assert abs(got["sum"] - want["sum"]) <= 1e-4 * max(1.0, want["abs_sum"])


if __name__ == "__main__":
    if "--regen" in sys.argv:
        res = _run_current()
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        with open(GOLDEN, "w") as f:
            json.dump(res, f, indent=1)
        print(f"wrote {GOLDEN}")
