"""End-to-end behaviour tests: train a tiny model, loss decreases; serve
greedy decode teacher-forced == forward; synthetic pipeline determinism."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.data.pipeline import synth_batch
from repro.models import build_model
from repro.optim.adamw import AdamW, cosine_schedule


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("granite-8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_training_reduces_loss(tiny):
    cfg, model, params = tiny
    opt = AdamW(lr=cosine_schedule(peak_lr=3e-3, warmup=5, total=100))
    state = opt.init(params)
    B, S = 4, 32
    rng = np.random.default_rng(0)
    tok = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(tok), "labels": jnp.asarray(tok)}

    @jax.jit
    def step(p, s, b):
        (loss, m), g = jax.value_and_grad(model.loss, has_aux=True)(p, b)
        p, s = opt.update(g, s, p)
        return p, s, loss

    losses = []
    for _ in range(20):
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses
    assert np.isfinite(losses).all()


def test_greedy_decode_consistency(tiny):
    cfg, model, params = tiny
    B, S = 2, 16
    tok = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    logits, _ = model.forward(params, {"tokens": tok, "labels": tok})
    cache = model.init_cache(B, S, jnp.float32)
    for t in range(S):
        lg, cache = model.decode_step(params, cache, tok[:, t:t + 1],
                                      jnp.array(t, jnp.int32))
    assert jnp.allclose(lg[:, 0], logits[:, -1], atol=1e-4)


def test_synth_batch_deterministic():
    cfg = get_config("qwen2-1.5b")
    from repro.configs.base import TRAIN_4K
    import dataclasses
    shape = dataclasses.replace(TRAIN_4K, global_batch=2, seq_len=64)
    b1 = synth_batch(cfg, shape, step=7)
    b2 = synth_batch(cfg, shape, step=7)
    b3 = synth_batch(cfg, shape, step=8)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].max() < cfg.vocab_size
