"""Optional-import shim for ``hypothesis``.

The property-based tier prefers real hypothesis (shrinking, example DB,
fuzzing budget control). On boxes without it — the pinned CI image only
bakes the jax toolchain — we fall back to a *seeded-examples* stub: each
``@given`` test runs ``max_examples`` deterministic draws from a PCG64
stream keyed on the test name, so the tier stays meaningful (and green)
either way.

Importing this module guarantees ``import hypothesis`` works afterwards;
``tests/conftest.py`` imports it before collection so test modules can keep
the plain ``from hypothesis import given, settings, strategies as st``.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types

import numpy as np

try:                                       # real hypothesis wins when present
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if not HAVE_HYPOTHESIS:
    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        """A seeded value generator standing in for a hypothesis strategy."""

        def __init__(self, draw):
            self._draw = draw

        def example_for(self, rng):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

        def filter(self, pred, _tries=64):
            def draw(rng):
                for _ in range(_tries):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise ValueError("filter predicate never satisfied")
            return _Strategy(draw)

    def integers(min_value=0, max_value=2**31 - 1):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.integers(len(elements))])

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    def just(value):
        return _Strategy(lambda rng: value)

    def lists(elem, min_size=0, max_size=10):
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elem.example_for(rng) for _ in range(size)]
        return _Strategy(draw)

    def tuples(*elems):
        return _Strategy(
            lambda rng: tuple(e.example_for(rng) for e in elems))

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(**strategies_kw):
        def deco(fn):
            n_examples = getattr(fn, "_compat_max_examples",
                                 _DEFAULT_MAX_EXAMPLES)

            @functools.wraps(fn)
            def runner(*args, **kwargs):
                # Deterministic per-test stream: same draws every run.
                seed = np.frombuffer(
                    fn.__qualname__.encode(), dtype=np.uint8).sum()
                rng = np.random.default_rng(int(seed))
                n = getattr(runner, "_compat_max_examples", n_examples)
                for i in range(n):
                    drawn = {k: s.example_for(rng)
                             for k, s in strategies_kw.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example #{i}: {drawn!r}") from e
            # settings() may be applied after given() in the decorator stack
            runner._compat_max_examples = n_examples
            # Hide strategy-drawn params from pytest's fixture resolution:
            # it must see only the remaining (fixture) parameters.
            sig = inspect.signature(fn)
            params = [p for name, p in sig.parameters.items()
                      if name not in strategies_kw]
            runner.__signature__ = sig.replace(parameters=params)
            del runner.__wrapped__
            return runner
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = integers
    _st.sampled_from = sampled_from
    _st.floats = floats
    _st.booleans = booleans
    _st.just = just
    _st.lists = lists
    _st.tuples = tuples

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = given
    _hyp.settings = settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None,
                                             data_too_large=None,
                                             filter_too_much=None)
    _hyp.__is_compat_stub__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
