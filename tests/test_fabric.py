"""The Fabric layer — one topology object from single-process to
multi-host ``jax.distributed``.

Part A — in-process (1 device): ``fabric_key`` byte-compatibility with
the legacy ``_mesh_key`` tuple, the ``as_fabric`` warn-once mesh shim,
constructors + portal detection, the shared ``resolve_caps`` capacity
resolver, ``host_slice`` partition properties, chunked-ingest parity
(the global edge multiset is independent of the host count), the
``reshard`` no-op fast path (no ``device_get`` on unchanged leaves) and
``rescale`` onto a fabric's mesh, and the MeshInfo/_axsize delegation.

Part B — subprocess (8 fake host devices): for 1/2/4/8 devices a raw
Mesh launch and a ``Fabric`` launch of the same topology produce
bit-identical results/drop streams AND share ONE compile-cache entry
(hits increment, misses don't); same for the pod/portal 2x4 fabric and
``dcra_scatter``; ``ProgramServer(Fabric)`` serves identically to
``ProgramServer(mesh)``; ``Fabric.resize`` + ``rescale`` move state onto
a shrunk device set with values preserved and no-op leaves untouched.

Part C — one TRUE multi-process run: two CPU processes under
``jax.distributed`` build one Fabric (flat, and with the portal axis
across processes), run BFS, and the results and per-round message/drop
streams are bit-identical to the single-process run on the same total
device count.
"""
import json
import os
import socket
import subprocess
import sys
import warnings

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# Part A: in-process (1 device)
# ---------------------------------------------------------------------------

def _mesh1():
    from repro.core.compat import make_mesh
    return make_mesh((1,), ("data",))


def test_fabric_key_matches_legacy_mesh_key():
    from repro.core.fabric import Fabric
    mesh = _mesh1()
    legacy = (tuple(mesh.axis_names), tuple(mesh.devices.shape),
              tuple(d.id for d in mesh.devices.flat))
    f = Fabric.of(mesh)
    assert f.fabric_key() == legacy
    assert Fabric.fake(1).fabric_key() == legacy


def test_as_fabric_warns_once_and_fabric_never():
    from repro.core import fabric as fab_mod
    from repro.core.fabric import Fabric, as_fabric
    mesh = _mesh1()
    fab_mod._WARNED[0] = False
    with pytest.warns(DeprecationWarning, match="raw Mesh"):
        f1 = as_fabric(mesh)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        f2 = as_fabric(mesh)          # latched: once per process
        f3 = as_fabric(Fabric.of(mesh))
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert f1.fabric_key() == f2.fabric_key() == f3.fabric_key()
    fab = Fabric.of(mesh)
    assert as_fabric(fab) is fab      # pass-through identity


class _DuckMesh:
    """The admission-only serve-test idiom: no axis_names, no real
    devices — Fabric accessors must stay lazy and degrade gracefully."""
    devices = np.zeros(4)


def test_of_accepts_duck_meshes_lazily():
    from repro.core.fabric import Fabric
    f = Fabric.of(_DuckMesh())
    assert f.n_devices == 4
    assert f.axis_names == ()
    assert f.process_indices == (0,) and not f.is_multiprocess


def test_portal_detection_and_pod_axis():
    from types import SimpleNamespace
    from repro.core.fabric import Fabric
    multi = SimpleNamespace(axis_names=("pod", "data"),
                            devices=np.zeros((2, 4)))
    f = Fabric.of(multi)
    assert f.portal_axis == "pod" and f.pod_axis == "pod"
    assert f.axis_sizes == {"pod": 2, "data": 4}
    assert f.axis_size(("pod", "data")) == 8 and f.axis_size(None) == 1
    # a size-1 portal axis cannot route across pods
    single = SimpleNamespace(axis_names=("pod", "data"),
                             devices=np.zeros((1, 4)))
    assert Fabric.of(single).pod_axis is None
    flat = SimpleNamespace(axis_names=("data",), devices=np.zeros(4))
    assert Fabric.of(flat).portal_axis is None


def test_launchconfig_pod_axis_for_accepts_fabric_and_mesh():
    from types import SimpleNamespace
    from repro.core.fabric import Fabric
    from repro.dse.autoconfig import LaunchConfig
    from repro.dse.space import ConfigSpace
    pt_hier = next(p for p in ConfigSpace.quick().points()
                   if p.topology == "hier_torus")
    lc = LaunchConfig(point=pt_hier, source="explicit")
    multi = SimpleNamespace(axis_names=("pod", "data"),
                            devices=np.zeros((2, 4)))
    assert lc.pod_axis_for(multi) == "pod"
    assert lc.pod_axis_for(Fabric.of(multi)) == "pod"
    flat = SimpleNamespace(axis_names=("data",), devices=np.zeros(4))
    assert lc.pod_axis_for(flat) is None


def test_resolve_caps_matches_legacy_resolvers():
    from types import SimpleNamespace
    from repro.core.queues import QueueConfig
    from repro.core.routing import (resolve_caps, resolve_flat_cap,
                                    resolve_hier_caps)
    fab = SimpleNamespace(axis_sizes={"pod": 2, "data": 4}, n_devices=8)
    q = QueueConfig.from_factor(2.0, "T3")
    caps, pods = resolve_caps(fab, q, "T3", 64, "data", None)
    assert pods is None
    assert caps == (resolve_flat_cap(q, "T3", 64, 8),)
    capsc, _ = resolve_caps(fab, q, "T3", 64, "data", None, clamp=True)
    assert capsc == (resolve_flat_cap(q, "T3", 64, 8, clamp=True),)
    caps2, pods2 = resolve_caps(fab, q, "T3", 64, "data", "pod")
    assert pods2 == (4, 2)
    assert caps2 == resolve_hier_caps(q, "T3", 64, 4, 2)
    with pytest.raises(ValueError, match="flat path"):
        resolve_caps(fab, QueueConfig.from_cap(5, "T3"), "T3", 64,
                     "data", "pod")


def test_host_slice_partitions_exactly():
    from repro.core.fabric import Fabric
    f = Fabric.of(_DuckMesh())
    for total in (0, 1, 7, 16, 23):
        for world in (1, 2, 3, 5):
            slices = [f.host_slice(total, rank=r, world=world)
                      for r in range(world)]
            # contiguous, disjoint, covering, balanced
            assert slices[0][0] == 0 and slices[-1][1] == total
            for (a, b), (c, d) in zip(slices, slices[1:]):
                assert b == c and a <= b
            lens = [hi - lo for lo, hi in slices]
            assert max(lens) - min(lens) <= 1
    with pytest.raises(ValueError, match="rank"):
        f.host_slice(8, rank=3, world=3)


def _edge_multiset(src, dst, w):
    return sorted(zip(src.tolist(), dst.tolist(), w.tolist()))


def test_ingest_union_is_host_count_independent():
    from repro.sparse.datasets import ingest_edges
    full = ingest_edges(6, edge_factor=4, seed=3, n_chunks=8)
    want = _edge_multiset(*full)
    assert len(want) > 0
    for world in (2, 3, 8):
        parts = [ingest_edges(6, edge_factor=4, seed=3, n_chunks=8,
                              rank=r, world=world) for r in range(world)]
        src = np.concatenate([p[0] for p in parts])
        dst = np.concatenate([p[1] for p in parts])
        w = np.concatenate([p[2] for p in parts])
        assert _edge_multiset(src, dst, w) == want
        # no host holds the full edge list (world > 1)
        assert all(len(p[0]) < len(full[0]) for p in parts)


def test_ingest_is_deterministic_and_fabric_driven():
    from repro.core.fabric import Fabric
    from repro.sparse.datasets import ingest_edges, rmat_edge_chunk
    a = rmat_edge_chunk(6, 2, 8, edge_factor=4, seed=3)
    b = rmat_edge_chunk(6, 2, 8, edge_factor=4, seed=3)
    for x, y in zip(a, b):
        assert np.array_equal(x, y)
    f = Fabric.of(_DuckMesh())          # single "process" -> whole range
    via_fab = ingest_edges(6, edge_factor=4, seed=3, n_chunks=8, fabric=f)
    plain = ingest_edges(6, edge_factor=4, seed=3, n_chunks=8)
    assert _edge_multiset(*via_fab) == _edge_multiset(*plain)


def test_ingest_graph_runs_bfs():
    from repro.sparse.datasets import ingest_graph
    from repro.sparse.jax_apps import dcra_bfs
    from repro.core.fabric import Fabric
    g = ingest_graph(6, edge_factor=4, seed=3, n_chunks=4)
    d, stats = dcra_bfs(g, 0, Fabric.fake(1), capacity_factor=8.0)
    assert d.shape == (64,) and stats.rounds > 0


def test_reshard_skips_noop_leaves(monkeypatch):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.runtime import elastic
    mesh = _mesh1()
    sh = NamedSharding(mesh, P("data"))
    x = jax.device_put(np.arange(8, dtype=np.float32), sh)
    calls = []
    real_get = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda v: (calls.append(1), real_get(v))[1])
    out = elastic.reshard({"a": x}, {"a": sh})
    assert calls == []                  # unchanged path: no host round-trip
    assert out["a"] is x
    sh2 = NamedSharding(mesh, P())
    out2 = elastic.reshard({"a": x}, {"a": sh2})
    assert len(calls) == 1              # a real move still round-trips
    assert out2["a"].sharding == sh2
    assert np.array_equal(np.asarray(out2["a"]), np.arange(8))


def test_rescale_places_leaves_on_fabric_mesh():
    from jax.sharding import NamedSharding, PartitionSpec as P
    import jax.numpy as jnp
    from repro.core.fabric import Fabric
    from repro.runtime.elastic import rescale
    fab = Fabric.fake(1)
    tree = {"w": jnp.arange(8.0), "b": jnp.arange(4.0)}
    out = rescale(tree, fab, {"w": P("data"), "b": P()})
    assert out["w"].sharding == NamedSharding(fab.mesh, P("data"))
    assert out["b"].sharding == NamedSharding(fab.mesh, P())
    assert np.array_equal(np.asarray(out["w"]), np.arange(8.0))


def test_meshinfo_and_axsize_delegate_to_fabric():
    from repro.core.dispatch import MeshInfo
    from repro.core.fabric import Fabric
    from repro.launch.sharding import _axsize
    mesh = _mesh1()
    mi = MeshInfo(mesh)
    assert mi.axis_size(None) == 1
    assert mi.axis_size("data") == 1
    assert mi.axis_size(["data"]) == 1 and mi.axis_size(("data",)) == 1
    fab = Fabric.of(mesh)
    assert MeshInfo(fab).mesh is mesh   # Fabric accepted, unwrapped
    assert _axsize(mesh, ("data",)) == 1 and _axsize(fab, None) == 1


def test_launch_mesh_fabric_constructors_share_shapes():
    # shape/axis contracts only — 256-device meshes can't build here
    from repro.launch import mesh as lm
    assert lm.make_production_fabric.__doc__ is not None
    from repro.core.fabric import Fabric
    from types import SimpleNamespace
    pod = SimpleNamespace(axis_names=("pod", "data", "model"),
                          devices=np.zeros((2, 16, 16)))
    assert lm.model_axes(pod) == ("model",)
    assert lm.batch_axes(pod) == ("pod", "data")
    assert lm.batch_axes(Fabric.of(pod)) == ("pod", "data")


# ---------------------------------------------------------------------------
# Part B: subprocess, 8 fake host devices — Fabric vs raw-Mesh parity
# ---------------------------------------------------------------------------

SCRIPT_B = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import json
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.compat import make_mesh
from repro.core.fabric import Fabric
from repro.sparse import datasets, program
from repro.sparse.jax_apps import dcra_bfs, dcra_scatter
from repro.serve.engine import ProgramServer, Request

res = {}
g = datasets.wiki_like(192, avg_degree=6, seed=7)

# -- flat parity + shared cache entry at every device count -----------------
for n_dev in (1, 2, 4, 8):
    mesh = make_mesh((n_dev,), ('data',))
    d1, s1 = dcra_bfs(g, 0, mesh, capacity_factor=0.25)     # overflowing
    c0 = program.cache_stats()
    d2, s2 = dcra_bfs(g, 0, Fabric.fake(n_dev), capacity_factor=0.25)
    c1 = program.cache_stats()
    res[f'flat{n_dev}'] = {
        'equal': bool(np.array_equal(d1, d2)),
        'msgs_equal': bool(np.array_equal(s1.messages, s2.messages)),
        'drops_equal': bool(np.array_equal(s1.drops, s2.drops)),
        'drops_total': int(s1.total_drops),
        'hit_delta': c1['hits'] - c0['hits'],
        'miss_delta': c1['misses'] - c0['misses']}

# -- pod/portal parity ------------------------------------------------------
hier_mesh = make_mesh((2, 4), ('pod', 'data'))
hier_fab = Fabric.single((2, 4), ('pod', 'data'))
d1, s1 = dcra_bfs(g, 0, hier_mesh, pod_axis='pod', capacity_factor=0.25)
c0 = program.cache_stats()
d2, s2 = dcra_bfs(g, 0, hier_fab, pod_axis='pod', capacity_factor=0.25)
c1 = program.cache_stats()
res['hier'] = {
    'equal': bool(np.array_equal(d1, d2)),
    'msgs_equal': bool(np.array_equal(s1.messages, s2.messages)),
    'drops_equal': bool(np.array_equal(s1.drops, s2.drops)),
    'portal': hier_fab.pod_axis,
    'hit_delta': c1['hits'] - c0['hits'],
    'miss_delta': c1['misses'] - c0['misses']}

# -- one-round scatter parity ----------------------------------------------
dest = jnp.asarray(np.arange(64) % 16)
vals = jnp.ones(64, jnp.float32)
mesh8 = make_mesh((8,), ('data',))
y1, dr1 = dcra_scatter(dest, vals, 16, mesh8, capacity_factor=2.0)
c0 = program.cache_stats()
y2, dr2 = dcra_scatter(dest, vals, 16, Fabric.fake(8), capacity_factor=2.0)
c1 = program.cache_stats()
res['scatter'] = {'equal': bool(np.array_equal(np.asarray(y1),
                                               np.asarray(y2))),
                  'drops_equal': int(dr1) == int(dr2),
                  'hit_delta': c1['hits'] - c0['hits'],
                  'miss_delta': c1['misses'] - c0['misses']}

# -- ProgramServer(Fabric) vs ProgramServer(mesh) ---------------------------
reqs = [Request(req_id=i, tenant=f't{i % 3}', program='bfs', graph='g',
                root=(7 * i) % g.n) for i in range(6)]
srv_mesh = ProgramServer(make_mesh((4,), ('data',)), {'g': g},
                         batch_width=2)
srv_fab = ProgramServer(Fabric.fake(4), {'g': g}, batch_width=2)
r1 = srv_mesh.run(list(reqs))
r2 = srv_fab.run(list(reqs))
res['serve'] = {
    'statuses': [a.status for a in r1] == [b.status for b in r2],
    'results': all((a.result is None and b.result is None)
                   or bool(np.array_equal(a.result, b.result))
                   for a, b in zip(r1, r2)),
    'n': len(r1) == len(reqs) == len(r2)}

# -- elastic: resize + rescale ---------------------------------------------
fab8 = Fabric.fake(8)
fab4 = fab8.resize(jax.devices()[:4])
from repro.runtime.elastic import rescale
x = jax.device_put(np.arange(16, dtype=np.float32),
                   NamedSharding(fab8.mesh, P('data')))
moved = rescale({'x': x}, fab4, {'x': P('data')})
same = rescale(moved, fab4, {'x': P('data')})        # no-op second pass
hier_small = Fabric.single((2, 4), ('pod', 'data')).resize(jax.devices()[:4])
res['elastic'] = {
    'shape4': fab4.shape == (4,),
    'names': fab4.axis_names == ('data',),
    'values': bool(np.array_equal(np.asarray(moved['x']), np.arange(16))),
    'moved_sharding': moved['x'].sharding == NamedSharding(fab4.mesh,
                                                           P('data')),
    'noop_identity': same['x'] is moved['x'],
    'hier_shape': hier_small.shape == (1, 4),
    'hier_names': hier_small.axis_names == ('pod', 'data'),
    'hier_pod_off': hier_small.pod_axis is None,
    'key_stable': fab8.fabric_key() == Fabric.fake(8).fabric_key()}
print('RESULT ' + json.dumps(res))
"""


@pytest.fixture(scope="module")
def results_b():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", SCRIPT_B], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


@pytest.mark.parametrize("n_dev", [1, 2, 4, 8])
def test_fabric_and_mesh_launches_are_bit_identical(results_b, n_dev):
    r = results_b[f"flat{n_dev}"]
    assert r["equal"] and r["msgs_equal"] and r["drops_equal"], r
    # the Fabric launch HIT the raw-mesh launch's cache entry: same key
    assert r["hit_delta"] >= 1 and r["miss_delta"] == 0, r


def test_some_flat_case_exercises_drops(results_b):
    assert any(results_b[f"flat{n}"]["drops_total"] > 0
               for n in (2, 4, 8)), "capacity_factor=0.25 should drop"


def test_pod_portal_fabric_parity(results_b):
    r = results_b["hier"]
    assert r["equal"] and r["msgs_equal"] and r["drops_equal"], r
    assert r["portal"] == "pod"
    assert r["hit_delta"] >= 1 and r["miss_delta"] == 0, r


def test_scatter_fabric_parity(results_b):
    r = results_b["scatter"]
    assert r["equal"] and r["drops_equal"], r
    assert r["hit_delta"] >= 1 and r["miss_delta"] == 0, r


def test_program_server_accepts_fabric(results_b):
    r = results_b["serve"]
    assert r["statuses"] and r["results"] and r["n"], r


def test_elastic_resize_and_rescale(results_b):
    r = results_b["elastic"]
    assert all(r.values()), r


# ---------------------------------------------------------------------------
# Part C: TRUE multi-process (2 CPU processes over jax.distributed)
# ---------------------------------------------------------------------------

WORKER_C = r"""
import os, sys
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=2'
os.environ.setdefault('JAX_PLATFORMS', 'cpu')
import json
import numpy as np

coord, pid = sys.argv[1], int(sys.argv[2])
try:
    from repro.core.fabric import Fabric
    fab = Fabric.distributed(coordinator_address=coord, num_processes=2,
                             process_id=pid)
except Exception as e:     # no multi-process runtime in this env
    print('UNSUPPORTED ' + repr(e), flush=True)
    sys.exit(17)

import jax
assert fab.is_multiprocess and fab.n_processes == 2, fab.process_indices
assert fab.n_devices == 4 and fab.axis_names == ('data',)
assert fab.dcn_axes() == ('data',)     # flat: every hop crosses the DCN
assert fab.host_slice(8) in ((0, 4), (4, 8))

from repro.sparse import datasets
from repro.sparse.jax_apps import dcra_bfs

g = datasets.erdos_renyi(96, avg_degree=6, seed=5)
res = {}
d, st = dcra_bfs(g, 0, fab, capacity_factor=1.0)
res['flat'] = {'dist': np.asarray(d).tolist(),
               'messages': st.messages.tolist(),
               'drops': st.drops.tolist(), 'rounds': st.rounds}

# portal axis ACROSS the two processes (leading axis is process-major)
hier = Fabric.distributed((2, 2), ('portal', 'data'), portal_axis='portal')
assert hier.dcn_axes() == ('portal',)  # only the portal hop crosses DCN
assert hier.pod_axis == 'portal'
d2, st2 = dcra_bfs(g, 0, hier, pod_axis='portal', capacity_factor=1.0)
res['hier'] = {'dist': np.asarray(d2).tolist(),
               'messages': st2.messages.tolist(),
               'drops': st2.drops.tolist(), 'rounds': st2.rounds}

if pid == 0:
    print('RESULT ' + json.dumps(res), flush=True)
"""

REF_C = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
import json
import numpy as np
from repro.core.fabric import Fabric
from repro.sparse import datasets
from repro.sparse.jax_apps import dcra_bfs

g = datasets.erdos_renyi(96, avg_degree=6, seed=5)
res = {}
d, st = dcra_bfs(g, 0, Fabric.fake(4), capacity_factor=1.0)
res['flat'] = {'dist': np.asarray(d).tolist(),
               'messages': st.messages.tolist(),
               'drops': st.drops.tolist(), 'rounds': st.rounds}
hier = Fabric.single((2, 2), ('portal', 'data'), portal_axis='portal')
d2, st2 = dcra_bfs(g, 0, hier, pod_axis='portal', capacity_factor=1.0)
res['hier'] = {'dist': np.asarray(d2).tolist(),
               'messages': st2.messages.tolist(),
               'drops': st2.drops.tolist(), 'rounds': st2.rounds}
print('RESULT ' + json.dumps(res), flush=True)
"""


def _free_port():
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _result_line(stdout):
    lines = [l for l in stdout.splitlines() if l.startswith("RESULT ")]
    assert lines, stdout[-2000:]
    return json.loads(lines[0][len("RESULT "):])


def test_two_process_fabric_matches_single_process():
    """The acceptance-criteria run: 2 real CPU processes, one Fabric,
    portal axis across the DCN — BFS dist + per-round message/drop
    streams bit-identical to single-process on 4 total devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    coord = f"127.0.0.1:{_free_port()}"
    procs = [subprocess.Popen(
        [sys.executable, "-c", WORKER_C, coord, str(pid)], env=env,
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
        for pid in (0, 1)]
    outs = []
    try:
        for p in procs:
            outs.append(p.communicate(timeout=600))
    finally:
        for p in procs:
            p.kill()
    if any(p.returncode == 17 for p in procs):
        pytest.skip("jax.distributed multi-process unavailable: "
                    + (outs[0][0] + outs[1][0])[:500])
    for p, (so, se) in zip(procs, outs):
        assert p.returncode == 0, (so[-1500:], se[-3000:])
    dist_res = _result_line(outs[0][0])

    ref = subprocess.run([sys.executable, "-c", REF_C], env=env,
                         capture_output=True, text=True, timeout=600)
    assert ref.returncode == 0, ref.stderr[-3000:]
    ref_res = _result_line(ref.stdout)

    for k in ("flat", "hier"):
        assert dist_res[k] == ref_res[k], (k, dist_res[k], ref_res[k])
