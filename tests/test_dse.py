"""Property tests for the design-space exploration layer
(:mod:`repro.dse`).

Part A — in-process properties: Pareto frontier invariants, silicon-cost
monotonicity in die area, ConfigSpace enumeration validity, the
Evaluator's decoupled re-pricing cache, and the analytic bounded-IQ drop
count vs an independent per-channel numpy oracle.

Part B — the analytic-vs-executable contract under shard_map (subprocess,
same pattern as tests/test_routing.py): for swept queue capacities, the
``repro.dse.shardcheck`` worker must report exact message/drop agreement
between ``TaskEngine.route`` and the real ``dcra_spmv`` /
``dcra_histogram`` executables, and the quick sweep CLI must emit a valid
``BENCH_dse.json`` trajectory end to end.
"""
import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import EngineConfig, QueueConfig, TaskEngine, TileGrid
from repro.costmodel.silicon import die_cost_usd, murphy_yield
from repro.dse import compare as dse_compare
from repro.dse.driver import SweepTask, run_sweep
from repro.dse.evaluate import Evaluator
from repro.dse.pareto import dominates, pareto_frontier, pareto_indices
from repro.dse.space import ConfigSpace, DesignPoint
from repro.sparse import datasets

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    return env


# ---------------------------------------------------------------------------
# Part A: Pareto frontier invariants
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([1, 4, 40]),
       k=st.sampled_from([2, 3]))
def test_pareto_invariants(seed, n, k):
    rng = np.random.default_rng(seed)
    # quantized so duplicates / exact ties actually occur
    vals = np.round(rng.random((n, k)), 1)
    idx = pareto_indices(vals)
    assert 1 <= len(idx) <= n
    assert set(idx) <= set(range(n))                  # frontier ⊆ input
    for i in idx:                                     # nothing kept is dominated
        assert not any(dominates(vals[j], vals[i]) for j in range(n))
    for i in set(range(n)) - set(idx):                # everything dropped is
        assert any(dominates(vals[j], vals[i]) for j in idx)


def test_pareto_frontier_respects_directions():
    recs = [
        {"teps": 1.0, "watts": 1.0, "package_usd": 1.0},  # dominated by #1
        {"teps": 2.0, "watts": 1.0, "package_usd": 1.0},
        {"teps": 2.0, "watts": 2.0, "package_usd": 0.5},  # trade-off: kept
    ]
    assert pareto_frontier(recs) == [1, 2]


def test_pareto_keeps_duplicate_optima():
    recs = [{"teps": 2.0, "watts": 1.0, "package_usd": 1.0}] * 3
    assert pareto_frontier(recs) == [0, 1, 2]


# ---------------------------------------------------------------------------
# Part A: silicon economics monotonicity (the DSE cost axis)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_yield_and_die_cost_monotone_in_area(seed):
    rng = np.random.default_rng(seed)
    areas = np.sort(rng.uniform(5.0, 800.0, 8))
    ys = [murphy_yield(a, 0.0007) for a in areas]
    assert all(a >= b - 1e-12 for a, b in zip(ys, ys[1:])), \
        "murphy_yield must not increase with area"
    cs = [die_cost_usd(a) for a in areas]
    assert all(b >= a * (1 - 1e-9) for a, b in zip(cs, cs[1:])), \
        "die_cost_usd must not decrease with area"


# ---------------------------------------------------------------------------
# Part A: ConfigSpace enumeration
# ---------------------------------------------------------------------------

def test_quick_space_shape_and_validity():
    pts = list(ConfigSpace.quick().points())
    assert len(pts) >= 24
    assert len({p.point_id for p in pts}) == len(pts)   # ids are unique
    for p in pts:
        assert p.grid_side % p.die_side == 0
        cfg = p.engine_config()
        assert cfg.grid.topology == p.topology
        assert cfg.grid.noc_width_bits == p.noc_width_bits
        assert cfg.queues.iq("T3") == p.iq_capacity
        assert cfg.queues.oq("T3") == p.oq_capacity
        assert cfg.dram.present == (p.mem_tech == "hbm")
        assert p.package_usd() > 0 and p.system_usd() >= p.package_usd()


def test_design_point_round_trips_and_rejects_bad_axes():
    p = next(ConfigSpace.quick().points())
    assert DesignPoint.from_dict(p.to_dict()) == p
    with pytest.raises(ValueError):
        DesignPoint(topology="ring")
    with pytest.raises(ValueError):
        DesignPoint(mem_tech="optane")


def test_moe_capacity_factor_is_the_iq_axis():
    """The MoE dispatch knob is a ConfigSpace axis and resolves through
    the same QueueConfig path the kernel uses (no parallel knob)."""
    from types import SimpleNamespace
    from repro.core.dispatch import dispatch_queues

    p = DesignPoint(moe_capacity_factor=1.0)
    kernel_q = dispatch_queues(SimpleNamespace(capacity_factor=1.0))
    for task, tasks, chans in (("dispatch", 4096 * 8, 8),
                               ("portal", 1024, 2), ("expert", 640, 4)):
        assert (p.moe_queues().channel_cap(task, tasks, chans)
                == kernel_q.channel_cap(task, tasks, chans))
    # swept like any other compile-time axis, with distinct identities
    pts = list(ConfigSpace.quick().points())
    pts2 = list(ConfigSpace(**{**ConfigSpace.quick().to_dict(),
                               "moe_capacity_factors": (1.0, 1.25)}).points())
    assert len(pts2) == 2 * len(pts)
    assert len({q.point_id for q in pts2}) == len(pts2)


def test_full_space_covers_every_topology_and_mem_tech():
    pts = list(ConfigSpace.full().points())
    assert {p.topology for p in pts} == {"mesh", "torus", "hier_torus"}
    assert {p.mem_tech for p in pts} == {"sram", "hbm"}
    assert len(pts) >= 24


# ---------------------------------------------------------------------------
# Part A: Evaluator decoupled re-pricing
# ---------------------------------------------------------------------------

def test_evaluator_reprices_cached_stats_across_width_and_mem():
    data = {"R6": datasets.rmat(6, edge_factor=4, seed=1)}
    ev = Evaluator(data, ("bfs", "spmv"))
    a = DesignPoint(grid_side=16, die_side=16, mem_tech="hbm")
    b = a.with_(noc_width_bits=32, mem_tech="sram", oq_capacity=48)
    ra, rb = ev.evaluate_point(a), ev.evaluate_point(b)
    # same stats_key -> the routed stream is simulated once, re-priced twice
    assert ev.stats_for(a, "bfs", "R6") is ev.stats_for(b, "bfs", "R6")
    for r in (ra, rb):
        assert r.teps > 0 and np.isfinite(r.teps)
        assert r.watts > 0 and r.system_usd > 0
    assert ra.system_usd != rb.system_usd      # mem tech re-prices dollars


# ---------------------------------------------------------------------------
# Part A: analytic bounded-IQ drops vs independent channel oracle
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), cap=st.sampled_from([1, 8, 16]),
       T=st.sampled_from([2, 4, 8]),
       self_heavy=st.booleans())
def test_engine_drop_count_matches_channel_overflow(seed, cap, T,
                                                    self_heavy):
    """Analytic drops == per-channel overflow, INCLUDING same-tile
    (src == dst) channels — the shard_map bucket queues self-owned tasks
    through its own bucket at the same capacity, so the analytic model
    must charge them too (heavy self-traffic stream exercises exactly
    those channels)."""
    rng = np.random.default_rng(seed)
    n = 64
    src = rng.integers(0, n, 300)
    if self_heavy:
        # ~90% of tasks stay on their own tile: dst ≡ src (mod T)
        dst = np.where(rng.random(300) < 0.9,
                       src, rng.integers(0, n, 300))
    else:
        dst = rng.integers(0, n, 300)
    engine = TaskEngine(EngineConfig(grid=TileGrid(1, T),
                                     queues=QueueConfig(default_iq=cap)), n)
    rs = engine.route("T3", src_idx=src, dst_idx=dst)
    chan = {}
    for s, d in zip(src % T, dst % T):
        chan[(s, d)] = chan.get((s, d), 0) + 1
    want = sum(max(c - cap, 0) for c in chan.values())
    assert rs.drops == want
    if self_heavy and cap == 1 and T <= 4:
        assert rs.drops > 0          # self channels really did overflow
    # per-task sizing beats the default — QueueConfig is the only source
    engine2 = TaskEngine(EngineConfig(
        grid=TileGrid(1, T),
        queues=QueueConfig(default_iq=cap, iq_sizes={"T3": 10**9})), n)
    assert engine2.route("T3", src_idx=src, dst_idx=dst).drops == 0
    # unbounded config restores the legacy no-drop stats
    engine3 = TaskEngine(EngineConfig(grid=TileGrid(1, T),
                                      queues=QueueConfig.unbounded()), n)
    assert engine3.route("T3", src_idx=src, dst_idx=dst).drops == 0


def test_factor_based_queueconfig_bounds_the_analytic_model():
    """A relative (capacity-factor) QueueConfig must bound route() too —
    not silently fall back to unbounded while the executables drop: both
    paths resolve through channel_cap."""
    rng = np.random.default_rng(7)
    T, n, n_tasks = 4, 64, 300
    src = rng.integers(0, n, n_tasks)
    dst = np.zeros(n_tasks, np.int64)            # hotspot owner tile
    queues = QueueConfig.from_factor(0.25)
    engine = TaskEngine(EngineConfig(grid=TileGrid(1, T), queues=queues), n)
    rs = engine.route("T3", src_idx=src, dst_idx=dst)
    cap = queues.channel_cap("T3", -(-n_tasks // T), T)
    assert cap is not None
    chan = {}
    for s, d in zip(src % T, dst % T):
        chan[(s, d)] = chan.get((s, d), 0) + 1
    assert rs.drops == sum(max(c - cap, 0) for c in chan.values()) > 0
    # and the accessors agree on what "no explicit entry" means
    assert queues.iq("T3") is None               # no fixed entry count


# ---------------------------------------------------------------------------
# Part A: resumable sweep driver — error records resume correctly
# ---------------------------------------------------------------------------

def _flaky_tasks(calls, fail_keys=()):
    def make(key):
        def run():
            calls.append(key)
            if key in fail_keys:
                raise RuntimeError(f"boom {key}")
            return {"value": key.upper()}
        return SweepTask(key=key, run=run, meta={"m": 1})
    return [make(k) for k in ("a", "b", "c")]


def test_error_records_carry_their_task_key(tmp_path):
    out = str(tmp_path / "sweep.json")
    calls = []
    results = run_sweep(_flaky_tasks(calls, fail_keys=("b",)), out=out)
    by_key = {r["task_key"]: r for r in results}
    assert set(by_key) == {"a", "b", "c"}
    assert "error" in by_key["b"] and by_key["b"]["m"] == 1


def test_resume_does_not_rerun_errored_points_by_default(tmp_path):
    out = str(tmp_path / "sweep.json")
    calls = []
    run_sweep(_flaky_tasks(calls, fail_keys=("b",)), out=out)
    assert calls == ["a", "b", "c"]
    # resume: nothing re-runs, no duplicate records accumulate
    results = run_sweep(_flaky_tasks(calls, fail_keys=("b",)), out=out)
    assert calls == ["a", "b", "c"]
    assert len(results) == 3


def test_retry_errors_reruns_only_failures_without_duplicates(tmp_path):
    out = str(tmp_path / "sweep.json")
    calls = []
    run_sweep(_flaky_tasks(calls, fail_keys=("b",)), out=out)
    results = run_sweep(_flaky_tasks(calls), out=out, retry_errors=True)
    assert calls == ["a", "b", "c", "b"]      # only the failure re-ran
    assert len(results) == 3                  # stale error record replaced
    by_key = {r["task_key"]: r for r in results}
    assert by_key["b"]["value"] == "B" and "error" not in by_key["b"]


# ---------------------------------------------------------------------------
# Part A: frontier trajectory comparison (the nightly regression gate)
# ---------------------------------------------------------------------------

def _bench_with(points):
    return {"schema": "dcra-dse-bench/v1",
            "points": [{"point_id": pid, "pareto": True,
                        "metrics": {"teps_geomean": t, "watts_geomean": w,
                                    "package_usd": c,
                                    "teps_per_usd": t / c}}
                       for pid, t, w, c in points]}


def test_compare_accepts_improvement_and_drift():
    old = _bench_with([("p1", 100.0, 5.0, 40.0)])
    new = _bench_with([("p2", 120.0, 4.0, 35.0)])   # new ids, all better
    failures, notes = dse_compare.compare(old, new, tol=0.05)
    assert not failures
    assert any("drift" in n for n in notes)


def test_compare_flags_objective_best_regression():
    old = _bench_with([("p1", 100.0, 5.0, 40.0)])
    new = _bench_with([("p1", 80.0, 5.0, 40.0)])    # -20% teps
    failures, _ = dse_compare.compare(old, new, tol=0.05)
    assert failures and any("teps" in f for f in failures)
    # within tolerance passes
    ok, _ = dse_compare.compare(old, _bench_with([("p1", 97.0, 5.0, 40.0)]),
                                tol=0.05)
    assert not ok


def _bench_with_slices(points):
    """v2 bench: one app slice ('bfs') over all points."""
    b = _bench_with([(pid, t, w, c) for pid, t, w, c, _ in points])
    b["schema"] = "dcra-dse-bench/v2"
    for rec, (_, _, _, _, bfs_teps) in zip(b["points"], points):
        rec["per_cell"] = {"bfs:D": {"teps": bfs_teps, "seconds": 1.0,
                                     "energy_j": 1.0}}
    b["app_frontiers"] = {"bfs": [r["point_id"] for r in b["points"]]}
    return b


def test_compare_flags_per_app_slice_regression():
    old = _bench_with_slices([("p1", 100.0, 5.0, 40.0, 90.0)])
    new = _bench_with_slices([("p1", 100.0, 5.0, 40.0, 60.0)])  # -33% bfs
    failures, _ = dse_compare.compare(old, new, tol=0.05)
    assert failures and any("bfs" in f for f in failures)
    ok, notes = dse_compare.compare(old, old, tol=0.05)
    assert not ok and any("bfs" in n for n in notes)


def test_compare_notes_v1_v2_schema_mix():
    old = _bench_with([("p1", 100.0, 5.0, 40.0)])          # v1: no slices
    new = _bench_with_slices([("p1", 100.0, 5.0, 40.0, 90.0)])
    failures, notes = dse_compare.compare(old, new, tol=0.05)
    assert not failures
    assert any("one side only" in n for n in notes)


def test_compare_rejects_unknown_schema(tmp_path):
    good = _bench_with([("p1", 100.0, 5.0, 40.0)])
    bad = dict(good, schema="dcra-dse-bench/v99")
    pg, pb = str(tmp_path / "g.json"), str(tmp_path / "b.json")
    with open(pg, "w") as f:
        json.dump(good, f)
    with open(pb, "w") as f:
        json.dump(bad, f)
    assert dse_compare.main([pg, pb]) == 1


def test_compare_cli_exit_codes(tmp_path):
    old_p, new_p = str(tmp_path / "old.json"), str(tmp_path / "new.json")
    with open(old_p, "w") as f:
        json.dump(_bench_with([("p1", 100.0, 5.0, 40.0)]), f)
    with open(new_p, "w") as f:
        json.dump(_bench_with([("p1", 50.0, 5.0, 40.0)]), f)
    assert dse_compare.main([old_p, new_p]) == 2
    assert dse_compare.main([old_p, old_p]) == 0
    assert dse_compare.main([old_p, str(tmp_path / "nope.json")]) == 1


# ---------------------------------------------------------------------------
# Part B: shard_map revalidation across swept queue capacities (subprocess)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def shardcheck_results():
    spec = {"n_dev": 8, "scale": 8, "seed": 0,
            "checks": [{"point_id": f"iq{iq}", "iq_capacity": iq,
                        "apps": ["spmv", "histogram", "histogram_self",
                                 "bfs", "wcc", "kcore"]}
                       for iq in (8, 64)]}
    out = subprocess.run(
        [sys.executable, "-m", "repro.dse.shardcheck"],
        input=json.dumps(spec), env=_env(),
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_shardcheck_agrees_for_swept_capacities(shardcheck_results):
    assert len(shardcheck_results) == 12         # 2 caps x 6 apps
    for r in shardcheck_results:
        assert r["ok"], r
        assert r["executable"] == r["analytic"]


def test_shardcheck_covers_iterative_task_programs(shardcheck_results):
    """The revalidation now replays the iterative apps' TaskProgram twins
    too — multi-round trajectories, not just the one-round scatters."""
    iterative = [r for r in shardcheck_results
                 if r["app"] in ("bfs", "wcc", "kcore")]
    assert len(iterative) == 6
    assert all(r["ok"] for r in iterative)
    assert all(r["executable"]["rounds"] > 1 for r in iterative)


def test_shardcheck_exercises_the_overflow_path(shardcheck_results):
    """Tight queues must actually drop, or the agreement is vacuous."""
    tight = [r for r in shardcheck_results if r["cap"] == 8]
    assert tight and all(r["analytic"]["drops"] > 0 for r in tight)


def test_shardcheck_covers_heavy_self_traffic(shardcheck_results):
    """The same-tile (src == dst) channels must overflow and agree: the
    analytic model charges self channels because the executable bucket
    queues self-owned tasks at the same capacity (drop parity by
    construction, not by coincidence)."""
    selfs = [r for r in shardcheck_results if r["app"] == "histogram_self"]
    assert len(selfs) == 2
    assert all(r["ok"] for r in selfs)
    assert any(r["analytic"]["drops"] > 0 for r in selfs)


# ---------------------------------------------------------------------------
# Part B: the sweep CLI end to end (the BENCH_dse.json contract)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def quick_bench():
    with tempfile.TemporaryDirectory() as td:
        out = os.path.join(td, "BENCH_dse.json")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.dse.sweep", "--quick",
             "--out", out],
            env=_env(), capture_output=True, text=True, timeout=900)
        assert proc.returncode == 0, proc.stderr[-3000:] + proc.stdout[-1000:]
        with open(out) as f:
            return json.load(f)


def test_quick_sweep_meets_the_bench_contract(quick_bench):
    b = quick_bench
    assert b["schema"] == "dcra-dse-bench/v2"
    valid = [r for r in b["points"] if "metrics" in r]
    assert len(valid) >= 24                      # evaluated config points
    assert len(b["apps"]) >= 3                   # across >= 3 apps
    assert b["pareto"]                           # non-empty frontier
    frontier = {r["point_id"] for r in valid if r["pareto"]}
    assert set(b["pareto"]) == frontier
    for r in valid:
        m = r["metrics"]
        assert m["teps_geomean"] > 0 and m["package_usd"] > 0
        assert np.isfinite(m["watts_geomean"])
    # schema v2: one Pareto slice per swept app, ids drawn from the points
    ids = {r["point_id"] for r in valid}
    assert set(b["app_frontiers"]) == set(b["apps"])
    for app, pids in b["app_frontiers"].items():
        assert pids and set(pids) <= ids, app


def test_quick_sweep_revalidates_a_winner_on_shard_map(quick_bench):
    reval = quick_bench["revalidation"]
    assert reval, "top-K winners must be revalidated on the executables"
    assert all(r["ok"] for r in reval)
    assert {r["point_id"] for r in reval} <= set(quick_bench["pareto"])
    # ... and the revalidation spans every app, iterative ones included
    from repro.dse.sweep import REVALIDATION_APPS
    assert {r["app"] for r in reval} == set(REVALIDATION_APPS)
