"""Fault-tolerant serving (:mod:`repro.serve.resilience`).

Part A — host-side pieces, no devices: the injection-schedule house
primitive (and ``FailurePlan`` back-compat), the shared
training/serving ``RetryLedger`` (deterministic no-``random`` backoff),
the circuit-breaker state machine, seeded chaos plans (hypothesis tier:
every seed yields the same reproducible 3-fault plan), the head-of-queue
``push_front`` requeue on both formers, breaker fast-fail at admission,
and the ``_inflight_demand`` leak/double-finish regression.

Part B (subprocess, 8 fake host devices) — the chaos parity contract:

* a seeded ``ServeFailurePlan`` injecting one launch fault, one
  device-side fault and one host loss at fixed launch indices leaves
  ``ProgramServer.run`` with exactly one response per request, every
  retried request served **bit-identical** to the fault-free run, the
  ledger exact with retries counted, the circuit breaker observed
  opening and re-closing, and zero extra re-traces for the unaffected
  shape class (only the class with queued traffic re-prewarms on the
  shrunken fabric);
* host loss with a non-empty inflight window poisons and relaunches the
  window's riders on the survivors;
* a mid-stream MoE dispatch fault (between two healthy graph batches)
  keeps responses streaming in launch order with an intact ledger, both
  terminal (``max_retries=0``) and retried;
* deadlines fail non-retriably with a distinct reason; exhausted retry
  budgets say how many retries were burned; backoff really waits.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# Part A: host-side pieces
# ---------------------------------------------------------------------------

def test_injection_schedule_fires_once_and_records():
    from repro.runtime.fault_tolerance import (InjectedFailure,
                                               InjectionSchedule)
    sched = InjectionSchedule(at={3: "ici-timeout", 5: "preemption"})
    assert sched.peek(3) == "ici-timeout"      # peek does not consume
    assert not sched.exhausted
    assert sched.due(1) is None
    assert sched.due(3) == "ici-timeout"
    assert sched.due(3) is None                # fires exactly once
    with pytest.raises(InjectedFailure, match="preemption at step 5"):
        sched.check(5)
    assert sched.exhausted
    assert sched.fired == [(3, "ici-timeout"), (5, "preemption")]


def test_failure_plan_backcompat_constructor():
    """The historical FailurePlan(at_steps=...) surface keeps working on
    top of InjectionSchedule — same check() message, same pop-once."""
    from repro.runtime.fault_tolerance import FailurePlan, InjectedFailure
    p = FailurePlan(at_steps={7: "ici-timeout"})
    assert p.at_steps == {7: "ici-timeout"} and p.at_steps is p.at
    p.check(6)                                  # not due: no raise
    with pytest.raises(InjectedFailure, match="ici-timeout at step 7"):
        p.check(7)
    p.check(7)                                  # consumed
    assert p.exhausted
    assert FailurePlan().at_steps == {}


def test_serve_failure_plan_validates_kinds():
    from repro.serve import FAULT_KINDS, ServeFailurePlan
    ServeFailurePlan(at={0: k for k in []})     # empty is fine
    ServeFailurePlan(at=dict(enumerate(FAULT_KINDS)))
    with pytest.raises(ValueError, match="unknown fault kinds"):
        ServeFailurePlan(at={0: "meteor"})
    assert ServeFailurePlan(at={2: "launch"}).noun == "launch"


def test_retry_ledger_shared_counting_rule():
    from repro.runtime.fault_tolerance import RetryLedger
    led = RetryLedger(max_retries=2)
    assert led.attempt(9) == 0
    assert led.record_failure(9)                # retry 1 granted
    assert led.record_failure(9)                # retry 2 granted
    assert not led.record_failure(9)            # budget exhausted
    assert led.attempt(9) == 3
    assert led.total_retries == 2               # only GRANTED retries
    led.clear(9)
    assert led.attempt(9) == 0 and not led.attempts
    assert led.total_retries == 2               # aggregate survives clear
    # max_retries=0: first failure is terminal, nothing ever granted
    led0 = RetryLedger(max_retries=0)
    assert not led0.record_failure(1) and led0.total_retries == 0


@settings(max_examples=25)
@given(key=st.integers(0, 10_000), attempts=st.integers(1, 4))
def test_retry_ledger_backoff_deterministic_no_random(key, attempts):
    """Backoff is a pure function of (key, attempt): exponential in the
    attempt, jittered by an integer hash of the key — zero randomness,
    so a replayed chaos run waits identical delays."""
    from repro.runtime.fault_tolerance import RetryLedger
    a = RetryLedger(max_retries=10, backoff_base_s=0.25)
    b = RetryLedger(max_retries=10, backoff_base_s=0.25)
    for _ in range(attempts):
        a.record_failure(key)
        b.record_failure(key)
    assert a.backoff_s(key) == b.backoff_s(key)
    base = 0.25 * 2.0 ** (attempts - 1)
    assert base <= a.backoff_s(key) < 2 * base  # jitter in [0, 1)
    assert RetryLedger(max_retries=1).backoff_s(key) == 0.0  # base 0


def test_circuit_breaker_state_machine():
    from repro.serve.resilience import (BREAKER_CLOSED, BREAKER_HALF_OPEN,
                                        BREAKER_OPEN, CircuitBreaker)
    br = CircuitBreaker(threshold=2, klass=("sssp", "wiki"))
    assert br.allows_launch() and br.state == BREAKER_CLOSED
    assert not br.record_failure()              # 1 of 2: still closed
    assert not br.record_success()              # success resets the run
    assert not br.record_failure()
    assert br.record_failure()                  # 2 consecutive: OPEN
    assert br.state == BREAKER_OPEN and br.opens == 1
    assert br.allows_launch()                   # the half-open probe
    assert br.state == BREAKER_HALF_OPEN
    assert not br.allows_launch()               # probe in flight: hold
    assert br.record_failure()                  # probe fails: re-OPEN —
    assert br.state == BREAKER_OPEN             # each trip counts
    assert br.opens == 2
    assert br.allows_launch()                   # second probe
    assert br.record_success()                  # closes
    assert br.state == BREAKER_CLOSED and br.closes == 1
    assert "sssp/wiki" in br.reject_reason()


@settings(max_examples=30)
@given(seed=st.integers(0, 500), n_launches=st.integers(3, 32))
def test_seeded_chaos_plan_reproducible(seed, n_launches):
    """The CI chaos-smoke seed contract: any seed yields the same plan
    in any process — 3 distinct in-range indices, one fault of each
    kind, the host loss last (the shrunken fabric serves the tail)."""
    from repro.serve import (FAULT_DEVICE, FAULT_HOST_LOSS, FAULT_LAUNCH,
                             seeded_chaos_plan)
    a = seeded_chaos_plan(seed, n_launches, keep_devices=4)
    b = seeded_chaos_plan(seed, n_launches, keep_devices=4)
    assert a.at == b.at and a.keep_devices == 4
    assert len(a.at) == 3
    assert all(0 <= i < n_launches for i in a.at)
    assert sorted(a.at.values()) == sorted(
        [FAULT_LAUNCH, FAULT_DEVICE, FAULT_HOST_LOSS])
    assert a.at[max(a.at)] == FAULT_HOST_LOSS
    with pytest.raises(ValueError):
        seeded_chaos_plan(seed, 2)


def test_serve_options_resilience_validation():
    from repro.serve import ServeOptions
    ServeOptions(max_retries=3, backoff_base_s=0.5, deadline_s=10.0,
                 breaker_threshold=2).resolve()
    ServeOptions().resolve()                    # defaults: all off
    for bad in (dict(max_retries=-1), dict(backoff_base_s=-0.1),
                dict(deadline_s=0.0), dict(breaker_threshold=0)):
        with pytest.raises(ValueError):
            ServeOptions(**bad).resolve()


class _E:
    """Minimal former entry (the formers only read these attributes)."""

    def __init__(self, tenant, klass, demand=1):
        self.tenant, self.klass, self.demand = tenant, klass, demand


def test_push_front_requeues_at_head_both_formers():
    from repro.serve import DrrFormer, FifoFormer
    for former in (FifoFormer(), DrrFormer()):
        a, b = _E("t0", ("bfs", "g")), _E("t1", ("bfs", "g"))
        late = _E("t0", ("sssp", "g"))
        former.push(late)
        # a failed batch's riders are requeued in reverse so the batch
        # order is restored ahead of everything already queued
        for e in reversed([a, b]):
            former.push_front(e)
        assert len(former) == 3
        assert former.pending_classes()[0] == ("bfs", "g")
        assert set(former.pending_classes()) == {("bfs", "g"), ("sssp", "g")}
        batch = former.form(lambda _e: 4)
        assert batch == [a, b], type(former).__name__
        assert former.form(lambda _e: 4) == [late]


def test_breaker_fast_fails_submissions_retriably():
    """A non-closed breaker rejects the class at admission — retriable,
    naming the breaker, counted as rejected in the ledger — and leaves
    other classes untouched."""
    from repro.serve import ProgramServer, Request, STATUS_REJECTED
    from repro.serve.resilience import BREAKER_OPEN, CircuitBreaker
    from repro.sparse import datasets

    class _FakeMesh:
        devices = np.zeros(4)

    g = datasets.erdos_renyi(32, avg_degree=3, seed=7)
    srv = ProgramServer(_FakeMesh(), {"g": g}, batch_width=2)
    srv._breakers[("bfs", "g")] = CircuitBreaker(
        threshold=1, klass=("bfs", "g"), state=BREAKER_OPEN, failures=1)
    resp = srv.submit(Request(0, "acme", "bfs", "g", root=1))
    assert resp is not None and resp.status == STATUS_REJECTED
    assert resp.retriable
    assert "circuit breaker open" in resp.reason
    assert "bfs/g" in resp.reason
    srv.stats.verify()                          # rejected is accounted
    assert srv.stats.tenant("acme").rejected == 1
    assert srv.submit(Request(1, "acme", "sssp", "g", root=1)) is None
    assert srv.queue_depth == 1                 # breaker charged no budget


def test_inflight_demand_drops_zeroed_keys_and_catches_double_finish():
    """Regression: zeroed _inflight_demand slots must be deleted (a
    resident server leaked one per tenant ever seen), and a negative
    residue — the double-_finish signature — must assert loudly."""
    from repro.serve import ProgramServer, Request
    from repro.serve.engine import Response, STATUS_OK
    from repro.sparse import datasets

    class _FakeMesh:
        devices = np.zeros(4)

    g = datasets.erdos_renyi(32, avg_degree=3, seed=7)
    srv = ProgramServer(_FakeMesh(), {"g": g}, batch_width=2)
    assert srv.submit(Request(0, "acme", "bfs", "g", root=1)) is None
    entry = srv._former.form(lambda _e: 2)[0]
    srv._finish(entry, Response(0, "acme", STATUS_OK))
    assert srv._inflight_demand == {}           # no leaked zero slot
    with pytest.raises(AssertionError, match="double _finish"):
        srv._finish(entry, Response(0, "acme", STATUS_OK))


def test_run_training_uses_shared_retry_ledger(tmp_path):
    """The dedupe satellite: run_training's restart counting now rides
    RetryLedger — same grant rule as serving (n <= max_retries), same
    result surface as before."""
    import jax.numpy as jnp
    from repro.runtime.fault_tolerance import (FailurePlan, InjectedFailure,
                                               run_training)

    def init_state():
        return {"w": jnp.array([4.0])}, {"m": jnp.array([0.0])}

    def step_fn(params, opt_state, batch):
        params = {"w": params["w"] - 0.1 * batch}
        return params, opt_state, {"loss": float(jnp.sum(params["w"]))}

    res = run_training(step_fn, init_state, lambda s: jnp.array(1.0),
                       total_steps=12, ckpt_dir=str(tmp_path / "a"),
                       ckpt_every=4, max_restarts=3,
                       failure_plan=FailurePlan(at_steps={5: "ici-timeout",
                                                          9: "preemption"}))
    assert res.final_step == 12 and res.restarts == 2
    assert len(res.metrics_history) == 12
    with pytest.raises(InjectedFailure):
        run_training(step_fn, init_state, lambda s: jnp.array(1.0),
                     total_steps=6, ckpt_dir=str(tmp_path / "b"),
                     ckpt_every=100, max_restarts=1,
                     failure_plan=FailurePlan(
                         at_steps={0: "a", 1: "b", 2: "c"}))


# ---------------------------------------------------------------------------
# Part B: chaos parity under shard_map (subprocess, 8 fake devices)
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import json
import time
import numpy as np
from repro.core.compat import make_mesh
from repro.sparse import datasets, program
from repro.serve import (ProgramServer, Request, ServeFailurePlan,
                         ServeOptions, STATUS_OK)

res = {}
g = datasets.wiki_like(192, avg_degree=6, seed=3)
mesh = make_mesh((8,), ('data',))
WIDTH = 4
TENANTS = ['acme', 'globex', 'initech', 'umbrella']
# 8 sssp then 8 bfs: two fused batches per class, deterministic order
reqs = ([Request(i, TENANTS[i % 4], 'sssp', 'wiki', root=(i * 13) % g.n)
         for i in range(8)]
        + [Request(8 + i, TENANTS[i % 4], 'bfs', 'wiki',
                   root=(i * 7) % g.n) for i in range(8)])

def _sig(rs):
    return [(r.req_id, r.tenant, r.status, r.retriable,
             None if r.result is None else r.result.tobytes())
            for r in sorted(rs, key=lambda r: r.req_id)]

def _ledger(s):
    return {t: (v.submitted, v.served, v.rejected, v.failed, v.retries)
            for t, v in s.stats.tenants.items()}

# ---- fault-free reference on the full 8-device fabric ------------------
program.clear_cache()
ref = ProgramServer(mesh, {'wiki': g}, batch_width=WIDTH)
ref.prewarm(('bfs', 'sssp'))
ref_resps = ref.run(reqs)
ref.stats.verify()
ref_sig = _sig(ref_resps)
res['ref'] = {'statuses': [r.status for r in ref_resps],
              'launches': ref.stats.launches}

# ---- chaos parity: launch fault @0, device fault @2, host loss @4 ------
# Expected walk (depth 1, FIFO, breaker threshold 1, zero backoff):
#   idx0 sssp A: injected launch fault -> breaker sssp/wiki OPENS,
#        riders requeued head-of-queue (4 retries)
#   idx1 sssp A again as the half-open probe: OK -> breaker CLOSES
#   idx2 sssp B: injected device fault surfacing at harvest -> OPENS
#   idx3 sssp B probe: OK -> CLOSES
#   idx4 bfs C: host loss BEFORE launch -> fabric 8 -> 4, riders
#        requeued, ONLY bfs/wiki (the class with queued traffic)
#        re-prewarms on the survivors; relaunch consumes idx4
#   idx5 bfs D: OK on the shrunken fabric
program.clear_cache()
plan = ServeFailurePlan(at={0: 'launch', 2: 'device', 4: 'host_loss'},
                        keep_devices=4)
srv = ProgramServer(mesh, {'wiki': g}, batch_width=WIDTH,
                    serve_options=ServeOptions(max_retries=3,
                                               breaker_threshold=1),
                    failure_plan=plan)
srv.prewarm(('bfs', 'sssp'))
t0 = program.cache_stats()
resps = srv.run(reqs)
t1 = program.cache_stats()
srv.stats.verify()
snap = srv.stats.snapshot()
res['chaos'] = {
    'n_responses': len(resps),
    'statuses': [r.status for r in resps],
    'sig_equal': _sig(resps) == ref_sig,
    'per_req_retries': [r.retries for r in resps],
    'ledger': _ledger(srv),
    'retries': snap['retries'],
    'breaker_opens': snap['breaker_opens'],
    'breaker_closes': snap['breaker_closes'],
    'host_losses': snap['host_losses'],
    'plan_exhausted': plan.exhausted,
    'fired': plan.fired,
    'n_devices_after': srv.fabric.n_devices,
    'total_traces': program.cache_stats()['kernel_traces'],
    'stream_traces': t1['kernel_traces'] - t0['kernel_traces'],
    'inflight_demand': srv._inflight_demand,
    'retry_ledger_entries': len(srv._retry.attempts),
    'depth_samples': len(srv.stats.queue_depth_samples),
    'min_depth_sample': min(srv.stats.queue_depth_samples),
    'max_queue_depth': snap['max_queue_depth'],
}

# ---- host loss with a NON-empty inflight window (depth 2) --------------
# NOTE: the compile cache deliberately carries over from scenario 1 —
# bfs@4dev is already cached there, so THIS shrink re-prewarms with
# zero new traces (prewarm-or-cached, never a forced re-trace)
mesh_b = make_mesh((8,), ('data',))
plan_b = ServeFailurePlan(at={1: 'host_loss'}, keep_devices=4)
srv_b = ProgramServer(mesh_b, {'wiki': g}, batch_width=WIDTH,
                      serve_options=ServeOptions(inflight_depth=2,
                                                 max_retries=1),
                      failure_plan=plan_b)
srv_b.prewarm(('bfs',), ('wiki',))
t0 = program.cache_stats()
b_reqs = [Request(i, TENANTS[i % 4], 'bfs', 'wiki', root=(i * 7) % g.n)
          for i in range(8)]
b_resps = srv_b.run(b_reqs)
t1 = program.cache_stats()
srv_b.stats.verify()
b_ref = {r.req_id: (None if r.result is None else r.result.tobytes())
         for r in ref_resps if r.req_id >= 8}
res['window_loss'] = {
    'statuses': [r.status for r in b_resps],
    'identical': all(b_resps[i].result.tobytes() == b_ref[8 + i]
                     for i in range(8)),
    'retries': srv_b.stats.retries,
    'host_losses': srv_b.stats.host_losses,
    'n_devices_after': srv_b.fabric.n_devices,
    # bfs@4dev was traced by scenario 1's re-prewarm into the SAME
    # process-wide cache: this shrink re-prewarms without re-tracing
    'stream_traces': t1['kernel_traces'] - t0['kernel_traces'],
}

# ---- deadline: fails non-retriably with a distinct reason --------------
srv_d = ProgramServer(mesh, {'wiki': g}, batch_width=WIDTH,
                      serve_options=ServeOptions(deadline_s=1e-6))
d_resps = srv_d.run([Request(i, 't', 'bfs', 'wiki', root=i)
                     for i in range(2)])
time.sleep(0.001)
srv_d.stats.verify()
res['deadline'] = {
    'statuses': [r.status for r in d_resps],
    'retriable': [r.retriable for r in d_resps],
    'reasons': [r.reason for r in d_resps],
    'ledger': _ledger(srv_d)}

# ---- retry budget exhausted: terminal failure names the count ----------
srv_x = ProgramServer(mesh, {'wiki': g}, batch_width=WIDTH,
                      serve_options=ServeOptions(max_retries=2),
                      failure_plan=ServeFailurePlan(
                          at={0: 'launch', 1: 'launch', 2: 'launch'}))
x_resps = srv_x.run([Request(i, TENANTS[i], 'bfs', 'wiki', root=1 + i)
                     for i in range(4)])
srv_x.stats.verify()
res['exhausted'] = {
    'statuses': [r.status for r in x_resps],
    'retriable': [r.retriable for r in x_resps],
    'reasons': [r.reason for r in x_resps],
    'per_req_retries': [r.retries for r in x_resps],
    'retries': srv_x.stats.retries,
    'ledger': _ledger(srv_x)}

# ---- backoff really waits (deterministic jitter, no random) ------------
srv_w = ProgramServer(mesh, {'wiki': g}, batch_width=WIDTH,
                      serve_options=ServeOptions(max_retries=1,
                                                 backoff_base_s=0.05),
                      failure_plan=ServeFailurePlan(at={0: 'launch'}))
tw0 = time.perf_counter()
w_resps = srv_w.run([Request(i, TENANTS[i], 'bfs', 'wiki', root=1)
                     for i in range(4)])
elapsed = time.perf_counter() - tw0
srv_w.stats.verify()
res['backoff'] = {'statuses': [r.status for r in w_resps],
                  'elapsed': elapsed, 'retries': srv_w.stats.retries}

# ---- MoE lane mid-stream fault: launch-order streaming intact ----------
class StubMoE:
    '''Engine-facing MoEService contract (batch/demand/prewarm/dispatch)
    without a model: dispatch doubles the payload. The injected fault
    fires in _step_moe BEFORE dispatch, which is the seam under test.'''
    def __init__(self, batch=2):
        self.batch = batch
        self.calls = 0
    def demand(self, payload):
        return int(payload.shape[0])
    def prewarm(self, mesh):
        pass
    def dispatch(self, payloads, mesh):
        self.calls += 1
        return [p * 2.0 for p in payloads], self.calls > 1

payloads = [np.full((4, 8), 1.0 + i, np.float32) for i in range(2)]
m_reqs = ([Request(i, f'a{i}', 'bfs', 'wiki', root=1) for i in range(4)]
          + [Request(4 + i, f'm{i}', 'moe', payload=payloads[i])
             for i in range(2)]
          + [Request(6 + i, f'b{i}', 'bfs', 'wiki', root=2)
             for i in range(4)])
for retries, key in ((0, 'moe_terminal'), (1, 'moe_retried')):
    stub = StubMoE()
    srv_m = ProgramServer(mesh, {'wiki': g}, batch_width=WIDTH, moe=stub,
                          serve_options=ServeOptions(max_retries=retries),
                          failure_plan=ServeFailurePlan(at={1: 'moe'}))
    for r in m_reqs:
        assert srv_m.submit(r) is None
    drained = srv_m.drain()          # launch order, NOT req_id-sorted
    srv_m.stats.verify()
    ok_moe = [r for r in drained if r.tenant.startswith('m')
              and r.status == STATUS_OK]
    res[key] = {
        'drain_ids': [r.req_id for r in drained],
        'statuses_by_id': [r.status for r in
                           sorted(drained, key=lambda r: r.req_id)],
        'reasons': [r.reason for r in drained if r.status != STATUS_OK],
        'moe_results_doubled': all(
            np.array_equal(r.result, payloads[r.req_id - 4] * 2.0)
            for r in ok_moe),
        'dispatch_calls': stub.calls,
        'retries': srv_m.stats.retries,
        'ledger': _ledger(srv_m)}

print('RESULT ' + json.dumps(res))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1500)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_chaos_parity_every_request_served_bit_identical(results):
    """The acceptance contract: one response per request, all OK, every
    result byte-equal to the fault-free run — across a launch fault, a
    device fault and a host loss."""
    assert results["ref"]["statuses"] == ["ok"] * 16
    c = results["chaos"]
    assert c["n_responses"] == 16
    assert c["statuses"] == ["ok"] * 16
    assert c["sig_equal"]                      # bit-identical survivors
    assert c["plan_exhausted"]
    assert [k for _i, k in c["fired"]] == ["launch", "device", "host_loss"]
    assert c["n_devices_after"] == 4           # the shrink really happened


def test_chaos_parity_ledger_and_retry_accounting(results):
    c = results["chaos"]
    # every rider of the three poisoned batches retried exactly once
    assert c["retries"] == 12
    assert c["per_req_retries"] == [1] * 12 + [0] * 4
    for t, (sub, served, rej, failed, retries) in c["ledger"].items():
        assert (sub, served, rej, failed) == (4, 4, 0, 0), t
        assert retries == 3, t                 # 3 poisoned batches / 4 ten.
    # terminal outcomes emptied the retry ledger and the demand tracker
    assert c["retry_ledger_entries"] == 0
    assert c["inflight_demand"] == {}


def test_chaos_breaker_opens_and_recloses(results):
    c = results["chaos"]
    assert c["breaker_opens"] == 2             # launch fault + device fault
    assert c["breaker_closes"] == 2            # both half-open probes OK
    assert c["host_losses"] == 1


def test_chaos_zero_extra_retraces_for_unaffected_classes(results):
    """After the host loss only bfs/wiki (the class with queued traffic)
    re-prewarms on the shrunken fabric: 2 prewarm traces + 1 re-prewarm
    trace, sssp/wiki NEVER re-traced."""
    c = results["chaos"]
    assert c["total_traces"] == 3
    assert c["stream_traces"] == 1             # exactly the bfs re-prewarm


def test_chaos_queue_depth_trace_observed_in_step(results):
    """The S2 fix: formation-time observations make the drawdown
    visible — the trace must reach 0 during drain, not only rise."""
    c = results["chaos"]
    assert c["min_depth_sample"] == 0
    assert c["depth_samples"] > 16             # submits + formations
    assert c["max_queue_depth"] >= 12


def test_host_loss_poisons_and_relaunches_inflight_window(results):
    w = results["window_loss"]
    assert w["statuses"] == ["ok"] * 8
    assert w["identical"]                      # bit-identical on 4 devices
    assert w["retries"] == 8                   # window riders + formed batch
    assert w["host_losses"] == 1
    assert w["n_devices_after"] == 4
    assert w["stream_traces"] == 0             # bfs@4dev already cached


def test_deadline_fails_nonretriably_with_distinct_reason(results):
    d = results["deadline"]
    assert d["statuses"] == ["failed"] * 2
    assert d["retriable"] == [False] * 2
    assert all("deadline 1e-06s exceeded" in r for r in d["reasons"])
    for sub, served, rej, failed, retries in d["ledger"].values():
        assert (sub, served, rej, failed, retries) == (2, 0, 0, 2, 0)


def test_retry_budget_exhaustion_names_the_count(results):
    x = results["exhausted"]
    assert x["statuses"] == ["failed"] * 4
    assert x["retriable"] == [False] * 4
    assert all("launch fault at launch 2" in r
               and "[failed after 2 retries]" in r for r in x["reasons"])
    assert x["per_req_retries"] == [2] * 4
    assert x["retries"] == 8                   # 2 granted retries x 4 riders
    for sub, served, rej, failed, retries in x["ledger"].values():
        assert (sub, served, rej, failed, retries) == (1, 0, 0, 1, 2)


def test_backoff_actually_waits(results):
    b = results["backoff"]
    assert b["statuses"] == ["ok"] * 4
    assert b["retries"] == 4
    assert b["elapsed"] >= 0.05                # base delay really elapsed


def test_moe_midstream_fault_streams_in_launch_order(results):
    """The S3 satellite: an MoE batch failing between two healthy graph
    batches neither reorders the stream nor corrupts the ledger."""
    t = results["moe_terminal"]
    # drain order == launch order: graph batch, MoE batch, graph batch
    assert t["drain_ids"] == [0, 1, 2, 3, 4, 5, 6, 7, 8, 9]
    assert t["statuses_by_id"] == (["ok"] * 4 + ["failed"] * 2 + ["ok"] * 4)
    assert all("moe fault at launch 1 (moe)" in r for r in t["reasons"])
    assert t["dispatch_calls"] == 0            # fault fired before dispatch
    assert t["retries"] == 0
    for tenant, (sub, served, rej, failed, _r) in t["ledger"].items():
        expect = (1, 0, 0, 1) if tenant.startswith("m") else (1, 1, 0, 0)
        assert (sub, served, rej, failed) == expect, tenant


def test_moe_midstream_fault_retried_to_success(results):
    r = results["moe_retried"]
    # the retried MoE batch relaunches right after its failure — still
    # in launch order, before the trailing graph batch
    assert r["drain_ids"] == [0, 1, 2, 3, 4, 5, 6, 7, 8, 9]
    assert r["statuses_by_id"] == ["ok"] * 10
    assert r["moe_results_doubled"]
    assert r["dispatch_calls"] == 1
    assert r["retries"] == 2
    for _t, (sub, served, rej, failed, _r2) in r["ledger"].items():
        assert (sub, served, rej, failed) == (1, 1, 0, 0)
