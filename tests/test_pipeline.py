"""The pipelined round shape and the LaunchOptions launch surface.

Part A — in-process (1 device): the ``resolve_options`` deprecation shim
(legacy kwargs and ``options=`` resolve to THE SAME compile-cache entry,
the warning fires once per process, conflicts raise), ``round_mode`` /
``route_impl`` land in the compile-cache key, every entrypoint accepts
``options=``, ``local_route_reduce`` is bit-identical to the two-pass
``bucket`` + ``reduce_received`` shape, the round-level route_compare
gate, and a pipelined ``ProgramServer`` serves identically.

Part B (subprocess, 8 fake host devices) — the bit-identity contract of
``round_mode="pipelined"``: for every iterative program, flat AND
pod/portal, loose AND overflowing caps, 1/2/4/8 devices, the pipelined
executable's results, rounds, and per-round message/drop streams equal
lockstep's exactly — and the UNCHANGED analytic twin
(``program_app_stats``) still matches the pipelined run.
"""
import json
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

ITER_APPS = ("bfs", "sssp", "wcc", "pagerank", "kcore")


# ---------------------------------------------------------------------------
# Part A: the launch surface (1 device, in-process)
# ---------------------------------------------------------------------------

def _tiny():
    from repro.sparse import datasets
    return datasets.wiki_like(96, avg_degree=4, seed=11)


def _mesh1():
    from repro.core.compat import make_mesh
    return make_mesh((1,), ("data",))


def test_legacy_kwargs_and_options_share_one_cache_entry():
    """The shim is an alias, not a fork: same key, same jitted callable,
    bit-identical result."""
    from repro.core import fabric as fab_mod
    from repro.sparse import LaunchOptions, options as opt_mod, program
    from repro.sparse.jax_apps import dcra_bfs
    g, mesh = _tiny(), _mesh1()
    program.clear_cache()
    opt_mod._WARNED[0] = False
    fab_mod._WARNED[0] = True   # isolate the kwarg shim from the mesh shim
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        d1, s1 = dcra_bfs(g, 0, mesh, capacity_factor=2.0)
        d1b, _ = dcra_bfs(g, 0, mesh, capacity_factor=2.0)
    legacy_warns = [x for x in w if issubclass(x.category,
                                               DeprecationWarning)]
    assert len(legacy_warns) == 1            # once per process, not per call
    after_legacy = program.cache_stats()
    d2, s2 = dcra_bfs(g, 0, mesh,
                      options=LaunchOptions(capacity_factor=2.0))
    after_options = program.cache_stats()
    assert after_options["misses"] == after_legacy["misses"]   # same key
    assert after_options["hits"] == after_legacy["hits"] + 1
    assert np.array_equal(np.asarray(d1), np.asarray(d2))
    assert np.array_equal(np.asarray(d1), np.asarray(d1b))
    assert s1.rounds == s2.rounds and s1.total_drops == s2.total_drops


def test_round_mode_and_route_impl_are_cache_key_dimensions():
    from repro.sparse import LaunchOptions, program
    from repro.sparse.jax_apps import dcra_bfs
    g, mesh = _tiny(), _mesh1()
    program.clear_cache()
    dcra_bfs(g, 0, mesh)
    assert program.cache_stats()["misses"] == 1
    dcra_bfs(g, 0, mesh, options=LaunchOptions(round_mode="pipelined"))
    assert program.cache_stats()["misses"] == 2
    dcra_bfs(g, 0, mesh, options=LaunchOptions(round_mode="pipelined"))
    assert program.cache_stats()["misses"] == 2    # pipelined entry reused
    dcra_bfs(g, 0, mesh, options=LaunchOptions(route_impl="sort"))
    assert program.cache_stats()["misses"] == 3


def test_option_conflicts_raise():
    from repro.sparse import LaunchOptions
    from repro.sparse.jax_apps import dcra_bfs, dcra_spmv
    g = _tiny()
    with pytest.raises(ValueError, match="conflicts"):
        dcra_bfs(g, 0, mesh=None, cap=4, capacity_factor=2.0)
    with pytest.raises(ValueError, match="conflicts"):
        dcra_spmv(g, np.ones(g.n), mesh=None, cap=4, config="auto")
    with pytest.raises(ValueError, match="conflicts"):
        dcra_bfs(g, 0, mesh=None, options=LaunchOptions(), cap=4)
    with pytest.raises(ValueError, match="round_mode"):
        dcra_bfs(g, 0, mesh=None, round_mode="warp")
    with pytest.raises(ValueError, match="route_impl"):
        LaunchOptions(route_impl="bogus").resolve()
    with pytest.raises(TypeError, match="unknown"):
        from repro.sparse.options import resolve_options
        resolve_options(None, caps=4)


def test_every_entrypoint_accepts_options():
    """All seven dcra_* apps + run_program + dcra_scatter take options=
    and agree bitwise with their legacy-kwarg spelling."""
    from repro.sparse import LaunchOptions, jax_apps
    from repro.sparse import datasets
    from repro.sparse.jax_apps import PROGRAMS, dcra_scatter, run_program
    import jax.numpy as jnp
    g, mesh = _tiny(), _mesh1()
    x = np.random.default_rng(0).random(g.n)
    els = datasets.histogram_data(512, 16, seed=4)
    opts = LaunchOptions(capacity_factor=2.0)
    calls = {
        "bfs": lambda **kw: jax_apps.dcra_bfs(g, 0, mesh, **kw),
        "sssp": lambda **kw: jax_apps.dcra_sssp(g, 0, mesh, **kw),
        "wcc": lambda **kw: jax_apps.dcra_wcc(g, mesh, **kw),
        "pagerank": lambda **kw: jax_apps.dcra_pagerank(
            g, mesh, iters=3, **kw),
        "kcore": lambda **kw: jax_apps.dcra_kcore(g, 3, mesh, **kw),
        "spmv": lambda **kw: jax_apps.dcra_spmv(g, x, mesh, **kw),
        "histogram": lambda **kw: jax_apps.dcra_histogram(
            els, 16, mesh, **kw),
    }
    assert set(calls) == set(PROGRAMS)
    for app, call in calls.items():
        got, _ = call(options=opts)
        want, _ = call(capacity_factor=2.0)
        assert np.array_equal(np.asarray(got), np.asarray(want)), app
    r1, _ = run_program(PROGRAMS["bfs"], g, mesh, options=opts,
                        params={"root": 0})
    r2, _ = run_program(PROGRAMS["bfs"], g, mesh, capacity_factor=2.0,
                        params={"root": 0})
    assert np.array_equal(np.asarray(r1), np.asarray(r2))
    dest = jnp.asarray(np.arange(32) % 8)
    vals = jnp.ones(32, jnp.float32)
    y1, _ = dcra_scatter(dest, vals, 8, mesh, options=opts)
    y2, _ = dcra_scatter(dest, vals, 8, mesh, capacity_factor=2.0)
    assert np.array_equal(np.asarray(y1), np.asarray(y2))


@pytest.mark.parametrize("op", ["min", "store"])
def test_local_route_reduce_matches_two_pass_shape(op):
    """The 1-device pipelined fold == bucket + reduce_received, bitwise,
    including the drop count, under overflowing caps."""
    import jax.numpy as jnp
    from repro.core.routing import (bucket, local_route_reduce,
                                    reduce_received)
    rng = np.random.default_rng(5)
    n, s, cap, n_local = 512, 8, 16, 64        # 512 >> s*cap: drops
    dest = jnp.asarray(rng.integers(0, s, n), jnp.int32)
    valid = jnp.asarray(rng.random(n) < 0.8)
    vals = jnp.asarray(rng.random(n), jnp.float32)
    slots = jnp.asarray(rng.integers(0, n_local, n), jnp.int32)
    xb, (slot_b,), _, nd_ref = bucket(vals[:, None], dest, valid, [slots],
                                      s, cap)
    want = reduce_received(slot_b, xb[:, 0], n_local, op)
    got, nd = local_route_reduce(vals, slots, dest, valid, s, cap,
                                 n_local, op)
    assert int(nd) == int(nd_ref) and int(nd) > 0
    assert np.array_equal(np.asarray(want), np.asarray(got))
    with pytest.raises(ValueError):
        local_route_reduce(vals, slots, dest, valid, s, cap, n_local,
                           "add")


def test_route_compare_gates_round_cells():
    from repro.dse.route_compare import compare
    rcell = {"n": 131072, "s": 128, "cap": 2048, "rounds": 6,
             "round_speedup": {"onehot": 1.2, "sort": 1.5, "pallas": 2.3}}
    old = {"schema": "dcra-route-bench/v2", "cells": [
        {"n": 1, "s": 1, "speedup_vs_onehot": {"onehot": 1.0}}],
        "round_cells": [rcell]}
    f, _ = compare(old, old)
    assert not f
    worse = json.loads(json.dumps(old))
    worse["round_cells"][0]["round_speedup"]["pallas"] = 1.0   # -57%
    f, _ = compare(old, worse)
    assert any("round" in x and "REGRESSED" in x for x in f)
    gone = json.loads(json.dumps(old))
    gone["round_cells"] = []
    f, _ = compare(old, gone)
    assert any("round_cells" in x for x in f)
    v1 = {"schema": "dcra-route-bench/v1", "cells": old["cells"]}
    f, notes = compare(v1, old)                # v1 baseline: report, no gate
    assert not f and any("not gated" in x for x in notes)


def test_pipelined_program_server_serves_identically():
    from repro.serve import LaunchOptions, ProgramServer, Request
    g, mesh = _tiny(), _mesh1()
    reqs = [Request(req_id=i, tenant=f"t{i % 2}", program=p, graph="g",
                    root=i % g.n)
            for i, p in enumerate(("bfs", "sssp", "bfs", "sssp"))]
    base = ProgramServer(mesh, {"g": g}).run(list(reqs))
    pipe = ProgramServer(
        mesh, {"g": g},
        options=LaunchOptions(round_mode="pipelined")).run(list(reqs))
    assert len(base) == len(pipe) == len(reqs)
    for a, b in zip(base, pipe):
        assert a.status == b.status and a.rounds == b.rounds
        assert np.array_equal(np.asarray(a.result), np.asarray(b.result))
    with pytest.raises(ValueError, match="conflicts"):
        ProgramServer(mesh, {"g": g}, axis="model",
                      options=LaunchOptions())


# ---------------------------------------------------------------------------
# Part B: pipelined == lockstep under shard_map (subprocess)
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import json
import jax
import numpy as np
from repro.core.compat import make_mesh
from repro.sparse import datasets
from repro.sparse.jax_apps import PROGRAMS
from repro.sparse.program import program_app_stats, run_program

g = datasets.wiki_like(256, avg_degree=8, seed=7)
PARAMS = {'bfs': {'root': 0}, 'sssp': {'root': 0}, 'wcc': {},
          'pagerank': {'damping': 0.85, 'iters': 4}, 'kcore': {'k': 8.0}}
ITER = tuple(PARAMS)

def pair(app, mesh, n_dev, tag, twin_kw, **kw):
    r_l, s_l = run_program(PROGRAMS[app], g, mesh, params=PARAMS[app],
                           round_mode='lockstep', **kw)
    r_p, s_p = run_program(PROGRAMS[app], g, mesh, params=PARAMS[app],
                           round_mode='pipelined', **kw)
    leaves = zip(jax.tree_util.tree_leaves(r_l),
                 jax.tree_util.tree_leaves(r_p))
    twin = program_app_stats(PROGRAMS[app], g, n_dev, params=PARAMS[app],
                             **twin_kw)
    return {'app': app, 'n_dev': n_dev, 'tag': tag,
            'results_equal': all(np.array_equal(np.asarray(a),
                                                np.asarray(b))
                                 for a, b in leaves),
            'rounds_equal': s_l.rounds == s_p.rounds,
            'streams_equal': (np.array_equal(s_l.messages, s_p.messages)
                              and np.array_equal(s_l.drops, s_p.drops)),
            'twin_ok': (twin.rounds == s_p.rounds
                        and np.array_equal(twin.messages, s_p.messages)
                        and np.array_equal(twin.drops, s_p.drops)),
            'drops': int(s_p.total_drops), 'rounds': int(s_p.rounds)}

cases = []
for n_dev in (1, 2, 4, 8):
    mesh = make_mesh((n_dev,), ('data',))
    apps = ITER if n_dev in (1, 8) else ('bfs',)
    for app in apps:
        cases.append(pair(app, mesh, n_dev, 'cap2', {'cap': 2}, cap=2))
        if n_dev == 8:
            cases.append(pair(app, mesh, n_dev, 'cf4',
                              {'capacity_factor': 4.0},
                              capacity_factor=4.0))
hier = make_mesh((2, 4), ('pod', 'data'))
for app, cf in (('bfs', 0.25), ('bfs', 4.0), ('pagerank', 0.5)):
    cases.append(pair(app, hier, 8, f'pod-cf{cf}',
                      {'capacity_factor': cf, 'pods': (4, 2)},
                      pod_axis='pod', capacity_factor=cf))
print('RESULT ' + json.dumps(cases))
"""


@pytest.fixture(scope="module")
def cases():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1500)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


@pytest.mark.parametrize("app", ITER_APPS)
def test_pipelined_is_bit_identical_to_lockstep(cases, app):
    mine = [c for c in cases if c["app"] == app]
    assert mine, app
    bad = [c for c in mine if not (c["results_equal"] and c["rounds_equal"]
                                   and c["streams_equal"])]
    assert not bad, bad


@pytest.mark.parametrize("app", ITER_APPS)
def test_unchanged_twin_matches_pipelined(cases, app):
    """program_app_stats needed NO pipelined variant — the analytic twin
    models rounds, and the pipeline only reshapes their execution."""
    bad = [c for c in cases if c["app"] == app and not c["twin_ok"]]
    assert not bad, bad


def test_tight_caps_drop_under_pipelining(cases):
    """cap=2 must overflow in the pipelined shape too, or the drop-stream
    agreement above is vacuous."""
    for app in ITER_APPS:
        tight = [c for c in cases if c["app"] == app and c["tag"] == "cap2"]
        assert any(c["drops"] > 0 for c in tight), (app, tight)


def test_pod_portal_covered_both_modes(cases):
    pods = [c for c in cases if c["tag"].startswith("pod")]
    assert {c["app"] for c in pods} == {"bfs", "pagerank"}
    assert all(c["results_equal"] and c["streams_equal"] and c["twin_ok"]
               for c in pods), pods
