"""The six paper apps vs pure-numpy oracles — exact results + stats sanity."""
import numpy as np
import pytest

from repro.core import EngineConfig, TaskEngine, TileGrid
from repro.sparse import apps, datasets, ref


@pytest.fixture(scope="module")
def graph():
    return datasets.rmat(10, edge_factor=8, seed=2)


@pytest.fixture()
def engine(graph):
    grid = TileGrid(8, 8, "hier_torus", die_rows=4, die_cols=4)
    return TaskEngine(EngineConfig(grid=grid), graph.n)


def test_bfs(graph, engine):
    d, stats = apps.bfs(engine, graph, 0)
    assert np.array_equal(d, ref.bfs_ref(graph, 0))
    assert stats.total_messages > 0 and stats.total_hops > 0


def test_sssp(graph, engine):
    d, _ = apps.sssp(engine, graph, 0)
    assert np.allclose(d, ref.sssp_ref(graph, 0))


def test_pagerank(graph, engine):
    d, stats = apps.pagerank(engine, graph, iters=5)
    assert np.allclose(d, ref.pagerank_ref(graph, iters=5), atol=1e-12)
    assert any(r.barrier for r in stats.rounds)   # epochs marked


def test_wcc(graph, engine):
    d, _ = apps.wcc(engine, graph)
    assert np.array_equal(d, ref.wcc_ref(graph))


def test_spmv(graph, engine):
    x = np.random.default_rng(0).random(graph.n)
    y, _ = apps.spmv(engine, graph, x)
    assert np.allclose(y, ref.spmv_ref(graph, x))


def test_histogram(engine):
    els = datasets.histogram_data(1 << 12, 64)
    h, _ = apps.histogram(engine, els, 64)
    assert np.array_equal(h, ref.histogram_ref(els, 64))


def test_wiki_like_shape():
    g = datasets.wiki_like(512, avg_degree=8)
    assert g.n == 512 and g.nnz > 512
    # heavier-tailed in-degree than out-degree
    indeg = g.transpose().degrees()
    assert indeg.max() > np.median(indeg) * 4
