"""Differential tests: all SEVEN apps (the paper's six + k-core) on the
distributed owner-routed path vs the numpy oracles in ``sparse/ref.py``.

Coverage matrix (subprocess, 8 fake host devices):
  * Erdős–Rényi + power-law (wiki-like) graphs, 8 devices, all apps;
  * a disconnected graph for BFS (unreachable -> -1) and WCC (two
    components keep distinct labels);
  * a second device count (4) over ER for all apps — the result must
    be layout-independent.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import json
import numpy as np
from repro.core.compat import make_mesh
from repro.sparse import datasets, ref
from repro.sparse.jax_apps import (dcra_bfs, dcra_histogram, dcra_kcore,
                                   dcra_pagerank, dcra_spmv, dcra_sssp,
                                   dcra_wcc)

def run_six(g, mesh, tag, res):
    x = np.random.default_rng(0).random(g.n)
    y, drops = dcra_spmv(g, x, mesh, capacity_factor=3.0)
    res[f'{tag}/spmv'] = {
        'err': float(np.max(np.abs(np.asarray(y) - ref.spmv_ref(g, x)))
                     / max(1.0, float(np.abs(ref.spmv_ref(g, x)).max()))),
        'drops': int(drops), 'rounds': 1}
    els = datasets.histogram_data(1 << 12, 64, seed=4)
    h, d = dcra_histogram(els, 64, mesh, capacity_factor=3.0)
    res[f'{tag}/histogram'] = {
        'err': float(np.max(np.abs(np.asarray(h) -
                                   ref.histogram_ref(els, 64)))),
        'drops': int(d), 'rounds': 1}
    d_, st = dcra_bfs(g, 0, mesh)
    res[f'{tag}/bfs'] = {
        'err': float(np.max(np.abs(d_ - ref.bfs_ref(g, 0)))),
        'drops': st.total_drops, 'rounds': st.rounds,
        'messages': st.total_messages}
    s_, st = dcra_sssp(g, 0, mesh)
    want = ref.sssp_ref(g, 0)
    both = np.where(np.isfinite(want), np.abs(s_ - want),
                    (~np.isinf(s_)).astype(float))
    res[f'{tag}/sssp'] = {'err': float(np.max(both)),
                          'drops': st.total_drops, 'rounds': st.rounds}
    p_, st = dcra_pagerank(g, mesh)
    res[f'{tag}/pagerank'] = {
        'err': float(np.max(np.abs(p_ - ref.pagerank_ref(g)))
                     / ref.pagerank_ref(g).max()),
        'drops': st.total_drops, 'rounds': st.rounds}
    w_, st = dcra_wcc(g, mesh)
    res[f'{tag}/wcc'] = {
        'err': float(np.max(np.abs(w_ - ref.wcc_ref(g)))),
        'drops': st.total_drops, 'rounds': st.rounds}
    k_, st = dcra_kcore(g, 12, mesh)
    res[f'{tag}/kcore'] = {
        'err': float(np.max(np.abs(k_ - ref.kcore_ref(g, 12)))),
        'drops': st.total_drops, 'rounds': st.rounds}

res = {}
mesh8 = make_mesh((8,), ('data',))
mesh4 = make_mesh((4,), ('data',))
er = datasets.erdos_renyi(256, avg_degree=8, seed=5)
pl = datasets.wiki_like(512, avg_degree=8, seed=7)
run_six(er, mesh8, 'er8', res)
run_six(pl, mesh8, 'pl8', res)
run_six(er, mesh4, 'er4', res)

# disconnected graph: BFS from component A, WCC labels
dg = datasets.disconnected_pair(128, avg_degree=6, seed=11)
d_, _ = dcra_bfs(dg, 0, mesh8)
want = ref.bfs_ref(dg, 0)
res['disc/bfs'] = {'err': float(np.max(np.abs(d_ - want))),
                   'unreachable_ok': bool((d_[128:] == -1).all()
                                          and (want[128:] == -1).all()),
                   'drops': 0, 'rounds': 0}
w_, _ = dcra_wcc(dg, mesh8)
wref = ref.wcc_ref(dg)
res['disc/wcc'] = {'err': float(np.max(np.abs(w_ - wref))),
                   'two_components': bool(
                       len(np.unique(wref)) >= 2 and
                       set(np.unique(w_)) == set(np.unique(wref))),
                   'drops': 0, 'rounds': 0}
print('RESULT ' + json.dumps(res))
"""

CASES = [f"{tag}/{app}" for tag in ("er8", "pl8", "er4")
         for app in ("spmv", "histogram", "bfs", "sssp", "pagerank", "wcc",
                     "kcore")]


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


@pytest.mark.parametrize("case", CASES)
def test_app_matches_oracle(results, case):
    r = results[case]
    assert r["err"] < 1e-4, r
    assert r["drops"] == 0, r


@pytest.mark.parametrize("case", [c for c in CASES if "/bfs" in c
                                  or "/sssp" in c or "/wcc" in c])
def test_iterative_apps_report_rounds_and_converge(results, case):
    assert 0 < results[case]["rounds"] < 128


def test_bfs_disconnected_unreachable_is_minus_one(results):
    r = results["disc/bfs"]
    assert r["err"] == 0 and r["unreachable_ok"]


def test_wcc_disconnected_keeps_two_components(results):
    r = results["disc/wcc"]
    assert r["err"] == 0 and r["two_components"]
