"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward + one train step on CPU, asserting shapes + finite values."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import TRAIN_4K
from repro.data.pipeline import synth_batch
from repro.models import build_model
from repro.models.transformer import padded_vocab
from repro.optim.adamw import AdamW, cosine_schedule

SMOKE_SHAPE = dataclasses.replace(TRAIN_4K, global_batch=2, seq_len=64)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    raw = synth_batch(get_config(arch), SMOKE_SHAPE, step=0)
    batch = {}
    for k, v in raw.items():
        if k in ("tokens", "labels"):
            v = np.minimum(v, cfg.vocab_size - 1)
        if k in ("src_embeds", "patch_embeds"):
            v = v[..., :cfg.d_model] if v.shape[-1] >= cfg.d_model else \
                np.repeat(v, -(-cfg.d_model // v.shape[-1]),
                          axis=-1)[..., :cfg.d_model]
        batch[k] = jnp.asarray(v)

    logits, aux = model.forward(params, batch)
    S_out = batch["tokens"].shape[1]
    assert logits.shape == (2, S_out, padded_vocab(cfg.vocab_size))
    assert bool(jnp.isfinite(logits).all())

    opt = AdamW(lr=cosine_schedule())
    state = opt.init(params)
    (loss, metrics), grads = jax.value_and_grad(
        model.loss, has_aux=True)(params, batch)
    new_params, state = opt.update(grads, state, params)
    assert np.isfinite(float(loss))
    # params changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(new_params)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_dims_match_assignment(arch):
    """The FULL configs carry the exact published dimensions."""
    cfg = get_config(arch)
    expect = {
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "rwkv6-7b": (32, 4096, 0, 0, 14336, 65536),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect
    if arch == "mixtral-8x22b":
        assert cfg.moe.num_experts == 8 and cfg.moe.top_k == 2
        assert cfg.sliding_window > 0
    if arch == "olmoe-1b-7b":
        assert cfg.moe.num_experts == 64 and cfg.moe.top_k == 8
    if arch == "zamba2-7b":
        assert cfg.ssm.state_dim == 64
    if arch == "qwen2-vl-7b":
        assert cfg.mrope and cfg.qkv_bias
    if arch == "qwen2-1.5b":
        assert cfg.qkv_bias
