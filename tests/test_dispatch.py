"""DCRA MoE dispatch vs the einsum oracle on a multi-device (fake) mesh.

Runs in a subprocess so XLA_FLAGS device-count doesn't leak into other
tests (smoke tests must see 1 device, per the dry-run spec).
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import json, dataclasses
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.core.compat import make_mesh, set_mesh
from repro.core.dispatch import MeshInfo, moe_dcra
from repro.models.moe import init_moe, moe_einsum

cfg = get_config('olmoe-1b-7b').reduced()
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe,
                                                       capacity_factor=8.0))
params = init_moe(jax.random.key(0), cfg)
x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model))
out_e, aux_e = moe_einsum(params, x, cfg)
cfg8 = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe,
                                                        num_experts=8,
                                                        capacity_factor=8.0))
params8 = init_moe(jax.random.key(2), cfg8)
out_e8, _ = moe_einsum(params8, x, cfg8)

res = {}
mesh = make_mesh((2, 2, 2), ('data', 'expert', 'tp'))
info = MeshInfo(mesh, pod_axis=None)
with set_mesh(mesh):
    out_d, _ = jax.jit(lambda p, x: moe_dcra(p, x, cfg, info))(params, x)
res['single_pod_fused'] = float(jnp.max(jnp.abs(out_d - out_e)))

info_tp = MeshInfo(mesh, pod_axis=None, fuse_tp=False)
with set_mesh(mesh):
    out_t, _ = jax.jit(lambda p, x: moe_dcra(p, x, cfg, info_tp))(params, x)
res['tp_ffn'] = float(jnp.max(jnp.abs(out_t - out_e)))

mesh2 = make_mesh((2, 1, 2, 2), ('pod', 'data', 'expert', 'tp'))
info2 = MeshInfo(mesh2, pod_axis='pod')
assert info2.dispatch_plan(8)[1] is True   # spans pods (hierarchical)
with set_mesh(mesh2):
    out_h, _ = jax.jit(lambda p, x: moe_dcra(p, x, cfg8, info2))(params8, x)
res['hierarchical'] = float(jnp.max(jnp.abs(out_h - out_e8)))

with set_mesh(mesh2):
    g = jax.jit(jax.grad(lambda p, x: moe_dcra(p, x, cfg8, info2)[0].sum()))(
        params8, x)
res['grads_finite'] = all(bool(jnp.isfinite(v).all())
                          for v in jax.tree.leaves(g))
print('RESULT ' + json.dumps(res))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_single_pod_fused_matches_einsum(results):
    assert results["single_pod_fused"] < 1e-4


def test_tp_ffn_path_matches_einsum(results):
    assert results["tp_ffn"] < 1e-4


def test_hierarchical_two_stage_matches_einsum(results):
    assert results["hierarchical"] < 1e-4


def test_gradients_flow(results):
    assert results["grads_finite"]
