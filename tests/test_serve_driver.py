"""The LM serving driver (:func:`repro.launch.serve.serve`) edge cases.

Regression: ``--gen 0`` used to raise ``UnboundLocalError`` — the
``t == P - 1`` branch that initialised the output list never ran when no
tokens were generated. Short prompts (P == 1) exercise the adjacent
boundary where the first decode step already emits a generated token.

Uses a deterministic stub model (predicts ``tok + 1 mod V``) so the test
pins the prefill/decode indexing without paying for a real transformer.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import serve

V = 17


class _StubModel:
    """decode_step predicts (tok + 1) % V with probability one."""

    def init_cache(self, B, L, dtype):
        return jnp.zeros((B, 1), jnp.int32)

    def decode_step(self, params, cache, tok, t):
        logits = jax.nn.one_hot((tok + 1) % V, V, dtype=jnp.float32)
        return logits, cache


def _expected(prompts, gen):
    """Greedy rollout of the stub: last prompt id + 1, +2, ... (mod V)."""
    last = np.asarray(prompts)[:, -1:]
    return (last + np.arange(1, gen + 1)) % V


@pytest.mark.parametrize("B,P", [(2, 4), (1, 1), (3, 1)])
def test_serve_gen_zero_returns_empty(B, P):
    prompts = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P) % V
    out = serve(None, _StubModel(), None, prompts, 0)
    assert out.shape == (B, 0)


@pytest.mark.parametrize("P,gen", [(4, 3), (1, 1), (1, 5), (2, 1)])
def test_serve_short_prompts_greedy_decode(P, gen):
    B = 2
    prompts = (jnp.arange(B * P, dtype=jnp.int32).reshape(B, P) * 3 + 1) % V
    out = serve(None, _StubModel(), None, prompts, gen)
    assert out.shape == (B, gen)
    assert np.array_equal(np.asarray(out), _expected(prompts, gen))
