"""Per-kernel Pallas (interpret mode) vs ref.py oracles: shape/dtype sweeps
plus hypothesis property tests on invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.histogram import histogram_pallas
from repro.kernels.moe_gmm import gmm_pallas
from repro.kernels.spmv import bsr_spmv_pallas, csr_to_bsr, spmv_csr
from repro.sparse import datasets
from repro.sparse import ref as sref


# ---------------------------------------------------------------------------
# histogram
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,bins", [(1024, 256), (4096, 512), (2048, 64),
                                    (8192, 1024)])
def test_histogram_shapes(n, bins):
    els = jax.random.randint(jax.random.key(n), (n,), 0, bins)
    got = histogram_pallas(els, bins)
    want = ref.histogram_ref(els, bins)
    assert (got == want).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), logbins=st.integers(3, 8))
def test_histogram_property(seed, logbins):
    bins = 1 << logbins
    els = jax.random.randint(jax.random.key(seed), (1024,), 0, bins)
    got = histogram_pallas(els, bins)
    assert int(got.sum()) == 1024           # conservation
    assert (got >= 0).all()
    assert (got == ref.histogram_ref(els, bins)).all()


@pytest.mark.parametrize("n,bins", [(997, 61), (1031, 257), (7, 3),
                                    (1024, 509), (1025, 256)])
def test_histogram_non_tile_aligned(n, bins):
    """Prime / off-tile shapes: the tails are padded and sliced, not
    asserted away (regression for the hard tile-divisibility assert)."""
    els = jax.random.randint(jax.random.key(n * bins), (n,), 0, bins)
    got = histogram_pallas(els, bins)
    assert got.shape == (bins,)
    assert int(got.sum()) == n
    assert (got == ref.histogram_ref(els, bins)).all()


def test_histogram_negative_ids_are_no_ops():
    """-1 sentinel entries (task-stream padding) match no bin."""
    els = jnp.asarray([0, -1, 2, -1, 2], jnp.int32)
    got = histogram_pallas(els, 3)
    assert got.tolist() == [1, 0, 2]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,hd,tq,tk,dtype", [
    (128, 64, 64, 64, jnp.float32),
    (256, 64, 128, 64, jnp.float32),
    (256, 128, 64, 128, jnp.float32),
    (128, 64, 64, 64, jnp.bfloat16),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(S, hd, tq, tk, dtype, causal):
    B, H = 2, 2
    ks = jax.random.split(jax.random.key(0), 3)
    q, k, v = (jax.random.normal(kk, (B * H, S, hd)).astype(dtype)
               for kk in ks)
    got = flash_attention_pallas(q, k, v, causal=causal, tq=tq, tk=tk)
    want = ref.flash_attention_ref(
        q.reshape(B, H, S, hd), k.reshape(B, H, S, hd),
        v.reshape(B, H, S, hd), causal=causal).reshape(B * H, S, hd)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    assert jnp.max(jnp.abs(got.astype(jnp.float32)
                           - want.astype(jnp.float32))) < tol


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_flash_attention_rows_sum_property(seed):
    """Attention output of constant-V inputs equals that constant."""
    S, hd = 128, 64
    q = jax.random.normal(jax.random.key(seed), (1, S, hd))
    k = jax.random.normal(jax.random.key(seed + 1), (1, S, hd))
    v = jnp.ones((1, S, hd))
    out = flash_attention_pallas(q, k, v, causal=True, tq=64, tk=64)
    assert jnp.allclose(out, 1.0, atol=1e-5)


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,D,F,E,rt", [
    (256, 64, 128, 2, 128), (512, 32, 256, 4, 128), (384, 128, 128, 3, 128),
])
def test_gmm(T, D, F, E, rt):
    x = jax.random.normal(jax.random.key(0), (T, D))
    w = jax.random.normal(jax.random.key(1), (E, D, F))
    gids = jax.random.randint(jax.random.key(2), (T // rt,), 0, E)
    got = gmm_pallas(x, w, gids, rt=rt)
    want = ref.gmm_ref(x, w, gids)
    assert jnp.max(jnp.abs(got - want)) < 1e-4


# ---------------------------------------------------------------------------
# BSR SpMV
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,Kb,BS,Ncb", [(4, 3, 32, 6), (8, 2, 64, 8),
                                         (2, 5, 128, 4)])
def test_bsr_spmv(R, Kb, BS, Ncb):
    rng = np.random.default_rng(0)
    bc = jnp.asarray(rng.integers(0, Ncb, (R, Kb)), jnp.int32)
    blocks = jnp.asarray(rng.random((R, Kb, BS, BS)), jnp.float32)
    x = jnp.asarray(rng.random(Ncb * BS), jnp.float32)
    got = bsr_spmv_pallas(bc, blocks, x)
    want = ref.bsr_spmv_ref(bc, blocks, x)
    assert jnp.max(jnp.abs(got - want)) < 1e-3


def test_spmv_end_to_end_vs_graph_oracle():
    g = datasets.rmat(9, edge_factor=8, seed=2)
    x = np.random.default_rng(1).random(g.n)
    y = spmv_csr(g, x, bs=64)
    want = sref.spmv_ref(g, x)
    assert np.allclose(np.asarray(y), want, rtol=1e-4, atol=1e-2)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_spmv_linearity_property(seed):
    """SpMV is linear: A(ax) == a * A(x)."""
    g = datasets.rmat(8, edge_factor=4, seed=seed % 100 + 1)
    x = np.random.default_rng(seed).random(g.n)
    y1 = np.asarray(spmv_csr(g, x, bs=64))
    y2 = np.asarray(spmv_csr(g, 2.0 * x, bs=64))
    assert np.allclose(y2, 2.0 * y1, rtol=1e-4, atol=1e-2)
