"""Shared benchmark machinery: run the six apps on a DCRA config, report
TEPS / TEPS-per-watt / TEPS-per-dollar (paper §V metrics).

Datasets are scale-reduced stand-ins (CI box) with the paper's *names*
retained; trends, not absolute TEPS, are the reproduction target (the
absolute numbers need the cycle-accurate Dalorex simulator — DESIGN.md §2).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import EngineConfig, TaskEngine, TileGrid
from repro.core.cache import DRAMConfig, SRAMConfig
from repro.core.queues import QueueConfig
from repro.costmodel import (dcra_die_area_mm2, package_cost, run_energy,
                             run_perf)
from repro.sparse import apps, datasets

APPS = ("sssp", "pagerank", "bfs", "wcc", "spmv", "histogram")


def load_datasets(scale: int = 12) -> Dict[str, object]:
    return {
        f"R{scale}": datasets.rmat(scale, edge_factor=16, seed=1),
        "WK": datasets.wiki_like(1 << (scale - 1), avg_degree=25),
    }


def run_app(app: str, engine: TaskEngine, g, rng_seed: int = 0):
    if app == "bfs":
        return apps.bfs(engine, g, root=0)
    if app == "sssp":
        return apps.sssp(engine, g, root=0)
    if app == "pagerank":
        return apps.pagerank(engine, g, iters=5)
    if app == "wcc":
        return apps.wcc(engine, g)
    if app == "spmv":
        x = np.random.default_rng(rng_seed).random(g.n)
        return apps.spmv(engine, g, x)
    if app == "histogram":
        els = datasets.histogram_data(g.nnz, max(g.n // 16, 64))
        return apps.histogram(engine, els, max(g.n // 16, 64))
    raise ValueError(app)


@dataclass
class ConfigResult:
    teps: float
    teps_per_watt: float
    teps_per_dollar: float
    seconds: float
    energy_j: float
    cost_usd: float
    hops: int
    breakdown: object = None


def evaluate(cfg: EngineConfig, g, app: str,
             cost_usd: Optional[float] = None) -> ConfigResult:
    engine = TaskEngine(cfg, getattr(g, "n", len(np.atleast_1d(g))))
    _, stats = run_app(app, engine, g)
    edges = g.nnz if hasattr(g, "nnz") else len(g)
    dbytes = g.memory_bytes() if hasattr(g, "memory_bytes") else edges * 8
    fanout = edges / max(getattr(g, "n", 1), 1)
    perf = run_perf(stats, cfg, edges, dataset_bytes=dbytes, fanout=fanout)
    en = run_energy(stats, cfg, dataset_bytes=dbytes)
    if cost_usd is None:
        cost_usd = config_cost(cfg)
    watts = en.total_j / max(perf.seconds, 1e-12)
    return ConfigResult(
        teps=perf.teps,
        teps_per_watt=perf.teps / max(watts, 1e-12),
        teps_per_dollar=perf.teps / max(cost_usd, 1e-12),
        seconds=perf.seconds, energy_j=en.total_j, cost_usd=cost_usd,
        hops=stats.total_hops, breakdown=en)


def config_cost(cfg: EngineConfig) -> float:
    g = cfg.grid
    tiles_per_die = g.die_rows * g.die_cols
    n_dies = max(1, g.n_tiles // tiles_per_die)
    area = dcra_die_area_mm2(tiles_per_die, cfg.sram.kb_per_tile,
                             cfg.pus_per_tile, g.noc_width_bits,
                             g.noc_freq_ghz)
    hbm_gb = cfg.dram.gb_per_die * n_dies if cfg.dram.present else 0.0
    return package_cost(n_dies, area, hbm_gb).total


def geomean(vals: List[float]) -> float:
    vals = [max(v, 1e-12) for v in vals]
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


def sweep(configs: Dict[str, EngineConfig], data: Dict[str, object],
          apps_list=APPS, baseline: Optional[str] = None
          ) -> List[Tuple[str, str, str, ConfigResult]]:
    rows = []
    for cname, cfg in configs.items():
        for dname, g in data.items():
            for app in apps_list:
                rows.append((cname, dname, app, evaluate(cfg, g, app)))
    return rows


def improvements(rows, baseline: str, metric: str) -> Dict[str, float]:
    """Geomean improvement of each config over the baseline config."""
    base = {(d, a): getattr(r, metric)
            for c, d, a, r in rows if c == baseline}
    out: Dict[str, List[float]] = {}
    for c, d, a, r in rows:
        if c == baseline:
            continue
        out.setdefault(c, []).append(getattr(r, metric) / max(base[(d, a)],
                                                              1e-12))
    return {c: geomean(v) for c, v in out.items()}


def emit(rows_csv: List[Tuple], header: str):
    print(header)
    for row in rows_csv:
        print(",".join(str(x) for x in row))
