"""Shared benchmark machinery: run the six apps on a DCRA config, report
TEPS / TEPS-per-watt / TEPS-per-dollar (paper §V metrics).

The evaluation primitives (``evaluate`` / ``config_cost`` / ``run_app`` /
``load_datasets``) live in :mod:`repro.dse.evaluate` — the DSE engine and
the figure benchmarks share one analytic code path; this module keeps the
figure-presentation helpers (sweeps over named configs, geomean
improvement tables, CSV emission).

Datasets are scale-reduced stand-ins (CI box) with the paper's *names*
retained; trends, not absolute TEPS, are the reproduction target (the
absolute numbers need the cycle-accurate Dalorex simulator — DESIGN.md §2).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.task_engine import EngineConfig
from repro.dse.evaluate import (APPS, ConfigResult, config_cost,  # noqa: F401
                                evaluate, geomean, load_datasets, run_app)


def sweep(configs: Dict[str, EngineConfig], data: Dict[str, object],
          apps_list=APPS, baseline: Optional[str] = None
          ) -> List[Tuple[str, str, str, ConfigResult]]:
    rows = []
    for cname, cfg in configs.items():
        for dname, g in data.items():
            for app in apps_list:
                rows.append((cname, dname, app, evaluate(cfg, g, app)))
    return rows


def improvements(rows, baseline: str, metric: str) -> Dict[str, float]:
    """Geomean improvement of each config over the baseline config."""
    base = {(d, a): getattr(r, metric)
            for c, d, a, r in rows if c == baseline}
    out: Dict[str, List[float]] = {}
    for c, d, a, r in rows:
        if c == baseline:
            continue
        out.setdefault(c, []).append(getattr(r, metric) / max(base[(d, a)],
                                                              1e-12))
    return {c: geomean(v) for c, v in out.items()}


def emit(rows_csv: List[Tuple], header: str):
    print(header)
    for row in rows_csv:
        print(",".join(str(x) for x in row))
