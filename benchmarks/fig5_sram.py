"""Paper Fig. 5: SRAM/tile {64..512KB} x tiles-per-HBM-channel, 32x32 tiles.

Expected trends: perf rises strongly with SRAM (hit-rate -> effective BW;
~2.6x geomean 64KB->512KB); 16x16 tiles/die (4x DRAM BW per tile) adds
~1.4x perf but ~halves perf-per-dollar (4x more HBM devices).
"""
from __future__ import annotations

from repro.core import EngineConfig, TileGrid
from repro.core.cache import DRAMConfig, SRAMConfig

from .common import emit, improvements, load_datasets, sweep


def configs():
    out = {}
    for kb in (64, 128, 256, 512):
        # 32x32 tiles per die -> 1024 tiles per 8-channel HBM: T/C = 128
        out[f"{kb}KB_TC128"] = EngineConfig(
            grid=TileGrid(32, 32, "hier_torus", die_rows=32, die_cols=32),
            sram=SRAMConfig(kb_per_tile=kb),
            dram=DRAMConfig(tiles_per_die=1024))
    # 16x16 tiles per die -> 256 tiles/HBM: T/C = 32 (4x BW per tile)
    out["512KB_TC32"] = EngineConfig(
        grid=TileGrid(32, 32, "hier_torus", die_rows=16, die_cols=16),
        sram=SRAMConfig(kb_per_tile=512),
        dram=DRAMConfig(tiles_per_die=256))
    return out


def main(scale: int = 16):
    data = load_datasets(scale)
    rows = sweep(configs(), data)
    out = []
    for metric in ("teps", "teps_per_watt", "teps_per_dollar"):
        for c, v in improvements(rows, "64KB_TC128", metric).items():
            out.append(("fig5", c, metric, f"{v:.3f}"))
    emit(out, "figure,config,metric,geomean_improvement_over_64KB_TC128")
    return rows, out


if __name__ == "__main__":
    main()
