"""Paper Fig. 7: PU frequency {0.25, 0.5, 1, 2} GHz, 64x64 tiles, 512KB.

Expected: ~linear to 1GHz, then saturation (2GHz ~ +38% geomean over 1GHz).
"""
from __future__ import annotations

from repro.core import EngineConfig, TileGrid
from repro.core.cache import SRAMConfig

from .common import emit, improvements, load_datasets, sweep


def configs():
    grid = TileGrid(64, 64, "hier_torus", die_rows=16, die_cols=16)
    return {f"{f}GHz": EngineConfig(grid=grid,
                                    sram=SRAMConfig(kb_per_tile=512),
                                    pu_freq_ghz=f)
            for f in (0.25, 0.5, 1.0, 2.0)}


def main(scale: int = 16):
    data = load_datasets(scale)
    rows = sweep(configs(), data)
    out = []
    for metric in ("teps", "teps_per_watt"):
        for c, v in improvements(rows, "0.25GHz", metric).items():
            out.append(("fig7", c, metric, f"{v:.3f}"))
    emit(out, "figure,config,metric,geomean_improvement_over_250MHz")
    return rows, out


if __name__ == "__main__":
    main()
