"""Single-stage vs hierarchical (pod/portal) owner-routed NoC collectives.

Runs the shared routing layer (:mod:`repro.core.routing`) both ways on the
same task streams — one flat all_to_all over all devices vs the paper's
§III-A two-stage tile-NoC / die-NoC path — and reports wall-clock,
IQ-overflow drops, and the analytic die-crossing count from the topology
model (the quantity the portal aggregation exists to cut).

  PYTHONPATH=src python -m benchmarks.noc_routing [--devices 8] [--scale 11]
"""
from __future__ import annotations

import os

# Only mutate the device topology when this module IS the program — when
# imported (e.g. by benchmarks.run, which executes it in a subprocess) the
# importer's jax device count must stay untouched.
if (__name__ == "__main__"
        and "host_platform_device_count" not in os.environ.get("XLA_FLAGS",
                                                               "")):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8"
                               ).strip()

import argparse      # noqa: E402
import time          # noqa: E402

import numpy as np   # noqa: E402

from repro.core import EngineConfig, TaskEngine, TileGrid   # noqa: E402
from repro.core.compat import make_mesh                      # noqa: E402
from repro.sparse import datasets, ref                       # noqa: E402
from repro.sparse.jax_apps import (dcra_bfs, dcra_histogram,  # noqa: E402
                                   dcra_spmv)

from .common import emit                                     # noqa: E402


def _timed(fn, reps=5):
    y, d = fn()                      # compile + correctness sample
    np.asarray(y)
    t = time.perf_counter()
    for _ in range(reps):
        y, d = fn()
        np.asarray(y)
    return (time.perf_counter() - t) / reps * 1e3, int(d), y


def die_crossings(dest, n_dev, n_pods):
    """Analytic die-NoC crossings for the same stream (topology model)."""
    grid = TileGrid(1, n_dev, "hier_torus", die_rows=1,
                    die_cols=n_dev // n_pods)
    eng = TaskEngine(EngineConfig(grid=grid), int(dest.max()) + 1)
    valid = dest >= 0
    src = (np.arange(len(dest)) % n_dev)[valid]   # edge-parallel src shards
    rs = eng.route("T3", src_idx=src, dst_idx=dest[valid])
    return rs.die_crossings


def _bfs_stats(g, mesh, **kw):
    d, st = dcra_bfs(g, 0, mesh, capacity_factor=4.0, **kw)
    return d.astype(np.float64), st.total_drops


def main(scale: int = 11, n_dev: int = 8, n_pods: int = 2):
    flat = make_mesh((n_dev,), ("data",))
    hier = make_mesh((n_pods, n_dev // n_pods), ("pod", "data"))

    g = datasets.rmat(scale, edge_factor=8, seed=3)
    x = np.random.default_rng(0).random(g.n)
    els = datasets.histogram_data(1 << 16, 1 << 10)

    rows = []
    for name, fn_flat, fn_hier, oracle in (
        ("spmv",
         lambda: dcra_spmv(g, x, flat, capacity_factor=3.0),
         lambda: dcra_spmv(g, x, hier, pod_axis="pod", capacity_factor=3.0),
         ref.spmv_ref(g, x)),
        ("histogram",
         lambda: dcra_histogram(els, 1 << 10, flat, capacity_factor=3.0),
         lambda: dcra_histogram(els, 1 << 10, hier, pod_axis="pod",
                                capacity_factor=3.0),
         ref.histogram_ref(els, 1 << 10)),
        # iterative TaskPrograms route hierarchically too: every
        # while_loop round re-enters the two-stage pod/portal collective
        ("bfs",
         lambda: _bfs_stats(g, flat),
         lambda: _bfs_stats(g, hier, pod_axis="pod"),
         ref.bfs_ref(g, 0).astype(np.float64)),
    ):
        for mode, fn in (("single_stage", fn_flat), ("hierarchical", fn_hier)):
            ms, drops, y = _timed(fn)
            err = float(np.max(np.abs(np.asarray(y, np.float64) - oracle)))
            rows.append(("noc_routing", name, mode, f"{ms:.2f}ms",
                         f"drops={drops}", f"err={err:.2e}"))
    dest = g.row_of()
    rows.append(("noc_routing", "analytic", "die_crossings_flat",
                 die_crossings(dest, n_dev, n_dev), "", ""))
    rows.append(("noc_routing", "analytic", "die_crossings_hier",
                 die_crossings(dest, n_dev, n_pods), "", ""))
    emit(rows, "figure,app,mode,ms_per_round,drops,err")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--scale", type=int, default=11)
    a = ap.parse_args()
    main(scale=a.scale, n_dev=a.devices, n_pods=a.pods)
