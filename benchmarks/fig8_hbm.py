"""Paper Fig. 8/9: DCRA-SRAM vs Dalorex vs DCRA-HBM (packaging-time knob).

The three systems are :class:`repro.dse.space.DesignPoint`\\ s differing in
the package-time memory-tech axis (and Dalorex's pre-silicon die/SRAM
choices) — the same points the DSE sweep enumerates, sized per dataset to
the smallest deployment grid where it fits.

Each system runs at the smallest parallelization where the dataset fits:
DCRA-HBM (8MB/PU incl. HBM) smallest grid, Dalorex (2MB SRAM/tile) 4x tiles,
DCRA-SRAM (512KB/tile) 16x tiles. Expected: DCRA-SRAM fastest (most
scaled-out); DCRA-HBM best TEPS/$ nearly across the board; energy mixed.
Also emits the Fig. 9 energy breakdown (PU / memory / NoC shares).
"""
from __future__ import annotations

import math

from repro.costmodel.silicon import monolithic_wafer_cost
from repro.dse.space import DesignPoint

from .common import APPS, config_cost, emit, evaluate, load_datasets


def _side_for(n_tiles: int, die: int = 16) -> int:
    return max(int(math.sqrt(n_tiles)), die)


def design_points(dataset_bytes: float):
    """Size each system to the smallest grid where the dataset fits."""
    def tiles_needed(bytes_per_tile):
        # scale-reduced datasets can fit one tile: clamp the shift at 0
        need = max(dataset_bytes / bytes_per_tile, 1.0)
        return max(256, 1 << max(0, math.ceil(math.log2(need))))
    hbm_tiles = tiles_needed(8 * 2**20)          # 8MB/PU with HBM
    dal_tiles = hbm_tiles * 4                     # 2MB SRAM/tile
    sram_tiles = dal_tiles * 4                    # 512KB SRAM/tile
    return {
        "DCRA-HBM": DesignPoint(
            grid_side=_side_for(hbm_tiles), die_side=16,
            sram_kb_per_tile=512, mem_tech="hbm"),
        "Dalorex": DesignPoint(
            grid_side=_side_for(dal_tiles, die=64), die_side=64,
            topology="torus", sram_kb_per_tile=2048, mem_tech="sram"),
        "DCRA-SRAM": DesignPoint(
            grid_side=_side_for(sram_tiles), die_side=16,
            sram_kb_per_tile=512, mem_tech="sram"),
    }


def systems(dataset_bytes: float):
    return {name: p.engine_config()
            for name, p in design_points(dataset_bytes).items()}


def main(scale: int = 16):
    data = load_datasets(scale)
    out = []
    for dname, g in data.items():
        cfgs = systems(g.memory_bytes())
        for cname, cfg in cfgs.items():
            cost = (monolithic_wafer_cost() if cname == "Dalorex"
                    else config_cost(cfg))
            for app in APPS:
                r = evaluate(cfg, g, app, cost_usd=cost)
                out.append(("fig8", cname, dname, app, f"{r.teps:.3e}",
                            f"{r.teps_per_dollar:.3e}",
                            f"{r.teps_per_watt:.3e}"))
                b = r.breakdown
                out.append(("fig9", cname, dname, app,
                            f"pu={b.pu_j / b.total_j:.2f}",
                            f"mem={b.memory_j / b.total_j:.2f}",
                            f"noc={b.noc_j / b.total_j:.2f}"))
    emit(out, "figure,system,dataset,app,teps|pu,teps_per_usd|mem,teps_per_w|noc")
    return out


if __name__ == "__main__":
    main()
