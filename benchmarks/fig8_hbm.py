"""Paper Fig. 8/9: DCRA-SRAM vs Dalorex vs DCRA-HBM (packaging-time knob).

Each system runs at the smallest parallelization where the dataset fits:
DCRA-HBM (8MB/PU incl. HBM) smallest grid, Dalorex (2MB SRAM/tile) 4x tiles,
DCRA-SRAM (512KB/tile) 16x tiles. Expected: DCRA-SRAM fastest (most
scaled-out); DCRA-HBM best TEPS/$ nearly across the board; energy mixed.
Also emits the Fig. 9 energy breakdown (PU / memory / NoC shares).
"""
from __future__ import annotations

import math

from repro.core import EngineConfig, TileGrid
from repro.core.cache import DRAMConfig, SRAMConfig
from repro.costmodel.silicon import monolithic_wafer_cost

from .common import config_cost, emit, evaluate, load_datasets, APPS


def _grid_for(n_tiles: int, die: int = 16) -> TileGrid:
    side = max(int(math.sqrt(n_tiles)), die)
    return TileGrid(side, side, "hier_torus", die_rows=die, die_cols=die)


def systems(dataset_bytes: float):
    """Size each system to the smallest grid where the dataset fits."""
    def tiles_needed(bytes_per_tile):
        return max(256, 1 << math.ceil(math.log2(dataset_bytes
                                                 / bytes_per_tile)))
    hbm_tiles = tiles_needed(8 * 2**20)          # 8MB/PU with HBM
    dal_tiles = hbm_tiles * 4                     # 2MB SRAM/tile
    sram_tiles = dal_tiles * 4                    # 512KB SRAM/tile
    return {
        "DCRA-HBM": EngineConfig(
            grid=_grid_for(hbm_tiles), sram=SRAMConfig(kb_per_tile=512),
            dram=DRAMConfig(present=True)),
        "Dalorex": EngineConfig(
            grid=_grid_for(dal_tiles, die=64).with_(topology="torus"),
            sram=SRAMConfig(kb_per_tile=2048),
            dram=DRAMConfig(present=False)),
        "DCRA-SRAM": EngineConfig(
            grid=_grid_for(sram_tiles), sram=SRAMConfig(kb_per_tile=512),
            dram=DRAMConfig(present=False)),
    }


def main(scale: int = 16):
    data = load_datasets(scale)
    out = []
    for dname, g in data.items():
        cfgs = systems(g.memory_bytes())
        for cname, cfg in cfgs.items():
            cost = (monolithic_wafer_cost() if cname == "Dalorex"
                    else config_cost(cfg))
            for app in APPS:
                r = evaluate(cfg, g, app, cost_usd=cost)
                out.append(("fig8", cname, dname, app, f"{r.teps:.3e}",
                            f"{r.teps_per_dollar:.3e}",
                            f"{r.teps_per_watt:.3e}"))
                b = r.breakdown
                out.append(("fig9", cname, dname, app,
                            f"pu={b.pu_j / b.total_j:.2f}",
                            f"mem={b.memory_j / b.total_j:.2f}",
                            f"noc={b.noc_j / b.total_j:.2f}"))
    emit(out, "figure,system,dataset,app,teps|pu,teps_per_usd|mem,teps_per_w|noc")
    return out


if __name__ == "__main__":
    main()
