"""Resident serving under sustained mixed-tenant traffic (serving tier).

Drives the :class:`repro.serve.engine.ProgramServer` with a synthetic
multi-tenant request stream (BFS + SSSP roots over resident graphs),
after a one-shot pre-warm of every (program, graph, width) shape class,
and reports the serving metrics: request throughput, per-tenant p50/p99
latency, compile-cache hit rate, fused-launch count, padding overhead,
and the NoC-drop ledger.

``--smoke`` is the CI leg: a short stream that *asserts* the serving
invariants (>= 1 compile-cache hit after warm-up, zero kernel re-traces
under load, zero unaccounted drops, results bit-identical to a
standalone launch) and prints ``RESULT ok``.

  PYTHONPATH=src python -m benchmarks.serve_bench [--devices 8]
      [--requests 48] [--tenants 6] [--smoke] [--fabric]

``--fabric`` drives the whole bench through the :class:`repro.core.fabric`
launch surface (``Fabric.fake`` -> ``ProgramServer(fabric, ...)``) instead
of a raw Mesh; both legs must report identical serving invariants.
"""
from __future__ import annotations

import os
import sys

# Only mutate the device topology when this module IS the program — when
# imported (e.g. by benchmarks.run, which executes it in a subprocess)
# the importer's jax device count must stay untouched. --devices has to
# be pre-scanned: jax fixes the topology at import time.
if (__name__ == "__main__"
        and "host_platform_device_count" not in os.environ.get("XLA_FLAGS",
                                                               "")):
    _n = 8
    if "--devices" in sys.argv:
        _n = int(sys.argv[sys.argv.index("--devices") + 1])
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --xla_force_host_platform_device_count={_n}"
                               ).strip()

import argparse      # noqa: E402
import time          # noqa: E402

import numpy as np   # noqa: E402

from repro.core.compat import make_mesh                      # noqa: E402
from repro.serve import ProgramServer, Request, STATUS_OK    # noqa: E402
from repro.sparse import datasets                            # noqa: E402
from repro.sparse import program as program_mod              # noqa: E402
from repro.sparse.jax_apps import BFS, SSSP                  # noqa: E402
from repro.sparse.program import run_program                 # noqa: E402

from .common import emit                                     # noqa: E402

PROGRAMS = ("bfs", "sssp")
STANDALONE = {"bfs": BFS, "sssp": SSSP}


def make_stream(graphs, tenants: int, requests: int, seed: int = 0):
    """Round-robin tenants over (program, graph) classes, random roots."""
    rng = np.random.default_rng(seed)
    names = sorted(graphs)
    classes = len(PROGRAMS) * len(names)
    reqs = []
    for i in range(requests):
        gname = names[(i // len(PROGRAMS)) % len(names)]
        reqs.append(Request(
            # tenant advances once per full (program, graph) cycle, so
            # same-class requests rotate tenants and batches fuse wide
            req_id=i, tenant=f"tenant{(i // classes) % tenants}",
            program=PROGRAMS[i % len(PROGRAMS)], graph=gname,
            root=int(rng.integers(graphs[gname].n))))
    return reqs


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8,
                    help="fake CPU devices (applied only when __main__)")
    ap.add_argument("--tenants", type=int, default=6)
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--width", type=int, default=4,
                    help="tenant columns per fused launch")
    ap.add_argument("--vertices", type=int, default=192)
    ap.add_argument("--smoke", action="store_true",
                    help="short CI stream; assert serving invariants")
    ap.add_argument("--fabric", action="store_true",
                    help="launch through the Fabric surface instead of a "
                         "raw Mesh")
    args = ap.parse_args(argv)
    if args.smoke:
        args.tenants = min(args.tenants, 4)
        args.requests = min(args.requests, 16)

    import jax
    n_dev = min(args.devices, len(jax.devices()))
    if args.fabric:
        from repro.core.fabric import Fabric
        mesh = Fabric.fake(n_dev)
    else:
        mesh = make_mesh((n_dev,), ("data",))
    graphs = {
        "wiki": datasets.wiki_like(args.vertices, avg_degree=6, seed=3),
        "er": datasets.erdos_renyi(args.vertices, avg_degree=4, seed=7),
    }
    server = ProgramServer(mesh, graphs, batch_width=args.width)

    t0 = time.perf_counter()
    server.prewarm(PROGRAMS)
    warm_s = time.perf_counter() - t0
    traces0 = program_mod.cache_stats()["kernel_traces"]

    stream = make_stream(graphs, args.tenants, args.requests)
    t0 = time.perf_counter()
    responses = server.run(stream)
    serve_s = time.perf_counter() - t0
    new_traces = program_mod.cache_stats()["kernel_traces"] - traces0

    server.stats.verify()
    snap = server.stats.snapshot()
    rows = [(t, s["submitted"], s["served"], s["rejected"], s["failed"],
             f"{s['p50_latency_s'] * 1e3:.1f}",
             f"{s['p99_latency_s'] * 1e3:.1f}")
            for t, s in sorted(snap["tenants"].items())]
    emit(rows, "tenant,submitted,served,rejected,failed,p50_ms,p99_ms")
    print(f"# devices={n_dev} width={args.width} "
          f"surface={'fabric' if args.fabric else 'mesh'} "
          f"prewarm={warm_s:.1f}s "
          f"serve={serve_s:.1f}s "
          f"throughput={args.requests / serve_s:.1f} req/s")
    print(f"# launches={snap['launches']} "
          f"batched={snap['batched_requests']} "
          f"pad_columns={snap['pad_columns']} "
          f"cache_hit_rate={snap['cache_hit_rate']:.2f} "
          f"re_traces={new_traces} noc_drops={snap['noc_drops']} "
          f"p50_round={snap['p50_round_latency_s'] * 1e3:.1f}ms "
          f"p99_round={snap['p99_round_latency_s'] * 1e3:.1f}ms")

    if args.smoke:
        assert all(r.status == STATUS_OK for r in responses), \
            [r.reason for r in responses if r.status != STATUS_OK]
        assert snap["cache_hits"] >= 1, snap
        assert new_traces == 0, f"{new_traces} re-traces under load"
        assert snap["noc_drops"] == 0, snap   # default sizing is drop-free
        # one spot-check: the batched column matches a standalone launch
        r0 = responses[0]
        (ref,), _ = run_program(STANDALONE[stream[0].program],
                                graphs[stream[0].graph], mesh,
                                params={"root": stream[0].root})
        assert np.array_equal(np.asarray(r0.result), np.asarray(ref)), \
            "batched result != standalone"
        print("RESULT ok")


if __name__ == "__main__":
    main()
