"""Resident serving under sustained mixed-tenant traffic (serving tier).

Drives the :class:`repro.serve.engine.ProgramServer` with a synthetic
multi-tenant request stream (BFS + SSSP roots over resident graphs),
after a one-shot pre-warm of every (program, graph, width) shape class,
and reports the serving metrics: request throughput, per-tenant p50/p99
latency — decomposed into **queue-wait** (submit -> launch) and **device
time** (launch -> harvest) — compile-cache hit rate, fused-launch count,
padding overhead, and the NoC-drop ledger.

``--depth`` sets ``ServeOptions.inflight_depth``: at depth k the server
keeps k fused launches in flight (JAX async dispatch) and forms batch
k+1 while batch k computes. ``--smoke`` is the CI leg: a short stream
that *asserts* the serving invariants (>= 1 compile-cache hit after
warm-up, zero kernel re-traces under load, zero unaccounted drops,
results bit-identical to a standalone launch) and prints ``RESULT ok``;
with ``--depth k > 1`` it additionally runs the same stream at depth 1
and asserts the overlapped responses are bit-identical (results,
statuses, reasons, per-tenant ledger). ``--bench-out BENCH_serve.json``
measures the synchronous drain vs the overlapped drain on one stream and
writes the ``dcra-serve-bench/v1`` trajectory artifact gated by
:mod:`repro.dse.serve_compare`.

``--chaos SEED`` is the chaos-smoke leg: the stream runs fault-free
once, then replays under :func:`repro.serve.seeded_chaos_plan` (one
launch fault, one device-side fault, one host loss that halves the
fabric) with retries and a circuit breaker enabled, and *asserts* the
fault-tolerance contract — every planned fault fired, the ledger stayed
exact, at least one retry and one breaker open/close cycle happened, and
the surviving responses are bit-identical to the fault-free reference.

  PYTHONPATH=src python -m benchmarks.serve_bench [--devices 8]
      [--requests 48] [--tenants 6] [--depth 3] [--fairness drr]
      [--donate] [--smoke] [--chaos SEED] [--fabric]
      [--bench-out BENCH_serve.json]

``--fabric`` drives the whole bench through the :class:`repro.core.fabric`
launch surface (``Fabric.fake`` -> ``ProgramServer(fabric, ...)``) instead
of a raw Mesh; both legs must report identical serving invariants.
"""
from __future__ import annotations

import os
import sys

# Only mutate the device topology when this module IS the program — when
# imported (e.g. by benchmarks.run, which executes it in a subprocess)
# the importer's jax device count must stay untouched. --devices has to
# be pre-scanned: jax fixes the topology at import time.
if (__name__ == "__main__"
        and "host_platform_device_count" not in os.environ.get("XLA_FLAGS",
                                                               "")):
    _n = 8
    if "--devices" in sys.argv:
        _n = int(sys.argv[sys.argv.index("--devices") + 1])
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --xla_force_host_platform_device_count={_n}"
                               ).strip()

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402

import numpy as np   # noqa: E402

from repro.core.compat import make_mesh                      # noqa: E402
from repro.serve import (ProgramServer, Request,             # noqa: E402
                         STATUS_OK, ServeOptions)
from repro.sparse import datasets                            # noqa: E402
from repro.sparse import program as program_mod              # noqa: E402
from repro.sparse.jax_apps import BFS, SSSP                  # noqa: E402
from repro.sparse.program import run_program                 # noqa: E402

from .common import emit                                     # noqa: E402

PROGRAMS = ("bfs", "sssp")
STANDALONE = {"bfs": BFS, "sssp": SSSP}
BENCH_SCHEMA = "dcra-serve-bench/v1"


def make_stream(graphs, tenants: int, requests: int, seed: int = 0):
    """Round-robin tenants over (program, graph) classes, random roots."""
    rng = np.random.default_rng(seed)
    names = sorted(graphs)
    classes = len(PROGRAMS) * len(names)
    reqs = []
    for i in range(requests):
        gname = names[(i // len(PROGRAMS)) % len(names)]
        reqs.append(Request(
            # tenant advances once per full (program, graph) cycle, so
            # same-class requests rotate tenants and batches fuse wide
            req_id=i, tenant=f"tenant{(i // classes) % tenants}",
            program=PROGRAMS[i % len(PROGRAMS)], graph=gname,
            root=int(rng.integers(graphs[gname].n))))
    return reqs


def serve_stream(mesh, graphs, stream, width, serve_options):
    """Pre-warm + run one stream on a fresh server; returns the server
    and the timing/trace envelope."""
    server = ProgramServer(mesh, graphs, batch_width=width,
                           serve_options=serve_options)
    t0 = time.perf_counter()
    server.prewarm(PROGRAMS)
    warm_s = time.perf_counter() - t0
    traces0 = program_mod.cache_stats()["kernel_traces"]
    t0 = time.perf_counter()
    responses = server.run(stream)
    serve_s = time.perf_counter() - t0
    new_traces = program_mod.cache_stats()["kernel_traces"] - traces0
    server.stats.verify()
    return server, responses, warm_s, serve_s, new_traces


def _quant(xs, q):
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))]


def bench_row(mode, opts, responses, serve_s, new_traces, snap):
    """One dcra-serve-bench/v1 row: throughput + the latency split."""
    ok = [r for r in responses if r.status == STATUS_OK]
    return {
        "mode": mode, "depth": opts.inflight_depth,
        "fairness": opts.fairness, "donate": opts.donate_buffers,
        "serve_s": serve_s,
        "throughput_rps": len(responses) / serve_s if serve_s else 0.0,
        "p50_latency_s": _quant([r.latency_s for r in ok], 0.50),
        "p99_latency_s": _quant([r.latency_s for r in ok], 0.99),
        "p50_queue_wait_s": _quant([r.queue_wait_s for r in ok], 0.50),
        "p99_queue_wait_s": _quant([r.queue_wait_s for r in ok], 0.99),
        "p50_device_s": _quant([r.device_s for r in ok], 0.50),
        "p99_device_s": _quant([r.device_s for r in ok], 0.99),
        "launches": snap["launches"],
        "cache_hit_rate": snap["cache_hit_rate"],
        "re_traces": new_traces,
    }


def _signature(responses):
    """The bit-identity signature: results, statuses, reasons — and
    nothing wall-clock."""
    return [(r.req_id, r.tenant, r.status, r.retriable, r.reason,
             None if r.result is None else r.result.tobytes(),
             r.batch_drops, r.batch_messages, r.rounds, r.batch_width)
            for r in sorted(responses, key=lambda r: r.req_id)]


def _ledger(server):
    return {t: (s.submitted, s.served, s.rejected, s.failed)
            for t, s in server.stats.tenants.items()}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8,
                    help="fake CPU devices (applied only when __main__)")
    ap.add_argument("--tenants", type=int, default=6)
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--width", type=int, default=4,
                    help="tenant columns per fused launch")
    ap.add_argument("--vertices", type=int, default=192)
    ap.add_argument("--depth", type=int, default=1,
                    help="inflight window depth (1 = synchronous drain)")
    ap.add_argument("--fairness", choices=("fifo", "drr"), default="fifo")
    ap.add_argument("--donate", action="store_true",
                    help="donate retired batch state buffers to the next "
                         "launch of the shape class")
    ap.add_argument("--smoke", action="store_true",
                    help="short CI stream; assert serving invariants")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="run the stream twice — fault-free, then under "
                         "the seeded chaos plan (one launch fault, one "
                         "device fault, one host loss) — and assert the "
                         "chaos run converges to the same responses")
    ap.add_argument("--fabric", action="store_true",
                    help="launch through the Fabric surface instead of a "
                         "raw Mesh")
    ap.add_argument("--bench-out", default=None, metavar="PATH",
                    help="measure sync vs overlapped drain and write the "
                         "dcra-serve-bench/v1 artifact")
    args = ap.parse_args(argv)
    if args.smoke:
        args.tenants = min(args.tenants, 4)
        args.requests = min(args.requests, 16)

    import jax
    n_dev = min(args.devices, len(jax.devices()))
    if args.fabric:
        from repro.core.fabric import Fabric
        mesh = Fabric.fake(n_dev)
    else:
        mesh = make_mesh((n_dev,), ("data",))
    graphs = {
        "wiki": datasets.wiki_like(args.vertices, avg_degree=6, seed=3),
        "er": datasets.erdos_renyi(args.vertices, avg_degree=4, seed=7),
    }
    opts = ServeOptions(inflight_depth=args.depth, fairness=args.fairness,
                        donate_buffers=args.donate)
    stream = make_stream(graphs, args.tenants, args.requests)

    if args.chaos is not None:
        # The chaos-smoke leg: a fault-free reference sizes the plan (its
        # launch count bounds the injectable indices), then the SAME
        # stream replays under the seeded plan with retries + a breaker.
        # Every fault must fire, exactly one host loss must shrink the
        # fabric, and the surviving responses must converge bit-identical
        # to the reference — min-reduce programs don't care how many
        # devices finished the job. The --smoke zero-re-trace assert does
        # NOT apply here: the shrink re-prewarms the affected classes.
        from repro.serve import seeded_chaos_plan
        ref_srv, ref_resp, _, _, _ = serve_stream(
            mesh, graphs, stream, args.width, ServeOptions())
        n_ref = ref_srv.stats.snapshot()["launches"]
        plan = seeded_chaos_plan(args.chaos, n_ref,
                                 keep_devices=max(1, n_dev // 2))
        planned = dict(plan.at)
        chaos_opts = ServeOptions(inflight_depth=args.depth,
                                  fairness=args.fairness,
                                  max_retries=3, breaker_threshold=1)
        srv = ProgramServer(mesh, graphs, batch_width=args.width,
                            serve_options=chaos_opts, failure_plan=plan)
        srv.prewarm(PROGRAMS)
        responses = srv.run(stream)
        srv.stats.verify()
        snap = srv.stats.snapshot()

        def reduced(rs):
            return [(r.req_id, r.tenant, r.status, r.retriable,
                     None if r.result is None else r.result.tobytes())
                    for r in sorted(rs, key=lambda r: r.req_id)]

        assert plan.exhausted, f"unfired faults: {plan.at}"
        assert [k for _, k in plan.fired] == [planned[i]
                                              for i in sorted(planned)], \
            f"fault order diverged from the plan: {plan.fired}"
        assert snap["host_losses"] == 1, snap
        assert snap["retries"] > 0, "no request ever retried"
        assert snap["breaker_opens"] >= 1 and snap["breaker_closes"] >= 1, \
            snap
        assert all(r.status == STATUS_OK for r in responses), \
            [r.reason for r in responses if r.status != STATUS_OK]
        assert reduced(responses) == reduced(ref_resp), \
            "chaos responses diverged from the fault-free reference"
        assert _ledger(srv) == _ledger(ref_srv), \
            "chaos per-tenant ledger diverged from the fault-free reference"
        print(f"# chaos seed={args.chaos} plan={planned} "
              f"retries={snap['retries']} "
              f"breaker_opens={snap['breaker_opens']} "
              f"devices {n_dev} -> {srv.fabric.n_devices}")
        print("RESULT chaos ok")
        return

    if args.bench_out:
        # sync vs overlapped on the SAME stream — the trajectory artifact
        sync_opts = ServeOptions(inflight_depth=1)
        over_opts = ServeOptions(inflight_depth=max(2, args.depth),
                                 fairness=args.fairness,
                                 donate_buffers=args.donate)
        rows = []
        sigs = []
        for mode, o in (("sync", sync_opts), ("overlapped", over_opts)):
            srv, resp, _, serve_s, tr = serve_stream(
                mesh, graphs, stream, args.width, o)
            rows.append(bench_row(mode, o, resp, serve_s, tr,
                                  srv.stats.snapshot()))
            sigs.append(_signature(resp))
        assert sigs[0] == sigs[1], \
            "overlapped responses diverged from the synchronous drain"
        speedup = rows[1]["throughput_rps"] / rows[0]["throughput_rps"]
        bench = {
            "schema": BENCH_SCHEMA,
            "backend": jax.default_backend(),
            "config": {"devices": n_dev, "width": args.width,
                       "tenants": args.tenants, "requests": args.requests,
                       "vertices": args.vertices,
                       "depth": over_opts.inflight_depth,
                       "fairness": over_opts.fairness,
                       "donate": over_opts.donate_buffers},
            "rows": rows,
            "overlap_speedup": speedup,
        }
        with open(args.bench_out, "w") as f:
            json.dump(bench, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.bench_out}: overlapped "
              f"{rows[1]['throughput_rps']:.1f} req/s vs sync "
              f"{rows[0]['throughput_rps']:.1f} req/s "
              f"({speedup:.2f}x, depth={over_opts.inflight_depth})")
        return

    server, responses, warm_s, serve_s, new_traces = serve_stream(
        mesh, graphs, stream, args.width, opts)
    snap = server.stats.snapshot()
    rows = [(t, s["submitted"], s["served"], s["rejected"], s["failed"],
             f"{s['p50_latency_s'] * 1e3:.1f}",
             f"{s['p99_latency_s'] * 1e3:.1f}",
             f"{s['p50_queue_wait_s'] * 1e3:.1f}",
             f"{s['p50_device_s'] * 1e3:.1f}")
            for t, s in sorted(snap["tenants"].items())]
    emit(rows, "tenant,submitted,served,rejected,failed,p50_ms,p99_ms,"
               "p50_wait_ms,p50_device_ms")
    print(f"# devices={n_dev} width={args.width} depth={args.depth} "
          f"fairness={args.fairness} "
          f"surface={'fabric' if args.fabric else 'mesh'} "
          f"prewarm={warm_s:.1f}s "
          f"serve={serve_s:.1f}s "
          f"throughput={args.requests / serve_s:.1f} req/s")
    print(f"# launches={snap['launches']} "
          f"batched={snap['batched_requests']} "
          f"pad_columns={snap['pad_columns']} "
          f"cache_hit_rate={snap['cache_hit_rate']:.2f} "
          f"re_traces={new_traces} noc_drops={snap['noc_drops']} "
          f"p50_round={snap['p50_round_latency_s'] * 1e3:.1f}ms "
          f"p99_round={snap['p99_round_latency_s'] * 1e3:.1f}ms")

    if args.smoke:
        assert all(r.status == STATUS_OK for r in responses), \
            [r.reason for r in responses if r.status != STATUS_OK]
        assert snap["cache_hits"] >= 1, snap
        assert new_traces == 0, f"{new_traces} re-traces under load"
        assert snap["noc_drops"] == 0, snap   # default sizing is drop-free
        # one spot-check: the batched column matches a standalone launch
        r0 = responses[0]
        (ref,), _ = run_program(STANDALONE[stream[0].program],
                                graphs[stream[0].graph], mesh,
                                params={"root": stream[0].root})
        assert np.array_equal(np.asarray(r0.result), np.asarray(ref)), \
            "batched result != standalone"
        if args.depth > 1:
            # the overlapped leg: the same stream at depth 1 must produce
            # bit-identical responses AND ledger, with zero re-traces
            ref_srv, ref_resp, _, _, ref_traces = serve_stream(
                mesh, graphs, stream, args.width, ServeOptions())
            assert ref_traces == 0, f"{ref_traces} re-traces (sync leg)"
            assert _signature(responses) == _signature(ref_resp), \
                f"depth={args.depth} responses != synchronous drain"
            assert _ledger(server) == _ledger(ref_srv), \
                f"depth={args.depth} ledger != synchronous drain"
        print("RESULT ok")


if __name__ == "__main__":
    main()
