"""Roofline terms per (arch x shape) from the dry-run compiled artifacts.

Reads the cached dry-run results (launch/dryrun.py writes
``/root/repo/dryrun_results.json``); if absent, emits a pointer instead of
recomputing (the 512-device dry-run is its own entry point).
"""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")


def main():
    path = os.path.abspath(RESULTS)
    if not os.path.exists(path):
        print(f"roofline_table,SKIPPED,run `PYTHONPATH=src python -m "
              f"repro.launch.dryrun` first (writes {path})")
        return []
    with open(path) as f:
        rows = json.load(f)
    print("arch,shape,mesh,compute_s,memory_s,collective_s,bottleneck,"
          "model_flops_ratio,bytes_per_device")
    out = []
    for r in rows:
        if "error" in r:
            print(f"{r['arch']},{r['shape']},{r['mesh']},ERROR,,,{r['error'][:60]},,")
            continue
        if "skipped" in r:
            print(f"{r['arch']},{r['shape']},{r['mesh']},SKIP,,,"
                  f"{r['skipped'][:60]},,")
            continue
        if r.get("tag"):
            continue   # hillclimb variants belong to §Perf
        line = (f"{r['arch']},{r['shape']},{r['mesh']},"
                f"{r['compute_s']:.3e},{r['memory_s']:.3e},"
                f"{r['collective_s']:.3e},{r['bottleneck']},"
                f"{r.get('model_flops_ratio', 0):.3f},"
                f"{r.get('bytes_per_device', 0):.3e}")
        print(line)
        out.append(r)
    return out


if __name__ == "__main__":
    main()
