"""Paper Fig. 11: strong scaling of one dataset across grid sizes.

Expected: throughput rises sub-linearly (message hops/work grow with the
grid); TEPS/W roughly stable; TEPS/$ peaks at a modest grid (~64x64 in the
paper) because cost grows linearly while speedup saturates.
"""
from __future__ import annotations

from repro.core import EngineConfig, TileGrid
from repro.core.cache import DRAMConfig, SRAMConfig
from repro.sparse import datasets

from .common import emit, evaluate

GRIDS = (16, 32, 64, 128)


def main(scale: int = 16, app: str = "pagerank"):
    g = datasets.rmat(scale, edge_factor=16, seed=1)
    out = []
    for side in GRIDS:
        cfg = EngineConfig(
            grid=TileGrid(side, side, "hier_torus", die_rows=16, die_cols=16),
            sram=SRAMConfig(kb_per_tile=512),
            dram=DRAMConfig(present=True))
        r = evaluate(cfg, g, app)
        out.append(("fig11", f"{side}x{side}", app, f"{r.teps:.3e}",
                    f"{r.teps_per_watt:.3e}", f"{r.teps_per_dollar:.3e}",
                    r.hops))
    emit(out, "figure,grid,app,teps,teps_per_watt,teps_per_dollar,total_hops")
    return out


if __name__ == "__main__":
    main()
