"""Paper Fig. 10: output-queue sizing (OQ2 vs OQ1), 64x64 tiles.

OQ2 holds per-edge vertex-update pushes; OQ1 holds per-vertex edge lookups.
Expected: sizing OQ2 up to ~the average degree helps (R-MAT avg 32 gains
more than WK avg 25, which mostly helps SPMV).
"""
from __future__ import annotations

from repro.core import EngineConfig, TileGrid
from repro.core.queues import QueueConfig

from .common import emit, improvements, load_datasets, sweep

OQ1 = 12


def configs():
    grid = TileGrid(64, 64, "hier_torus", die_rows=16, die_cols=16)
    out = {}
    for mult in (1, 2, 4, 8, 16):
        out[f"OQ2_{mult}x"] = EngineConfig(
            grid=grid,
            queues=QueueConfig(oq_sizes={"T3": OQ1 * mult}, default_oq=OQ1))
    return out


def main(scale: int = 16):
    data = load_datasets(scale)
    apps_list = ("sssp", "pagerank", "bfs", "wcc", "spmv")  # histogram: 2 tasks
    rows = sweep(configs(), data, apps_list=apps_list)
    out = []
    base = {(d, a): r.teps for c, d, a, r in rows if c == "OQ2_1x"}
    for c, d, a, r in rows:
        if c != "OQ2_1x":
            out.append(("fig10", c, a, d, f"{r.teps / base[(d, a)]:.3f}"))
    emit(out, "figure,config,app,dataset,teps_improvement_over_OQ2=OQ1")
    return rows, out


if __name__ == "__main__":
    main()
