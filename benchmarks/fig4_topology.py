"""Paper Fig. 4: NoC topology/width/frequency sweep on 64x64 tiles.

Configs are :class:`repro.dse.space.DesignPoint`\\ s — figure reproduction
and the DSE sweep share one code path; this figure is the named
compile-time/pre-silicon slice of the space (topology × link width ×
NoC frequency at a fixed 64×64 deployment).

Expected trends: mesh width 2x -> ~2x perf; torus ~2.6x geomean over 32-bit
mesh (up to ~8x for SPMV); hierarchical torus adds ~+9% perf and ~+19%
energy efficiency; 2GHz NoC adds little perf (~3%) at 3x cost.
"""
from __future__ import annotations

from repro.dse.space import DesignPoint

from .common import emit, improvements, load_datasets, sweep

SIDE = 64
DIE = 16  # 16 chiplets of 16x16 tiles (paper: 16 chiplets of 32x32)

BASE = DesignPoint(grid_side=SIDE, die_side=DIE, mem_tech="hbm",
                   dies_per_package=16)

POINTS = {
    "mesh32": BASE.with_(topology="mesh", noc_width_bits=32),
    "mesh64": BASE.with_(topology="mesh", noc_width_bits=64),
    "torus64": BASE.with_(topology="torus", noc_width_bits=64),
    "hier64": BASE.with_(topology="hier_torus", noc_width_bits=64),
    "hier64_2ghz": BASE.with_(topology="hier_torus", noc_width_bits=64,
                              noc_freq_ghz=2.0),
}


def configs():
    return {name: p.engine_config() for name, p in POINTS.items()}


def main(scale: int = 16):
    data = load_datasets(scale)
    rows = sweep(configs(), data)
    out = []
    for metric in ("teps", "teps_per_watt", "teps_per_dollar"):
        imp = improvements(rows, "mesh32", metric)
        for c, v in imp.items():
            out.append(("fig4", c, metric, f"{v:.3f}"))
    emit(out, "figure,config,metric,geomean_improvement_over_mesh32")
    return rows, out


if __name__ == "__main__":
    main()
