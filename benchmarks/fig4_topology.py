"""Paper Fig. 4: NoC topology/width/frequency sweep on 64x64 tiles.

Expected trends: mesh width 2x -> ~2x perf; torus ~2.6x geomean over 32-bit
mesh (up to ~8x for SPMV); hierarchical torus adds ~+9% perf and ~+19%
energy efficiency; 2GHz NoC adds little perf (~3%) at 3x cost.
"""
from __future__ import annotations

from repro.core import EngineConfig, TileGrid

from .common import emit, improvements, load_datasets, sweep

ROWS = COLS = 64
DIE = 16  # 16 chiplets of 16x16 tiles (paper: 16 chiplets of 32x32)


def configs():
    def grid(topo, width=64, freq=1.0):
        return TileGrid(ROWS, COLS, topology=topo, die_rows=DIE, die_cols=DIE,
                        noc_width_bits=width, noc_freq_ghz=freq)
    return {
        "mesh32": EngineConfig(grid=grid("mesh", 32)),
        "mesh64": EngineConfig(grid=grid("mesh", 64)),
        "torus64": EngineConfig(grid=grid("torus", 64)),
        "hier64": EngineConfig(grid=grid("hier_torus", 64)),
        "hier64_2ghz": EngineConfig(grid=grid("hier_torus", 64, 2.0)),
    }


def main(scale: int = 16):
    data = load_datasets(scale)
    rows = sweep(configs(), data)
    out = []
    for metric in ("teps", "teps_per_watt", "teps_per_dollar"):
        imp = improvements(rows, "mesh32", metric)
        for c, v in imp.items():
            out.append(("fig4", c, metric, f"{v:.3f}"))
    emit(out, "figure,config,metric,geomean_improvement_over_mesh32")
    return rows, out


if __name__ == "__main__":
    main()
