"""DCRA technique on the LM side: flat einsum dispatch vs DCRA owner-routed
dispatch — compares *collective payload bytes* (the NoC traffic the paper
optimizes) analytically, plus wall-clock of both paths on CPU.

einsum (GShard-style) moves dispatch/combine mask tensors [G,T,E,C] plus
padded [E,C,D] buffers; DCRA moves only n_peers*cap*D payload + int meta —
the queue-capacity bound (IQ) from the paper.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.dispatch import dispatch_queues
from repro.models.moe import GROUP_SIZE, capacity, init_moe, moe_einsum

from .common import emit


def analytic_bytes(arch: str, tokens: int, d_model: int) -> dict:
    cfg = get_config(arch)
    mc = cfg.moe
    g = min(GROUP_SIZE, tokens)
    G = tokens // g
    C = capacity(g, mc)
    E = mc.num_experts
    # einsum path: x_e [G,E,C,D] formed via dispatch mask (bf16 payload moved
    # through the a2a twice: dispatch + combine)
    einsum_bytes = 2 * G * E * C * d_model * 2
    # dcra path: per expert-shard cap buffers, K copies of each token —
    # the bucket capacity the real kernel resolves through QueueConfig
    n_shards = min(E, 8)
    cap = dispatch_queues(mc).channel_cap("dispatch", tokens * mc.top_k,
                                          n_shards)
    dcra_bytes = 2 * n_shards * cap * d_model * 2 + n_shards * cap * 8
    return {"einsum_MB": einsum_bytes / 2**20, "dcra_MB": dcra_bytes / 2**20,
            "ratio": einsum_bytes / dcra_bytes}


def main():
    out = []
    for arch in ("mixtral-8x22b", "olmoe-1b-7b"):
        full = get_config(arch)
        a = analytic_bytes(arch, tokens=32768, d_model=full.d_model)
        out.append(("moe_dispatch", arch, "einsum_MB", f"{a['einsum_MB']:.1f}"))
        out.append(("moe_dispatch", arch, "dcra_MB", f"{a['dcra_MB']:.1f}"))
        out.append(("moe_dispatch", arch, "einsum/dcra", f"{a['ratio']:.2f}"))
        # wall-clock sanity on reduced config (CPU)
        cfg = full.reduced()
        params = init_moe(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (4, 64, cfg.d_model))
        f = jax.jit(lambda p, x: moe_einsum(p, x, cfg)[0])
        f(params, x).block_until_ready()
        t = time.perf_counter()
        for _ in range(10):
            f(params, x).block_until_ready()
        us = (time.perf_counter() - t) / 10 * 1e6
        out.append(("moe_dispatch", arch, "einsum_us_per_call", f"{us:.0f}"))
    emit(out, "figure,arch,metric,value")
    return out


if __name__ == "__main__":
    main()
