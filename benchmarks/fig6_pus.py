"""Paper Fig. 6: PUs per tile {1,4,16} at constant 64x64 total PUs.

Multiple PUs share one IQ -> hotspots from skew are softened. Expected:
PageRank benefits most (~2.5x at 16 PUs/tile); barrier-less apps less.
"""
from __future__ import annotations

from repro.core import EngineConfig, TileGrid
from repro.core.cache import SRAMConfig

from .common import emit, improvements, load_datasets, sweep


def configs():
    # same total PUs / SRAM / bisection: scale tile resources with PU count
    return {
        "1pu": EngineConfig(
            grid=TileGrid(64, 64, "hier_torus", die_rows=16, die_cols=16),
            sram=SRAMConfig(kb_per_tile=512), pus_per_tile=1),
        "4pu": EngineConfig(
            grid=TileGrid(32, 32, "hier_torus", die_rows=8, die_cols=8,
                          noc_width_bits=128),
            sram=SRAMConfig(kb_per_tile=2048), pus_per_tile=4),
        "16pu": EngineConfig(
            grid=TileGrid(16, 16, "hier_torus", die_rows=4, die_cols=4,
                          noc_width_bits=256),
            sram=SRAMConfig(kb_per_tile=8192), pus_per_tile=16),
    }


def main(scale: int = 16):
    data = load_datasets(scale)
    rows = sweep(configs(), data)
    out = []
    for metric in ("teps", "teps_per_watt"):
        for c, v in improvements(rows, "1pu", metric).items():
            out.append(("fig6", c, metric, f"{v:.3f}"))
    # per-app detail (PageRank is the interesting case)
    base = {(d, a): r.teps for c, d, a, r in rows if c == "1pu"}
    for c, d, a, r in rows:
        if c != "1pu":
            out.append(("fig6_app", f"{c}/{a}/{d}", "teps",
                        f"{r.teps / base[(d, a)]:.3f}"))
    emit(out, "figure,config,metric,improvement_over_1pu")
    return rows, out


if __name__ == "__main__":
    main()
