"""Benchmark harness: one module per paper table/figure (DESIGN.md §8).

``python -m benchmarks.run [--scale N] [--quick]`` runs every figure and
prints CSV blocks. --quick uses small graphs (CI); default scale=16 matches
the paper's vertices-per-tile regime (see DESIGN.md §2 scaling note).
"""
from __future__ import annotations

import argparse
import subprocess
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=16)
    ap.add_argument("--quick", action="store_true",
                    help="scale-12 graphs, skip the slowest sweeps")
    args = ap.parse_args()
    scale = 12 if args.quick else args.scale

    from . import (fig4_topology, fig5_sram, fig6_pus, fig7_freq, fig8_hbm,
                   fig10_queues, fig11_scaling, moe_dispatch, roofline_table,
                   route_bench)

    figs = [
        ("fig4_topology", lambda: fig4_topology.main(scale)),
        ("fig5_sram", lambda: fig5_sram.main(scale)),
        ("fig6_pus", lambda: fig6_pus.main(scale)),
        ("fig7_freq", lambda: fig7_freq.main(scale)),
        ("fig8_hbm", lambda: fig8_hbm.main(scale)),
        ("fig10_queues", lambda: fig10_queues.main(scale)),
        ("fig11_scaling", lambda: fig11_scaling.main(scale)),
        ("moe_dispatch", moe_dispatch.main),
        # wall-clock routing hot path -> BENCH_route.json (the committed
        # baseline is the --quick grid; see repro.dse.route_compare)
        ("route_bench", lambda: route_bench.main(
            ["--quick"] if args.quick else [])),
        # subprocess: needs its own 8-fake-device jax, must not retopologize
        # the sibling benchmarks in this process
        ("noc_routing", lambda: subprocess.run(
            [sys.executable, "-m", "benchmarks.noc_routing",
             "--scale", str(min(scale, 11))], check=True)),
        # subprocess for the same reason: the resident serving bench
        # wants its own fake-device topology
        ("serve_bench", lambda: subprocess.run(
            [sys.executable, "-m", "benchmarks.serve_bench"]
            + (["--smoke", "--devices", "4"] if args.quick else []),
            check=True)),
        ("roofline_table", roofline_table.main),
    ]
    failures = []
    for name, fn in figs:
        t = time.time()
        print(f"== {name} ==", flush=True)
        try:
            fn()
        except Exception as e:  # keep the suite running, but gate at exit
            print(f"{name},ERROR,{type(e).__name__}: {e}", file=sys.stderr)
            failures.append(name)
        print(f"# {name} took {time.time() - t:.1f}s", flush=True)
    if failures:
        print(f"FAILED figures: {', '.join(failures)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
