"""Wall-clock benchmark of the routing hot path — the repo's first
wall-clock perf trajectory artifact.

``python -m benchmarks.route_bench [--quick] [--out BENCH_route.json]``
emits schema ``dcra-route-bench/v2`` with two kinds of wall-clock cells:

* **op-level** ``cells`` — one owner-route-shaped ``bucket()`` round
  (rank + capacity test + slot scatter, payload + one metadata column)
  per ``route_impl`` over an N x S grid, with ``speedup_vs_onehot`` per
  impl — the machine-portable number the CI gate
  (:mod:`repro.dse.route_compare`) tracks, since absolute ms do not
  transfer across runners;
* **round-level** ``round_cells`` — what users actually pay per
  iteration: a jitted multi-round min-relay loop (payload gather ->
  admission -> receive-reduce -> frontier update, the per-shard work of
  one ``run_program`` round between collectives), timed in BOTH round
  shapes per impl: ``lockstep`` (``bucket`` + ``reduce_received``, the
  classic two-pass round) vs ``pipelined`` (``local_route_reduce``, the
  round_mode="pipelined" fold of the receive-reduce into the
  communication edge). The bench itself asserts the two shapes are
  bit-identical (final state AND per-round drop streams) before timing,
  and ``round_speedup`` (lockstep ms / pipelined ms per impl) is gated
  by :mod:`repro.dse.route_compare` like the op-level ratios.

``pallas_lowering`` records what the "pallas" impl actually ran:
``"mosaic"`` on TPU, ``"xla"`` elsewhere (the interpreter-free tile-scan
rendering of the same algorithm — the deployed fast path; the Pallas
interpreter is never benchmarked).

The committed BENCH_route.json at the repo root is the quick-grid
baseline the bench-smoke CI job compares against.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import numpy as np


QUICK_GRID = [(4096, 8), (4096, 64), (16384, 16), (65536, 8), (65536, 64),
              (131072, 128)]
FULL_GRID = QUICK_GRID + [(262144, 64), (262144, 256)]
# Round-level cells are ~ROUNDS x the op cost, so use a smaller grid that
# still ends on the headline cell the acceptance gate tracks.
ROUND_QUICK_GRID = [(16384, 16), (65536, 64), (131072, 128)]
ROUND_FULL_GRID = ROUND_QUICK_GRID + [(262144, 256)]
ROUNDS = 6
IMPLS = ("onehot", "sort", "pallas")
MODES = ("lockstep", "pipelined")
SCHEMA = "dcra-route-bench/v2"


def _bench_cell(n: int, s: int, reps: int) -> Dict:
    import jax
    import jax.numpy as jnp
    from repro.core.queues import round8
    from repro.core.routing import bucket

    cap = round8(2 * n // max(s, 1))
    rng = np.random.default_rng(n + s)
    dest = jnp.asarray(rng.integers(0, s, n), jnp.int32)
    valid = jnp.asarray(rng.random(n) < 0.9)
    vals = jnp.asarray(rng.random((n, 1)), jnp.float32)
    slot_ids = jnp.asarray(rng.integers(0, n, n), jnp.int32)

    fns = {}
    outs = {}
    est = []
    for impl in IMPLS:
        f = jax.jit(lambda v, d, va, sl, impl=impl: bucket(
            v, d, va, [sl], s, cap, impl=impl))
        outs[impl] = f(vals, dest, valid, slot_ids)    # compile
        jax.block_until_ready(outs[impl])
        t0 = time.perf_counter()                       # warm + estimate
        jax.block_until_ready(f(vals, dest, valid, slot_ids))
        est.append(time.perf_counter() - t0)
        fns[impl] = f
    # Sub-ms cells need many samples for a stable median — scale reps so
    # every impl accumulates >= ~150 ms of measurement (capped), and
    # interleave the impls per rep so machine-load drift hits all three
    # equally instead of biasing whichever ran last.
    reps = max(reps, min(100, int(0.15 / max(min(est), 1e-5)) + 1))
    times: Dict[str, List[float]] = {impl: [] for impl in IMPLS}
    for _ in range(reps):
        for impl in IMPLS:
            t0 = time.perf_counter()
            jax.block_until_ready(fns[impl](vals, dest, valid, slot_ids))
            times[impl].append(time.perf_counter() - t0)
    ms = {impl: float(np.median(times[impl]) * 1e3) for impl in IMPLS}
    # the bench is only meaningful if the impls agree — assert it here
    ref = outs["onehot"]
    for impl in ("sort", "pallas"):
        got = outs[impl]
        assert jax.numpy.array_equal(ref[0], got[0]), (n, s, impl)
        assert int(ref[3]) == int(got[3]), (n, s, impl)
    return {"n": n, "s": s, "cap": cap, "ms": ms,
            "speedup_vs_onehot": {i: ms["onehot"] / ms[i] for i in IMPLS}}


def _bench_round_cell(n: int, s: int, reps: int) -> Dict:
    """Time ROUNDS iterations of a min-relay round in both round shapes.

    The loop body is the per-shard work of one ``run_program`` round
    between collectives: gather payloads from the frontier, admit into
    capacity-bounded buckets, receive-reduce into the state vector, and
    recompute the frontier from what improved. ``lockstep`` renders it as
    the classic two-pass ``bucket`` -> ``reduce_received``; ``pipelined``
    as the fused ``local_route_reduce`` fold (exactly what
    ``round_mode="pipelined"`` runs on a single shard). Both are asserted
    bit-identical — same final state, same per-round drop stream — before
    any timing, so the speedup column can never hide a semantic change.
    """
    import jax
    import jax.numpy as jnp
    from repro.core.queues import round8
    from repro.core.routing import bucket, local_route_reduce, reduce_received

    cap = round8(2 * n // max(s, 1))
    n_local = max(n // 4, s)
    rng = np.random.default_rng(n + s + 1)
    src = jnp.asarray(rng.integers(0, n_local, n), jnp.int32)
    dest = jnp.asarray(rng.integers(0, s, n), jnp.int32)
    slot_ids = jnp.asarray(rng.integers(0, n_local, n), jnp.int32)
    w = jnp.asarray(rng.random(n) + 0.05, jnp.float32)
    state0 = jnp.full((n_local,), jnp.inf, jnp.float32).at[0].set(0.0)
    frontier0 = jnp.isfinite(state0)

    def step(state, frontier, impl, mode):
        active = frontier[src]
        vals = state[src] + w
        if mode == "lockstep":
            xb, (slot_b,), _, nd = bucket(
                vals[:, None], dest, active, [slot_ids], s, cap, impl=impl)
            upd = reduce_received(slot_b, xb[:, 0], n_local, "min", impl=impl)
        else:
            upd, nd = local_route_reduce(
                vals, slot_ids, dest, active, s, cap, n_local, "min",
                impl=impl)
        frontier2 = upd < state
        return jnp.minimum(state, upd), frontier2, nd

    def run(impl, mode):
        def body(_, carry):
            state, frontier, drops, r = carry
            state, frontier, nd = step(state, frontier, impl, mode)
            return state, frontier, drops.at[r].set(nd), r + 1
        init = (state0, frontier0, jnp.zeros((ROUNDS,), jnp.int32),
                jnp.int32(0))
        state, _, drops, _ = jax.lax.fori_loop(0, ROUNDS, body, init)
        return state, drops

    fns = {}
    outs = {}
    est = []
    for impl in IMPLS:
        for mode in MODES:
            f = jax.jit(lambda impl=impl, mode=mode: run(impl, mode))
            outs[impl, mode] = jax.block_until_ready(f())   # compile
            t0 = time.perf_counter()                        # warm + estimate
            jax.block_until_ready(f())
            est.append(time.perf_counter() - t0)
            fns[impl, mode] = f
    # bit-identity across shapes AND impls before any timing
    ref_state, ref_drops = outs["onehot", "lockstep"]
    for key, (got_state, got_drops) in outs.items():
        assert jax.numpy.array_equal(ref_state, got_state), (n, s, key)
        assert jax.numpy.array_equal(ref_drops, got_drops), (n, s, key)
    reps = max(reps, min(50, int(0.15 / max(min(est), 1e-5)) + 1))
    times: Dict = {key: [] for key in fns}
    for _ in range(reps):
        for key, f in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(f())
            times[key].append(time.perf_counter() - t0)
    ms = {mode: {impl: float(np.median(times[impl, mode]) * 1e3)
                 for impl in IMPLS} for mode in MODES}
    return {"n": n, "s": s, "cap": cap, "rounds": ROUNDS, "round_ms": ms,
            "round_speedup": {i: ms["lockstep"][i] / ms["pipelined"][i]
                              for i in IMPLS}}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI grid (the committed baseline's grid)")
    ap.add_argument("--out", default="BENCH_route.json")
    ap.add_argument("--reps", type=int, default=0,
                    help="timing reps per impl (0 = 7 quick / 9 full)")
    args = ap.parse_args(argv)
    import jax

    grid = QUICK_GRID if args.quick else FULL_GRID
    round_grid = ROUND_QUICK_GRID if args.quick else ROUND_FULL_GRID
    reps = args.reps or (7 if args.quick else 9)
    cells: List[Dict] = []
    for n, s in grid:
        cell = _bench_cell(n, s, reps)
        cells.append(cell)
        sp = cell["speedup_vs_onehot"]
        print(f"route_bench,N={n},S={s},cap={cell['cap']},"
              f"onehot={cell['ms']['onehot']:.3f}ms,"
              f"sort={sp['sort']:.2f}x,pallas={sp['pallas']:.2f}x",
              flush=True)
    round_cells: List[Dict] = []
    for n, s in round_grid:
        cell = _bench_round_cell(n, s, reps)
        round_cells.append(cell)
        sp = cell["round_speedup"]
        print(f"round_bench,N={n},S={s},cap={cell['cap']},"
              f"rounds={cell['rounds']},"
              f"lockstep={cell['round_ms']['lockstep']['pallas']:.3f}ms,"
              f"pipelined:onehot={sp['onehot']:.2f}x,"
              f"sort={sp['sort']:.2f}x,pallas={sp['pallas']:.2f}x",
              flush=True)
    bench = {
        "schema": SCHEMA,
        "backend": jax.default_backend(),
        "pallas_lowering": ("mosaic" if jax.default_backend() == "tpu"
                            else "xla"),
        "quick": bool(args.quick),
        "impls": list(IMPLS),
        "cells": cells,
        "round_cells": round_cells,
    }
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {args.out} ({len(cells)} cells, "
          f"{len(round_cells)} round cells)")


if __name__ == "__main__":
    main()
