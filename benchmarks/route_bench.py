"""Wall-clock benchmark of the routing hot path — the repo's first
wall-clock perf trajectory artifact.

``python -m benchmarks.route_bench [--quick] [--out BENCH_route.json]``
times one owner-route-shaped ``bucket()`` round (rank + capacity test +
slot scatter, payload + one metadata column) per ``route_impl`` over an
N x S grid, emitting schema ``dcra-route-bench/v1``:

* per-cell, per-impl median ms (jit-compiled, ``block_until_ready``);
* ``speedup_vs_onehot`` per impl — the machine-portable number the CI
  gate (:mod:`repro.dse.route_compare`) tracks, since absolute ms do not
  transfer across runners;
* ``pallas_lowering`` records what the "pallas" impl actually ran:
  ``"mosaic"`` on TPU, ``"xla"`` elsewhere (the interpreter-free
  tile-scan rendering of the same algorithm — the deployed fast path;
  the Pallas interpreter is never benchmarked).

The committed BENCH_route.json at the repo root is the quick-grid
baseline the bench-smoke CI job compares against.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import numpy as np


QUICK_GRID = [(4096, 8), (4096, 64), (16384, 16), (65536, 8), (65536, 64),
              (131072, 128)]
FULL_GRID = QUICK_GRID + [(262144, 64), (262144, 256)]
IMPLS = ("onehot", "sort", "pallas")
SCHEMA = "dcra-route-bench/v1"


def _bench_cell(n: int, s: int, reps: int) -> Dict:
    import jax
    import jax.numpy as jnp
    from repro.core.queues import round8
    from repro.core.routing import bucket

    cap = round8(2 * n // max(s, 1))
    rng = np.random.default_rng(n + s)
    dest = jnp.asarray(rng.integers(0, s, n), jnp.int32)
    valid = jnp.asarray(rng.random(n) < 0.9)
    vals = jnp.asarray(rng.random((n, 1)), jnp.float32)
    slot_ids = jnp.asarray(rng.integers(0, n, n), jnp.int32)

    fns = {}
    outs = {}
    est = []
    for impl in IMPLS:
        f = jax.jit(lambda v, d, va, sl, impl=impl: bucket(
            v, d, va, [sl], s, cap, impl=impl))
        outs[impl] = f(vals, dest, valid, slot_ids)    # compile
        jax.block_until_ready(outs[impl])
        t0 = time.perf_counter()                       # warm + estimate
        jax.block_until_ready(f(vals, dest, valid, slot_ids))
        est.append(time.perf_counter() - t0)
        fns[impl] = f
    # Sub-ms cells need many samples for a stable median — scale reps so
    # every impl accumulates >= ~150 ms of measurement (capped), and
    # interleave the impls per rep so machine-load drift hits all three
    # equally instead of biasing whichever ran last.
    reps = max(reps, min(100, int(0.15 / max(min(est), 1e-5)) + 1))
    times: Dict[str, List[float]] = {impl: [] for impl in IMPLS}
    for _ in range(reps):
        for impl in IMPLS:
            t0 = time.perf_counter()
            jax.block_until_ready(fns[impl](vals, dest, valid, slot_ids))
            times[impl].append(time.perf_counter() - t0)
    ms = {impl: float(np.median(times[impl]) * 1e3) for impl in IMPLS}
    # the bench is only meaningful if the impls agree — assert it here
    ref = outs["onehot"]
    for impl in ("sort", "pallas"):
        got = outs[impl]
        assert jax.numpy.array_equal(ref[0], got[0]), (n, s, impl)
        assert int(ref[3]) == int(got[3]), (n, s, impl)
    return {"n": n, "s": s, "cap": cap, "ms": ms,
            "speedup_vs_onehot": {i: ms["onehot"] / ms[i] for i in IMPLS}}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI grid (the committed baseline's grid)")
    ap.add_argument("--out", default="BENCH_route.json")
    ap.add_argument("--reps", type=int, default=0,
                    help="timing reps per impl (0 = 7 quick / 9 full)")
    args = ap.parse_args(argv)
    import jax

    grid = QUICK_GRID if args.quick else FULL_GRID
    reps = args.reps or (7 if args.quick else 9)
    cells: List[Dict] = []
    for n, s in grid:
        cell = _bench_cell(n, s, reps)
        cells.append(cell)
        sp = cell["speedup_vs_onehot"]
        print(f"route_bench,N={n},S={s},cap={cell['cap']},"
              f"onehot={cell['ms']['onehot']:.3f}ms,"
              f"sort={sp['sort']:.2f}x,pallas={sp['pallas']:.2f}x",
              flush=True)
    bench = {
        "schema": SCHEMA,
        "backend": jax.default_backend(),
        "pallas_lowering": ("mosaic" if jax.default_backend() == "tpu"
                            else "xla"),
        "quick": bool(args.quick),
        "impls": list(IMPLS),
        "cells": cells,
    }
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {args.out} ({len(cells)} cells)")


if __name__ == "__main__":
    main()
