"""Deterministic synthetic token pipeline (host-sharded, restartable).

Produces reproducible batches keyed by (seed, step) — restart-safe without
saving data-loader state (the step index in the checkpoint is enough, the
standard trick for elastic training). Per-family extras (VLM patch embeds,
enc-dec source frames) are generated to the same contracts as
launch/sharding.batch_struct.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from ..configs.base import ArchConfig, ShapeConfig

VLM_PATCH_TOKENS = 256


def synth_batch(cfg: ArchConfig, shape: ShapeConfig, step: int,
                seed: int = 0) -> Dict[str, np.ndarray]:
    """One global batch for (cfg, shape) at ``step`` — pure function."""
    rng = np.random.default_rng(np.uint64(seed) * 1_000_003 + step)
    B, S = shape.global_batch, shape.seq_len
    V = cfg.vocab_size

    def tokens(b, s):
        # zipf-ish marginal over the vocab: realistic token frequencies
        z = rng.zipf(1.2, size=(b, s)).astype(np.int64)
        return (z % V).astype(np.int32)

    if cfg.family == "encdec":
        s_src = min(S // 2, 4096)
        s_tgt = S - s_src
        tgt = tokens(B, s_tgt)
        return {"src_embeds": rng.normal(
                    0, 1, (B, s_src, cfg.d_model)).astype(np.float32),
                "tokens": tgt, "labels": tgt}
    if cfg.family == "vlm":
        n_patch = min(VLM_PATCH_TOKENS, S // 2)
        grid = int(n_patch ** 0.5)
        n_patch = grid * grid
        s_txt = S - n_patch
        tok = tokens(B, s_txt)
        # M-RoPE positions: patches get (t=0, h, w); text gets (t, t, t)
        pos = np.zeros((B, 3, S), np.int32)
        hh, ww = np.meshgrid(np.arange(grid), np.arange(grid), indexing="ij")
        pos[:, 1, :n_patch] = hh.reshape(-1)
        pos[:, 2, :n_patch] = ww.reshape(-1)
        t = np.arange(s_txt) + grid
        pos[:, :, n_patch:] = t
        return {"tokens": tok, "labels": tok,
                "patch_embeds": rng.normal(
                    0, 1, (B, n_patch, cfg.d_model)).astype(np.float32),
                "positions": pos}
    tok = tokens(B, S)
    return {"tokens": tok, "labels": tok}


def batches(cfg: ArchConfig, shape: ShapeConfig, start_step: int = 0,
            seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield synth_batch(cfg, shape, step, seed)
        step += 1
