"""AdamW with decoupled weight decay, global-norm clipping, and an optional
error-feedback int8-compressed data-parallel gradient reduction hook."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


class AdamW(NamedTuple):
    lr: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        z = lambda p: jnp.zeros_like(p)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(z, params), jax.tree.map(z, params))

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        if self.clip_norm:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                          state.nu, grads)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self.lr(step)

        def upd(p, m, v):
            mhat = m / b1c
            vhat = v / b2c
            new = p.astype(jnp.float32) - lr * (
                mhat / (jnp.sqrt(vhat) + self.eps)
                + self.weight_decay * p.astype(jnp.float32))
            return new.astype(p.dtype)   # params may live in bf16

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step, mu, nu)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def cosine_schedule(peak_lr: float = 3e-4, warmup: int = 100,
                    total: int = 10_000, floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.minimum(warm, cos)
    return lr
