"""Error-feedback int8 gradient compression for the DP all-reduce.

1000-node posture (DESIGN.md §6): cross-pod gradient reduction is the
dominant wide-area collective. We quantize grads to int8 with a per-tensor
scale before the psum and keep the quantization residual locally (error
feedback), which provably preserves SGD convergence. 4x fewer bytes on the
``pod``/``data`` axes per step.

Used inside ``shard_map`` (manual collectives) by the train loop when
``compress_grads=True``.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any          # same pytree as grads


def init_ef(grads_shape) -> EFState:
    return EFState(jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32),
                                grads_shape))


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_psum(grads, ef: EFState, axis_names) -> Tuple[Any, EFState]:
    """Per-leaf: quantize(grad + residual) -> psum(int32) -> dequantize.

    Must run inside shard_map with ``axis_names`` manual axes.
    """
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = quantize_int8(g32)
        # sum int8 payloads in int32 (no overflow for <= 2^23 participants),
        # and average the scales — participants see near-identical scales
        # after the first steps; the residual absorbs the mismatch.
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_names)
        ssum = jax.lax.psum(scale, axis_names)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_names)
        mean = qsum.astype(jnp.float32) * (ssum / n) / n
        new_r = g32 - dequantize(q, scale)
        return mean.astype(g.dtype), new_r

    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(ef.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = tree.unflatten([o[0] for o in out])
    new_ef = EFState(tree.unflatten([o[1] for o in out]))
    return new_g, new_ef
