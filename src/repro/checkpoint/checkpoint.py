"""Mesh-agnostic checkpointing with atomic writes and elastic restore.

Design (DESIGN.md §6):
* leaves are saved as ``.npy`` files keyed by pytree path, plus a json
  manifest (step, tree structure, dtypes) — no pickle, portable;
* writes go to ``<dir>.tmp`` then ``os.replace`` -> crash/preemption safe;
* restore is MESH-AGNOSTIC: arrays are loaded on host then device_put with
  the *target* sharding, so a checkpoint from N devices restores onto M
  (elastic rescale) — the paper's "same dies, different packaging" applied
  to training state;
* ``keep`` oldest-eviction retention.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str, step: int, tree: Any, keep: int = 3) -> str:
    """Save pytree; returns the final directory path."""
    dest = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = dest + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _flatten(tree)
    manifest = {"step": step, "keys": []}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = re.sub(r"[^A-Za-z0-9_.-]", "_", key) + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["keys"].append({"key": key, "file": fname,
                                 "dtype": str(arr.dtype),
                                 "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(dest):
        shutil.rmtree(dest)
    os.replace(tmp, dest)      # atomic publish
    _evict(ckpt_dir, keep)
    return dest


def _evict(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target: Any,
            shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``target`` (pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    NamedSharding for elastic placement onto the current mesh."""
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {e["key"]: e for e in manifest["keys"]}
    flat_t, treedef = _flatten(target)
    flat_s, _ = _flatten(shardings) if shardings is not None else ({}, None)
    out = []
    for key, leaf in flat_t.items():
        e = by_key[key]
        arr = np.load(os.path.join(src, e["file"]))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"target {leaf.shape}")
        sh = flat_s.get(key)
        out.append(jax.device_put(arr.astype(leaf.dtype), sh)
                   if sh is not None else
                   jax.device_put(arr.astype(leaf.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out)
