"""DCRA task-routed MoE dispatch (the paper's technique as an LM feature).

Mapping (DESIGN.md §3): tokens = task invocations, experts = tiles owning
data, top-k routing = task spawning, expert capacity = IQ size (overflow is
dropped and carried by the residual — the paper's queue-overflow semantics),
and the dispatch all-to-all is the NoC. The *hierarchical* path performs a
two-stage all-to-all — intra-pod over the ``expert`` axis (tile-NoC), then
across pods over the ``pod`` axis (die-NoC) — the paper's §III-A two-level
torus: long-distance traffic is aggregated at a per-pod "portal", exactly
one die-NoC hop, instead of every tile talking across the package boundary.

Only the payload (x) and the local-expert id travel; source-slot and gate
metadata stay on the devices that need them for the return path, so the
collective bytes are the minimum the routing requires.

The bucketing / fused-payload all_to_all / pod-portal machinery lives in
:mod:`repro.core.routing` (shared with the distributed graph apps in
:mod:`repro.sparse.jax_apps`); this module keeps only what is MoE-specific:
the dispatch plan, the expert FFN, gating, and the return/combine path.
Everything is built from ``segment_sum`` scatter/gather (differentiable) and
one fused ``all_to_all`` per NoC stage under ``shard_map``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .compat import shard_map_unchecked
from .queues import QueueConfig
from .routing import (bucket as _bucket, fused_all_to_all, gather_rows,
                      noc_all_to_all as _a2a, resolve_route_impl,
                      slot_scatter as _slot_scatter)


def dispatch_queues(moe_cfg) -> QueueConfig:
    """The MoE dispatch IQ sizing as a :class:`QueueConfig`.

    The ``capacity_factor`` knob IS the paper's IQ-size axis (Table II
    knob #8) — expressed here as relative ``iq_factors`` for the three
    bounded queues the dispatch routes through: the stage-1 tile-NoC
    bucket ("dispatch"), the stage-2 pod-portal bucket ("portal"), and the
    per-local-expert receive bucket ("expert"). ``moe_dcra`` resolves every
    bucket capacity with :meth:`QueueConfig.channel_cap` — the same path
    the graph apps and the analytic ``TaskEngine`` use.
    """
    return QueueConfig.for_moe_dispatch(moe_cfg.capacity_factor)


@dataclass(frozen=True)
class MeshInfo:
    mesh: Mesh
    data_axis: str = "data"
    expert_axis: str = "expert"
    tp_axis: str = "tp"
    pod_axis: Optional[str] = None       # set on the multi-pod mesh
    hierarchical: bool = True            # 2-stage a2a when experts span pods
    fsdp: bool = True                    # expert weights sharded over data
    fuse_tp: bool = True                 # fold tp into the expert group when
                                         # E divides (no psum, no seq gather)

    def __post_init__(self):
        from .fabric import Fabric
        if isinstance(self.mesh, Fabric):      # accept a Fabric transparently
            object.__setattr__(self, "mesh", self.mesh.mesh)

    def axis_size(self, name) -> int:
        from .fabric import Fabric
        if isinstance(name, list):
            name = tuple(name)
        return Fabric.of(self.mesh).axis_size(name)

    def all_axes(self) -> Tuple[str, ...]:
        return tuple(self.mesh.axis_names)

    def dispatch_plan(self, num_experts: int):
        """How experts map onto the mesh — the packaging-time knob.

        Returns (group_axes_in_pod, spans_pods, tp_shards_ffn):
        * group_axes_in_pod: tuple of axes whose devices each own E/K experts
          (the stage-1 / tile-NoC all-to-all group);
        * spans_pods: stage-2 over the pod axis (die-NoC) is needed;
        * tp_shards_ffn: tp is NOT in the group -> expert FFN dim is
          tp-sharded (partial-F psum) and seq must be gathered over tp.
        """
        n_ex = self.axis_size(self.expert_axis)
        n_tp = self.axis_size(self.tp_axis)
        n_pod = self.axis_size(self.pod_axis)
        has_pod = self.pod_axis is not None and n_pod > 1
        cands = []
        if self.fuse_tp:
            if has_pod and self.hierarchical:
                cands.append(((self.expert_axis, self.tp_axis), True))
            cands.append(((self.expert_axis, self.tp_axis), False))
        if has_pod and self.hierarchical:
            cands.append(((self.expert_axis,), True))
        cands.append(((self.expert_axis,), False))
        for group, spans in cands:
            total = self.axis_size(group) * (n_pod if spans else 1)
            if num_experts % total == 0:
                return group, spans, self.tp_axis not in group
        return (self.expert_axis,), False, True


def _expert_ffn(xe, wg, wu, wd, tp_axis, n_tp):
    """xe [E_l, C, D]; wg/wu [E_l, D, F_l]; wd [E_l, F_l, D] -> [E_l, C, D]."""
    dt = xe.dtype
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg.astype(dt))) * \
        jnp.einsum("ecd,edf->ecf", xe, wu.astype(dt))
    y = jnp.einsum("ecf,efd->ecd", h, wd.astype(dt))
    if n_tp > 1:
        y = jax.lax.psum(y, tp_axis)   # F is tp-sharded -> partial sums
    return y


def moe_dcra(params, x, cfg, info: MeshInfo,
             queues: Optional[QueueConfig] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """DCRA owner-routed dispatch. x [B, S, D] -> (out [B,S,D], aux []).

    ``queues`` overrides the dispatch queue sizing; the default derives it
    from ``cfg.moe.capacity_factor`` via :func:`dispatch_queues` (a
    ``DesignPoint.moe_queues()`` plugs in here for DSE sweeps).
    """
    mc = cfg.moe
    assert mc is not None
    if queues is None:
        queues = dispatch_queues(mc)
    # the three bounded dispatch buckets share one routing engine
    impl = resolve_route_impl(queues.route_impl)
    E = mc.num_experts
    group, spans_pods, tp_ffn = info.dispatch_plan(E)
    n_group = info.axis_size(group)
    n_pod = info.axis_size(info.pod_axis) if spans_pods else 1
    n_ex = n_group
    E_local = E // (n_group * n_pod)
    n_tp = info.axis_size(info.tp_axis) if tp_ffn else 1

    batch_ax = ((info.pod_axis, info.data_axis) if info.pod_axis
                else info.data_axis)

    def _div(n, ax):
        return ax is not None and n % info.axis_size(ax) == 0

    b_in, s_in, _ = x.shape
    if not _div(b_in, batch_ax):       # tiny-batch decode fallbacks
        batch_ax = info.data_axis if _div(b_in, info.data_axis) else None
    # Preferred: seq sharded over the WHOLE dispatch group (+tp when the
    # FFN is tp-split) — tokens arrive distinct per shard, no pre-gather,
    # no slice (the residual stream is already seq-sharded this way by SP).
    grp = tuple(group) if isinstance(group, tuple) else (group,)
    seq_group = grp + ((info.tp_axis,) if tp_ffn else ())
    if _div(s_in, seq_group):
        seq_ax, seq_mode = seq_group, "group"
    elif _div(s_in, info.tp_axis) and info.axis_size(info.tp_axis) > 1:
        seq_ax, seq_mode = info.tp_axis, "tp"
    else:
        seq_ax, seq_mode = None, None
    x_spec = P(batch_ax, seq_ax, None)
    e_dim = ((info.pod_axis,) + tuple(group) if spans_pods else
             (group if isinstance(group, tuple) else (group,)))
    e_dim = e_dim[0] if len(e_dim) == 1 else e_dim
    f_axis = info.tp_axis if tp_ffn else None
    d_axis = info.data_axis if info.fsdp else None
    w_specs = (P(None, None),                 # router (replicated)
               P(e_dim, d_axis, f_axis),      # wg
               P(e_dim, d_axis, f_axis),      # wu
               P(e_dim, f_axis, d_axis))      # wd

    def kernel(router, wg, wu, wd, xb):
        s_shard = xb.shape[1]
        tp_gather = tp_ffn and n_tp > 1 and seq_mode is not None
        if tp_gather:
            # FFN is tp-split on F (partial psum): every tp rank must hold
            # the same tokens -> gather the seq shards.
            xb = jax.lax.all_gather(xb, info.tp_axis, axis=1, tiled=True)
        b_l, s_l, D = xb.shape
        T_l = b_l * s_l
        xf = xb.reshape(T_l, D)
        # In "group" seq mode tokens are already distinct per expert-rank.
        # Otherwise the residual stream is REPLICATED over the expert axis
        # (it serves as a TP axis for dense layers) — each expert-rank then
        # dispatches only its 1/n_ex slice and the output is re-gathered.
        n_slice = info.axis_size(info.expert_axis)
        do_slice = (seq_mode != "group" and n_slice > 1
                    and T_l % n_slice == 0)
        if do_slice:
            e_i = jax.lax.axis_index(info.expert_axis)
            T_l = T_l // n_slice
            xf = jax.lax.dynamic_slice_in_dim(xf, e_i * T_l, T_l, 0)
        if info.fsdp:
            wg = jax.lax.all_gather(wg, info.data_axis, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, info.data_axis, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, info.data_axis, axis=2, tiled=True)

        # --- routing (task spawning) -----------------------------------
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                            router.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gates, eids = jax.lax.top_k(probs, mc.top_k)        # [T_l, K]
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        K = mc.top_k
        eids_f = eids.reshape(-1)
        gates_f = gates.reshape(-1).astype(jnp.float32)
        src_f = jnp.repeat(jnp.arange(T_l, dtype=jnp.int32), K)

        owner = eids_f // E_local                           # global shard id
        cap1 = queues.channel_cap("dispatch", T_l * K, n_ex)
        all_valid = jnp.ones_like(eids_f, dtype=bool)

        if not spans_pods:
            # ---- single-stage fused a2a (tile-NoC) ---------------------
            _, (eid1, tok1), slot_of_task, _ = _bucket(
                src_f[:, None] * 0, owner, all_valid,
                [eids_f % E_local, src_f], n_ex, cap1, impl=impl)
            xb1 = gather_rows(xf, tok1)
            xr, (eidr,) = fused_all_to_all(xb1, [eid1], group)
        else:
            # ---- stage 1 over expert axis (tile-NoC) -------------------
            e_coord = owner % n_ex
            p_coord = owner // n_ex
            _, (pc1, eid1, tok1), slot_of_task, _ = _bucket(
                src_f[:, None] * 0, e_coord, all_valid,
                [p_coord, eids_f % E_local, src_f], n_ex, cap1, impl=impl)
            xb1 = gather_rows(xf, tok1)
            xs1, (pcs, eids1) = fused_all_to_all(xb1, [pc1, eid1], group)
            n1 = xs1.shape[0]
            # ---- stage 2 over pod axis (die-NoC portal) ----------------
            valid1 = pcs >= 0
            cap2 = queues.channel_cap("portal", n1, n_pod)
            _, (eid2, slot1_of_s2), _, _ = _bucket(
                pcs[:, None] * 0, jnp.maximum(pcs, 0), valid1,
                [eids1, jnp.arange(n1, dtype=jnp.int32)], n_pod, cap2,
                impl=impl)
            xb2 = gather_rows(xs1, slot1_of_s2)
            xr, (eidr,) = fused_all_to_all(xb2, [eid2], info.pod_axis)

        # --- local expert execution (owner computes) --------------------
        N_r = xr.shape[0]
        validr = eidr >= 0
        if E_local == 1:
            ye = _expert_ffn(xr[None].astype(xb.dtype), wg, wu, wd,
                             info.tp_axis, n_tp)[0]
            ye = ye * validr[:, None].astype(ye.dtype)
        else:
            # second-level IQ: bucket received tasks by local expert
            cap_e = queues.channel_cap("expert", N_r, E_local)
            _, (srce,), _, _ = _bucket(
                validr[:, None].astype(jnp.int32) * 0, jnp.maximum(eidr, 0),
                validr, [jnp.arange(N_r, dtype=jnp.int32)], E_local, cap_e,
                impl=impl)
            xe = gather_rows(xr, srce)
            ye_b = _expert_ffn(xe.reshape(E_local, cap_e, D).astype(xb.dtype),
                               wg, wu, wd, info.tp_axis, n_tp)
            ye = _slot_scatter(ye_b.reshape(E_local * cap_e, D),
                               jnp.maximum(srce, 0), srce >= 0, N_r)

        # --- return path (retrace the NoC route) ------------------------
        if not spans_pods:
            yb1 = _a2a(ye, group)
        else:
            y2 = _a2a(ye, info.pod_axis)                    # back to portal
            y1 = _slot_scatter(y2, jnp.maximum(slot1_of_s2, 0),
                               slot1_of_s2 >= 0, n1)
            yb1 = _a2a(y1, group)                # back to source

        # combine at the source: task slot -> token, weighted by gate
        task_y = jnp.where(
            (slot_of_task >= 0)[:, None],
            yb1[jnp.maximum(slot_of_task, 0)], 0.0).astype(jnp.float32)
        out = jax.ops.segment_sum(task_y * gates_f[:, None], src_f,
                                  num_segments=T_l)

        # aux: load-balance loss, averaged over all devices
        frac = jax.nn.one_hot(eids, E, dtype=jnp.float32).sum(1).mean(0)
        aux = E * jnp.sum(frac * probs.mean(0))
        aux = jax.lax.pmean(aux, info.all_axes())
        if do_slice:   # restore the expert-replicated layout
            out = jax.lax.all_gather(out, info.expert_axis, axis=0,
                                     tiled=True)
        out = out.reshape(b_l, s_l, D).astype(x.dtype)
        if tp_gather:   # slice back this rank's seq shard
            tp_i = jax.lax.axis_index(info.tp_axis)
            out = jax.lax.dynamic_slice_in_dim(out, tp_i * s_shard, s_shard,
                                               axis=1)
        return out, aux

    fn = shard_map_unchecked(kernel, mesh=info.mesh,
                             in_specs=(*w_specs, x_spec),
                             out_specs=(x_spec, P()))
    out, aux = fn(params["router"], params["wg"], params["wu"], params["wd"],
                  x)
    return out, aux
