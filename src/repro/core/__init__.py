# The paper's primary contribution, adapted to TPU/JAX (see DESIGN.md):
# task-based PGAS execution (task_engine), software-reconfigurable torus
# topology model (topology), queue & SRAM-cache models (queues, cache), and
# the DCRA owner-routed hierarchical MoE dispatch (dispatch).
from .cache import CacheModel, DRAMConfig, SRAMConfig          # noqa: F401
from .dispatch import MeshInfo, moe_dcra                        # noqa: F401
from .queues import QueueConfig, QueueStats                     # noqa: F401
from .task_engine import EngineConfig, RunStats, TaskEngine     # noqa: F401
from .topology import TileGrid                                  # noqa: F401
