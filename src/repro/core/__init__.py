# The paper's primary contribution, adapted to TPU/JAX (see README.md):
# task-based PGAS execution (task_engine), software-reconfigurable torus
# topology model (topology), queue & SRAM-cache models (queues, cache), the
# shared owner-routed NoC collective layer (routing), and the DCRA
# owner-routed hierarchical MoE dispatch built on it (dispatch).
from .cache import CacheModel, DRAMConfig, SRAMConfig          # noqa: F401
from .compat import make_mesh, set_mesh, shard_map_unchecked   # noqa: F401
from .dispatch import MeshInfo, dispatch_queues, moe_dcra       # noqa: F401
from .fabric import Fabric, as_fabric, axis_sizes_of            # noqa: F401
from .queues import QueueConfig, QueueStats                     # noqa: F401
from .routing import (bucket, fused_all_to_all, gather_rows,    # noqa: F401
                      noc_all_to_all, owner_route,
                      owner_route_hier, positions_by_dest,
                      reduce_received, round8, slot_scatter)
from .task_engine import EngineConfig, RunStats, TaskEngine     # noqa: F401
from .topology import TileGrid                                  # noqa: F401
