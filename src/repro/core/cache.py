"""Reconfigurable SRAM management model (paper §III-B).

The tile's SRAM serves as scratchpad and/or a direct-mapped cache backed by
the die's private HBM slice. We model the *hit rate* analytically from the
access structure the task model exposes (the paper's own simulator works at
the same level for energy):

* streaming arrays (CSR values / column indices, walked once per round in
  order) hit at ``1 - 1/elems_per_line`` — the next-line prefetcher (paper
  §III-B) pushes this to ~1 when enabled;
* irregularly indexed arrays (vertex state) behave like random access into
  the cached segment: hit rate ≈ min(1, cache_capacity / footprint) for a
  direct-mapped cache under uniform reuse (conflict misses folded into the
  capacity term — datasets are much larger than the cache).

Effective per-tile bandwidth (paper §V-B):
  BW_eff = SRAM_bw * hit + DRAM_bw_per_tile * (1 - hit)
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class SRAMConfig:
    kb_per_tile: int = 512            # Table II knob #3 (Fig. 5 sweeps it)
    line_bits: int = 512              # = DRAM controller bitline (§III-B)
    scratchpad_fraction: float = 0.25  # program + queues + pinned arrays
    prefetch: bool = True


@dataclass(frozen=True)
class DRAMConfig:
    present: bool = True               # packaging-time knob #6
    gb_per_die: float = 8.0            # HBM2E device per chiplet
    channels: int = 8
    gbps_per_channel: float = 64.0     # 8 x 64 GB/s (Table III)
    tiles_per_die: int = 1024          # 32x32 -> 128 tiles/channel


@dataclass
class CacheModel:
    sram: SRAMConfig
    dram: DRAMConfig

    # ---- capacity ------------------------------------------------------
    def cache_bytes(self) -> float:
        return self.sram.kb_per_tile * 1024 * (1 - self.sram.scratchpad_fraction)

    # ---- hit rates -------------------------------------------------------
    def stream_hit_rate(self, elem_bytes: int) -> float:
        per_line = (self.sram.line_bits // 8) / elem_bytes
        base = 1.0 - 1.0 / max(per_line, 1.0)
        return 1.0 - (1.0 - base) * (0.1 if self.sram.prefetch else 1.0)

    def random_hit_rate(self, footprint_bytes_per_tile: float) -> float:
        if not self.dram.present:
            return 1.0  # pure scratchpad: everything resident by construction
        cap = self.cache_bytes()
        if footprint_bytes_per_tile <= 0:
            return 1.0
        return min(1.0, cap / footprint_bytes_per_tile)

    def hit_rate(self, stream_bytes: float, random_bytes: float,
                 footprint_bytes_per_tile: float, elem_bytes: int = 8) -> float:
        """Weighted hit rate over the access mix of one round."""
        tot = stream_bytes + random_bytes
        if tot == 0:
            return 1.0
        return (self.stream_hit_rate(elem_bytes) * stream_bytes +
                self.random_hit_rate(footprint_bytes_per_tile) * random_bytes
                ) / tot

    # ---- bandwidth -------------------------------------------------------
    def sram_bw_bytes_per_ns(self) -> float:
        # 0.82ns access, line-width port (Table III)
        return (self.sram.line_bits / 8) / 0.82

    def dram_bw_per_tile_bytes_per_ns(self) -> float:
        if not self.dram.present:
            return 0.0
        total = self.dram.channels * self.dram.gbps_per_channel  # GB/s
        return total / self.dram.tiles_per_die                   # ~bytes/ns

    def effective_bw(self, hit: float) -> float:
        """bytes/ns per tile (paper §V-B formula)."""
        dram_bw = self.dram_bw_per_tile_bytes_per_ns()
        if not self.dram.present:
            return self.sram_bw_bytes_per_ns()
        return self.sram_bw_bytes_per_ns() * hit + dram_bw * (1 - hit)
