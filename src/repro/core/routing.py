"""Owner-routed NoC collective layer — THE shared DCRA primitive.

Everything DCRA routes — MoE tokens to expert-owning tiles
(:mod:`repro.core.dispatch`) and graph/sparse update tasks to
vertex-owning tiles (:mod:`repro.sparse.jax_apps`) — is the same motion:

  1. *bucket*: tasks are grouped by destination shard into capacity-bounded
     buckets (the paper's input queue; overflow is dropped and counted);
  2. *deliver*: ONE ``all_to_all`` per NoC round carries a *fused payload* —
     int32 metadata columns are bitcast (bytes reinterpreted, never
     converted) to f32 and packed next to the value columns, so index+value
     travel in a single collective instead of two;
  3. optionally *hierarchical*: when shards span pods, stage 1 routes over
     the intra-pod axis to the destination's "portal" (the device in the
     sender's pod sharing the destination's intra-pod coordinate), stage 2
     hops once over the pod axis (die-NoC) — the paper's §III-A two-level
     torus.

All functions here are **per-shard**: they are meant to be called *inside*
a ``shard_map`` kernel (possibly inside a ``lax.while_loop`` for iterative
apps), so callers control layout, reduction, and the return path.

Shard-id convention for the hierarchical path: global shard
``g = pod * n_intra + intra`` — pods are the slow axis, matching a mesh
declared as ``('pod', ..., intra_axis)``.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# Capacity helpers live with the queue-sizing source of truth; re-exported
# here because every routing call site thinks in lane-aligned bucket sizes.
from .queues import round8  # noqa: F401
# The routing hot path has a kernel tier: `impl="pallas"` ranks/scatters
# through repro.kernels.route (Mosaic on TPU, the same tiled algorithm in
# plain XLA off-TPU); "sort" is the argsort fallback below; "onehot" is
# the legacy O(N*S) rank. Re-exported so call sites resolve the knob once.
from ..kernels.route import (_on_tpu, bucket_rank,  # noqa: F401
                             bucket_scatter_pallas, bucket_sort_gather,
                             fused_kernels_enabled, onehot_rank,
                             reduce_received_pallas, resolve_route_impl)


# ---------------------------------------------------------------------------
# per-round capacity resolution (shared by every routing call site)
# ---------------------------------------------------------------------------

def resolve_flat_cap(queues, task: str, e_local: int, n_shards: int,
                     clamp: bool = False) -> int:
    """One flat routing round's per-channel bucket capacity.

    Resolves through :meth:`QueueConfig.channel_cap` (the single IQ
    source of truth). ``None`` (unbounded) resolves to ``e_local`` — every
    local task fits its owner bucket. ``clamp=True`` additionally trims an
    explicit capacity at ``e_local``: a shard can never send more than its
    whole slice to one owner, so the clamp only shrinks the *allocation*
    (the receive buffer), never the admission behaviour — drop counts are
    identical either way, which is what keeps the analytic twin exact.
    """
    cap = queues.channel_cap(task, e_local, n_shards)
    if cap is None:
        cap = max(1, e_local)
    elif clamp:
        cap = min(int(cap), max(1, e_local))
    return max(1, int(cap))


def resolve_hier_caps(queues, task: str, e_local: int, n_intra: int,
                      n_pods: int) -> Tuple[int, int]:
    """Stage-1 (tile-NoC) / stage-2 (die-NoC portal) capacities for the
    pod/portal path. Stage 2 sizes from stage 1's worst-case egress
    (``n_intra * cap1`` tasks can land on one portal)."""
    cap1 = queues.channel_cap(task, e_local, n_intra)
    cap1 = max(1, e_local) if cap1 is None else int(cap1)
    cap2 = queues.channel_cap(task, n_intra * cap1, n_pods)
    cap2 = max(1, n_intra * cap1) if cap2 is None else int(cap2)
    return cap1, cap2


def resolve_caps(fabric, queues, task: str, e_local: int, axis: str,
                 pod_axis: Optional[str], *, clamp: bool = False
                 ) -> Tuple[Tuple[int, ...], Optional[Tuple[int, int]]]:
    """One launch's per-round capacities against a fabric: ``(caps, pods)``.

    Flat path (``pod_axis is None``): a 1-tuple cap over the fabric's
    whole device count, ``pods = None``. Pod/portal path: the 2-stage
    caps plus ``pods = (n_intra, n_pods)`` read off the fabric's axis
    sizes — the ONE place launches turn mesh axes into routing stage
    sizes (previously re-derived privately by ``dcra_scatter`` and the
    graph runtime). Explicit per-``task`` capacities are only defined for
    the flat path — the DSE revalidation honors them exactly, while the
    2-stage caps are relative. ``fabric`` is duck-typed (anything with
    ``axis_sizes`` / ``n_devices``, i.e. :class:`repro.core.fabric
    .Fabric`), so this layer stays import-free of the fabric module.
    """
    if queues.iq_sizes.get(task) is not None and pod_axis is not None:
        raise ValueError("explicit cap is only defined for the flat path")
    if pod_axis is None:
        return ((resolve_flat_cap(queues, task, e_local, fabric.n_devices,
                                  clamp=clamp),), None)
    sizes = fabric.axis_sizes
    pods = (sizes[axis], sizes[pod_axis])
    return resolve_hier_caps(queues, task, e_local, *pods), pods


# ---------------------------------------------------------------------------
# bucketing (the bounded IQ)
# ---------------------------------------------------------------------------

def positions_by_dest(dest, valid, n_buckets, impl=None):
    """Stable position of each *valid* task within its destination bucket
    (invalid entries are unspecified — callers mask with ``valid``).

    ``impl`` selects the ranking engine (see module doc of
    :mod:`repro.kernels.route`): ``"pallas"`` streams elements in tiles
    against per-destination running counts — O(N + S*tiles); ``"sort"``
    is the argsort-by-dest + segment-offsets fallback; ``"onehot"`` is
    the legacy O(N*S) one-hot cumsum.
    """
    impl = resolve_route_impl(impl)
    if impl == "pallas":
        return bucket_rank(dest, valid, n_buckets)
    if impl == "sort":
        return _positions_by_dest_sort(dest, valid, n_buckets)
    return onehot_rank(dest, valid, n_buckets)


def _positions_by_dest_sort(dest, valid, n_buckets):
    """Sort-based rank: stable argsort by destination (invalid pushed to a
    sentinel bucket), position = index - first index of the run — the same
    trick :func:`repro.sparse.program._pack_edges` uses host-side."""
    n = dest.shape[0]
    key = jnp.where(valid, dest.astype(jnp.int32), n_buckets)
    order = jnp.argsort(key, stable=True)
    ks = key[order]
    start = jnp.searchsorted(ks, ks, side="left")
    pos_sorted = (jnp.arange(n, dtype=jnp.int32)
                  - start.astype(jnp.int32))
    return jnp.zeros(n, jnp.int32).at[order].set(pos_sorted)


def slot_scatter(data, slot, valid, num_slots):
    """Scatter rows of ``data`` into slots (each slot receives <= 1 row)."""
    seg = jnp.where(valid, slot, num_slots)
    if data.ndim > 1:
        data = data * valid[:, None].astype(data.dtype)
    else:
        data = data * valid.astype(data.dtype)
    return jax.ops.segment_sum(data, seg, num_segments=num_slots + 1)[:num_slots]


def bucket(x_tasks, dest, valid, aux_ints, n_buckets, cap, impl=None):
    """Capacity-bounded bucketing (the IQ). Returns (xb, ints, slot, n_drop).

    xb [n_buckets*cap, D]; ints: like aux_ints but slot-ordered (-1 = empty);
    also returns each task's slot (-1 if dropped) for building return maps.

    ``impl`` picks the hot-path engine (see :func:`positions_by_dest`);
    drop semantics are bit-identical across impls — first ``cap`` tasks
    per channel in array order — so the analytic twins stay exact no
    matter which impl a launch resolves.
    """
    impl = resolve_route_impl(impl)
    if impl == "pallas" and _on_tpu() and fused_kernels_enabled():
        # fused Mosaic kernel: rank + capacity test + scatter in one pass
        # (opt-in until TPU-validated — see fused_kernels_enabled)
        x2 = x_tasks[:, None] if x_tasks.ndim == 1 else x_tasks
        xb, ints, task_slot, n_drop = bucket_scatter_pallas(
            x2, dest, valid, aux_ints, n_buckets, cap, interpret=False)
        if x_tasks.ndim == 1:
            xb = xb[:, 0]
        return xb, ints, task_slot, n_drop
    if impl == "sort":
        # the argsort already groups each bucket contiguously: build xb by
        # gathering the first `cap` of each run instead of paying a second
        # segment-sum scatter (bit-identical drop semantics)
        return bucket_sort_gather(x_tasks, dest, valid, aux_ints,
                                  n_buckets, cap)
    pos = positions_by_dest(dest, valid, n_buckets, impl=impl)
    keep = valid & (pos < cap)
    slot = dest * cap + jnp.minimum(pos, cap - 1)
    total = n_buckets * cap
    xb = slot_scatter(x_tasks, slot, keep, total)
    ints = [slot_scatter((a + 1).astype(jnp.int32), slot, keep, total) - 1
            for a in aux_ints]
    task_slot = jnp.where(keep, slot, -1)
    n_drop = jnp.sum(valid & ~keep)
    return xb, ints, task_slot, n_drop


def gather_rows(table, ids):
    """rows = table[ids] with id -1 -> zero rows (one gather; no K-fold
    payload replication before bucketing)."""
    rows = table[jnp.maximum(ids, 0)]
    return rows * (ids >= 0)[:, None].astype(rows.dtype)


# ---------------------------------------------------------------------------
# the NoC round: one fused all_to_all
# ---------------------------------------------------------------------------

def noc_all_to_all(x, axis):
    """One NoC round over ``axis`` (tiled all_to_all on the leading dim)."""
    return jax.lax.all_to_all(x, axis, 0, 0, tiled=True)


def pack_wire(vals: Optional[jax.Array], int_cols: Sequence[jax.Array]
              ) -> Tuple[jax.Array, tuple]:
    """Pack value + int32 metadata columns into one f32 wire array.

    Ints are *bitcast* to f32 — bytes reinterpreted, never converted.
    Half-width payloads (bf16/f16) are packed two per f32 wire lane
    (bitcast, not upcast), so fusing never inflates the wire bytes: the
    packed array has exactly ``ceil(D/2) + len(int_cols)`` columns for a
    half payload, ``D + len(int_cols)`` otherwise. Returns
    ``(packed, meta)``; feed ``meta`` to :func:`unpack_wire` for the
    exact round-trip (tested in tests/test_routing.py).
    """
    if vals is None and not int_cols:
        raise ValueError("nothing to route")
    cols = []
    squeeze = False
    dtype = None
    d_vals = 0
    half = False
    if vals is not None:
        dtype = vals.dtype
        v2 = vals
        if v2.ndim == 1:
            v2, squeeze = v2[:, None], True
        d_vals = v2.shape[1]
        half = dtype.itemsize == 2
        if half:
            if d_vals % 2:
                v2 = jnp.concatenate([v2, jnp.zeros_like(v2[:, :1])], axis=1)
            wire = jax.lax.bitcast_convert_type(
                v2.reshape(v2.shape[0], -1, 2), jnp.float32)
        else:
            wire = v2.astype(jnp.float32)
        cols.append(wire)
    for c in int_cols:
        packed_i = jax.lax.bitcast_convert_type(c.astype(jnp.int32),
                                                jnp.float32)
        cols.append(packed_i[:, None])
    packed = cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)
    return packed, (dtype, d_vals, half, squeeze, len(int_cols))


def unpack_wire(recv: jax.Array, meta: tuple
                ) -> Tuple[Optional[jax.Array], List[jax.Array]]:
    """Exact inverse of :func:`pack_wire` (bitcast round-trip)."""
    dtype, d_vals, half, squeeze, n_int = meta
    ints_out = []
    if n_int:
        tail = recv[:, recv.shape[1] - n_int:]
        ints_out = [jax.lax.bitcast_convert_type(tail[:, i], jnp.int32)
                    for i in range(n_int)]
    if dtype is None:
        return None, ints_out
    v_wire = recv[:, :recv.shape[1] - n_int]
    if half:
        v_out = jax.lax.bitcast_convert_type(v_wire, dtype)
        v_out = v_out.reshape(v_out.shape[0], -1)[:, :d_vals]
    else:
        v_out = v_wire.astype(dtype)
    if squeeze:
        v_out = v_out[:, 0]
    return v_out, ints_out


def fused_all_to_all(vals: Optional[jax.Array], int_cols: Sequence[jax.Array],
                     axis) -> Tuple[Optional[jax.Array], List[jax.Array]]:
    """Deliver value columns + int32 metadata columns in ONE all_to_all.

    ``vals`` [N, D] (or [N], or None) float payload; ``int_cols`` are [N]
    int32 arrays (slot ids, expert ids, ...). The columns are packed into
    a single f32 wire array (:func:`pack_wire` — ints bitcast, half-width
    payloads two per lane, never inflating the collective bytes), so each
    NoC round issues a single collective; the round-trip is exact.
    """
    packed, meta = pack_wire(vals, int_cols)
    recv = noc_all_to_all(packed, axis)
    return unpack_wire(recv, meta)


# ---------------------------------------------------------------------------
# owner-routed rounds (bucket + fused a2a), flat and hierarchical
# ---------------------------------------------------------------------------

def owner_route(vals, slot_ids, owner, valid, n_shards, cap, axis,
                impl=None):
    """One flat NoC round: route ``(slot_ids, vals)`` tasks to ``owner``.

    Per-shard (call inside shard_map). vals [N] f32 payload, slot_ids [N]
    int32 destination slot at the owner, owner [N] in [0, n_shards).
    Returns (recv_slot [n_shards*cap], recv_val, n_drop_local) — recv_slot
    is -1 for empty queue entries; n_drop_local counts this shard's
    IQ-overflow drops (psum over ``axis`` for the global count).
    """
    xb, (slot_b,), _, n_drop = bucket(vals[:, None], owner, valid,
                                      [slot_ids], n_shards, cap, impl=impl)
    recv_vals, (recv_slot,) = fused_all_to_all(xb, [slot_b], axis)
    return recv_slot, recv_vals[:, 0], n_drop


def owner_route_hier(vals, slot_ids, owner, valid, n_intra, intra_axis,
                     n_pods, pod_axis, cap1, cap2, impl=None):
    """Two-stage pod/portal NoC round (paper §III-A two-level torus).

    Stage 1 (tile-NoC): tasks go to the device in the *sender's* pod with
    the destination's intra-pod coordinate — the per-pod portal — so every
    package-boundary message is aggregated there. Stage 2 (die-NoC): the
    portal forwards over the pod axis, exactly one die crossing.
    Returns (recv_slot [n_pods*cap2], recv_val, n_drop_local).
    """
    e_coord = owner % n_intra
    p_coord = owner // n_intra
    xb, (pc_b, slot_b), _, drop1 = bucket(vals[:, None], e_coord, valid,
                                          [p_coord, slot_ids], n_intra, cap1,
                                          impl=impl)
    v1, (pc1, slot1) = fused_all_to_all(xb, [pc_b, slot_b], intra_axis)
    valid1 = pc1 >= 0
    xb2, (slot2_b,), _, drop2 = bucket(v1, jnp.maximum(pc1, 0), valid1,
                                       [slot1], n_pods, cap2, impl=impl)
    v2, (recv_slot,) = fused_all_to_all(xb2, [slot2_b], pod_axis)
    return recv_slot, v2[:, 0], drop1 + drop2


# ---------------------------------------------------------------------------
# split-phase rounds (the pipelined execution shape's communication edge)
# ---------------------------------------------------------------------------
#
# ``round_mode="pipelined"`` in :func:`repro.sparse.program.run_program`
# rotates the round loop: the collective for round k is LAUNCHED at the
# tail of loop iteration k-1 and its receive-reduce is consumed at the
# head of iteration k — the in-flight wire buffer is the loop carry (the
# double buffer). The helpers below split :func:`owner_route` /
# :func:`owner_route_hier` into that start/finish pair, and optionally
# ride a broadcast int32 *signal* (the while-loop's global frontier
# count) on the same collective as one extra row per destination bucket,
# so the pipelined loop needs NO per-round ``psum`` at all: one fused
# collective per round, where the lockstep shape issues four (a2a +
# message/drop/convergence psums).


def _a2a_with_signal(packed, n_blocks, signal, axis):
    """Tiled all_to_all of a packed wire array [n_blocks*rows, C] with one
    broadcast signal row appended per destination block.

    Every peer receives the sender's int32 ``signal`` (bitcast into
    column 0 of the extra row); the task rows' bytes are untouched — the
    exchanged blocks are simply [rows+1, C] instead of [rows, C], so the
    stripped receive buffer is value-identical to the plain collective.
    Returns ``(recv [n_blocks*rows, C], gsignal)`` where ``gsignal`` is
    the sum of all senders' signals — a global reduction ridden on the
    collective the round pays anyway (+1/rows wire overhead).
    """
    total, c = packed.shape
    rows = total // n_blocks
    sig = jax.lax.bitcast_convert_type(
        jnp.asarray(signal, jnp.int32), jnp.float32)
    sig_row = jnp.zeros((n_blocks, 1, c), packed.dtype).at[:, 0, 0].set(sig)
    wire = jnp.concatenate([packed.reshape(n_blocks, rows, c), sig_row],
                           axis=1).reshape(n_blocks * (rows + 1), c)
    recv = noc_all_to_all(wire, axis).reshape(n_blocks, rows + 1, c)
    gsignal = jnp.sum(jax.lax.bitcast_convert_type(recv[:, rows, 0],
                                                   jnp.int32))
    return recv[:, :rows].reshape(n_blocks * rows, c), gsignal


def owner_route_start(vals, slot_ids, owner, valid, n_shards, cap, axis,
                      signal, impl=None):
    """Produce half of one flat NoC round: bucket + pack + the fused
    collective (with ``signal`` ridden along, see :func:`_a2a_with_signal`).

    Returns ``(recv_wire, meta, n_drop_local, gsignal)``; hand
    ``(recv_wire, meta)`` to :func:`owner_route_finish` — possibly across
    a loop-carry boundary — for the exact :func:`owner_route` receive
    values. ``meta`` is static (shape/dtype bookkeeping), so only the
    wire buffer itself is carried.
    """
    xb, (slot_b,), _, n_drop = bucket(vals[:, None], owner, valid,
                                      [slot_ids], n_shards, cap, impl=impl)
    packed, meta = pack_wire(xb, [slot_b])
    recv, gsignal = _a2a_with_signal(packed, n_shards, signal, axis)
    return recv, meta, n_drop, gsignal


def owner_route_finish(recv_wire, meta):
    """Consume half: unpack the carried wire buffer into
    ``(recv_slot, recv_val)`` — feed :func:`reduce_received` to fold the
    receive-reduce into the communication edge."""
    recv_vals, (recv_slot,) = unpack_wire(recv_wire, meta)
    return recv_slot, recv_vals[:, 0]


def owner_route_hier_start(vals, slot_ids, owner, valid, n_intra,
                           intra_axis, n_pods, pod_axis, cap1, cap2,
                           signal, impl=None):
    """Produce half of one pod/portal round (both stages complete here —
    stage-2 bucketing needs stage-1's receive, so the die-NoC edge is the
    one the pipelined loop carries). The signal crosses both stages:
    stage 1 sums it pod-locally at every portal, stage 2 sums the pod
    totals, so ``gsignal`` is the same global sum the flat path yields.
    Returns ``(recv_wire2, meta2, n_drop_local, gsignal)``."""
    e_coord = owner % n_intra
    p_coord = owner // n_intra
    xb, (pc_b, slot_b), _, drop1 = bucket(vals[:, None], e_coord, valid,
                                          [p_coord, slot_ids], n_intra, cap1,
                                          impl=impl)
    packed1, meta1 = pack_wire(xb, [pc_b, slot_b])
    recv1, sig1 = _a2a_with_signal(packed1, n_intra, signal, intra_axis)
    v1, (pc1, slot1) = unpack_wire(recv1, meta1)
    valid1 = pc1 >= 0
    xb2, (slot2_b,), _, drop2 = bucket(v1, jnp.maximum(pc1, 0), valid1,
                                       [slot1], n_pods, cap2, impl=impl)
    packed2, meta2 = pack_wire(xb2, [slot2_b])
    recv2, gsignal = _a2a_with_signal(packed2, n_pods, sig1, pod_axis)
    return recv2, meta2, drop1 + drop2, gsignal


def local_route_reduce(vals, slot_ids, dest, valid, n_buckets, cap, n_local,
                       op, impl=None):
    """One whole round with a LOCAL communication edge: when producer and
    consumer are the same shard (``n_dev == 1`` launches; the per-shard
    round the bench simulates), folding the receive-reduce into admission
    eliminates the wire buffer — rank, capacity-test, and segment-reduce
    straight off the task stream, never materializing the
    ``[n_buckets*cap]`` bucket array or re-reading it at the receiver.

    Valid only for order-insensitive reduces (``min`` / ``store``): the
    kept set is identical to ``bucket`` + :func:`reduce_received` (same
    first-``cap``-per-channel rule, same rank ``impl``) and min/max are
    exact in f32, so the result and drop count are bit-identical to the
    two-pass path. ``add`` must keep the two-pass path — its summation
    order would differ. Returns ``(y [n_local], n_drop)``.
    """
    if op not in ("min", "store"):
        raise ValueError(f"local_route_reduce needs an order-insensitive "
                         f"reduce, got {op!r}")
    pos = positions_by_dest(dest, valid, n_buckets, impl=impl)
    keep = valid & (pos < cap)
    n_drop = jnp.sum(valid & ~keep)
    seg = jnp.where(keep, slot_ids, n_local)
    if op == "min":
        y = jax.ops.segment_min(jnp.where(keep, vals, jnp.inf), seg,
                                num_segments=n_local + 1)[:n_local]
        y = jnp.where(jnp.isfinite(y), y, jnp.inf)
    else:                                                # "store" (max)
        y = jax.ops.segment_max(jnp.where(keep, vals, -jnp.inf), seg,
                                num_segments=n_local + 1)[:n_local]
        y = jnp.where(jnp.isfinite(y), y, 0.0)
    return y, n_drop


def reduce_received(recv_slot, recv_val, n_local, op, impl=None):
    """Apply received tasks at the owner: segment add/min/store into local
    slots.

    ``op='store'`` is a last-writer overwrite with a *deterministic*
    tie-break: among duplicate destinations the maximum value wins —
    independent of bucket/slot arrival order, and by construction the same
    winner the analytic ``TaskEngine._reduce(op='store')`` picks for the
    same task stream (differential-tested in tests/test_core_engine.py).
    Slots that received no task read as 0. ``impl="pallas"`` on TPU runs
    the fused receive-reduce kernel (opt-in until TPU-validated — see
    :func:`repro.kernels.route.fused_kernels_enabled`); elsewhere the
    segment ops below are already the fastest XLA rendering.
    """
    if (resolve_route_impl(impl) == "pallas" and _on_tpu()
            and fused_kernels_enabled()):
        return reduce_received_pallas(recv_slot, recv_val, n_local, op,
                                      interpret=False)
    valid = recv_slot >= 0
    seg = jnp.where(valid, recv_slot, n_local)
    if op == "add":
        y = jax.ops.segment_sum(jnp.where(valid, recv_val, 0.0), seg,
                                num_segments=n_local + 1)[:n_local]
    elif op == "min":
        y = jax.ops.segment_min(jnp.where(valid, recv_val, jnp.inf), seg,
                                num_segments=n_local + 1)[:n_local]
        y = jnp.where(jnp.isfinite(y), y, jnp.inf)
    elif op == "store":
        y = jax.ops.segment_max(jnp.where(valid, recv_val, -jnp.inf), seg,
                                num_segments=n_local + 1)[:n_local]
        y = jnp.where(jnp.isfinite(y), y, 0.0)
    else:
        raise ValueError(op)
    return y
