"""Task queues (IQ/OQ) — DCRA Table II knob #8.

Each task type has an input queue (IQ) at the consumer tile and an output
queue (OQ) at the producer. The engine records per-round occupancies; the
performance model converts overflow into producer stalls (the paper's
Fig. 10 mechanism: undersized OQ2 stalls the upstream task at high fanout).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np


@dataclass
class QueueConfig:
    iq_sizes: Dict[str, int] = field(default_factory=dict)
    oq_sizes: Dict[str, int] = field(default_factory=dict)
    default_iq: int = 12     # task-invocation messages (paper Fig. 10)
    default_oq: int = 12

    def iq(self, task: str) -> int:
        return self.iq_sizes.get(task, self.default_iq)

    def oq(self, task: str) -> int:
        return self.oq_sizes.get(task, self.default_oq)


@dataclass
class QueueStats:
    """Per-round aggregate queue pressure."""
    peak_iq: Dict[str, int] = field(default_factory=dict)
    peak_oq: Dict[str, int] = field(default_factory=dict)
    total_tasks: Dict[str, int] = field(default_factory=dict)

    def record(self, task: str, per_tile_in: np.ndarray,
               per_tile_out: np.ndarray):
        self.peak_iq[task] = max(self.peak_iq.get(task, 0),
                                 int(per_tile_in.max(initial=0)))
        self.peak_oq[task] = max(self.peak_oq.get(task, 0),
                                 int(per_tile_out.max(initial=0)))
        self.total_tasks[task] = self.total_tasks.get(task, 0) + \
            int(per_tile_in.sum())
