"""Task queues (IQ/OQ) — DCRA Table II knob #8. THE single source of
queue-capacity truth.

Each task type has an input queue (IQ) at the consumer tile and an output
queue (OQ) at the producer. The engine records per-round occupancies; the
performance model converts overflow into producer stalls (the paper's
Fig. 10 mechanism: undersized OQ2 stalls the upstream task at high fanout).

Since PR 3 every bounded-queue capacity in the repo resolves through
:class:`QueueConfig` — there is no ``TaskEngine(iq_capacity=...)`` /
``route(iq_capacity=...)`` side-channel any more:

* the analytic :meth:`repro.core.task_engine.TaskEngine.route` reads
  ``cfg.queues.iq(task)`` per task type (``None`` = unbounded legacy
  stats, via :meth:`QueueConfig.unbounded`);
* the executable routing layer (``dcra_scatter`` and the MoE dispatch)
  resolves per-round bucket capacities with :meth:`QueueConfig.channel_cap`
  — either an explicit entry count (the DSE IQ axis, honored exactly) or a
  relative *capacity factor* (``iq_factors``; the MoE dispatch knob),
  lane-aligned with :func:`round8`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np


def round8(x: int) -> int:
    """Round a capacity up to a multiple of 8 (TPU lane alignment)."""
    return max(8, -(-x // 8) * 8)


# The MoE dispatch's bounded-queue task names (see for_moe_dispatch).
MOE_DISPATCH_TASKS = ("dispatch", "portal", "expert")


@dataclass
class QueueConfig:
    iq_sizes: Dict[str, int] = field(default_factory=dict)
    oq_sizes: Dict[str, int] = field(default_factory=dict)
    default_iq: Optional[int] = 12  # task-invocation messages (paper Fig. 10)
    default_oq: int = 12
    # Relative sizing: capacity = tasks_per_round * factor / n_channels
    # (the MoE "capacity factor" IS the IQ axis — ROADMAP fold-in). An
    # explicit per-task entry in ``iq_sizes`` always wins over a factor.
    iq_factors: Dict[str, float] = field(default_factory=dict)
    # Routing hot-path engine: "pallas" | "sort" | "onehot" (None = the
    # backend-autodetected fast path). Capacity *semantics* are identical
    # across impls — this only picks how the executable ranks/scatters,
    # so the analytic TaskEngine twin needs no matching knob. See
    # repro.kernels.route.
    route_impl: Optional[str] = None

    def iq(self, task: str) -> Optional[int]:
        """Explicit per-channel IQ capacity for ``task`` (None =
        unbounded). Factor-sized tasks (``iq_factors``) have no fixed
        entry count — resolve those per round with :meth:`channel_cap`,
        which is what ``TaskEngine.route`` and the executables both use,
        so the two paths can't disagree."""
        return self.iq_sizes.get(task, self.default_iq)

    def oq(self, task: str) -> int:
        return self.oq_sizes.get(task, self.default_oq)

    def channel_cap(self, task: str, tasks_per_round: int,
                    n_channels: int, lane_align: bool = True
                    ) -> Optional[int]:
        """Resolve one routing round's per-channel bucket capacity.

        Explicit sizes (``iq_sizes`` / ``default_iq``) are honored exactly
        — the DSE revalidation sweeps the IQ axis in queue entries, so
        rounding would validate a different capacity than the analytic
        model swept. Factor-derived capacities (``iq_factors``) are
        lane-aligned via :func:`round8` unless ``lane_align=False``.
        Returns ``None`` when the task's queue is unbounded.
        """
        explicit = self.iq_sizes.get(task)
        if explicit is None and task not in self.iq_factors:
            explicit = self.default_iq
        if explicit is not None:
            return max(1, int(explicit))
        factor = self.iq_factors.get(task)
        if factor is None:
            return None
        cap = int(tasks_per_round * factor / max(n_channels, 1))
        return round8(cap) if lane_align else max(1, cap)

    def round_budget(self, task: str, tasks_per_round: int,
                     n_channels: int) -> Optional[int]:
        """Total per-round admission budget for ``task``: the per-channel
        IQ capacity times the channel count — what a whole tenant may
        inject into the NoC in one round before overflowing its queues.

        This is the serving tier's admission-control knob
        (:class:`repro.serve.engine.ProgramServer`): a request whose
        estimated per-round task demand exceeds its tenant's budget is
        rejected with a retriable status *before* launch, instead of
        silently dropping tasks in flight. ``None`` = unbounded (no
        admission limit).
        """
        cap = self.channel_cap(task, tasks_per_round, n_channels)
        return None if cap is None else int(cap) * max(1, n_channels)

    @classmethod
    def unbounded(cls) -> "QueueConfig":
        """Legacy physics: no IQ bound, no modeled drops."""
        return cls(default_iq=None)

    @classmethod
    def from_factor(cls, factor: float, task: str = "T3") -> "QueueConfig":
        """Relative sizing only (the MoE-style capacity-factor knob)."""
        return cls(default_iq=None, iq_factors={task: factor})

    @classmethod
    def from_cap(cls, cap: int, task: str = "T3") -> "QueueConfig":
        """One explicit per-channel capacity, honored exactly."""
        return cls(default_iq=None, iq_sizes={task: int(cap)})

    @classmethod
    def for_moe_dispatch(cls, factor: float) -> "QueueConfig":
        """The MoE dispatch's three bounded buckets — stage-1 tile-NoC
        ("dispatch"), stage-2 pod portal ("portal"), per-local-expert
        receive ("expert") — at one capacity factor. The single home of
        those bucket names: ``repro.core.dispatch.dispatch_queues`` and
        ``DesignPoint.moe_queues`` both delegate here."""
        return cls(default_iq=None,
                   iq_factors={t: factor for t in MOE_DISPATCH_TASKS})


@dataclass
class QueueStats:
    """Per-round aggregate queue pressure."""
    peak_iq: Dict[str, int] = field(default_factory=dict)
    peak_oq: Dict[str, int] = field(default_factory=dict)
    total_tasks: Dict[str, int] = field(default_factory=dict)

    def record(self, task: str, per_tile_in: np.ndarray,
               per_tile_out: np.ndarray):
        self.peak_iq[task] = max(self.peak_iq.get(task, 0),
                                 int(per_tile_in.max(initial=0)))
        self.peak_oq[task] = max(self.peak_oq.get(task, 0),
                                 int(per_tile_out.max(initial=0)))
        self.total_tasks[task] = self.total_tasks.get(task, 0) + \
            int(per_tile_in.sum())
