"""The Fabric — ONE topology object from single-process to multi-host.

The paper's headline claim is DCRA as a *scale-out* compute node: packages
composed into larger systems over a software-configurable torus, with the
long-haul (die-NoC / DCN) hops concentrated at per-pod portals. Before
this module, every layer of the reproduction independently re-derived the
same topology facts from a raw ``jax.sharding.Mesh`` — axis-size dicts in
``sparse/program.py``, ``core/dispatch.py``, ``dse/autoconfig.py`` and
``launch/sharding.py``; mesh cache keys in the compile cache; pod/portal
detection in ``LaunchConfig.pod_axis_for`` — and all of it hard-assumed
one process.

:class:`Fabric` owns those facts in one frozen object:

* **construction** — :meth:`Fabric.single` (single-process),
  :meth:`Fabric.fake` (the ``xla_force_host_platform_device_count``
  subprocess rig every distributed test uses), and
  :meth:`Fabric.distributed` (multi-process ``jax.distributed`` — the
  leading mesh axis is process-major, so it is the axis whose collectives
  cross the data-center network);
* **introspection** — :attr:`axis_sizes` / :meth:`axis_size` (the single
  copy of the axis-size dict), :attr:`pod_axis` (portal derivation),
  :meth:`device_coords` (tile coordinates for the analytic models),
  :meth:`dcn_axes` (which axes actually cross processes);
* **identity** — :meth:`fabric_key`, the stable compile-cache key
  component, byte-compatible with the legacy ``_mesh_key`` so Fabric and
  raw-Mesh launches share cache entries;
* **scale-out** — :meth:`host_slice` (per-host ingest sharding, see
  :func:`repro.sparse.datasets.ingest_edges`) and :meth:`resize` (elastic
  rescale onto a changed device set, see :func:`repro.runtime.elastic`).

Raw meshes keep working everywhere through :func:`as_fabric` — the
warn-once deprecation shim the launch entrypoints funnel through —
and :meth:`Fabric.of`, the silent wrapper for query-only helpers.
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass, replace
from functools import cached_property
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

from .compat import make_mesh
from .topology import TileGrid

#: conventional names of the axis that crosses pods / the DCN
PORTAL_AXIS_NAMES = ("pod", "portal")

_WARNED = [False]        # one-element list so tests can reset the latch


def _warn_mesh_once() -> None:
    if _WARNED[0]:
        return
    _WARNED[0] = True
    warnings.warn(
        "passing a raw Mesh to a DCRA launch entrypoint is deprecated: "
        "wrap it in a repro.core.fabric.Fabric (raw meshes keep working "
        "through this shim, with identical compile-cache keys)",
        DeprecationWarning, stacklevel=4)


@dataclass(frozen=True)
class Fabric:
    """Frozen topology of one DCRA deployment — the single source of
    truth for everything the layers used to re-derive from a raw mesh.

    ``mesh`` is the underlying ``jax.sharding.Mesh`` (duck-typed: any
    object with ``.devices`` / ``.axis_names`` works, which is what lets
    admission-only server tests run without a real device topology).
    ``portal_axis`` names the axis that crosses pods / the DCN; ``None``
    means a flat (single-pod) fabric. Construction never touches jax
    global state except :meth:`distributed` (which initializes
    ``jax.distributed`` exactly once).
    """
    mesh: Any
    portal_axis: Optional[str] = None

    # ---- construction ----------------------------------------------------

    @classmethod
    def of(cls, mesh_or_fabric) -> "Fabric":
        """Silent wrap for query-only helpers: a :class:`Fabric` passes
        through, a raw mesh is wrapped (portal axis auto-detected from
        :data:`PORTAL_AXIS_NAMES`) without the deprecation warning."""
        if isinstance(mesh_or_fabric, Fabric):
            return mesh_or_fabric
        names = tuple(getattr(mesh_or_fabric, "axis_names", ()) or ())
        portal = next((a for a in PORTAL_AXIS_NAMES if a in names), None)
        return cls(mesh=mesh_or_fabric, portal_axis=portal)

    @classmethod
    def single(cls, axis_shapes: Sequence[int], axis_names: Sequence[str],
               devices=None, portal_axis: Optional[str] = None) -> "Fabric":
        """Single-process fabric over the first ``prod(axis_shapes)``
        devices (the CPU-host-friendly ``compat.make_mesh`` path)."""
        mesh = make_mesh(tuple(axis_shapes), tuple(axis_names),
                         devices=devices)
        if portal_axis is None:
            portal_axis = next((a for a in PORTAL_AXIS_NAMES
                                if a in tuple(axis_names)), None)
        return cls(mesh=mesh, portal_axis=portal_axis)

    @classmethod
    def fake(cls, n_dev: int, axis: str = "data") -> "Fabric":
        """The fake-device subprocess rig fabric: a flat ``n_dev``-way
        fabric over host CPU devices. The process must have been started
        with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
        (N >= n_dev) *before* the first jax import — exactly the rig
        tests/benchmarks already use."""
        return cls.single((int(n_dev),), (axis,))

    @classmethod
    def distributed(cls, axis_shapes: Optional[Sequence[int]] = None,
                    axis_names: Optional[Sequence[str]] = None, *,
                    coordinator_address: Optional[str] = None,
                    num_processes: Optional[int] = None,
                    process_id: Optional[int] = None,
                    portal_axis: Optional[str] = None) -> "Fabric":
        """Multi-process fabric over ``jax.distributed``.

        Initializes ``jax.distributed`` (idempotent — an
        already-initialized runtime is reused) and builds one global mesh
        over every process's devices. ``jax.devices()`` orders devices
        process-major, so the **leading** mesh axis is the one whose
        groups span processes: declare the portal axis first
        (``axis_shapes=(n_proc, local)``, ``axis_names=("portal",
        "data")``) and the pod/portal stage-2 hop is the only traffic
        that crosses the DCN — the paper's §III-A hierarchy, for real.
        With no shape given, the fabric is flat: one ``data`` axis over
        all global devices (every all_to_all crosses the DCN).

        On the CPU backend the gloo collectives implementation is
        selected automatically (required for cross-process collectives on
        CPU; a no-op elsewhere).
        """
        import jax
        try:   # must precede backend init; harmless if unavailable
            jax.config.update("jax_cpu_collectives_implementation", "gloo")
        except (AttributeError, ValueError):  # pragma: no cover - version
            pass
        if coordinator_address is not None:
            try:
                jax.distributed.initialize(
                    coordinator_address=coordinator_address,
                    num_processes=num_processes, process_id=process_id)
            except RuntimeError:   # already initialized — reuse it
                pass
        devices = jax.devices()
        if axis_shapes is None:
            axis_shapes, axis_names = (len(devices),), ("data",)
        if axis_names is None:
            raise ValueError("axis_names is required with axis_shapes")
        return cls.single(axis_shapes, axis_names, devices=devices,
                          portal_axis=portal_axis)

    # ---- introspection (the deduped axis-size copies) --------------------

    @cached_property
    def axis_names(self) -> Tuple[str, ...]:
        return tuple(getattr(self.mesh, "axis_names", ()) or ())

    @cached_property
    def shape(self) -> Tuple[int, ...]:
        return tuple(int(s) for s in self.mesh.devices.shape)

    @cached_property
    def axis_sizes(self) -> Dict[str, int]:
        """``{axis name: size}`` — THE axis-size dict (previously copied
        privately by program/dispatch/autoconfig/sharding)."""
        return dict(zip(self.axis_names, self.shape))

    def axis_size(self, axes) -> int:
        """Product size of ``axes`` (None -> 1; a name; or a tuple of
        names — the ``MeshInfo.axis_size`` / ``sharding._axsize``
        contract, now in one place)."""
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        return math.prod(self.axis_sizes[a] for a in axes)

    @property
    def n_devices(self) -> int:
        return int(self.mesh.devices.size)

    @cached_property
    def pod_axis(self) -> Optional[str]:
        """The portal axis when it can actually route across pods (size >
        1), else ``None`` — the mesh-introspection half of the old
        ``LaunchConfig.pod_axis_for``."""
        if self.portal_axis is None:
            return None
        if self.axis_sizes.get(self.portal_axis, 1) <= 1:
            return None
        return self.portal_axis

    # ---- identity: the compile-cache key ---------------------------------

    def fabric_key(self) -> tuple:
        """Stable identity for compile caches — byte-compatible with the
        legacy private ``_mesh_key(mesh)`` tuple, so a Fabric launch and
        a raw-Mesh launch of the same topology share ONE cache entry."""
        return (self.axis_names, self.shape,
                tuple(int(d.id) for d in self.mesh.devices.flat))

    # ---- multi-process topology ------------------------------------------

    @cached_property
    def process_indices(self) -> Tuple[int, ...]:
        """Sorted process indices owning this fabric's devices (``(0,)``
        for every single-process fabric, fake rigs included)."""
        try:
            procs = {int(d.process_index) for d in self.mesh.devices.flat}
        except AttributeError:          # duck-typed mesh (tests)
            procs = {0}
        return tuple(sorted(procs)) or (0,)

    @property
    def n_processes(self) -> int:
        return len(self.process_indices)

    @property
    def is_multiprocess(self) -> bool:
        return self.n_processes > 1

    @cached_property
    def process_index(self) -> int:
        """This process's rank within the fabric (0 single-process)."""
        if not self.is_multiprocess:
            return 0
        import jax
        return self.process_indices.index(int(jax.process_index()))

    def dcn_axes(self) -> Tuple[str, ...]:
        """Mesh axes along which neighboring devices live in *different*
        processes — the axes whose collectives cross the DCN. Empty for
        every single-process fabric."""
        if not self.is_multiprocess:
            return ()
        procs = np.array([[int(d.process_index)]
                          for d in self.mesh.devices.flat]
                         ).reshape(self.shape)
        out = []
        for i, name in enumerate(self.axis_names):
            if self.shape[i] > 1 and bool(
                    (np.diff(procs, axis=i) != 0).any()):
                out.append(name)
        return tuple(out)

    def host_slice(self, total: int, *, rank: Optional[int] = None,
                   world: Optional[int] = None) -> Tuple[int, int]:
        """This host's contiguous ``[lo, hi)`` slice of ``total`` ingest
        items (edge chunks, dataset rows): a balanced split over the
        fabric's processes, so no host ever materializes the full list.
        ``rank`` / ``world`` override the fabric's own process info (the
        single-process tests simulate multi-host splits with them)."""
        world = self.n_processes if world is None else int(world)
        rank = self.process_index if rank is None else int(rank)
        if not 0 <= rank < world:
            raise ValueError(f"rank {rank} outside world {world}")
        base, rem = divmod(int(total), world)
        lo = rank * base + min(rank, rem)
        return lo, lo + base + (1 if rank < rem else 0)

    # ---- analytic-model hooks --------------------------------------------

    def tile_grid(self) -> TileGrid:
        """The analytic-twin grid at this fabric's parallelism: one tile
        per shard (``TileGrid(1, n_devices)``), the channel structure the
        shardcheck revalidation relies on."""
        return TileGrid(1, self.n_devices)

    def device_coords(self) -> Tuple[Tuple[int, Tuple[int, ...]], ...]:
        """``((device_id, mesh coordinates), ...)`` in mesh order — tile
        coordinates for the analytic cost models and placement checks."""
        return tuple((int(d.id), tuple(int(c) for c in idx))
                     for idx, d in np.ndenumerate(self.mesh.devices))

    # ---- elasticity ------------------------------------------------------

    def resize(self, devices=None) -> "Fabric":
        """A new fabric over a *changed* device set (defaults to every
        currently-live ``jax.devices()``) — the elastic-rescale hook.

        Keeps the trailing (intra-pod) axis structure and lets the
        leading (host/DCN-crossing) axis absorb the change; when the new
        device count cannot keep that structure, degrades to a flat
        fabric over the last axis name. Pair with
        :func:`repro.runtime.elastic.rescale`: a lost host degrades
        capacity instead of killing the run.
        """
        if devices is None:
            import jax
            devices = jax.devices()
        devs = np.asarray(list(devices))
        if devs.size == 0:
            raise ValueError("cannot resize to an empty device set")
        inner = math.prod(self.shape[1:]) if len(self.shape) > 1 else 1
        lead, rem = divmod(devs.size, inner)
        if len(self.shape) > 1 and rem == 0 and lead >= 1:
            new_shape: Tuple[int, ...] = (lead,) + self.shape[1:]
            new_names = self.axis_names
        else:
            new_shape = (int(devs.size),)
            new_names = self.axis_names[-1:] or ("data",)
        import jax.sharding as jsh
        mesh = jsh.Mesh(devs.reshape(new_shape), new_names)
        portal = (self.portal_axis if self.portal_axis in new_names
                  else None)
        return replace(self, mesh=mesh, portal_axis=portal)

    def shrink(self, keep: int) -> "Fabric":
        """:meth:`resize` onto the first ``keep`` devices of THIS fabric
        (mesh order) — the host-loss degrade: the survivors are a prefix
        of the current device set, no fresh ``jax.devices()`` query (a
        lost host's devices may still be enumerable but unusable).
        ``ProgramServer`` calls this on an injected
        ``host_loss`` fault; a new ``fabric_key()`` means relaunched
        shape classes re-trace on the shrunken fabric by construction.
        """
        keep = int(keep)
        if not 1 <= keep <= self.n_devices:
            raise ValueError(f"shrink keeps {keep} of {self.n_devices} "
                             f"devices — need 1 <= keep <= n_devices")
        return self.resize(list(self.mesh.devices.flat)[:keep])


def axis_sizes_of(mesh_or_fabric) -> Dict[str, int]:
    """The one shared axis-size dict accessor (module-level sugar for
    call sites that hold a raw mesh)."""
    return Fabric.of(mesh_or_fabric).axis_sizes


def as_fabric(mesh_or_fabric) -> Fabric:
    """THE launch-entrypoint shim: a :class:`Fabric` passes through; a
    raw mesh is wrapped with a one-time :class:`DeprecationWarning` (same
    latch pattern as the LaunchOptions legacy-kwarg shim). Cache keys are
    identical either way (:meth:`Fabric.fabric_key`)."""
    if isinstance(mesh_or_fabric, Fabric):
        return mesh_or_fabric
    _warn_mesh_once()
    return Fabric.of(mesh_or_fabric)
