"""DCRA task-based PGAS execution engine (paper §III + Dalorex model).

Execution model: data arrays are statically partitioned over tiles
(cyclic PGAS layout). A *task* operates only on tile-local data; writing to
remote data spawns a task invocation routed to the owner tile. The engine
renders this bulk-synchronously: each round, all pending task invocations
are (1) routed (owner-bucketed), (2) applied with a reduction, (3) may spawn
the next round's tasks. Results are exact; the NoC/queue/memory behaviour
of the message-driven original is captured as per-round statistics that the
cost model converts to cycles/energy/dollars (the paper's own simulator is
the same instrumentation + model approach).

Delivery reductions are vectorised (bincount / sort+reduceat) — no python
loops over messages.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from .cache import CacheModel, DRAMConfig, SRAMConfig
from .queues import QueueConfig, QueueStats
from .topology import TileGrid


@dataclass
class EngineConfig:
    grid: TileGrid
    queues: QueueConfig = field(default_factory=QueueConfig)
    sram: SRAMConfig = field(default_factory=SRAMConfig)
    dram: DRAMConfig = field(default_factory=DRAMConfig)
    pus_per_tile: int = 1              # Table II knob #2 (Fig. 6)
    pu_freq_ghz: float = 1.0           # Fig. 7
    word_bytes: int = 8                # task payload word


@dataclass
class RoundStats:
    messages: int = 0
    payload_bytes: int = 0
    hops: int = 0
    die_crossings: int = 0
    local_msgs: int = 0                # same-tile (no NoC traversal)
    tasks_per_tile_peak: int = 0
    tasks_total: int = 0
    stream_bytes: float = 0.0
    random_bytes: float = 0.0
    drops: int = 0                     # IQ-overflow discards (modeled)
    barrier: bool = False              # epoch boundary (PageRank)


@dataclass
class RunStats:
    rounds: List[RoundStats] = field(default_factory=list)
    queue: QueueStats = field(default_factory=QueueStats)

    @property
    def total_messages(self) -> int:
        return sum(r.messages for r in self.rounds)

    @property
    def total_hops(self) -> int:
        return sum(r.hops for r in self.rounds)

    @property
    def total_tasks(self) -> int:
        return sum(r.tasks_total for r in self.rounds)

    @property
    def total_die_crossings(self) -> int:
        return sum(r.die_crossings for r in self.rounds)

    @property
    def total_drops(self) -> int:
        return sum(r.drops for r in self.rounds)


class TaskEngine:
    """Owner-computes execution over a virtual tile grid."""

    def __init__(self, config: EngineConfig, n_items: int,
                 iq_capacity: Optional[int] = None):
        self.cfg = config
        self.n = n_items                       # global index space (vertices)
        self.T = config.grid.n_tiles
        self.cache = CacheModel(config.sram, config.dram)
        self.stats = RunStats()
        # default bounded-IQ model for every route() call (the DSE sweep's
        # compile-time queue axis); None keeps the legacy unbounded stats.
        self.iq_capacity = iq_capacity

    # ---- PGAS layout -----------------------------------------------------
    def owner(self, idx: np.ndarray) -> np.ndarray:
        """Cyclic layout: item i lives on tile i % T (Dalorex default)."""
        return idx % self.T

    # ---- message routing + delivery ---------------------------------------
    def route(self, task: str, src_idx: np.ndarray, dst_idx: np.ndarray,
              values: Optional[np.ndarray] = None,
              target: Optional[np.ndarray] = None, op: str = "add",
              payload_words: int = 2,
              stream_bytes_per_task: float = 0.0,
              random_bytes_per_task: float = 0.0,
              iq_capacity: Optional[int] = None) -> RoundStats:
        """Deliver one round of task invocations.

        src_idx/dst_idx: global item ids (message endpoints define tiles);
        values applied to ``target`` at dst_idx with reduction ``op``
        ('min'|'add'|'store'). Mutates ``target`` in place; returns stats.
        ``target=None`` records routing stats only (task-invocation
        messages whose effect is to spawn downstream tasks).

        ``iq_capacity`` models the bounded input queue the distributed
        routing layer (:mod:`repro.core.routing`) enforces: each
        (src tile -> dst tile) ingress channel accepts at most
        ``iq_capacity`` tasks per round; the overflow count is recorded in
        ``RoundStats.drops``. The reduction itself stays exact — drops are
        *modeled* traffic loss for the cost model, and the analytic count
        equals the real drop count of the shard_map path for the same task
        stream (property-tested in tests/test_routing.py).
        """
        if iq_capacity is None:
            iq_capacity = self.iq_capacity
        g = self.cfg.grid
        src_t = self.owner(np.asarray(src_idx))
        dst_t = self.owner(np.asarray(dst_idx))
        remote = src_t != dst_t
        hops = g.hops(src_t[remote], dst_t[remote])
        die_x = g.die_crossings(src_t[remote], dst_t[remote])

        msg_bytes = payload_words * self.cfg.word_bytes
        n_msgs = int(remote.sum())
        rs = RoundStats(
            messages=n_msgs,
            payload_bytes=n_msgs * msg_bytes,
            hops=int(hops.sum()),
            die_crossings=int(die_x.sum()),
            local_msgs=int((~remote).sum()),
            tasks_total=len(dst_idx),
        )
        in_per_tile = np.bincount(dst_t, minlength=self.T)
        out_per_tile = np.bincount(src_t, minlength=self.T)
        rs.tasks_per_tile_peak = int(in_per_tile.max(initial=0))
        if iq_capacity is not None:
            # O(n_tasks): only touched (src,dst) channels, never a dense TxT
            _, per_chan = np.unique(src_t * self.T + dst_t,
                                    return_counts=True)
            rs.drops = int(np.maximum(per_chan - iq_capacity, 0).sum())
        rs.stream_bytes = stream_bytes_per_task * len(dst_idx)
        rs.random_bytes = random_bytes_per_task * len(dst_idx)
        self.stats.queue.record(task, in_per_tile, out_per_tile)

        if target is not None:
            self._reduce(dst_idx, values, target, op)
        self.stats.rounds.append(rs)
        return rs

    def mark_barrier(self):
        """Tag the last round as an epoch barrier (PageRank §V-B tail)."""
        if self.stats.rounds:
            self.stats.rounds[-1].barrier = True

    @staticmethod
    def _reduce(dst_idx, values, target, op):
        dst_idx = np.asarray(dst_idx)
        if dst_idx.size == 0:      # empty round (e.g. frontier of leaves)
            return
        if op == "add":
            upd = np.bincount(dst_idx, weights=values.astype(np.float64),
                              minlength=target.shape[0])
            target += upd.astype(target.dtype)
        elif op == "min":
            order = np.argsort(dst_idx, kind="stable")
            ds, vs = dst_idx[order], values[order]
            first = np.flatnonzero(np.r_[True, ds[1:] != ds[:-1]])
            mins = np.minimum.reduceat(vs, first)
            uids = ds[first]
            np.minimum.at(target, uids, mins)  # one op per unique id — cheap
        elif op == "store":
            target[dst_idx] = values
        else:
            raise ValueError(op)

    # ---- derived ---------------------------------------------------------
    def footprint_per_tile(self, total_bytes: float) -> float:
        return total_bytes / self.T
