"""DCRA task-based PGAS execution engine (paper §III + Dalorex model).

Execution model: data arrays are statically partitioned over tiles
(cyclic PGAS layout). A *task* operates only on tile-local data; writing to
remote data spawns a task invocation routed to the owner tile. The engine
renders this bulk-synchronously: each round, all pending task invocations
are (1) routed (owner-bucketed), (2) applied with a reduction, (3) may spawn
the next round's tasks. Results are exact; the NoC/queue/memory behaviour
of the message-driven original is captured as per-round statistics that the
cost model converts to cycles/energy/dollars (the paper's own simulator is
the same instrumentation + model approach).

Delivery reductions are vectorised (bincount / sort+reduceat) — no python
loops over messages.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from .cache import CacheModel, DRAMConfig, SRAMConfig
from .queues import QueueConfig, QueueStats
from .topology import TileGrid


@dataclass
class EngineConfig:
    grid: TileGrid
    queues: QueueConfig = field(default_factory=QueueConfig)
    sram: SRAMConfig = field(default_factory=SRAMConfig)
    dram: DRAMConfig = field(default_factory=DRAMConfig)
    pus_per_tile: int = 1              # Table II knob #2 (Fig. 6)
    pu_freq_ghz: float = 1.0           # Fig. 7
    word_bytes: int = 8                # task payload word


@dataclass
class RoundStats:
    messages: int = 0
    payload_bytes: int = 0
    hops: int = 0
    die_crossings: int = 0
    local_msgs: int = 0                # same-tile (no NoC traversal)
    tasks_per_tile_peak: int = 0
    tasks_total: int = 0
    stream_bytes: float = 0.0
    random_bytes: float = 0.0
    drops: int = 0                     # IQ-overflow discards (modeled)
    barrier: bool = False              # epoch boundary (PageRank)


@dataclass
class RunStats:
    rounds: List[RoundStats] = field(default_factory=list)
    queue: QueueStats = field(default_factory=QueueStats)

    @property
    def total_messages(self) -> int:
        return sum(r.messages for r in self.rounds)

    @property
    def total_hops(self) -> int:
        return sum(r.hops for r in self.rounds)

    @property
    def total_tasks(self) -> int:
        return sum(r.tasks_total for r in self.rounds)

    @property
    def total_die_crossings(self) -> int:
        return sum(r.die_crossings for r in self.rounds)

    @property
    def total_drops(self) -> int:
        return sum(r.drops for r in self.rounds)


class TaskEngine:
    """Owner-computes execution over a virtual tile grid.

    All queue sizing comes from ``config.queues`` (:class:`QueueConfig`) —
    per-task IQ capacities bound every :meth:`route` round (Table II knob
    #8, Fig. 10); build the config with ``QueueConfig.unbounded()`` for the
    legacy unbounded statistics.
    """

    def __init__(self, config: EngineConfig, n_items: int):
        self.cfg = config
        self.n = n_items                       # global index space (vertices)
        self.T = config.grid.n_tiles
        self.cache = CacheModel(config.sram, config.dram)
        self.stats = RunStats()

    # ---- PGAS layout -----------------------------------------------------
    def owner(self, idx: np.ndarray) -> np.ndarray:
        """Cyclic layout: item i lives on tile i % T (Dalorex default)."""
        return idx % self.T

    # ---- message routing + delivery ---------------------------------------
    def route(self, task: str, src_idx: np.ndarray, dst_idx: np.ndarray,
              values: Optional[np.ndarray] = None,
              target: Optional[np.ndarray] = None, op: str = "add",
              payload_words: int = 2,
              stream_bytes_per_task: float = 0.0,
              random_bytes_per_task: float = 0.0) -> RoundStats:
        """Deliver one round of task invocations.

        src_idx/dst_idx: global item ids (message endpoints define tiles);
        values applied to ``target`` at dst_idx with reduction ``op``
        ('min'|'add'|'store'). Mutates ``target`` in place; returns stats.
        ``target=None`` records routing stats only (task-invocation
        messages whose effect is to spawn downstream tasks).

        The per-task IQ capacity resolves through
        ``self.cfg.queues.channel_cap(task, ...)`` — explicit entry counts
        (``iq_sizes`` / ``default_iq``) are honored exactly, and
        factor-sized tasks (``iq_factors``, the MoE-style relative knob)
        derive the same lane-aligned capacity the executable bucketing
        would, so a factor-based ``QueueConfig`` bounds the analytic model
        instead of silently disabling it. It models the bounded input
        queue the distributed routing layer (:mod:`repro.core.routing`)
        enforces: each (src tile -> dst tile)
        ingress channel accepts at most that many tasks per round; the
        overflow count is recorded in ``RoundStats.drops``. Same-tile
        (src == dst) channels are bounded too — the shard_map ``bucket``
        primitive queues a shard's self-owned tasks through its own bucket
        at the same capacity, so charging the self channel here is what
        makes the analytic and executable drop counts agree *by
        construction* (property-tested in tests/test_routing.py and
        tests/test_dse.py, including heavy self-traffic streams). The
        reduction itself stays exact — drops are *modeled* traffic loss
        for the cost model.
        """
        # per-sender-tile task load mirrors the executable's e_local
        cap = self.cfg.queues.channel_cap(
            task, -(-len(dst_idx) // self.T), self.T)
        g = self.cfg.grid
        src_t = self.owner(np.asarray(src_idx))
        dst_t = self.owner(np.asarray(dst_idx))
        remote = src_t != dst_t
        hops = g.hops(src_t[remote], dst_t[remote])
        die_x = g.die_crossings(src_t[remote], dst_t[remote])

        msg_bytes = payload_words * self.cfg.word_bytes
        n_msgs = int(remote.sum())
        rs = RoundStats(
            messages=n_msgs,
            payload_bytes=n_msgs * msg_bytes,
            hops=int(hops.sum()),
            die_crossings=int(die_x.sum()),
            local_msgs=int((~remote).sum()),
            tasks_total=len(dst_idx),
        )
        in_per_tile = np.bincount(dst_t, minlength=self.T)
        out_per_tile = np.bincount(src_t, minlength=self.T)
        rs.tasks_per_tile_peak = int(in_per_tile.max(initial=0))
        if cap is not None:
            # O(n_tasks): only touched (src,dst) channels, never a dense TxT
            _, per_chan = np.unique(src_t * self.T + dst_t,
                                    return_counts=True)
            rs.drops = int(np.maximum(per_chan - cap, 0).sum())
        rs.stream_bytes = stream_bytes_per_task * len(dst_idx)
        rs.random_bytes = random_bytes_per_task * len(dst_idx)
        self.stats.queue.record(task, in_per_tile, out_per_tile)

        if target is not None:
            self._reduce(dst_idx, values, target, op)
        self.stats.rounds.append(rs)
        return rs

    def mark_barrier(self):
        """Tag the last round as an epoch barrier (PageRank §V-B tail)."""
        if self.stats.rounds:
            self.stats.rounds[-1].barrier = True

    @staticmethod
    def _reduce(dst_idx, values, target, op):
        dst_idx = np.asarray(dst_idx)
        if dst_idx.size == 0:      # empty round (e.g. frontier of leaves)
            return
        if op == "add":
            upd = np.bincount(dst_idx, weights=values.astype(np.float64),
                              minlength=target.shape[0])
            target += upd.astype(target.dtype)
        elif op == "min":
            order = np.argsort(dst_idx, kind="stable")
            ds, vs = dst_idx[order], values[order]
            first = np.flatnonzero(np.r_[True, ds[1:] != ds[:-1]])
            mins = np.minimum.reduceat(vs, first)
            uids = ds[first]
            np.minimum.at(target, uids, mins)  # one op per unique id — cheap
        elif op == "store":
            # deterministic overwrite: among duplicate destinations the
            # maximum value wins, independent of input (= routing) order —
            # the same winner the shard_map ``reduce_received`` picks.
            order = np.argsort(dst_idx, kind="stable")
            ds, vs = dst_idx[order], np.asarray(values)[order]
            first = np.flatnonzero(np.r_[True, ds[1:] != ds[:-1]])
            target[ds[first]] = np.maximum.reduceat(vs, first)
        else:
            raise ValueError(op)

    # ---- derived ---------------------------------------------------------
    def footprint_per_tile(self, total_bytes: float) -> float:
        return total_bytes / self.T
