"""jax version compatibility — the repo targets the pinned jax 0.4.37
toolchain (see requirements-dev.txt) while staying source-compatible with
the >= 0.7 API surface it was originally sketched against.

Three seams moved between those versions:

* ``shard_map``: ``jax.experimental.shard_map`` -> ``jax.shard_map``
  (and the ``check_rep`` kwarg was renamed ``check_vma``);
* mesh construction: ``jax.make_mesh(..., axis_types=...)`` did not exist /
  lacks ``axis_types`` on 0.4.x — we build ``jax.sharding.Mesh`` directly,
  which also allows meshes over a *subset* of devices (the routing property
  tier runs 1/2/4/8-device meshes inside one 8-device process);
* mesh scoping: ``jax.set_mesh`` -> ``Mesh`` as a context manager.
"""
from __future__ import annotations

import inspect

import jax
import numpy as np

try:                                     # jax >= 0.7
    shard_map = jax.shard_map
except AttributeError:                   # pragma: no cover - version dep
    from jax.experimental.shard_map import shard_map

_SM_PARAMS = inspect.signature(shard_map).parameters
_CHECK_KW = "check_vma" if "check_vma" in _SM_PARAMS else "check_rep"


def shard_map_unchecked(fn, mesh, in_specs, out_specs):
    """shard_map with replication/VMA checking off (collective-heavy
    kernels trip the static checker on both API generations)."""
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **{_CHECK_KW: False})


def make_mesh(axis_shapes, axis_names, devices=None):
    """A Mesh over the first prod(axis_shapes) devices (CPU-host friendly)."""
    if devices is None:
        devices = jax.devices()
    n = int(np.prod(axis_shapes))
    if len(devices) < n:
        raise ValueError(f"need {n} devices, have {len(devices)}")
    arr = np.asarray(devices[:n]).reshape(axis_shapes)
    return jax.sharding.Mesh(arr, axis_names)


def set_mesh(mesh):
    """Context manager scoping ``mesh`` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):                  # jax >= 0.7
        return jax.set_mesh(mesh)
    return mesh                                   # Mesh is a context manager


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a dict (0.4.x returns [dict])."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    return cost
