"""DCRA NoC topology model: mesh / torus / hierarchical (tile-NoC + die-NoC).

Reproduces the paper's §III-A network structure analytically:
* tiles in an R×C grid, grouped into dies of (dr×dc) tiles;
* the *tile-NoC* connects all tiles (mesh or folded torus — folding makes all
  links near-equal length, paper Fig. 2);
* the *die-NoC* hops once per die (radix-9 edge routers) — the paper's
  mechanism for cutting long-distance hop counts;
* topology is a runtime ("software") configuration — exactly the paper's
  reconfigurability claim — so the same ``TileGrid`` can be evaluated as any
  topology, including a torus spanning multiple dies/packages.

Vectorised hop/energy accounting: callers pass arrays of (src_tile,
dst_tile) and get hop counts / wire lengths back (numpy, no python loops).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple

import numpy as np

TOPOLOGIES = ("mesh", "torus", "hier_torus")


@dataclass(frozen=True)
class TileGrid:
    rows: int
    cols: int
    topology: str = "hier_torus"
    die_rows: int = 32            # tiles per die edge (32x32 default, §V-B)
    die_cols: int = 32
    noc_width_bits: int = 64      # Fig. 4 sweeps 32/64
    noc_freq_ghz: float = 1.0     # Fig. 4 tests 2.0 (double-pumped)

    @property
    def n_tiles(self) -> int:
        return self.rows * self.cols

    @property
    def dies(self) -> Tuple[int, int]:
        return (max(1, self.rows // self.die_rows),
                max(1, self.cols // self.die_cols))

    def coords(self, tile: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        return tile // self.cols, tile % self.cols

    # ---- hop counting --------------------------------------------------
    def _axis_hops(self, a, b, n, torus: bool):
        d = np.abs(a - b)
        return np.minimum(d, n - d) if torus else d

    def hops(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        """Router-to-router hops per message (vectorised)."""
        sr, sc = self.coords(src)
        dr, dc = self.coords(dst)
        if self.topology == "mesh":
            return self._axis_hops(sr, dr, self.rows, False) + \
                   self._axis_hops(sc, dc, self.cols, False)
        if self.topology == "torus":
            return self._axis_hops(sr, dr, self.rows, True) + \
                   self._axis_hops(sc, dc, self.cols, True)
        # hierarchical: intra-die torus; inter-die: travel to the die portal
        # (one hop per die on the die-NoC, paper Fig. 2), then local delivery.
        sdr, sdc = sr // self.die_rows, sc // self.die_cols
        ddr, ddc = dr // self.die_rows, dc // self.die_cols
        same_die = (sdr == ddr) & (sdc == ddc)
        # intra-die component (torus folded within the die)
        intra = (self._axis_hops(sr % self.die_rows, dr % self.die_rows,
                                 self.die_rows, True)
                 + self._axis_hops(sc % self.die_cols, dc % self.die_cols,
                                   self.die_cols, True))
        # to-portal + die-NoC hops (torus over dies) + from-portal
        n_dr, n_dc = self.dies
        die_hops = self._axis_hops(sdr, ddr, n_dr, True) + \
                   self._axis_hops(sdc, ddc, n_dc, True)
        # average distance to the portal ~ half the die diameter
        portal = (self.die_rows + self.die_cols) // 4
        return np.where(same_die, intra, portal * 2 + die_hops)

    def die_crossings(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        """# of die-to-die link traversals (for energy: 0.55 pJ/bit each)."""
        sr, sc = self.coords(src)
        dr, dc = self.coords(dst)
        sdr, sdc = sr // self.die_rows, sc // self.die_cols
        ddr, ddc = dr // self.die_rows, dc // self.die_cols
        if self.topology == "hier_torus":
            n_dr, n_dc = self.dies
            return self._axis_hops(sdr, ddr, n_dr, True) + \
                   self._axis_hops(sdc, ddc, n_dc, True)
        # flat topologies cross die boundaries along the path
        return np.abs(sdr - ddr) + np.abs(sdc - ddc)

    # ---- aggregate properties -------------------------------------------
    def bisection_links(self) -> int:
        base = min(self.rows, self.cols)
        mult = {"mesh": 1, "torus": 2, "hier_torus": 2}[self.topology]
        return base * mult

    def bisection_bytes_per_cycle(self) -> float:
        return self.bisection_links() * self.noc_width_bits / 8.0

    def avg_uniform_hops(self) -> float:
        """Mean hops under uniform random traffic.

        Exact closed form for the flat topologies (per-axis expectation of
        the distance between two independent uniform coordinates, summed
        over the two axes): mesh ``E|a-b| = (n^2-1)/(3n)``; torus
        ``E[min(d, n-d)] = n/4`` (even ``n``) or ``(n^2-1)/(4n)`` (odd).
        ``hier_torus`` has no simple closed form (the portal detour makes
        the axes non-separable), so it stays a seeded Monte-Carlo sample.
        """
        if self.topology == "mesh":
            def axis(n):
                return (n * n - 1) / (3.0 * n)
            return axis(self.rows) + axis(self.cols)
        if self.topology == "torus":
            def axis(n):
                return n / 4.0 if n % 2 == 0 else (n * n - 1) / (4.0 * n)
            return axis(self.rows) + axis(self.cols)
        n = 4096
        rng = np.random.default_rng(0)
        s = rng.integers(0, self.n_tiles, n)
        d = rng.integers(0, self.n_tiles, n)
        return float(self.hops(s, d).mean())

    def with_(self, **kw) -> "TileGrid":
        return dataclasses.replace(self, **kw)
