"""Pure-numpy oracles for the paper applications (§IV-A) plus k-core.

Independent implementations (no task engine, no tile grid) used to verify
the DCRA execution paths bit-for-bit / to float tolerance.
"""
from __future__ import annotations

import numpy as np

from .csr import CSR

INF = np.float64(np.inf)


def bfs_ref(g: CSR, root: int) -> np.ndarray:
    """Hop count from root; -1 if unreachable."""
    dist = np.full(g.n, -1, np.int64)
    dist[root] = 0
    frontier = np.array([root])
    level = 0
    while len(frontier):
        level += 1
        starts, ends = g.row_ptr[frontier], g.row_ptr[frontier + 1]
        nbrs = np.concatenate([g.col_idx[s:e] for s, e in zip(starts, ends)]) \
            if len(frontier) else np.array([], np.int32)
        nbrs = np.unique(nbrs)
        new = nbrs[dist[nbrs] < 0]
        dist[new] = level
        frontier = new
    return dist


def sssp_ref(g: CSR, root: int) -> np.ndarray:
    """Bellman-Ford shortest path weights; inf if unreachable."""
    dist = np.full(g.n, np.inf)
    dist[root] = 0.0
    rows = g.row_of()
    for _ in range(g.n):
        cand = dist[rows] + g.values
        upd = np.full(g.n, np.inf)
        np.minimum.at(upd, g.col_idx, cand)
        nd = np.minimum(dist, upd)
        if np.allclose(nd, dist, equal_nan=True):
            break
        dist = nd
    return dist


def pagerank_ref(g: CSR, damping: float = 0.85, iters: int = 20) -> np.ndarray:
    deg = g.degrees().astype(np.float64)
    rank = np.full(g.n, 1.0 / g.n)
    rows = g.row_of()
    for _ in range(iters):
        contrib = np.where(deg > 0, rank / np.maximum(deg, 1), 0.0)
        acc = np.bincount(g.col_idx, weights=contrib[rows], minlength=g.n)
        # dangling mass redistributed uniformly
        dangling = rank[deg == 0].sum()
        rank = (1 - damping) / g.n + damping * (acc + dangling / g.n)
    return rank


def wcc_ref(g: CSR) -> np.ndarray:
    """Label propagation (min label) — graph coloring per the paper [78]."""
    label = np.arange(g.n, dtype=np.int64)
    rows = g.row_of()
    changed = True
    while changed:
        upd = label.copy()
        np.minimum.at(upd, g.col_idx, label[rows])
        np.minimum.at(upd, rows, label[g.col_idx])
        changed = not np.array_equal(upd, label)
        label = upd
    return label


def spmv_ref(g: CSR, x: np.ndarray) -> np.ndarray:
    rows = g.row_of()
    return np.bincount(rows, weights=g.values * x[g.col_idx],
                       minlength=g.n).astype(np.float64)


def histogram_ref(elements: np.ndarray, n_bins: int) -> np.ndarray:
    return np.bincount(elements, minlength=n_bins).astype(np.int64)


def kcore_ref(g: CSR, k: int) -> np.ndarray:
    """k-core by iterative peel on the undirected view (degree counts each
    stored edge direction, like ``wcc_ref``'s both-ways propagation).

    Returns each surviving vertex's within-core degree, -1 if peeled.
    """
    src = np.concatenate([g.row_of(), g.col_idx.astype(np.int64)])
    dst = np.concatenate([g.col_idx.astype(np.int64), g.row_of()])
    deg = np.bincount(src, minlength=g.n).astype(np.int64)
    alive = np.ones(g.n, bool)
    frontier = alive & (deg < k)
    while frontier.any():
        dec = np.bincount(dst[frontier[src]], minlength=g.n)
        alive &= ~frontier
        deg = deg - dec
        frontier = alive & (deg < k)
    return np.where(alive, deg, -1).astype(np.int64)
