"""LaunchOptions — ONE object for every launch-configuration kwarg.

The seven ``dcra_*`` apps, :func:`repro.sparse.program.run_program` and
:func:`repro.sparse.program.dcra_scatter` historically each re-declared
the same 9-kwarg sprawl (``axis``, ``pod_axis``, ``cap``,
``capacity_factor``, ``queues``, ``config``, ``objective``, ``seed``,
``route_impl`` — and now ``round_mode``), with the cross-kwarg conflict
rules scattered across them. :class:`LaunchOptions` collapses that into
one frozen dataclass whose :meth:`LaunchOptions.resolve` owns ALL the
conflict checks in exactly one place; every entrypoint accepts
``options=``, and the legacy kwargs keep working through
:func:`resolve_options` — a shim that forwards them into a
``LaunchOptions`` and emits a one-time :class:`DeprecationWarning`.

    opts = LaunchOptions(capacity_factor=4.0, route_impl="sort",
                         round_mode="pipelined")
    dist, stats = dcra_bfs(g, 0, mesh, options=opts)

Migration table (old kwarg -> field) is in the README.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, fields, replace
from typing import Any, Optional

from ..core.queues import QueueConfig

ROUND_MODES = ("lockstep", "pipelined")

# legacy kwargs whose "unset" sentinel is a real value, not None — an
# explicitly passed default is indistinguishable from unset, which is
# exactly the old behavior the shim preserves
_NON_NONE_DEFAULTS = {"axis": "data", "objective": "teps", "seed": 0}

_WARNED = [False]        # one-element list so tests can reset the latch


@dataclass(frozen=True)
class LaunchOptions:
    """Every launch-configuration knob of one DCRA launch, in one place.

    ``axis`` / ``pod_axis`` name the mesh axes (``pod_axis`` selects the
    hierarchical pod/portal routing path); exactly one of ``queues`` /
    ``cap`` / ``capacity_factor`` may size the IQs (or ``config`` — a
    LaunchConfig, DesignPoint or ``"auto"`` — may own sizing entirely);
    ``objective`` steers ``config="auto"``; ``seed`` fixes the edge-pack
    shuffle; ``route_impl`` picks the routing hot-path engine ("pallas" |
    "sort" | "onehot" | None = autodetect); ``round_mode`` picks the round
    execution shape ("lockstep" | "pipelined" — bit-identical results,
    see README "Pipelined rounds").
    """
    axis: str = "data"
    pod_axis: Optional[str] = None
    cap: Optional[int] = None
    capacity_factor: Optional[float] = None
    queues: Optional[QueueConfig] = None
    config: Any = None
    objective: str = "teps"
    seed: int = 0
    route_impl: Optional[str] = None
    round_mode: str = "lockstep"

    def resolve(self) -> "LaunchOptions":
        """Validate cross-field consistency — THE single conflict-check
        path every entrypoint funnels through (legacy kwargs included,
        via :func:`resolve_options`). Returns ``self`` so call sites can
        chain; raises ``ValueError`` on any conflict."""
        sizing = tuple(name for name, v in
                       (("queues", self.queues), ("cap", self.cap),
                        ("capacity_factor", self.capacity_factor))
                       if v is not None)
        if len(sizing) > 1:
            raise ValueError(f"{sizing[0]}= conflicts with explicit "
                             f"{sizing[1:]}: IQ sizing resolves through "
                             f"exactly one of queues/cap/capacity_factor")
        if self.config is not None and sizing:
            raise ValueError(f"config= conflicts with explicit {sizing}: "
                             f"queue sizing comes from the resolved "
                             f"LaunchConfig, drop one of them")
        if self.round_mode not in ROUND_MODES:
            raise ValueError(f"unknown round_mode {self.round_mode!r} "
                             f"(expected one of {ROUND_MODES})")
        if self.route_impl is not None:
            from ..kernels.route import resolve_route_impl
            resolve_route_impl(self.route_impl)      # raises on unknown
        return self

    def with_(self, **changes) -> "LaunchOptions":
        """Functional update (dataclasses.replace sugar)."""
        return replace(self, **changes)


_FIELD_NAMES = tuple(f.name for f in fields(LaunchOptions))


def _warn_legacy(names) -> None:
    if _WARNED[0]:
        return
    _WARNED[0] = True
    warnings.warn(
        f"launch kwargs {tuple(names)} are deprecated: pass "
        f"options=LaunchOptions(...) instead (the legacy kwargs keep "
        f"working through this shim)", DeprecationWarning, stacklevel=4)


def resolve_options(options: Optional[LaunchOptions] = None,
                    **legacy) -> LaunchOptions:
    """The legacy-kwarg shim every entrypoint funnels through.

    With ``options=`` set, every legacy kwarg must be at its default —
    mixing the two styles raises rather than guessing precedence. With
    legacy kwargs only, they are forwarded into a :class:`LaunchOptions`
    (one ``DeprecationWarning`` per process, the first time any
    non-default legacy kwarg is seen). Either way the result is
    :meth:`LaunchOptions.resolve`-d, so both styles hit the identical
    conflict checks — and produce identical compile-cache keys.
    """
    unknown = [k for k in legacy if k not in _FIELD_NAMES]
    if unknown:
        raise TypeError(f"unknown launch kwargs {unknown}")
    explicit = {k: v for k, v in legacy.items()
                if v is not None and v != _NON_NONE_DEFAULTS.get(k)}
    if options is not None:
        if not isinstance(options, LaunchOptions):
            raise TypeError(f"options= expects a LaunchOptions, got "
                            f"{type(options).__name__}")
        if explicit:
            raise ValueError(f"options= conflicts with explicit legacy "
                             f"kwargs {tuple(sorted(explicit))}: fold "
                             f"them into the LaunchOptions")
        return options.resolve()
    if explicit:
        _warn_legacy(sorted(explicit))
    return LaunchOptions(**{k: v for k, v in legacy.items()
                            if v is not None}).resolve()
