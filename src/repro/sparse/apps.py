"""The paper applications (§IV-A, plus k-core) on the DCRA task engine.

Task structure follows Dalorex/DCRA: pointer indirections split tasks —
  T1 (vertex task, at owner(v))      — spawns an edge-list lookup   [OQ1]
  T2 (edge task, at owner_E(seg))    — walks the edge segment (streaming,
                                        next-line prefetch), spawns per-edge
                                        updates                      [OQ2]
  T3 (update task, at owner(u))      — reduction on the owned element
Histogram has only two task types (paper Fig. 10 note).

Each app returns exact results (validated against sparse/ref.py) plus
``RunStats`` — message/hop/queue/memory traffic that the cost model converts
to cycles, joules and dollars.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

from ..core.task_engine import EngineConfig, RunStats, TaskEngine
from .csr import CSR

# instruction-count profile per task (one instr/cycle, paper §IV-B);
# measured from the Dalorex artifact's task bodies (approximate).
INSTRS = {"T1": 6, "T2": 8, "T3": 5}
WORD = 8


def _owner_of_edge(engine: TaskEngine, g: CSR) -> np.ndarray:
    """Tile owning each vertex's edge segment (cyclic over the edge array)."""
    return (g.row_ptr[:-1] % engine.T).astype(np.int64)


def _expand(engine: TaskEngine, g: CSR, frontier: np.ndarray,
            values_per_v: np.ndarray, target: np.ndarray, op: str
            ) -> Tuple[np.ndarray, RunStats]:
    """One T1->T2->T3 round: frontier vertices push values along edges."""
    deg = g.degrees()[frontier]
    # OQ1: one edge-list lookup per frontier vertex (T1 -> T2).
    # dst is the edge-array index of the segment head (owner = idx % T).
    engine.route("T2", src_idx=frontier, dst_idx=g.row_ptr[frontier],
                 payload_words=2,
                 stream_bytes_per_task=8.0,        # row_ptr pair
                 random_bytes_per_task=8.0)        # vertex state
    # OQ2: per-edge update (T2 -> T3)
    starts, ends = g.row_ptr[frontier], g.row_ptr[frontier + 1]
    nbr = np.concatenate([g.col_idx[s:e] for s, e in zip(starts, ends)]) \
        if len(frontier) else np.array([], np.int64)
    wts = np.concatenate([g.values[s:e] for s, e in zip(starts, ends)]) \
        if len(frontier) else np.array([], np.float32)
    src_edge = np.repeat(g.row_ptr[frontier], deg)  # edge-segment identity
    vals = np.repeat(values_per_v, deg)
    if op == "min_plus_w":
        vals = vals + wts
        op = "min"
    elif op == "mul_add":
        vals = vals * wts
        op = "add"
    stats = engine.route(
        "T3", src_idx=src_edge, dst_idx=nbr.astype(np.int64),
        values=vals, target=target, op=op,
        payload_words=2,
        stream_bytes_per_task=8.0,                 # col_idx + weight
        random_bytes_per_task=8.0)                 # target element
    return nbr, stats


def bfs(engine: TaskEngine, g: CSR, root: int) -> Tuple[np.ndarray, RunStats]:
    dist = np.full(g.n, np.inf)
    dist[root] = 0
    frontier = np.array([root], np.int64)
    while len(frontier):
        before = dist.copy()
        _expand(engine, g, frontier, dist[frontier] + 1.0, dist, "min")
        frontier = np.flatnonzero(dist < before)
    out = np.where(np.isinf(dist), -1, dist).astype(np.int64)
    return out, engine.stats


def sssp(engine: TaskEngine, g: CSR, root: int) -> Tuple[np.ndarray, RunStats]:
    dist = np.full(g.n, np.inf)
    dist[root] = 0.0
    frontier = np.array([root], np.int64)
    while len(frontier):
        before = dist.copy()
        _expand(engine, g, frontier, dist[frontier], dist, "min_plus_w")
        frontier = np.flatnonzero(dist < before)
    return dist, engine.stats


def pagerank(engine: TaskEngine, g: CSR, damping: float = 0.85,
             iters: int = 20) -> Tuple[np.ndarray, RunStats]:
    deg = g.degrees().astype(np.float64)
    rank = np.full(g.n, 1.0 / g.n)
    all_v = np.arange(g.n, dtype=np.int64)
    active = all_v[deg > 0]
    for _ in range(iters):
        acc = np.zeros(g.n)
        contrib = np.where(deg > 0, rank / np.maximum(deg, 1), 0.0)
        _expand(engine, g, active, contrib[active], acc, "add")
        dangling = rank[deg == 0].sum()
        rank = (1 - damping) / g.n + damping * (acc + dangling / g.n)
        engine.mark_barrier()   # per-epoch sync: the §V-B imbalance tail
    return rank, engine.stats


def wcc(engine: TaskEngine, g: CSR) -> Tuple[np.ndarray, RunStats]:
    label = np.arange(g.n, dtype=np.float64)
    frontier = np.arange(g.n, dtype=np.int64)
    gt = g.transpose()
    while len(frontier):
        before = label.copy()
        _expand(engine, g, frontier, label[frontier], label, "min")
        _expand(engine, gt, frontier, label[frontier], label, "min")
        frontier = np.flatnonzero(label < before)
    return label.astype(np.int64), engine.stats


def spmv(engine: TaskEngine, g: CSR, x: np.ndarray
         ) -> Tuple[np.ndarray, RunStats]:
    """y = A @ x via owner-computes on x (paper: task at the x[j] owner)."""
    gt = g.transpose()           # columns of A = rows of A^T
    y = np.zeros(g.n)
    cols = np.arange(g.n, dtype=np.int64)
    active = cols[gt.degrees() > 0]
    _expand(engine, gt, active, x[active], y, "mul_add")
    return y, engine.stats


def kcore(engine: TaskEngine, g: CSR, k: int = 8
          ) -> Tuple[np.ndarray, RunStats]:
    """k-core decomposition: peel sub-``k`` vertices round by round, each
    removal routing unit degree-decrement tasks along both edge
    directions (the undirected view, like :func:`wcc`). Returns within-core
    degrees (-1 for peeled vertices) — matches ``ref.kcore_ref``."""
    gt = g.transpose()
    deg = (g.degrees() + gt.degrees()).astype(np.float64)
    alive = np.ones(g.n, bool)
    frontier = np.flatnonzero(alive & (deg < k))
    while len(frontier):
        dec = np.zeros(g.n)
        _expand(engine, g, frontier, np.ones(len(frontier)), dec, "add")
        _expand(engine, gt, frontier, np.ones(len(frontier)), dec, "add")
        alive[frontier] = False
        deg = deg - dec
        frontier = np.flatnonzero(alive & (deg < k))
    return np.where(alive, deg, -1).astype(np.int64), engine.stats


def histogram(engine: TaskEngine, elements: np.ndarray, n_bins: int
              ) -> Tuple[np.ndarray, RunStats]:
    counts = np.zeros(n_bins)
    idx = np.arange(len(elements), dtype=np.int64)
    engine.route("T2", src_idx=idx, dst_idx=elements.astype(np.int64),
                 values=np.ones(len(elements)), target=counts, op="add",
                 payload_words=2,
                 stream_bytes_per_task=8.0, random_bytes_per_task=8.0)
    return counts.astype(np.int64), engine.stats
