"""Executable JAX implementations of the paper apps — single-device jnp and
*distributed* owner-routed rounds under shard_map.

ALL SIX paper applications (§IV-A) now run on the distributed path: SpMV
and Histogram as one owner-routed scatter round, and BFS / SSSP / PageRank /
WCC as iterative executables (``lax.while_loop`` / ``fori_loop``) where every
round re-enters the shared NoC collective layer in
:mod:`repro.core.routing` — the same capacity-bounded bucketing + fused
all_to_all machinery the MoE dispatch uses, at graph granularity.

Layouts mirror DCRA's cyclic PGAS: vertex ``v`` lives on device
``v % n_dev`` at local slot ``v // n_dev``; edges are partitioned by the
owner of their *source* vertex so reading the frontier value is tile-local
and only the per-edge update crosses the NoC (tasks ``(dest, value)`` with
bounded input queues; overflow dropped and counted).

Each app returns per-round message/drop counts as :class:`AppStats`,
convertible to the cost model's ``RunStats`` — the executable path and the
analytic :mod:`repro.core.task_engine` twin expose the same instrumentation
shape.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from ..core.compat import shard_map_unchecked
from ..core.queues import QueueConfig
from ..core.routing import owner_route, owner_route_hier, reduce_received
from ..core.task_engine import RoundStats, RunStats
from .csr import CSR


# ---------------------------------------------------------------------------
# single-device (edge-parallel) reference executables
# ---------------------------------------------------------------------------

def spmv_jnp(rows, cols, vals, x, n):
    return jax.ops.segment_sum(vals * x[cols], rows, num_segments=n)


def histogram_jnp(elements, n_bins):
    return jax.ops.segment_sum(jnp.ones_like(elements), elements,
                               num_segments=n_bins)


def bfs_jnp(rows, cols, n, root, max_levels: Optional[int] = None):
    """Edge-parallel BFS: one scatter-min round per level."""
    dist = jnp.full((n,), jnp.inf).at[root].set(0.0)

    def round_(level, dist):
        cand = jnp.where(dist[rows] == level, level + 1.0, jnp.inf)
        upd = jax.ops.segment_min(cand, cols, num_segments=n)
        return jnp.minimum(dist, upd)

    levels = max_levels or n
    def body(i, d):
        return round_(jnp.asarray(i, jnp.float32), d)
    return jax.lax.fori_loop(0, levels, body, dist)


# ---------------------------------------------------------------------------
# per-round instrumentation (the executable twin of RunStats)
# ---------------------------------------------------------------------------

@dataclass
class AppStats:
    """Per-round NoC counters from a distributed run.

    ``messages`` counts routed tasks per round (including owner-local ones —
    they occupy IQ slots just the same); ``drops`` counts IQ-overflow
    discards. Convert with :meth:`to_run_stats` for the cost model.
    """
    rounds: int
    messages: np.ndarray          # [rounds] int64
    drops: np.ndarray             # [rounds] int64

    @property
    def total_messages(self) -> int:
        return int(self.messages.sum())

    @property
    def total_drops(self) -> int:
        return int(self.drops.sum())

    def to_run_stats(self, payload_words: int = 2,
                     word_bytes: int = 8) -> RunStats:
        rs = RunStats()
        for m, d in zip(self.messages.tolist(), self.drops.tolist()):
            rs.rounds.append(RoundStats(
                messages=int(m),
                payload_bytes=int(m) * payload_words * word_bytes,
                tasks_total=int(m),
                drops=int(d)))
        return rs


def _collect_stats(rounds, msgs, drops) -> AppStats:
    r = int(rounds)
    return AppStats(rounds=r,
                    messages=np.asarray(msgs)[:r].astype(np.int64),
                    drops=np.asarray(drops)[:r].astype(np.int64))


# ---------------------------------------------------------------------------
# the DCRA owner-routed round (distributed)
# ---------------------------------------------------------------------------

def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dcra_scatter(dest, vals, n, mesh, axis="data", op="add",
                 capacity_factor: float = 1.5, pod_axis=None,
                 cap: Optional[int] = None,
                 queues: Optional[QueueConfig] = None, task: str = "T3"):
    """Owner-routed scatter-reduce: one NoC round.

    dest/vals: [E] sharded over the device axes (edge-parallel tasks);
    returns y [n] sharded the same way (cyclic owner layout: item i lives
    on device i % n_dev at local slot i // n_dev) plus the dropped-task
    count (queue overflow).

    ``pod_axis`` selects the hierarchical pod/portal two-stage path
    (paper §III-A): stage 1 aggregates at the per-pod portal over ``axis``
    (tile-NoC), stage 2 crosses pods exactly once (die-NoC).

    Queue sizing resolves through ONE path — :class:`QueueConfig` — like
    everywhere else in the repo. ``queues`` names the per-``task`` IQ
    directly; the legacy ``cap=`` / ``capacity_factor=`` kwargs are sugar
    for ``QueueConfig.from_cap`` / ``QueueConfig.from_factor`` overrides.
    Explicit capacities are honored exactly (flat path only — the DSE
    revalidation sweeps the IQ axis in queue entries, so rounding would
    validate a different capacity than the analytic model swept);
    factor-derived capacities keep the lane-aligned round8.
    """
    n_dev = mesh.devices.size
    e_local = dest.shape[0] // n_dev
    n_local = -(-n // n_dev)
    spec = P((pod_axis, axis)) if pod_axis else P(axis)
    if queues is None:
        queues = (QueueConfig.from_cap(cap, task) if cap is not None
                  else QueueConfig.from_factor(capacity_factor, task))
    explicit = queues.iq_sizes.get(task, None)
    if explicit is not None and pod_axis is not None:
        raise ValueError("explicit cap is only defined for the flat path")

    if pod_axis is None:
        cap = queues.channel_cap(task, e_local, n_dev)
        if cap is None:          # unbounded -> every local task can fit
            cap = max(1, e_local)
        cap = max(1, int(cap))

        def kernel(dest_b, vals_b):
            valid = dest_b >= 0                    # padding -> no task
            dest_c = jnp.maximum(dest_b, 0)
            recv_slot, recv_val, n_drop = owner_route(
                vals_b, dest_c // n_dev, dest_c % n_dev, valid,
                n_dev, cap, axis)
            y = reduce_received(recv_slot, recv_val, n_local, op)
            return y, jax.lax.psum(n_drop, axis)
    else:
        sizes = _axis_sizes(mesh)
        n_intra, n_pods = sizes[axis], sizes[pod_axis]
        cap1 = queues.channel_cap(task, e_local, n_intra)
        cap1 = max(1, e_local) if cap1 is None else cap1
        cap2 = queues.channel_cap(task, n_intra * cap1, n_pods)
        cap2 = max(1, n_intra * cap1) if cap2 is None else cap2

        def kernel(dest_b, vals_b):
            valid = dest_b >= 0
            dest_c = jnp.maximum(dest_b, 0)
            recv_slot, recv_val, n_drop = owner_route_hier(
                vals_b, dest_c // n_dev, dest_c % n_dev, valid,
                n_intra, axis, n_pods, pod_axis, cap1, cap2)
            y = reduce_received(recv_slot, recv_val, n_local, op)
            return y, jax.lax.psum(n_drop, (pod_axis, axis))

    return shard_map_unchecked(kernel, mesh=mesh, in_specs=(spec, spec),
                               out_specs=(spec, P()))(dest, vals)


def _resolve_launch(config, g, app, objective="teps", kwargs_set=()):
    """Resolve an app's ``config=`` kwarg to a ``LaunchConfig`` (or None).

    ``"auto"`` runs the Pareto-guided selection in
    :mod:`repro.dse.autoconfig`; a ``LaunchConfig`` passes through; a
    ``DesignPoint`` is wrapped as an explicit choice. ``None`` keeps the
    legacy kwarg-driven sizing. ``kwargs_set`` names explicitly-passed
    sizing kwargs — combining those with ``config=`` is an error, not a
    silent override.
    """
    if config is None:
        return None
    if kwargs_set:
        raise ValueError(f"config= conflicts with explicit {kwargs_set}: "
                         f"queue sizing comes from the resolved "
                         f"LaunchConfig, drop one of them")
    from ..dse.autoconfig import LaunchConfig, autoconfigure, launch_for
    if isinstance(config, str):
        if config != "auto":
            raise ValueError(f"unknown config {config!r} (expected 'auto', "
                             f"a LaunchConfig or a DesignPoint)")
        return autoconfigure(g, app, objective=objective)
    if isinstance(config, LaunchConfig):
        return config
    return launch_for(config, g, objective=objective)


def owner_layout(arr_n, n_dev):
    """Reorder a dense [n] array into cyclic-owner order (device-major)."""
    n = arr_n.shape[0]
    n_local = -(-n // n_dev)
    idx = jnp.arange(n_local * n_dev)
    src = (idx % n_local) * n_dev + idx // n_local   # device-major -> global
    src = jnp.minimum(src, n - 1)
    valid = ((idx % n_local) * n_dev + idx // n_local) < n
    return jnp.where(valid, arr_n[src], 0), valid


def from_owner_layout(y_sharded, n, n_dev):
    """Inverse of owner_layout: [n_local*n_dev] -> global order [n]."""
    n_local = -(-n // n_dev)
    g = jnp.arange(n)
    pos = (g % n_dev) * n_local + g // n_dev
    return y_sharded[pos]


def _owner_pack_np(arr, n_dev, fill):
    """numpy owner_layout with a chosen fill for the padding slots."""
    arr = np.asarray(arr, np.float64)
    n = len(arr)
    n_local = -(-n // n_dev)
    idx = np.arange(n_local * n_dev)
    g = (idx % n_local) * n_dev + idx // n_local
    valid = g < n
    out = np.full(n_local * n_dev, fill, np.float64)
    out[valid] = arr[g[valid]]
    return out, valid


def spmv_task_stream(g: CSR, x: np.ndarray, n_dev: int, seed: int = 0
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """The exact flat (dest, value) task stream ``dcra_spmv`` routes.

    Device ``d`` owns the contiguous slice ``[d*e_local, (d+1)*e_local)``;
    padding tasks carry ``dest = -1`` (no-task). Exposed so the DSE
    revalidation can feed the *same* stream through the analytic
    ``TaskEngine.route`` twin and compare message/drop counts exactly.

    Edges are shuffled once (host-side): CSR order concentrates a
    high-degree row's edges on one device, overflowing its owner bucket —
    a uniform spread keeps per-owner load near E/(n_dev^2), the same reason
    Dalorex interleaves arrays cyclically.
    """
    E = g.nnz
    perm = np.random.default_rng(seed).permutation(E)
    rows = g.row_of()[perm]
    cols = g.col_idx[perm]
    vals = g.values[perm].astype(np.float32)
    pad = -(-E // n_dev) * n_dev - E
    dest = np.concatenate([rows, np.full(pad, -1)]).astype(np.int32)
    eff = vals * np.asarray(x, np.float32)[cols]
    vals_eff = np.concatenate([eff, np.zeros(pad, np.float32)])
    return dest, vals_eff


def histogram_task_stream(elements: np.ndarray, n_dev: int
                          ) -> Tuple[np.ndarray, np.ndarray]:
    """The flat (dest, value) stream ``dcra_histogram`` routes (see
    :func:`spmv_task_stream` for the sharded-slice convention)."""
    E = len(elements)
    pad = -(-E // n_dev) * n_dev - E
    dest = np.concatenate([np.asarray(elements),
                           np.full(pad, -1)]).astype(np.int32)
    vals = np.concatenate([np.ones(E, np.float32),
                           np.zeros(pad, np.float32)])
    return dest, vals


def dcra_spmv(g: CSR, x: np.ndarray, mesh, axis="data",
              capacity_factor: Optional[float] = None, seed: int = 0,
              pod_axis=None, cap: Optional[int] = None, config=None,
              objective="teps"):
    """Distributed y = A @ x via one owner-routed round.

    ``config="auto"`` resolves pod/portal routing and the per-task IQ
    sizing from the tracked Pareto frontier (see
    :mod:`repro.dse.autoconfig`) instead of the kwargs (combining the
    two raises). ``capacity_factor`` defaults to 2.0.
    """
    lc = _resolve_launch(config, g, "spmv", objective,
                         kwargs_set=[k for k, v in
                                     (("capacity_factor", capacity_factor),
                                      ("cap", cap)) if v is not None])
    if capacity_factor is None:
        capacity_factor = 2.0
    n_dev = mesh.devices.size
    dest, vals_eff = spmv_task_stream(g, x, n_dev, seed)
    queues = None
    if lc is not None:
        pod_axis = pod_axis if pod_axis is not None else lc.pod_axis_for(mesh)
        queues = lc.device_queues(n_dev, len(dest) // n_dev,
                                  pod=pod_axis is not None)
    y_sh, dropped = dcra_scatter(jnp.asarray(dest), jnp.asarray(vals_eff),
                                 g.n, mesh, axis,
                                 op="add", capacity_factor=capacity_factor,
                                 pod_axis=pod_axis, cap=cap, queues=queues)
    return from_owner_layout(y_sh, g.n, n_dev), dropped


def dcra_histogram(elements: np.ndarray, n_bins: int, mesh, axis="data",
                   capacity_factor: Optional[float] = None, pod_axis=None,
                   cap: Optional[int] = None, config=None,
                   objective="teps"):
    lc = _resolve_launch(config, elements, "histogram", objective,
                         kwargs_set=[k for k, v in
                                     (("capacity_factor", capacity_factor),
                                      ("cap", cap)) if v is not None])
    if capacity_factor is None:
        capacity_factor = 2.0
    n_dev = mesh.devices.size
    dest, ones = histogram_task_stream(elements, n_dev)
    queues = None
    if lc is not None:
        pod_axis = pod_axis if pod_axis is not None else lc.pod_axis_for(mesh)
        queues = lc.device_queues(n_dev, len(dest) // n_dev,
                                  pod=pod_axis is not None)
    y_sh, dropped = dcra_scatter(jnp.asarray(dest), jnp.asarray(ones),
                                 n_bins, mesh, axis, op="add",
                                 capacity_factor=capacity_factor,
                                 pod_axis=pod_axis, cap=cap, queues=queues)
    return from_owner_layout(y_sh, n_bins, n_dev), dropped


# ---------------------------------------------------------------------------
# iterative graph apps: owner-routed rounds under lax.while_loop/fori_loop
# ---------------------------------------------------------------------------

def _pack_edges(rows, cols, wts, n_dev, seed=0):
    """Partition edges by src-vertex owner (device-major flat arrays).

    Returns (src_slot, dst, w, E_max): each [n_dev * E_max]; padding edges
    carry dst = -1 (owner_route treats them as no-task). Edges are shuffled
    within each device so owner buckets fill uniformly.
    """
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(rows))
    rows, cols, wts = rows[perm], cols[perm], wts[perm]
    own = (rows % n_dev).astype(np.int64)
    counts = np.bincount(own, minlength=n_dev)
    E_max = max(8, int(counts.max()))
    src_slot = np.zeros((n_dev, E_max), np.int32)
    dst = np.full((n_dev, E_max), -1, np.int32)
    w = np.zeros((n_dev, E_max), np.float32)
    for d in range(n_dev):
        sel = own == d
        k = int(counts[d])
        src_slot[d, :k] = (rows[sel] // n_dev).astype(np.int32)
        dst[d, :k] = cols[sel].astype(np.int32)
        w[d, :k] = wts[sel]
    return (jnp.asarray(src_slot.reshape(-1)), jnp.asarray(dst.reshape(-1)),
            jnp.asarray(w.reshape(-1)), E_max)


def _graph_setup(g: CSR, mesh, undirected=False, seed=0):
    n_dev = mesh.devices.size
    rows, cols, wts = g.row_of(), g.col_idx.astype(np.int64), g.values
    if undirected:
        rows, cols = np.concatenate([rows, cols]), np.concatenate([cols, rows])
        wts = np.concatenate([wts, wts])
    src_slot, dst, w, E_max = _pack_edges(rows, cols, wts, n_dev, seed)
    n_local = -(-g.n // n_dev)
    return n_dev, n_local, src_slot, dst, w, E_max


def _frontier_min_app(g: CSR, mesh, dist0_np, *, value, axis="data",
                      capacity_factor: float = 4.0, max_rounds: int = 128,
                      undirected: bool = False, seed: int = 0,
                      launch=None):
    """Shared driver for BFS / SSSP / WCC: frontier-driven scatter-min
    rounds inside ONE lax.while_loop under shard_map.

    ``value`` chooses the per-edge task payload: 'hops' (dist+1), 'weight'
    (dist+w), or 'label' (dist itself). ``launch`` (a resolved
    ``LaunchConfig``) overrides the IQ sizing through ``QueueConfig``.
    """
    n_dev, n_local, src_slot, dst, w, E_max = _graph_setup(
        g, mesh, undirected=undirected, seed=seed)
    queues = (launch.device_queues(n_dev, E_max) if launch is not None
              else QueueConfig.from_factor(capacity_factor))
    cap = queues.channel_cap("T3", E_max, n_dev)
    cap = max(1, E_max) if cap is None else min(cap, max(1, E_max))
    dist0, _ = _owner_pack_np(dist0_np.astype(np.float64), n_dev, np.inf)
    dist0 = jnp.asarray(dist0, jnp.float32)

    def kernel(src_slot_b, dst_b, w_b, dist_b):
        owner = jnp.maximum(dst_b, 0) % n_dev
        slot = jnp.maximum(dst_b, 0) // n_dev
        evalid = dst_b >= 0

        def cond(state):
            _, _, r, _, _, changed = state
            return changed & (r < max_rounds)

        def body(state):
            dist, frontier, r, msgs, drops, _ = state
            active = frontier[src_slot_b] & evalid
            base = dist[src_slot_b]
            if value == "hops":
                vals = base + 1.0
            elif value == "weight":
                vals = base + w_b
            else:                                   # 'label'
                vals = base
            m = jax.lax.psum(jnp.sum(active.astype(jnp.int32)), axis)
            recv_slot, recv_val, nd = owner_route(
                vals, slot, owner, active, n_dev, cap, axis)
            upd = reduce_received(recv_slot, recv_val, n_local, "min")
            new_dist = jnp.minimum(dist, upd)
            frontier2 = new_dist < dist
            changed = jax.lax.psum(
                jnp.sum(frontier2.astype(jnp.int32)), axis) > 0
            msgs = msgs.at[r].set(m)
            drops = drops.at[r].set(
                jax.lax.psum(nd.astype(jnp.int32), axis))
            return (new_dist, frontier2, r + 1, msgs, drops, changed)

        zeros = jnp.zeros((max_rounds,), jnp.int32)
        state = (dist_b, jnp.isfinite(dist_b) if value != "label"
                 else jnp.ones_like(dist_b, bool),
                 jnp.int32(0), zeros, zeros, jnp.bool_(True))
        dist, _, r, msgs, drops, _ = jax.lax.while_loop(cond, body, state)
        return dist, r, msgs, drops

    spec = P(axis)
    dist, r, msgs, drops = shard_map_unchecked(
        kernel, mesh=mesh,
        in_specs=(spec, spec, spec, spec),
        out_specs=(spec, P(), P(), P()))(src_slot, dst, w, dist0)
    dist_np = np.asarray(from_owner_layout(dist, g.n, n_dev))
    return dist_np, _collect_stats(r, msgs, drops)


def _cf_kwargs_set(capacity_factor):
    return ["capacity_factor"] if capacity_factor is not None else []


def dcra_bfs(g: CSR, root: int, mesh, axis="data",
             capacity_factor: Optional[float] = None, max_rounds: int = 128,
             seed: int = 0, config=None, objective="teps"
             ) -> Tuple[np.ndarray, AppStats]:
    """Distributed BFS: hop count from root, -1 if unreachable.

    ``config="auto"`` picks the deployment (grid, topology, IQ sizing)
    from the tracked Pareto frontier for this graph + objective;
    ``capacity_factor`` (default 4.0) is the manual alternative —
    passing both raises.
    """
    lc = _resolve_launch(config, g, "bfs", objective,
                         kwargs_set=_cf_kwargs_set(capacity_factor))
    capacity_factor = 4.0 if capacity_factor is None else capacity_factor
    dist0 = np.full(g.n, np.inf)
    dist0[root] = 0.0
    d, stats = _frontier_min_app(g, mesh, dist0, value="hops", axis=axis,
                                 capacity_factor=capacity_factor,
                                 max_rounds=max_rounds, seed=seed,
                                 launch=lc)
    return np.where(np.isfinite(d), d, -1).astype(np.int64), stats


def dcra_sssp(g: CSR, root: int, mesh, axis="data",
              capacity_factor: Optional[float] = None, max_rounds: int = 256,
              seed: int = 0, config=None, objective="teps"
              ) -> Tuple[np.ndarray, AppStats]:
    """Distributed SSSP (frontier Bellman-Ford): inf if unreachable."""
    lc = _resolve_launch(config, g, "sssp", objective,
                         kwargs_set=_cf_kwargs_set(capacity_factor))
    capacity_factor = 4.0 if capacity_factor is None else capacity_factor
    dist0 = np.full(g.n, np.inf)
    dist0[root] = 0.0
    d, stats = _frontier_min_app(g, mesh, dist0, value="weight", axis=axis,
                                 capacity_factor=capacity_factor,
                                 max_rounds=max_rounds, seed=seed,
                                 launch=lc)
    return d.astype(np.float64), stats


def dcra_wcc(g: CSR, mesh, axis="data",
             capacity_factor: Optional[float] = None,
             max_rounds: int = 128, seed: int = 0, config=None,
             objective="teps") -> Tuple[np.ndarray, AppStats]:
    """Distributed WCC via min-label propagation over both edge directions."""
    if g.n > (1 << 24):
        # labels ride the f32 NoC payload; ids above 2^24 would collide
        raise ValueError(f"dcra_wcc supports up to 2^24 vertices, got {g.n}")
    lc = _resolve_launch(config, g, "wcc", objective,
                         kwargs_set=_cf_kwargs_set(capacity_factor))
    capacity_factor = 4.0 if capacity_factor is None else capacity_factor
    label0 = np.arange(g.n, dtype=np.float64)
    lab, stats = _frontier_min_app(g, mesh, label0, value="label", axis=axis,
                                   capacity_factor=capacity_factor,
                                   max_rounds=max_rounds, undirected=True,
                                   seed=seed, launch=lc)
    return lab.astype(np.int64), stats


def dcra_pagerank(g: CSR, mesh, damping: float = 0.85, iters: int = 20,
                  axis="data", capacity_factor: Optional[float] = None,
                  seed: int = 0, config=None, objective="teps"
                  ) -> Tuple[np.ndarray, AppStats]:
    """Distributed PageRank: ``iters`` owner-routed epochs (fori_loop),
    dangling mass redistributed uniformly each epoch (matches the oracle)."""
    lc = _resolve_launch(config, g, "pagerank", objective,
                         kwargs_set=_cf_kwargs_set(capacity_factor))
    capacity_factor = 4.0 if capacity_factor is None else capacity_factor
    n_dev, n_local, src_slot, dst, w, E_max = _graph_setup(g, mesh, seed=seed)
    queues = (lc.device_queues(n_dev, E_max) if lc is not None
              else QueueConfig.from_factor(capacity_factor))
    cap = queues.channel_cap("T3", E_max, n_dev)
    cap = max(1, E_max) if cap is None else min(cap, max(1, E_max))
    n = g.n
    deg, vvalid = _owner_pack_np(g.degrees().astype(np.float64), n_dev, 0.0)
    deg = jnp.asarray(deg, jnp.float32)
    vvalid = jnp.asarray(vvalid)
    rank0 = jnp.where(vvalid, jnp.float32(1.0 / n), 0.0)

    def kernel(src_slot_b, dst_b, deg_b, vvalid_b, rank_b):
        owner = jnp.maximum(dst_b, 0) % n_dev
        slot = jnp.maximum(dst_b, 0) // n_dev
        evalid = dst_b >= 0
        inv_n = jnp.float32(1.0 / n)

        def body(i, state):
            rank, msgs, drops = state
            contrib = jnp.where(deg_b > 0, rank / jnp.maximum(deg_b, 1.0),
                                0.0)
            vals = contrib[src_slot_b]
            m = jax.lax.psum(jnp.sum(evalid.astype(jnp.int32)), axis)
            recv_slot, recv_val, nd = owner_route(
                vals, slot, owner, evalid, n_dev, cap, axis)
            acc = reduce_received(recv_slot, recv_val, n_local, "add")
            dangling = jax.lax.psum(
                jnp.sum(jnp.where(vvalid_b & (deg_b == 0), rank, 0.0)), axis)
            rank2 = jnp.where(
                vvalid_b,
                (1.0 - damping) * inv_n + damping * (acc + dangling * inv_n),
                0.0)
            return (rank2, msgs.at[i].set(m),
                    drops.at[i].set(jax.lax.psum(nd.astype(jnp.int32),
                                                 axis)))

        zeros = jnp.zeros((iters,), jnp.int32)
        rank, msgs, drops = jax.lax.fori_loop(0, iters, body,
                                              (rank_b, zeros, zeros))
        return rank, msgs, drops

    spec = P(axis)
    rank, msgs, drops = shard_map_unchecked(
        kernel, mesh=mesh,
        in_specs=(spec, spec, spec, spec, spec),
        out_specs=(spec, P(), P()))(src_slot, dst, deg, vvalid, rank0)
    rank_np = np.asarray(from_owner_layout(rank, g.n, n_dev),
                         dtype=np.float64)
    return rank_np, _collect_stats(iters, msgs, drops)
