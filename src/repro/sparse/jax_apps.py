"""Executable JAX implementations of the paper apps — single-device jnp and
*distributed* owner-routed rounds under shard_map.

ALL SEVEN applications (the paper's six, §IV-A, plus k-core
decomposition) are now **TaskProgram definitions**: each app is a ~30-line
declarative spec — edge-payload rule, reduce op, frontier-update rule,
task class — and the shared :func:`repro.sparse.program.run_program`
runtime owns launch/queue resolution, the flat vs pod/portal path, the
cyclic owner layout, the one-round vs ``lax.while_loop`` execution shape,
per-round :class:`~repro.sparse.program.AppStats` and the compile cache.
Program rules are xp-generic, so the SAME definitions drive the analytic
twin (:func:`repro.sparse.program.program_app_stats`) the DSE
revalidation replays through ``TaskEngine.route``.

Layouts mirror DCRA's cyclic PGAS: vertex ``v`` lives on device
``v % n_dev`` at local slot ``v // n_dev``; edges are partitioned by the
owner of their *source* vertex so reading the frontier value is tile-local
and only the per-edge update crosses the NoC (tasks ``(dest, value)`` with
bounded input queues; overflow dropped and counted).

Every app's ``mesh`` argument accepts a :class:`repro.core.fabric.Fabric`
(single-process, fake-device rig or multi-process ``jax.distributed``) or
a raw ``jax.sharding.Mesh`` (deprecated, warn-once shim) — identical
compile-cache keys and bit-identical results either way.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

from .csr import CSR
from .options import LaunchOptions
# dcra_scatter / from_owner_layout are re-exported: tests and benchmarks
# address the one-round scatter and the layout inverse through this module
from .program import (AppStats, ProgramLaunch, TaskProgram,  # noqa: F401
                      dcra_scatter, from_owner_layout, launch_program,
                      run_program)


# ---------------------------------------------------------------------------
# single-device (edge-parallel) reference executables
# ---------------------------------------------------------------------------

def spmv_jnp(rows, cols, vals, x, n):  # noqa: PLR0917
    return jax.ops.segment_sum(vals * x[cols], rows, num_segments=n)


def histogram_jnp(elements, n_bins):
    return jax.ops.segment_sum(jax.numpy.ones_like(elements), elements,
                               num_segments=n_bins)


def bfs_jnp(rows, cols, n, root,  # noqa: PLR0917
             max_levels: Optional[int] = None):
    """Edge-parallel BFS: one scatter-min round per level."""
    jnp = jax.numpy
    dist = jnp.full((n,), jnp.inf).at[root].set(0.0)

    def round_(level, dist):
        cand = jnp.where(dist[rows] == level, level + 1.0, jnp.inf)
        upd = jax.ops.segment_min(cand, cols, num_segments=n)
        return jnp.minimum(dist, upd)

    levels = max_levels or n
    def body(i, d):
        return round_(jnp.asarray(i, jnp.float32), d)
    return jax.lax.fori_loop(0, levels, body, dist)


# ---------------------------------------------------------------------------
# task streams for the one-round scatter programs
# ---------------------------------------------------------------------------

def spmv_task_stream(g: CSR, x: np.ndarray, n_dev: int, seed: int = 0
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """The exact flat (dest, value) task stream ``dcra_spmv`` routes.

    Device ``d`` owns the contiguous slice ``[d*e_local, (d+1)*e_local)``;
    padding tasks carry ``dest = -1`` (no-task). Exposed so the DSE
    revalidation can feed the *same* stream through the analytic
    ``TaskEngine.route`` twin and compare message/drop counts exactly.

    Edges are shuffled once (host-side): CSR order concentrates a
    high-degree row's edges on one device, overflowing its owner bucket —
    a uniform spread keeps per-owner load near E/(n_dev^2), the same reason
    Dalorex interleaves arrays cyclically.
    """
    E = g.nnz
    perm = np.random.default_rng(seed).permutation(E)
    rows = g.row_of()[perm]
    cols = g.col_idx[perm]
    vals = g.values[perm].astype(np.float32)
    pad = -(-E // n_dev) * n_dev - E
    dest = np.concatenate([rows, np.full(pad, -1)]).astype(np.int32)
    eff = vals * np.asarray(x, np.float32)[cols]
    vals_eff = np.concatenate([eff, np.zeros(pad, np.float32)])
    return dest, vals_eff


def histogram_task_stream(elements: np.ndarray, n_dev: int
                          ) -> Tuple[np.ndarray, np.ndarray]:
    """The flat (dest, value) stream ``dcra_histogram`` routes (see
    :func:`spmv_task_stream` for the sharded-slice convention)."""
    E = len(elements)
    pad = -(-E // n_dev) * n_dev - E
    dest = np.concatenate([np.asarray(elements),
                           np.full(pad, -1)]).astype(np.int32)
    vals = np.concatenate([np.ones(E, np.float32),
                           np.zeros(pad, np.float32)])
    return dest, vals


def _spmv_stream(data, params, n_dev, seed):
    g, x = data
    dest, vals = spmv_task_stream(g, x, n_dev, seed)
    return dest, vals, g.n


def _histogram_stream(data, params, n_dev, seed):
    elements, n_bins = data
    dest, vals = histogram_task_stream(elements, n_dev)
    return dest, vals, n_bins


def _histogram_local_reduce(data, dest, vals, n_items):
    """Single-shard kernel-tier reduce: the MXU histogram kernel counts
    the task stream directly (dest IS the bin id; -1 padding matches no
    bin), replacing the owner-routed ``reduce_received`` round.

    Only consulted by ``run_program`` when no task can drop, so the
    counts are bit-identical to the routed path (differential-tested in
    tests/test_route_kernels.py). Returns None — falling back to the
    routed path — off-TPU at sizes where the interpret-mode kernel would
    be slower than the XLA scatter.
    """
    from ..kernels import ops
    if jax.default_backend() != "tpu" and len(dest) > 4096:
        return None
    counts = ops.histogram(jax.numpy.asarray(dest, jax.numpy.int32),
                           n_items)
    return counts.astype(jax.numpy.float32)


# ---------------------------------------------------------------------------
# program rule library (xp-generic: jnp in-kernel, numpy in the twin)
# ---------------------------------------------------------------------------

def _dist_init(g, params):
    dist = np.full(g.n, np.inf)
    dist[int(params["root"])] = 0.0
    return (dist,), (np.inf,)


def _multi_root_init(g, params):
    """Tenant-column init: ``g`` is a tenant-expanded graph (vertex
    ``t * n + v`` is base vertex ``v`` in tenant ``t``'s column, see
    :func:`repro.serve.batching.tenant_graph`); ``params['roots']`` holds
    one root per tenant. One frontier array carries all T tenants, so a
    single shard_map round serves the whole batch."""
    roots = params["roots"]
    n = g.n // len(roots)
    dist = np.full(g.n, np.inf)
    for t, root in enumerate(roots):
        r = int(root)
        if not 0 <= r < n:
            # a bad root must never wrap into another tenant's column
            raise ValueError(
                f"root {root} out of range [0, {n}) for tenant column {t}")
        dist[t * n + r] = 0.0
    return (dist,), (np.inf,)


def _label_init(g, params):
    return (np.arange(g.n, dtype=np.float64),), (np.inf,)


def _finite_frontier(ctx, state):
    return ctx.xp.isfinite(state[0])


def _all_frontier(ctx, state):
    return ctx.xp.ones(state[0].shape, bool)


def _hops_payload(ctx, state, src_slot, w):
    return state[0][src_slot] + 1.0


def _weight_payload(ctx, state, src_slot, w):
    return state[0][src_slot] + w


def _label_payload(ctx, state, src_slot, w):
    return state[0][src_slot]


def _min_update(ctx, state, frontier, upd):
    new = ctx.xp.minimum(state[0], upd)
    return (new,), new < state[0]


BFS = TaskProgram(name="bfs", reduce_op="min", payload=_hops_payload,
                  init=_dist_init, frontier0=_finite_frontier,
                  update=_min_update, init_only=("root",))

SSSP = TaskProgram(name="sssp", reduce_op="min", payload=_weight_payload,
                   init=_dist_init, frontier0=_finite_frontier,
                   update=_min_update, max_rounds=256,
                   init_only=("root",))

# Tenant-batched serving variants (the resident-serving tier's fused
# multi-root launch, :mod:`repro.serve`): the SAME payload/update rules —
# only init differs, reading per-tenant roots. ``roots`` is init-only, so
# every request batch of one shape class reuses one jitted callable.
BATCHED_BFS = TaskProgram(name="bfs_batched", reduce_op="min",
                          payload=_hops_payload, init=_multi_root_init,
                          frontier0=_finite_frontier, update=_min_update,
                          init_only=("roots",))

BATCHED_SSSP = TaskProgram(name="sssp_batched", reduce_op="min",
                           payload=_weight_payload, init=_multi_root_init,
                           frontier0=_finite_frontier, update=_min_update,
                           max_rounds=256, init_only=("roots",))

WCC = TaskProgram(name="wcc", reduce_op="min", payload=_label_payload,
                  init=_label_init, frontier0=_all_frontier,
                  update=_min_update, undirected=True)


def _pr_init(g, params):
    deg = g.degrees().astype(np.float64)
    rank = np.full(g.n, 1.0 / g.n)
    return (rank, deg, np.ones(g.n)), (0.0, 0.0, 0.0)


def _pr_payload(ctx, state, src_slot, w):
    rank, deg, vmask = state
    contrib = ctx.xp.where(deg > 0, rank / ctx.xp.maximum(deg, 1.0), 0.0)
    return contrib[src_slot]


def _pr_update(ctx, state, frontier, upd):
    rank, deg, vmask = state
    xp = ctx.xp
    damping = ctx.params["damping"]
    inv_n = xp.float32(1.0 / ctx.n)
    dangling = ctx.gsum(xp.sum(
        xp.where((vmask > 0) & (deg == 0), rank, 0.0)))
    rank2 = xp.where(vmask > 0, (1.0 - damping) * inv_n
                     + damping * (upd + dangling * inv_n), 0.0)
    return (rank2, deg, vmask), frontier


PAGERANK = TaskProgram(name="pagerank", reduce_op="add", mode="fixed",
                       active="all", payload=_pr_payload, init=_pr_init,
                       frontier0=_all_frontier, update=_pr_update)

SPMV = TaskProgram(name="spmv", reduce_op="add", mode="single",
                   default_capacity_factor=2.0, stream=_spmv_stream)

HISTOGRAM = TaskProgram(name="histogram", reduce_op="add", mode="single",
                        default_capacity_factor=2.0,
                        stream=_histogram_stream,
                        local_reduce=_histogram_local_reduce)


# ---- k-core decomposition: the seventh app, a pure program definition ----

def _kcore_init(g, params):
    # undirected view: degree counts each stored direction (in + out)
    deg = (g.degrees() + g.transpose().degrees()).astype(np.float64)
    return (deg, np.ones(g.n)), (0.0, 0.0)


def _kcore_frontier0(ctx, state):
    deg, alive = state
    return (alive > 0) & (deg < ctx.params["k"])


def _unit_payload(ctx, state, src_slot, w):
    return ctx.xp.ones(src_slot.shape, ctx.xp.float32)


def _kcore_update(ctx, state, frontier, upd):
    deg, alive = state
    alive2 = ctx.xp.where(frontier, 0.0, alive)   # peeled this round
    deg2 = deg - upd                              # received decrements
    return (deg2, alive2), (alive2 > 0) & (deg2 < ctx.params["k"])


KCORE = TaskProgram(name="kcore", reduce_op="add", undirected=True,
                    payload=_unit_payload, init=_kcore_init,
                    frontier0=_kcore_frontier0, update=_kcore_update)


PROGRAMS = {p.name: p for p in (BFS, SSSP, WCC, PAGERANK, SPMV, HISTOGRAM,
                                KCORE)}


# ---------------------------------------------------------------------------
# public app entry points (thin wrappers over run_program)
# ---------------------------------------------------------------------------

def dcra_spmv(g: CSR, x: np.ndarray, mesh, *,
              options: Optional[LaunchOptions] = None, axis="data",
              capacity_factor: Optional[float] = None, seed: int = 0,
              pod_axis=None, cap: Optional[int] = None, config=None,
              objective="teps", route_impl: Optional[str] = None,
              round_mode: Optional[str] = None):
    """Distributed y = A @ x via one owner-routed round.

    ``config="auto"`` resolves pod/portal routing and the per-task IQ
    sizing from the tracked Pareto frontier (see
    :mod:`repro.dse.autoconfig`) instead of the kwargs (combining the
    two raises). ``capacity_factor`` defaults to 2.0. ``options=`` takes
    a :class:`LaunchOptions` in place of the legacy launch kwargs.
    """
    y, stats = run_program(SPMV, (g, x), mesh, dataset=g, options=options,
                           axis=axis, pod_axis=pod_axis, cap=cap,
                           capacity_factor=capacity_factor, config=config,
                           objective=objective, seed=seed,
                           route_impl=route_impl, round_mode=round_mode)
    return y, stats.total_drops


def dcra_histogram(elements: np.ndarray, n_bins: int, mesh, *,
                   options: Optional[LaunchOptions] = None, axis="data",
                   capacity_factor: Optional[float] = None, pod_axis=None,
                   cap: Optional[int] = None, config=None,
                   objective="teps", route_impl: Optional[str] = None,
                   round_mode: Optional[str] = None):
    y, stats = run_program(HISTOGRAM, (elements, n_bins), mesh,
                           dataset=elements, options=options, axis=axis,
                           pod_axis=pod_axis, cap=cap,
                           capacity_factor=capacity_factor, config=config,
                           objective=objective, route_impl=route_impl,
                           round_mode=round_mode)
    return y, stats.total_drops


def dcra_bfs(g: CSR, root: int, mesh, *,
             options: Optional[LaunchOptions] = None, axis="data",
             capacity_factor: Optional[float] = None, max_rounds: int = 128,
             seed: int = 0, config=None, objective="teps",
             cap: Optional[int] = None, pod_axis=None,
             route_impl: Optional[str] = None,
             round_mode: Optional[str] = None
             ) -> Tuple[np.ndarray, AppStats]:
    """Distributed BFS: hop count from root, -1 if unreachable.

    ``config="auto"`` picks the deployment (grid, topology, IQ sizing)
    from the tracked Pareto frontier for this graph + objective;
    ``capacity_factor`` (default 4.0) is the manual alternative —
    passing both raises. ``options=`` takes a :class:`LaunchOptions` in
    place of the legacy launch kwargs; ``route_impl`` / ``round_mode``
    thread through to :func:`run_program` unchanged.
    """
    (d,), stats = run_program(BFS, g, mesh, options=options, axis=axis,
                              pod_axis=pod_axis, cap=cap,
                              capacity_factor=capacity_factor,
                              config=config, objective=objective,
                              params={"root": int(root)},
                              max_rounds=max_rounds, seed=seed,
                              route_impl=route_impl, round_mode=round_mode)
    return np.where(np.isfinite(d), d, -1).astype(np.int64), stats


def dcra_sssp(g: CSR, root: int, mesh, *,
              options: Optional[LaunchOptions] = None, axis="data",
              capacity_factor: Optional[float] = None, max_rounds: int = 256,
              seed: int = 0, config=None, objective="teps",
              cap: Optional[int] = None, pod_axis=None,
              route_impl: Optional[str] = None,
              round_mode: Optional[str] = None
              ) -> Tuple[np.ndarray, AppStats]:
    """Distributed SSSP (frontier Bellman-Ford): inf if unreachable."""
    (d,), stats = run_program(SSSP, g, mesh, options=options, axis=axis,
                              pod_axis=pod_axis, cap=cap,
                              capacity_factor=capacity_factor,
                              config=config, objective=objective,
                              params={"root": int(root)},
                              max_rounds=max_rounds, seed=seed,
                              route_impl=route_impl, round_mode=round_mode)
    return d.astype(np.float64), stats


def dcra_wcc(g: CSR, mesh, *,
             options: Optional[LaunchOptions] = None, axis="data",
             capacity_factor: Optional[float] = None,
             max_rounds: int = 128, seed: int = 0, config=None,
             objective="teps", cap: Optional[int] = None, pod_axis=None,
             route_impl: Optional[str] = None,
             round_mode: Optional[str] = None
             ) -> Tuple[np.ndarray, AppStats]:
    """Distributed WCC via min-label propagation over both edge directions."""
    if g.n > (1 << 24):
        # labels ride the f32 NoC payload; ids above 2^24 would collide
        raise ValueError(f"dcra_wcc supports up to 2^24 vertices, got {g.n}")
    (lab,), stats = run_program(WCC, g, mesh, options=options, axis=axis,
                                pod_axis=pod_axis, cap=cap,
                                capacity_factor=capacity_factor,
                                config=config, objective=objective,
                                max_rounds=max_rounds, seed=seed,
                                route_impl=route_impl, round_mode=round_mode)
    return lab.astype(np.int64), stats


def dcra_pagerank(g: CSR, mesh, damping: float = 0.85, iters: int = 20, *,
                  options: Optional[LaunchOptions] = None, axis="data",
                  capacity_factor: Optional[float] = None,
                  seed: int = 0, config=None, objective="teps",
                  cap: Optional[int] = None, pod_axis=None,
                  route_impl: Optional[str] = None,
                  round_mode: Optional[str] = None
                  ) -> Tuple[np.ndarray, AppStats]:
    """Distributed PageRank: ``iters`` owner-routed epochs (fori_loop),
    dangling mass redistributed uniformly each epoch (matches the oracle)."""
    (rank, _, _), stats = run_program(
        PAGERANK, g, mesh, options=options, axis=axis, pod_axis=pod_axis,
        cap=cap, capacity_factor=capacity_factor, config=config,
        objective=objective,
        params={"damping": float(damping), "iters": int(iters)}, seed=seed,
        route_impl=route_impl, round_mode=round_mode)
    return rank, stats


def dcra_kcore(g: CSR, k: int, mesh, *,
               options: Optional[LaunchOptions] = None, axis="data",
               capacity_factor: Optional[float] = None,
               max_rounds: int = 128, seed: int = 0, config=None,
               objective="teps", cap: Optional[int] = None, pod_axis=None,
               route_impl: Optional[str] = None,
               round_mode: Optional[str] = None
               ) -> Tuple[np.ndarray, AppStats]:
    """Distributed k-core decomposition: iterative peel via owner-routed
    degree decrements. Returns each vertex's within-core degree (in+out,
    counting each stored edge direction) or -1 if peeled out of the
    k-core. Oracle: :func:`repro.sparse.ref.kcore_ref`.
    """
    (deg, alive), stats = run_program(
        KCORE, g, mesh, options=options, axis=axis, pod_axis=pod_axis,
        cap=cap, capacity_factor=capacity_factor, config=config,
        objective=objective, params={"k": float(k)}, max_rounds=max_rounds,
        seed=seed, route_impl=route_impl, round_mode=round_mode)
    return np.where(alive > 0, deg, -1).astype(np.int64), stats
