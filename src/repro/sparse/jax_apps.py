"""Executable JAX implementations of the paper apps — single-device jnp and
*distributed* owner-routed rounds under shard_map.

The distributed primitive mirrors DCRA exactly: updates are tasks
``(dest_id, value)``; the owner tile of ``dest_id`` is static (cyclic PGAS);
tasks are bucketed per owner with a bounded queue (capacity = IQ size,
overflow dropped and counted) and delivered with ONE all-to-all per round —
the same machinery as :mod:`repro.core.dispatch`, at graph granularity.

These run the REAL computation on devices (validated against the numpy
oracles); the analytic :mod:`repro.core.task_engine` remains the
instrumented twin used for the paper's energy/cost figures.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSR

try:
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# single-device (edge-parallel) reference executables
# ---------------------------------------------------------------------------

def spmv_jnp(rows, cols, vals, x, n):
    return jax.ops.segment_sum(vals * x[cols], rows, num_segments=n)


def histogram_jnp(elements, n_bins):
    return jax.ops.segment_sum(jnp.ones_like(elements), elements,
                               num_segments=n_bins)


def bfs_jnp(rows, cols, n, root, max_levels: Optional[int] = None):
    """Edge-parallel BFS: one scatter-min round per level."""
    dist = jnp.full((n,), jnp.inf).at[root].set(0.0)

    def round_(level, dist):
        cand = jnp.where(dist[rows] == level, level + 1.0, jnp.inf)
        upd = jax.ops.segment_min(cand, cols, num_segments=n)
        return jnp.minimum(dist, upd)

    levels = max_levels or n
    def body(i, d):
        return round_(jnp.asarray(i, jnp.float32), d)
    return jax.lax.fori_loop(0, levels, body, dist)


# ---------------------------------------------------------------------------
# the DCRA owner-routed round (distributed)
# ---------------------------------------------------------------------------

def _round8(v):
    return max(8, -(-v // 8) * 8)


def dcra_scatter(dest, vals, n, mesh, axis="data", op="add",
                 capacity_factor: float = 1.5):
    """Owner-routed scatter-reduce: one NoC round.

    dest/vals: [E] sharded over ``axis`` (edge-parallel tasks);
    returns y [n] sharded over ``axis`` (cyclic owner layout: item i lives
    on device i % n_dev at local slot i // n_dev) plus the dropped-task
    count (queue overflow).
    """
    n_dev = mesh.devices.size
    e_local = dest.shape[0] // n_dev
    cap = _round8(int(e_local * capacity_factor / n_dev))
    n_local = -(-n // n_dev)
    init = 0.0 if op == "add" else jnp.inf

    def kernel(dest_b, vals_b):
        valid_in = dest_b >= 0                     # padding -> no task
        dest_c = jnp.maximum(dest_b, 0)
        owner = dest_c % n_dev
        slot_local = dest_c // n_dev
        # bucket by owner with bounded queue (the IQ)
        onehot = jax.nn.one_hot(owner, n_dev, dtype=jnp.int32)
        onehot = onehot * valid_in[:, None].astype(jnp.int32)
        pos = jnp.take_along_axis(jnp.cumsum(onehot, 0) - 1,
                                  owner[:, None], 1)[:, 0]
        keep = valid_in & (pos < cap)
        slot = owner * cap + jnp.minimum(pos, cap - 1)
        send_idx = jax.ops.segment_sum(
            (slot_local + 1) * keep, jnp.where(keep, slot, n_dev * cap),
            num_segments=n_dev * cap + 1)[:-1] - 1
        send_val = jax.ops.segment_sum(
            vals_b * keep, jnp.where(keep, slot, n_dev * cap),
            num_segments=n_dev * cap + 1)[:-1]
        dropped = jnp.sum(valid_in & ~keep)
        # one all-to-all = the NoC round
        recv_idx = jax.lax.all_to_all(send_idx, axis, 0, 0, tiled=True)
        recv_val = jax.lax.all_to_all(send_val, axis, 0, 0, tiled=True)
        valid = recv_idx >= 0
        seg = jnp.where(valid, recv_idx, n_local)
        if op == "add":
            y = jax.ops.segment_sum(jnp.where(valid, recv_val, 0.0), seg,
                                    num_segments=n_local + 1)[:n_local]
        else:
            y = jax.ops.segment_min(jnp.where(valid, recv_val, jnp.inf), seg,
                                    num_segments=n_local + 1)[:n_local]
            y = jnp.where(jnp.isfinite(y), y, jnp.inf)
        return y, jax.lax.psum(dropped, axis)

    return shard_map(kernel, mesh=mesh, in_specs=(P(axis), P(axis)),
                     out_specs=(P(axis), P()), check_vma=False)(dest, vals)


def owner_layout(arr_n, n_dev):
    """Reorder a dense [n] array into cyclic-owner order (device-major)."""
    n = arr_n.shape[0]
    n_local = -(-n // n_dev)
    pad = n_local * n_dev - n
    idx = jnp.arange(n_local * n_dev)
    src = (idx % n_local) * n_dev + idx // n_local   # device-major -> global
    src = jnp.minimum(src, n - 1)
    valid = ((idx % n_local) * n_dev + idx // n_local) < n
    return jnp.where(valid, arr_n[src], 0), valid


def from_owner_layout(y_sharded, n, n_dev):
    """Inverse of owner_layout: [n_local*n_dev] -> global order [n]."""
    n_local = -(-n // n_dev)
    g = jnp.arange(n)
    pos = (g % n_dev) * n_local + g // n_dev
    return y_sharded[pos]


def dcra_spmv(g: CSR, x: np.ndarray, mesh, axis="data",
              capacity_factor: float = 2.0, seed: int = 0):
    """Distributed y = A @ x via one owner-routed round.

    Edges are shuffled once (host-side): CSR order concentrates a
    high-degree row's edges on one device, overflowing its owner bucket —
    a uniform spread keeps per-owner load near E/(n_dev^2), the same reason
    Dalorex interleaves arrays cyclically.
    """
    n_dev = mesh.devices.size
    E = g.nnz
    perm = np.random.default_rng(seed).permutation(E)
    rows = jnp.asarray(g.row_of()[perm])
    cols = jnp.asarray(g.col_idx[perm])
    vals = jnp.asarray(g.values[perm])
    pad = -(-E // n_dev) * n_dev - E
    rows_p = jnp.pad(rows, (0, pad), constant_values=-1)
    cols_p = jnp.pad(cols, (0, pad))
    vals_p = jnp.pad(vals, (0, pad))
    vals_eff = jnp.where(jnp.arange(E + pad) < E,
                         vals_p * jnp.asarray(x, jnp.float32)[cols_p], 0.0)
    y_sh, dropped = dcra_scatter(rows_p, vals_eff, g.n, mesh, axis,
                                 op="add", capacity_factor=capacity_factor)
    return from_owner_layout(y_sh, g.n, n_dev), dropped


def dcra_histogram(elements: np.ndarray, n_bins: int, mesh, axis="data",
                   capacity_factor: float = 2.0):
    n_dev = mesh.devices.size
    E = len(elements)
    pad = -(-E // n_dev) * n_dev - E
    dest = jnp.pad(jnp.asarray(elements, jnp.int32), (0, pad),
                   constant_values=-1)
    ones = jnp.where(jnp.arange(E + pad) < E, 1.0, 0.0)
    y_sh, dropped = dcra_scatter(dest, ones, n_bins, mesh, axis, op="add",
                                 capacity_factor=capacity_factor)
    return from_owner_layout(y_sh, n_bins, n_dev), dropped
