"""Graph/sparse datasets: RMAT (Graph500) + a Wikipedia-like power-law graph.

The paper evaluates RMAT-22/25/26 and the Wikipedia graph. Full-scale RMATs
don't fit a CI box; dataset *names* are preserved with a ``scale`` override
so tests use RMAT-10..14 while the cost model can be queried at paper scale
(footprints are analytic). Generators are deterministic (seeded).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from .csr import CSR, from_edges

# Graph500 RMAT parameters
A, B, C = 0.57, 0.19, 0.19


def _rmat_pairs(scale: int, E: int, rng) -> tuple:
    """``E`` raw RMAT (src, dst) pairs from ``rng`` — the quadrant-walk
    inner loop shared by :func:`rmat` (one rng for everything, legacy
    sequence preserved) and :func:`rmat_edge_chunk` (one rng per chunk)."""
    src = np.zeros(E, np.int64)
    dst = np.zeros(E, np.int64)
    for bit in range(scale):
        u = rng.random(E)
        row = (u >= A + B)                        # BL or BR quadrant
        col = ((u >= A) & (u < A + B)) | (u >= A + B + C)   # TR or BR
        src = (src << 1) | row
        dst = (dst << 1) | col
    return src, dst


def rmat(scale: int, edge_factor: int = 16, seed: int = 1,
         undirected: bool = True) -> CSR:
    """RMAT-<scale>: 2**scale vertices, edge_factor * V edges (pre-dedup)."""
    rng = np.random.default_rng(seed)
    V = 1 << scale
    E = V * edge_factor
    src, dst = _rmat_pairs(scale, E, rng)
    # permute vertex ids to break the RMAT ordering artefact (Graph500)
    perm = rng.permutation(V)
    src, dst = perm[src], perm[dst]
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if undirected:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    # dedupe
    key = src * V + dst
    _, idx = np.unique(key, return_index=True)
    src, dst = src[idx], dst[idx]
    w = (rng.integers(1, 256, len(src))).astype(np.float32)
    return from_edges(V, src, dst, w)


# ---------------------------------------------------------------------------
# sharded ingest — no host ever materializes the full edge list
# ---------------------------------------------------------------------------
# NOTE: this module stays numpy-only (importable before jax init, the
# XLA_FLAGS rigs depend on that), so the balanced-slice arithmetic is
# deliberately duplicated from ``Fabric.host_slice`` instead of imported —
# ``repro.core`` pulls in jax at package import.

def _balanced_slice(total: int, rank: int, world: int) -> tuple:
    base, rem = divmod(int(total), int(world))
    lo = rank * base + min(rank, rem)
    return lo, lo + base + (1 if rank < rem else 0)


def rmat_edge_chunk(scale: int, chunk_id: int, n_chunks: int,
                    edge_factor: int = 16, seed: int = 1) -> tuple:
    """One chunk of a chunked RMAT-<scale> edge stream: directed
    ``(src, dst, w)`` arrays for chunk ``chunk_id`` of ``n_chunks``.

    Each chunk draws from its own ``SeedSequence((seed, chunk_id))`` rng,
    so the *global edge multiset* (the union over all chunks) is a pure
    function of ``(scale, edge_factor, seed, n_chunks)`` and independent
    of which host generates which chunk — the property the multi-host
    ingest parity test pins. The Graph500 vertex permutation comes from
    the plain ``seed`` rng so every chunk relabels identically.
    Self-loops are dropped per chunk; there is NO global dedup (chunked
    ingest is multigraph ingest — ``from_edges`` accumulates parallel
    edges).
    """
    V = 1 << scale
    E = V * edge_factor
    lo, hi = (chunk_id * E) // n_chunks, ((chunk_id + 1) * E) // n_chunks
    rng = np.random.default_rng(np.random.SeedSequence((seed, chunk_id)))
    src, dst = _rmat_pairs(scale, hi - lo, rng)
    perm = np.random.default_rng(seed).permutation(V)
    src, dst = perm[src], perm[dst]
    w = rng.integers(1, 256, len(src)).astype(np.float32)
    keep = src != dst
    return src[keep], dst[keep], w[keep]


def ingest_edges(scale: int, edge_factor: int = 16, seed: int = 1, *,
                 n_chunks: int = 16, fabric=None,
                 rank: Optional[int] = None, world: Optional[int] = None,
                 undirected: bool = True) -> tuple:
    """This host's share of a chunked RMAT edge stream: ``(src, dst, w)``.

    The ``n_chunks`` chunks are split contiguously and near-evenly over
    the participating hosts — via ``fabric.host_slice`` (a
    :class:`repro.core.fabric.Fabric`, duck-typed so this module stays
    jax-free) when given, else via explicit ``rank`` / ``world``
    (defaulting to the whole range). No host ever materializes the
    edges outside its slice. ``undirected`` mirrors each local chunk
    (both directions stay host-local, so the global multiset is still
    chunking-independent).
    """
    if fabric is not None:
        lo, hi = fabric.host_slice(n_chunks, rank=rank, world=world)
    else:
        lo, hi = _balanced_slice(n_chunks, int(rank or 0), int(world or 1))
    parts = [rmat_edge_chunk(scale, c, n_chunks, edge_factor, seed)
             for c in range(lo, hi)]
    if parts:
        src = np.concatenate([p[0] for p in parts])
        dst = np.concatenate([p[1] for p in parts])
        w = np.concatenate([p[2] for p in parts])
    else:                                   # more hosts than chunks
        src = np.zeros(0, np.int64)
        dst = np.zeros(0, np.int64)
        w = np.zeros(0, np.float32)
    if undirected:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        w = np.concatenate([w, w])
    return src, dst, w


def ingest_graph(scale: int, edge_factor: int = 16, seed: int = 1, *,
                 n_chunks: int = 16, undirected: bool = True) -> CSR:
    """The full chunked-ingest graph on one host (multigraph CSR —
    parallel edges accumulate; the single-host reference the sharded
    parity tests compare against)."""
    src, dst, w = ingest_edges(scale, edge_factor, seed, n_chunks=n_chunks,
                               undirected=undirected)
    return from_edges(1 << scale, src, dst, w)


def erdos_renyi(n: int, avg_degree: float = 8.0, seed: int = 5,
                undirected: bool = True) -> CSR:
    """G(n, p) with p = avg_degree / n (uniform degree — the paper's
    counterpoint to the power-law RMAT / Wikipedia graphs)."""
    rng = np.random.default_rng(seed)
    E = int(n * avg_degree)
    src = rng.integers(0, n, E)
    dst = rng.integers(0, n, E)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if undirected:
        # weight per *undirected* edge (w(a,b) == w(b,a)), then mirror
        a, b = np.minimum(src, dst), np.maximum(src, dst)
        _, idx = np.unique(a * n + b, return_index=True)
        a, b = a[idx], b[idx]
        w = rng.integers(1, 256, len(a)).astype(np.float32)
        src = np.concatenate([a, b])
        dst = np.concatenate([b, a])
        w = np.concatenate([w, w])
        return from_edges(n, src, dst, w)
    _, idx = np.unique(src * n + dst, return_index=True)
    src, dst = src[idx], dst[idx]
    w = rng.integers(1, 256, len(src)).astype(np.float32)
    return from_edges(n, src, dst, w)


def disconnected_pair(n_each: int = 128, avg_degree: float = 6.0,
                      seed: int = 11) -> CSR:
    """Two ER components with no edges between them (BFS/WCC edge case:
    unreachable vertices / multiple components)."""
    a = erdos_renyi(n_each, avg_degree, seed=seed)
    b = erdos_renyi(n_each, avg_degree, seed=seed + 1)
    ra, rb = a.row_of(), b.row_of()
    src = np.concatenate([ra, rb + n_each])
    dst = np.concatenate([a.col_idx.astype(np.int64),
                          b.col_idx.astype(np.int64) + n_each])
    w = np.concatenate([a.values, b.values])
    return from_edges(2 * n_each, src, dst, w)


def wiki_like(n_vertices: int = 4096, avg_degree: int = 25,
              seed: int = 7) -> CSR:
    """Wikipedia-like: heavier-tailed in/out degree (Zipf), directed."""
    rng = np.random.default_rng(seed)
    E = n_vertices * avg_degree
    # zipf-distributed popularity for destinations, lighter tail for sources
    ranks = np.arange(1, n_vertices + 1)
    p_dst = 1.0 / ranks ** 0.9
    p_dst /= p_dst.sum()
    p_src = 1.0 / ranks ** 0.6
    p_src /= p_src.sum()
    src = rng.choice(n_vertices, E, p=p_src)
    dst = rng.choice(n_vertices, E, p=p_dst)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = src.astype(np.int64) * n_vertices + dst
    _, idx = np.unique(key, return_index=True)
    src, dst = src[idx], dst[idx]
    w = rng.integers(1, 256, len(src)).astype(np.float32)
    return from_edges(n_vertices, src.astype(np.int64), dst.astype(np.int64), w)


@dataclass(frozen=True)
class DatasetInfo:
    """Analytic footprint of the paper's full-scale datasets (§IV-A)."""
    name: str
    vertices: int
    edges: int

    @property
    def footprint_bytes(self) -> float:
        # CSR: row_ptr (8B/V) + col_idx (4B/E) + values (4B/E) + output (4B/V)
        return 12.0 * self.vertices + 8.0 * self.edges


PAPER_DATASETS = {
    "R22": DatasetInfo("RMAT-22", 1 << 22, int(1 << 22) * 32),
    "R25": DatasetInfo("RMAT-25", 1 << 25, int(1 << 25) * 32),
    "R26": DatasetInfo("RMAT-26", 1 << 26, int(1.3e9)),
    "WK": DatasetInfo("Wikipedia", 4_200_000, 101_000_000),
}


def histogram_data(n: int = 1 << 16, n_bins: int = 1 << 12,
                   seed: int = 3) -> np.ndarray:
    """Element stream for the Histogram app (parboil-style: image-like
    values concentrated around the middle bins with mild hotspotting)."""
    rng = np.random.default_rng(seed)
    vals = rng.normal(n_bins / 2, n_bins / 6, n)
    return np.clip(vals, 0, n_bins - 1).astype(np.int64)
