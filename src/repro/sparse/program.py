"""The TaskProgram runtime — ONE engine executes every sparse app.

The paper frames every workload as owner-routed tasks flowing over a
software-configured network; a :class:`TaskProgram` is the software
equivalent of that claim (Tascade / Nexus Machine's task / active-message
program abstraction): an app is a ~30-line *spec* — edge-payload rule,
reduce op, frontier-update rule, convergence predicate, task class — and
:func:`run_program` owns everything the apps used to duplicate:

* ``config=`` launch resolution and the kwargs-conflict checks;
* :class:`~repro.core.queues.QueueConfig` capacity resolution + clamping
  (via the shared :func:`~repro.core.routing.resolve_caps` against the
  launch :class:`~repro.core.fabric.Fabric`);
* flat vs pod/portal path selection (iterative apps route hierarchically
  now, not just the one-round scatters);
* the cyclic owner layout pack/unpack;
* the one-round vs ``lax.while_loop`` / ``lax.fori_loop`` execution shape
  with per-round :class:`AppStats`;
* a **compile cache** keyed by (program, shapes, mesh, capacities) so
  repeated same-shape launches reuse the jitted shard_map callable
  instead of re-tracing (see :func:`cache_stats`).

Program rules are **xp-generic**: they receive a :class:`Ctx` whose
``xp`` is ``jax.numpy`` inside the shard_map kernel and plain ``numpy``
in the analytic twin, so one rule definition drives both paths. The twin
(:func:`program_app_stats` / :func:`program_rounds`) host-simulates the
*same* rounds — same packed-edge admission order, same
first-``cap``-per-channel keep rule the shard_map ``bucket`` applies,
kept-only state updates — and replays each round's task stream through
``TaskEngine.route``, which is what lets ``repro.dse.shardcheck``
revalidate *every* app (not just the one-round scatters) with exact
message/drop agreement.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from ..core.compat import shard_map_unchecked
from ..core.fabric import Fabric, as_fabric
from ..core.queues import QueueConfig
from ..core.routing import (local_route_reduce, owner_route,
                            owner_route_finish, owner_route_hier,
                            owner_route_hier_start, owner_route_start,
                            reduce_received, resolve_caps,
                            resolve_flat_cap, resolve_hier_caps,
                            resolve_route_impl)
from .options import LaunchOptions, resolve_options
from ..core.task_engine import (EngineConfig, RoundStats, RunStats,
                                TaskEngine)
from ..core.topology import TileGrid


# ---------------------------------------------------------------------------
# per-round instrumentation (the executable twin of RunStats)
# ---------------------------------------------------------------------------

@dataclass
class AppStats:
    """Per-round NoC counters from a distributed run.

    ``messages`` counts routed tasks per round (including owner-local ones —
    they occupy IQ slots just the same); ``drops`` counts IQ-overflow
    discards. Convert with :meth:`to_run_stats` for the cost model.
    """
    rounds: int
    messages: np.ndarray          # [rounds] int64
    drops: np.ndarray             # [rounds] int64

    @property
    def total_messages(self) -> int:
        return int(self.messages.sum())

    @property
    def total_drops(self) -> int:
        return int(self.drops.sum())

    def to_run_stats(self, payload_words: int = 2,
                     word_bytes: int = 8) -> RunStats:
        rs = RunStats()
        for m, d in zip(self.messages.tolist(), self.drops.tolist()):
            rs.rounds.append(RoundStats(
                messages=int(m),
                payload_bytes=int(m) * payload_words * word_bytes,
                tasks_total=int(m),
                drops=int(d)))
        return rs


def _collect_stats(rounds, msgs, drops) -> AppStats:
    r = int(rounds)
    return AppStats(rounds=r,
                    messages=np.asarray(msgs)[:r].astype(np.int64),
                    drops=np.asarray(drops)[:r].astype(np.int64))


# ---------------------------------------------------------------------------
# the program spec
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Ctx:
    """What a program rule sees, on either execution substrate.

    ``xp`` is ``jax.numpy`` inside the shard_map kernel and ``numpy`` in
    the analytic twin; ``gsum`` is the cross-shard scalar sum (``psum``
    under shard_map, identity in the twin, whose arrays are global).
    Rules must use ``ctx.gsum(ctx.xp.sum(...))`` for global reductions so
    one definition is correct on both substrates.
    """
    xp: object
    n: int                       # global item count
    n_dev: int
    params: Mapping
    gsum: Callable


@dataclass(frozen=True)
class TaskProgram:
    """Declarative spec of one DCRA sparse app.

    Graph programs define ``init`` / ``frontier0`` / ``payload`` /
    ``update`` (xp-generic rules, see :class:`Ctx`); one-round stream
    programs define only ``stream``. Vertex state is a tuple of f32
    arrays in the cyclic owner layout; the runtime owns routing,
    reduction, stats and the loop shape.

    Convergence for ``mode="while"`` is the universal frontier predicate:
    the loop continues while any shard's frontier is non-empty (and
    ``r < max_rounds``); ``mode="fixed"`` runs ``params["iters"]`` epochs.
    """
    name: str                              # autoconfig app key
    reduce_op: str = "min"                 # "add" | "min"
    mode: str = "while"                    # "while" | "fixed" | "single"
    undirected: bool = False               # route both edge directions
    active: str = "frontier"               # "frontier" | "all" edges emit
    task: str = "T3"                       # QueueConfig task class
    default_capacity_factor: float = 4.0
    max_rounds: int = 128                  # "while" bound (overridable)
    # Params consumed ONLY by the host-side ``init`` rule (e.g. BFS/SSSP
    # roots): excluded from the compile-cache key AND stripped from the
    # traced kernel's Ctx, so same-shape launches that differ only in
    # these params reuse the jitted callable (a rule that reads one
    # anyway fails loudly with a KeyError at trace time). This is what
    # makes the serving tier's per-request roots cache-transparent.
    init_only: Tuple[str, ...] = ()
    # graph rules ----------------------------------------------------------
    init: Optional[Callable] = None        # (g, params) -> (states, fills)
    frontier0: Optional[Callable] = None   # (ctx, state) -> bool mask
    payload: Optional[Callable] = None     # (ctx, state, src_slot, w) -> vals
    update: Optional[Callable] = None      # (ctx, state, frontier, upd)
    #                                      #   -> (state2, frontier2)
    # stream rule ----------------------------------------------------------
    stream: Optional[Callable] = None      # (data, params, n_dev, seed)
    #                                      #   -> (dest, vals, n_items)
    # optional kernel-tier local reduce for single-shard stream launches:
    # (data, dest, vals, n_items) -> y or None (None = use the routed
    # path). Only consulted when no task can drop (cap >= e_local), so
    # the result — and the analytic twin — stay bit-identical.
    local_reduce: Optional[Callable] = None


# ---------------------------------------------------------------------------
# cyclic owner layout (vertex v -> device v % n_dev, slot v // n_dev)
# ---------------------------------------------------------------------------

def owner_layout(arr_n, n_dev):
    """Reorder a dense [n] array into cyclic-owner order (device-major)."""
    n = arr_n.shape[0]
    n_local = -(-n // n_dev)
    idx = jnp.arange(n_local * n_dev)
    src = (idx % n_local) * n_dev + idx // n_local   # device-major -> global
    valid = src < n
    return jnp.where(valid, arr_n[jnp.minimum(src, n - 1)], 0), valid


def from_owner_layout(y_sharded, n, n_dev):
    """Inverse of owner_layout: [n_local*n_dev] -> global order [n]."""
    n_local = -(-n // n_dev)
    g = jnp.arange(n)
    pos = (g % n_dev) * n_local + g // n_dev
    return y_sharded[pos]


def _owner_pack_np(arr, n_dev, fill):
    """numpy owner_layout with a chosen fill for the padding slots."""
    arr = np.asarray(arr, np.float64)
    n = len(arr)
    n_local = -(-n // n_dev)
    idx = np.arange(n_local * n_dev)
    g = (idx % n_local) * n_dev + idx // n_local
    valid = g < n
    out = np.full(n_local * n_dev, fill, np.float64)
    out[valid] = arr[g[valid]]
    return out, valid


# ---------------------------------------------------------------------------
# edge packing (host-side, shared with the analytic twin)
# ---------------------------------------------------------------------------

def _pack_edges(rows, cols, wts, n_dev, seed=0):  # noqa: PLR0917
    """Partition edges by src-vertex owner (device-major flat arrays).

    Returns (src_slot, dst, w, E_max): each [n_dev * E_max]; padding edges
    carry dst = -1 (owner_route treats them as no-task). Edges are
    shuffled once so owner buckets fill uniformly, then grouped by owner
    with a single stable argsort + cumcount (no per-device python loop);
    the stable sort preserves the shuffled order within each device — the
    bucket admission order the analytic twin mirrors.
    """
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(rows))
    rows, cols, wts = rows[perm], cols[perm], wts[perm]
    own = (rows % n_dev).astype(np.int64)
    order = np.argsort(own, kind="stable")
    rows, cols, wts, own = rows[order], cols[order], wts[order], own[order]
    counts = np.bincount(own, minlength=n_dev)
    E_max = max(8, int(counts.max(initial=0)))
    starts = np.repeat(np.r_[0, np.cumsum(counts)[:-1]], counts)
    pos = np.arange(len(rows)) - starts
    flat = own * E_max + pos
    src_slot = np.zeros(n_dev * E_max, np.int32)
    dst = np.full(n_dev * E_max, -1, np.int32)
    w = np.zeros(n_dev * E_max, np.float32)
    src_slot[flat] = (rows // n_dev).astype(np.int32)
    dst[flat] = cols.astype(np.int32)
    w[flat] = wts
    return src_slot, dst, w, E_max


def _graph_setup(g, n_dev, undirected=False, seed=0):
    rows, cols, wts = g.row_of(), g.col_idx.astype(np.int64), g.values
    if undirected:
        rows, cols = np.concatenate([rows, cols]), np.concatenate([cols,
                                                                   rows])
        wts = np.concatenate([wts, wts])
    src_slot, dst, w, E_max = _pack_edges(rows, cols, wts, n_dev, seed)
    n_local = -(-g.n // n_dev)
    return n_local, src_slot, dst, w, E_max


# ---------------------------------------------------------------------------
# launch resolution (config= / kwargs conflicts) — shared by every app
# ---------------------------------------------------------------------------

def resolve_launch(config, g, app, objective="teps",  # noqa: PLR0917
                   kwargs_set=()):
    """Resolve an app's ``config=`` kwarg to a ``LaunchConfig`` (or None).

    ``"auto"`` runs the Pareto-guided selection in
    :mod:`repro.dse.autoconfig`; a ``LaunchConfig`` passes through; a
    ``DesignPoint`` is wrapped as an explicit choice. ``None`` keeps the
    legacy kwarg-driven sizing. ``kwargs_set`` names explicitly-passed
    sizing kwargs — combining those with ``config=`` is an error, not a
    silent override.
    """
    if config is None:
        return None
    if kwargs_set:
        raise ValueError(f"config= conflicts with explicit {kwargs_set}: "
                         f"queue sizing comes from the resolved "
                         f"LaunchConfig, drop one of them")
    from ..dse.autoconfig import LaunchConfig, autoconfigure, launch_for
    if isinstance(config, str):
        if config != "auto":
            raise ValueError(f"unknown config {config!r} (expected 'auto', "
                             f"a LaunchConfig or a DesignPoint)")
        return autoconfigure(g, app, objective=objective)
    if isinstance(config, LaunchConfig):
        return config
    return launch_for(config, g, objective=objective)


def _resolve_queues(prog: TaskProgram, queues, cap, capacity_factor):
    if queues is not None:
        return queues
    if cap is not None:
        return QueueConfig.from_cap(cap, prog.task)
    if capacity_factor is None:
        capacity_factor = prog.default_capacity_factor
    return QueueConfig.from_factor(capacity_factor, prog.task)


def _graph_caps(queues: QueueConfig, task: str,  # noqa: PLR0917
                e_local: int, n_dev: int,
                pods: Optional[Tuple[int, int]]) -> Tuple[int, ...]:
    """Per-round capacities for a graph program, flat or pod/portal.

    Explicit caps are only defined for the flat path (same rule as
    ``dcra_scatter``); the flat cap is allocation-clamped at ``e_local``.
    """
    if queues.iq_sizes.get(task) is not None and pods is not None:
        raise ValueError("explicit cap is only defined for the flat path")
    if pods is None:
        return (resolve_flat_cap(queues, task, e_local, n_dev, clamp=True),)
    n_intra, n_pods = pods
    return resolve_hier_caps(queues, task, e_local, n_intra, n_pods)


# ---------------------------------------------------------------------------
# multi-process I/O adapters (no-ops on every single-process fabric)
# ---------------------------------------------------------------------------

def _to_global(fab: Fabric, spec, arr):
    """Lay a host-global array out on the fabric's mesh.

    Single-process fabrics feed jit with plain (jnp-converted) arrays —
    unchanged, byte-identical path. On a multi-process fabric a host
    numpy array cannot feed a global-mesh jit directly, so wrap it with
    ``make_array_from_callback``: every process holds the same global
    values (the packed inputs are deterministic from the seed), and each
    callback slices out the shards this process owns.
    """
    if not fab.is_multiprocess:
        return jnp.asarray(arr)
    from jax import make_array_from_callback
    from jax.sharding import NamedSharding
    a = np.asarray(arr)
    return make_array_from_callback(
        a.shape, NamedSharding(fab.mesh, spec), lambda idx: a[idx])


def _host_gather(fab: Fabric, x):
    """One sharded output back to every host, as numpy (global order).
    Single-process: plain pass-through (no extra host copy — callers keep
    operating on the sharded jax array exactly as before)."""
    if not fab.is_multiprocess:
        return x
    from jax.experimental import multihost_utils
    return np.asarray(multihost_utils.process_allgather(x, tiled=True))


# ---------------------------------------------------------------------------
# the compile cache
# ---------------------------------------------------------------------------

_CACHE: Dict[tuple, Callable] = {}
CACHE_STATS = {"hits": 0, "misses": 0, "kernel_traces": 0}


def cache_stats() -> Dict[str, int]:
    """Copy of the compile-cache counters (asserted by tests: a repeated
    same-shape launch must be a ``hits`` increment with ``kernel_traces``
    unchanged — no re-trace)."""
    return dict(CACHE_STATS)


def clear_cache() -> None:
    _CACHE.clear()
    for k in CACHE_STATS:
        CACHE_STATS[k] = 0


def _mesh_key(mesh_or_fabric):
    """Legacy alias — the cache identity now lives on the fabric
    (:meth:`repro.core.fabric.Fabric.fabric_key`, byte-compatible)."""
    return Fabric.of(mesh_or_fabric).fabric_key()


def _cached(key, build):
    fn = _CACHE.get(key)
    if fn is None:
        CACHE_STATS["misses"] += 1
        fn = _CACHE[key] = build()
    else:
        CACHE_STATS["hits"] += 1
    return fn


def cache_keys() -> Tuple[tuple, ...]:
    """The live compile-cache keys (the serving tier asserts pre-warm
    populates exactly the expected shape classes)."""
    return tuple(_CACHE)


def prewarm_program(prog: TaskProgram, data, fabric, **kwargs) -> Tuple[
        tuple, ...]:
    """Trace + compile the jitted callable(s) for one (program,
    shape-class, fabric) before real traffic arrives.

    Runs one throwaway launch — jit compiles on first execution, so the
    throwaway run IS the warm-up — and returns the cache keys it
    populated (empty tuple = that shape class was already warm). Params
    named in ``prog.init_only`` (per-request roots and friends) are not
    part of the key, so a single pre-warm covers every later request in
    the same shape class.
    """
    before = set(_CACHE)
    run_program(prog, data, fabric, **kwargs)
    return tuple(k for k in _CACHE if k not in before)


# ---------------------------------------------------------------------------
# the one-round owner-routed scatter (stream programs; public API)
# ---------------------------------------------------------------------------

def dcra_scatter(dest, vals, n, fabric, axis="data", *,  # noqa: PLR0917
                 options: Optional[LaunchOptions] = None,
                 op="add", capacity_factor: Optional[float] = None,
                 pod_axis=None, cap: Optional[int] = None,
                 queues: Optional[QueueConfig] = None, task: str = "T3",
                 route_impl: Optional[str] = None,
                 round_mode: Optional[str] = None):
    """Owner-routed scatter-reduce: one NoC round.

    dest/vals: [E] sharded over the device axes (edge-parallel tasks);
    returns y [n] sharded the same way (cyclic owner layout: item i lives
    on device i % n_dev at local slot i // n_dev) plus the dropped-task
    count (queue overflow).

    ``pod_axis`` selects the hierarchical pod/portal two-stage path
    (paper §III-A): stage 1 aggregates at the per-pod portal over ``axis``
    (tile-NoC), stage 2 crosses pods exactly once (die-NoC).

    Queue sizing resolves through ONE path — :class:`QueueConfig` — like
    everywhere else in the repo. ``queues`` names the per-``task`` IQ
    directly; the legacy ``cap=`` / ``capacity_factor=`` kwargs are sugar
    for ``QueueConfig.from_cap`` / ``QueueConfig.from_factor`` overrides.
    Explicit capacities are honored exactly (flat path only — the DSE
    revalidation sweeps the IQ axis in queue entries, so rounding would
    validate a different capacity than the analytic model swept);
    factor-derived capacities keep the lane-aligned round8. Compiled
    kernels are cached by (shapes, fabric key, capacities, op, route
    impl). ``fabric`` is a :class:`~repro.core.fabric.Fabric` (raw
    meshes keep working through the warn-once shim, with the identical
    cache key — :meth:`~repro.core.fabric.Fabric.fabric_key`).

    ``route_impl`` picks the routing hot-path engine ("pallas" | "sort" |
    "onehot"; None = ``queues.route_impl`` or the backend-autodetected
    fast path — see :mod:`repro.kernels.route`); drop semantics are
    identical across impls, so the analytic twin needs no matching knob.

    ``options=`` takes a :class:`LaunchOptions` in place of the legacy
    kwargs (which keep working through the deprecation shim);
    ``round_mode`` is validated but has no effect here — a scatter is a
    single round, so lockstep and pipelined are the same shape (and share
    one cache entry).
    """
    opts = resolve_options(options, axis=axis, pod_axis=pod_axis, cap=cap,
                           capacity_factor=capacity_factor, queues=queues,
                           route_impl=route_impl, round_mode=round_mode)
    axis, pod_axis = opts.axis, opts.pod_axis
    queues, route_impl = opts.queues, opts.route_impl
    fab = as_fabric(fabric)
    n_dev = fab.n_devices
    e_local = dest.shape[0] // n_dev
    n_local = -(-n // n_dev)
    if queues is None:
        queues = (QueueConfig.from_cap(opts.cap, task)
                  if opts.cap is not None
                  else QueueConfig.from_factor(
                      1.5 if opts.capacity_factor is None
                      else opts.capacity_factor, task))
    caps, pods = resolve_caps(fab, queues, task, e_local, axis, pod_axis)
    impl = resolve_route_impl(route_impl if route_impl is not None
                              else queues.route_impl)

    key = ("scatter", op, n_local, n_dev, axis, pod_axis, pods, caps, impl,
           fab.fabric_key(), int(dest.shape[0]))
    fn = _cached(key, lambda: _build_scatter_fn(
        fab.mesh, axis, pod_axis, pods, n_dev, n_local, caps, op, impl))
    spec = P((pod_axis, axis)) if pod_axis else P(axis)
    return fn(_to_global(fab, spec, dest), _to_global(fab, spec, vals))


def _build_scatter_fn(mesh, axis, pod_axis, pods,  # noqa: PLR0917
                      n_dev, n_local, caps, op,
                      impl):
    spec = P((pod_axis, axis)) if pod_axis else P(axis)

    if pod_axis is None:
        (cap,) = caps

        def kernel(dest_b, vals_b):
            CACHE_STATS["kernel_traces"] += 1
            valid = dest_b >= 0                    # padding -> no task
            dest_c = jnp.maximum(dest_b, 0)
            recv_slot, recv_val, n_drop = owner_route(
                vals_b, dest_c // n_dev, dest_c % n_dev, valid,
                n_dev, cap, axis, impl=impl)
            y = reduce_received(recv_slot, recv_val, n_local, op, impl=impl)
            return y, jax.lax.psum(n_drop, axis)
    else:
        n_intra, n_pods = pods
        cap1, cap2 = caps

        def kernel(dest_b, vals_b):
            CACHE_STATS["kernel_traces"] += 1
            valid = dest_b >= 0
            dest_c = jnp.maximum(dest_b, 0)
            recv_slot, recv_val, n_drop = owner_route_hier(
                vals_b, dest_c // n_dev, dest_c % n_dev, valid,
                n_intra, axis, n_pods, pod_axis, cap1, cap2, impl=impl)
            y = reduce_received(recv_slot, recv_val, n_local, op, impl=impl)
            return y, jax.lax.psum(n_drop, (pod_axis, axis))

    return jax.jit(shard_map_unchecked(kernel, mesh=mesh,
                                       in_specs=(spec, spec),
                                       out_specs=(spec, P())))


# ---------------------------------------------------------------------------
# the runtime
# ---------------------------------------------------------------------------

class ProgramLaunch:
    """One in-flight graph-program launch — a *device future*.

    JAX dispatch is asynchronous: the jitted shard_map call returns as
    soon as the computation is enqueued, with the output ``jax.Array``\\ s
    still materializing on device. :func:`launch_program` hands those
    raw outputs back wrapped in this object instead of blocking on host
    readback, so a caller (the serving tier's inflight window) can form
    and launch the NEXT batch while this one computes.

    * :meth:`is_ready` — non-blocking poll: have all output buffers
      committed? (``jax.Array.is_ready`` where available; conservatively
      ``True`` otherwise, so harvesting degrades to blocking.)
    * :meth:`block` — wait for completion without transferring; runtime
      errors of the computation surface here (and only poison THIS
      launch — the caller fails its riders, not the window).
    * :meth:`result` — block + host transfer + owner-layout unpack:
      exactly the ``(state_arrays, AppStats)`` the synchronous
      :func:`run_program` returns, bit-identical.
    """

    def __init__(self, fab: Fabric, outs, n: int,  # noqa: PLR0917
                 n_dev: int, n_states: int):
        self._fab, self._outs = fab, outs
        self._n, self._n_dev, self._n_states = n, n_dev, n_states
        self._result = None

    def is_ready(self) -> bool:
        """True once every output buffer is committed (non-blocking)."""
        if self._result is not None:
            return True
        for a in self._outs:
            ready = getattr(a, "is_ready", None)
            if ready is not None and not ready():
                return False
        return True

    def block(self) -> "ProgramLaunch":
        """Wait for the device computation (no host transfer yet)."""
        jax.block_until_ready(self._outs)
        return self

    def result(self):
        """``(state_arrays, AppStats)`` — blocks, transfers, unpacks.
        Idempotent: the materialized result is cached on first call."""
        if self._result is None:
            outs = self._outs
            states = outs[:self._n_states]
            r, msgs, drops = outs[self._n_states:]
            stats = _collect_stats(r, msgs, drops)
            states_np = tuple(
                np.asarray(from_owner_layout(_host_gather(self._fab, s),
                                             self._n, self._n_dev),
                           np.float64)
                for s in states)
            self._result = (states_np, stats)
            self._outs = None                 # release device buffers
        return self._result


def launch_program(prog: TaskProgram, data, fabric, *,
                   options: Optional[LaunchOptions] = None,
                   params: Optional[Mapping] = None,
                   max_rounds: Optional[int] = None,
                   donate_states: bool = False) -> ProgramLaunch:
    """Launch a *graph* :class:`TaskProgram` without blocking on host
    readback: returns a :class:`ProgramLaunch` device future.

    The compile-cache key, admission behaviour and results are identical
    to :func:`run_program` (which is now a thin ``launch + .result()``)
    — the only difference is WHEN the host waits. Stream
    (``mode="single"``) programs have no launch future (their scatter
    already returns sharded arrays); asking for one is an error.

    ``donate_states=True`` threads ``donate_argnums`` through the jitted
    shard_map call for the packed state buffers: the input tenant-column
    state array of each launch is donated to its same-shape output, so a
    retired batch's buffer is recycled instead of allocating a fresh
    output per launch (the serving tier's
    ``ServeOptions(donate_buffers=True)``). Donation changes lowering,
    so the flag joins the compile-cache key — but ONLY when set: default
    launches keep byte-identical cache keys.
    """
    if prog.mode == "single":
        raise ValueError("launch_program handles graph programs only — "
                         "stream programs return sharded arrays from "
                         "dcra_scatter already; use run_program")
    opts = resolve_options(options)
    return _launch_graph(prog, data, as_fabric(fabric), opts,
                         dict(params or {}), max_rounds,
                         donate_states=donate_states)


def run_program(prog: TaskProgram, data, fabric, *,
                options: Optional[LaunchOptions] = None,
                axis="data", pod_axis=None,
                capacity_factor: Optional[float] = None,
                cap: Optional[int] = None,
                queues: Optional[QueueConfig] = None,
                config=None, objective="teps",
                params: Optional[Mapping] = None,
                max_rounds: Optional[int] = None, seed: int = 0,
                dataset=None, route_impl: Optional[str] = None,
                round_mode: Optional[str] = None,
                donate_states: bool = False):
    """Execute a :class:`TaskProgram` on ``fabric``.

    Graph programs return ``(state_arrays, AppStats)`` — each state array
    unpacked to global order as float64; stream programs return
    ``(y_global, AppStats)`` with a single round. ``fabric`` is a
    :class:`~repro.core.fabric.Fabric` (single-process, fake-device rig
    or multi-process ``jax.distributed`` — on a multi-process fabric the
    packed inputs are laid out globally and the unpacked states gathered
    back, same numbers); raw meshes keep working through the warn-once
    shim with the identical compile-cache key. ``dataset`` overrides
    what ``config="auto"`` signatures (defaults to ``data``).
    ``route_impl`` picks the routing hot-path engine ("pallas" | "sort" |
    "onehot"; None = ``queues.route_impl`` or backend autodetect) — part
    of the compile-cache key, never of the drop semantics.

    ``options=`` takes a :class:`LaunchOptions` holding every launch
    kwarg above (the legacy kwargs keep working through the deprecation
    shim, resolving through the identical conflict checks and producing
    the identical cache key). ``round_mode="pipelined"`` selects the
    double-buffered round shape (see :func:`_build_graph_fn`) —
    bit-identical results and per-round stats, fewer collectives.
    Graph programs dispatch through :func:`launch_program` and block on
    its :meth:`ProgramLaunch.result` — the asynchronous serving tier
    skips only that final wait, never the launch path itself.
    """
    opts = resolve_options(options, axis=axis, pod_axis=pod_axis,
                           capacity_factor=capacity_factor, cap=cap,
                           queues=queues, config=config, objective=objective,
                           seed=seed, route_impl=route_impl,
                           round_mode=round_mode)
    axis, pod_axis, queues = opts.axis, opts.pod_axis, opts.queues
    cap, capacity_factor = opts.cap, opts.capacity_factor
    config, objective, seed = opts.config, opts.objective, opts.seed
    route_impl, round_mode = opts.route_impl, opts.round_mode
    params = dict(params or {})
    lc = resolve_launch(config, data if dataset is None else dataset,
                        prog.name, objective)
    fab = as_fabric(fabric)
    n_dev = fab.n_devices

    if prog.mode == "single":
        dest, vals, n_items = prog.stream(data, params, n_dev, seed)
        if lc is not None:
            pod_axis = (pod_axis if pod_axis is not None
                        else lc.pod_axis_for(fab))
            queues = lc.device_queues(n_dev, len(dest) // n_dev,
                                      pod=pod_axis is not None)
        if queues is None:
            queues = _resolve_queues(prog, None, cap, capacity_factor)
        # an explicit route_impl request always runs the routed path —
        # the local-reduce shortcut only replaces the *default* engine
        if (prog.local_reduce is not None and n_dev == 1
                and pod_axis is None and route_impl is None
                and queues.route_impl is None):
            e_local = len(dest)
            rcap = resolve_flat_cap(queues, prog.task, e_local, n_dev)
            if rcap >= e_local:    # no task can drop -> bit-identical
                y = prog.local_reduce(data, dest, vals, n_items)
                if y is not None:
                    stats = AppStats(
                        rounds=1,
                        messages=np.array([int((dest >= 0).sum())],
                                          np.int64),
                        drops=np.array([0], np.int64))
                    return y, stats
        y_sh, dropped = dcra_scatter(
            jnp.asarray(dest), jnp.asarray(vals), n_items, fab,
            options=LaunchOptions(axis=axis, pod_axis=pod_axis,
                                  queues=queues, route_impl=route_impl),
            op=prog.reduce_op, task=prog.task)
        stats = AppStats(rounds=1,
                         messages=np.array([int((dest >= 0).sum())],
                                           np.int64),
                         drops=np.array([int(dropped)], np.int64))
        return from_owner_layout(_host_gather(fab, y_sh), n_items,
                                 n_dev), stats

    # ---- graph program: async dispatch + immediate harvest ---------------
    return _launch_graph(prog, data, fab, opts, params, max_rounds,
                         dataset=dataset,
                         donate_states=donate_states).result()


def _launch_graph(prog: TaskProgram, g, fab: Fabric,  # noqa: PLR0917
                  opts: LaunchOptions, params, max_rounds,
                  dataset=None, donate_states: bool = False
                  ) -> ProgramLaunch:
    """The graph-program launch path shared by :func:`run_program` and
    :func:`launch_program`: resolve, pack, hit the compile cache, and
    dispatch — returning the :class:`ProgramLaunch` device future
    *without* waiting on the result."""
    axis, pod_axis, queues = opts.axis, opts.pod_axis, opts.queues
    cap, capacity_factor = opts.cap, opts.capacity_factor
    seed, route_impl = opts.seed, opts.route_impl
    round_mode = opts.round_mode
    lc = resolve_launch(opts.config, g if dataset is None else dataset,
                        prog.name, opts.objective)
    n_dev = fab.n_devices
    n = g.n
    n_local, src_slot, dst, w, E_max = _graph_setup(
        g, n_dev, undirected=prog.undirected, seed=seed)
    if lc is not None:
        pod_axis = (pod_axis if pod_axis is not None
                    else lc.pod_axis_for(fab))
        queues = lc.device_queues(n_dev, E_max, pod=pod_axis is not None)
    if queues is None:
        queues = _resolve_queues(prog, None, cap, capacity_factor)
    caps, pods = resolve_caps(fab, queues, prog.task, E_max, axis,
                              pod_axis, clamp=True)
    impl = resolve_route_impl(route_impl if route_impl is not None
                              else queues.route_impl)

    states0, fills = prog.init(g, params)
    packed = tuple(np.asarray(_owner_pack_np(s, n_dev, f)[0], np.float32)
                   for s, f in zip(states0, fills))
    if prog.mode == "fixed":
        rounds = int(params["iters"])
    else:
        rounds = int(max_rounds if max_rounds is not None
                     else prog.max_rounds)

    # init-only params (per-request roots etc.) feed the packed state
    # arrays, never the traced rules — keep them out of the key and out
    # of the kernel's Ctx so serving-style request streams hit the cache
    kparams = {k: v for k, v in params.items() if k not in prog.init_only}
    if rounds == 0:
        round_mode = "lockstep"          # no rounds, nothing to overlap
    key = (prog, n, n_dev, n_local, E_max, axis, pod_axis, pods, caps,
           impl, rounds, round_mode, len(packed),
           tuple(sorted(kparams.items())), fab.fabric_key())
    if donate_states:
        # donation changes lowering (input/output buffer aliasing), so it
        # joins the key — but ONLY when set, keeping default launches'
        # cache keys byte-identical to every prior release
        key = key + ("donate",)
    fn = _cached(key, lambda: _build_graph_fn(
        prog, fab.mesh, axis, pod_axis, pods, n_dev, n_local, n, caps,
        kparams, rounds, len(packed), impl, round_mode=round_mode,
        donate_states=donate_states))
    spec = P((pod_axis, axis)) if pod_axis else P(axis)
    out = fn(*(_to_global(fab, spec, a)
               for a in (src_slot, dst, w) + packed))
    return ProgramLaunch(fab, tuple(out), n, n_dev, len(packed))


def _build_graph_fn(prog, mesh, axis, pod_axis, pods,  # noqa: PLR0917
                    n_dev, n_local, n,
                    caps, params, rounds, n_states, impl=None,
                    round_mode="lockstep", donate_states=False):
    """Build the jitted shard_map callable for one graph-program shape.

    Two execution shapes, selected by ``round_mode`` (bit-identical
    results and per-round stats — differentially tested in
    tests/test_pipeline.py):

    * ``"lockstep"`` — the classic round: payload -> bucket -> fused
      all_to_all -> receive-reduce -> update, plus per-round scalar psums
      for the message count, the drop count and (while mode) the
      convergence predicate: 4 collectives per round.
    * ``"pipelined"`` — the double-buffered round: the collective for
      round k is launched at the tail of loop iteration k-1 and its
      receive-reduce is folded into the head of iteration k, so round
      k+1's payload + bucket-rank run while round k's wire buffer is the
      loop carry. Message/drop counters stay shard-local int32 streams
      committed per round and are psum'd ONCE after the loop (integer
      sums — order-free, so the stats are bit-identical), and the
      while-mode convergence count rides the collective itself as one
      extra broadcast row per destination bucket
      (:func:`~repro.core.routing._a2a_with_signal`): 1 collective per
      round. A converged launch costs one ghost iteration whose commits
      are all gated off (``is_real``), exactly reproducing lockstep's
      "round 0 always executes" initial ``changed=True``.

      The degenerate 1-device flat launch with an order-insensitive
      reduce has a *local* communication edge, so the receive-reduce is
      instead folded into admission (:func:`local_route_reduce`) — no
      wire buffer at all; ``add``-reduce keeps the generic shape (its
      summation order must match lockstep's bucket order).
    """
    spec = P((pod_axis, axis)) if pod_axis else P(axis)
    axes = (pod_axis, axis) if pod_axis else axis

    def gsum(x):
        return jax.lax.psum(x, axes)

    ctx = Ctx(xp=jnp, n=n, n_dev=n_dev, params=params, gsum=gsum)
    fold_local = (round_mode == "pipelined" and pod_axis is None
                  and n_dev == 1 and prog.reduce_op in ("min", "store"))
    pipelined = round_mode == "pipelined" and not fold_local

    def kernel(src_slot_b, dst_b, w_b, *state_b):
        CACHE_STATS["kernel_traces"] += 1
        owner = jnp.maximum(dst_b, 0) % n_dev
        slot = jnp.maximum(dst_b, 0) // n_dev
        evalid = dst_b >= 0

        def active_of(frontier):
            return (frontier[src_slot_b] & evalid
                    if prog.active == "frontier" else evalid)

        def do_round(state, frontier):
            active = active_of(frontier)
            vals = prog.payload(ctx, state, src_slot_b,
                                w_b).astype(jnp.float32)
            m = gsum(jnp.sum(active.astype(jnp.int32)))
            if fold_local:
                upd, nd = local_route_reduce(
                    vals, slot, owner, active, n_dev, caps[0], n_local,
                    prog.reduce_op, impl=impl)
            else:
                if pod_axis is None:
                    recv_slot, recv_val, nd = owner_route(
                        vals, slot, owner, active, n_dev, caps[0], axis,
                        impl=impl)
                else:
                    recv_slot, recv_val, nd = owner_route_hier(
                        vals, slot, owner, active, pods[0], axis, pods[1],
                        pod_axis, caps[0], caps[1], impl=impl)
                upd = reduce_received(recv_slot, recv_val, n_local,
                                      prog.reduce_op, impl=impl)
            state2, frontier2 = prog.update(ctx, state, frontier, upd)
            return state2, frontier2, m, gsum(nd.astype(jnp.int32))

        # -- pipelined produce/consume halves --------------------------------
        meta_box = []                 # static wire meta (same every round)

        def produce(state, frontier):
            """Round tail: payload + bucket + LAUNCH the collective.
            Stats stay shard-local; the local frontier count rides the
            wire as the convergence signal."""
            active = active_of(frontier)
            vals = prog.payload(ctx, state, src_slot_b,
                                w_b).astype(jnp.float32)
            m_loc = jnp.sum(active.astype(jnp.int32))
            fcnt = jnp.sum(frontier.astype(jnp.int32))
            if pod_axis is None:
                recv, meta, nd_loc, gcnt = owner_route_start(
                    vals, slot, owner, active, n_dev, caps[0], axis,
                    fcnt, impl=impl)
            else:
                recv, meta, nd_loc, gcnt = owner_route_hier_start(
                    vals, slot, owner, active, pods[0], axis, pods[1],
                    pod_axis, caps[0], caps[1], fcnt, impl=impl)
            if not meta_box:
                meta_box.append(meta)
            return recv, m_loc, nd_loc, gcnt

        def consume(recv):
            """Round head: receive-reduce folded into the carried
            communication edge."""
            recv_slot, recv_val = owner_route_finish(recv, meta_box[0])
            return reduce_received(recv_slot, recv_val, n_local,
                                   prog.reduce_op, impl=impl)

        zeros = jnp.zeros((rounds,), jnp.int32)
        frontier0 = prog.frontier0(ctx, state_b)

        if prog.mode == "while" and pipelined:
            recv0, m0, nd0, g0 = produce(state_b, frontier0)

            def cond(s):
                r, running = s[6], s[9]
                return running & (r < rounds)

            def body(s):
                (state, frontier, recv, m_pend, nd_pend, gcnt, r, msgs,
                 drops, _run) = s
                upd = consume(recv)
                # gcnt is the global pre-round frontier count (summed
                # across both hier stages), identical on every shard —
                # round 0 always executes, like lockstep's changed=True
                is_real = (gcnt > 0) | (r == 0)
                state2, frontier2 = prog.update(ctx, state, frontier, upd)
                state_n = tuple(jnp.where(is_real, a, b)
                                for a, b in zip(state2, state))
                frontier_n = jnp.where(is_real, frontier2, frontier)
                msgs_n = jnp.where(is_real, msgs.at[r].set(m_pend), msgs)
                drops_n = jnp.where(is_real, drops.at[r].set(nd_pend),
                                    drops)
                r_n = r + is_real.astype(jnp.int32)
                recv_n, m_n, nd_n, g_n = produce(state_n, frontier_n)
                return (state_n, frontier_n, recv_n, m_n, nd_n, g_n, r_n,
                        msgs_n, drops_n, is_real)

            out = jax.lax.while_loop(
                cond, body, (state_b, frontier0, recv0, m0, nd0, g0,
                             jnp.int32(0), zeros, zeros, jnp.bool_(True)))
            state, r, msgs, drops = out[0], out[6], gsum(out[7]), gsum(out[8])
        elif prog.mode == "while":                 # lockstep / fold_local
            def cond(s):
                _, _, r, _, _, changed = s
                return changed & (r < rounds)

            def body(s):
                state, frontier, r, msgs, drops, _ = s
                state2, frontier2, m, nd = do_round(state, frontier)
                changed = gsum(jnp.sum(frontier2.astype(jnp.int32))) > 0
                return (state2, frontier2, r + 1, msgs.at[r].set(m),
                        drops.at[r].set(nd), changed)

            state, _, r, msgs, drops, _ = jax.lax.while_loop(
                cond, body, (state_b, frontier0, jnp.int32(0), zeros,
                             zeros, jnp.bool_(True)))
        elif pipelined:                            # "fixed", double-buffered
            recv0, m0, nd0, _g0 = produce(state_b, frontier0)

            def body(i, s):
                state, frontier, recv, m_pend, nd_pend, msgs, drops = s
                upd = consume(recv)
                state2, frontier2 = prog.update(ctx, state, frontier, upd)
                recv_n, m_n, nd_n, _g = produce(state2, frontier2)
                return (state2, frontier2, recv_n, m_n, nd_n,
                        msgs.at[i].set(m_pend), drops.at[i].set(nd_pend))

            # rounds-1 full iterations, then drain the last in-flight
            # round without launching a trailing (wasted) collective
            s = jax.lax.fori_loop(0, rounds - 1, body,
                                  (state_b, frontier0, recv0, m0, nd0,
                                   zeros, zeros))
            state, frontier, recv, m_pend, nd_pend, msgs, drops = s
            upd = consume(recv)
            state, _f = prog.update(ctx, state, frontier, upd)
            msgs = gsum(msgs.at[rounds - 1].set(m_pend))
            drops = gsum(drops.at[rounds - 1].set(nd_pend))
            r = jnp.int32(rounds)
        else:                                      # "fixed" lockstep/fold
            def body(i, s):
                state, frontier, msgs, drops = s
                state2, frontier2, m, nd = do_round(state, frontier)
                return (state2, frontier2, msgs.at[i].set(m),
                        drops.at[i].set(nd))

            state, _, msgs, drops = jax.lax.fori_loop(
                0, rounds, body, (state_b, frontier0, zeros, zeros))
            r = jnp.int32(rounds)
        return (*state, r, msgs, drops)

    in_specs = (spec, spec, spec) + (spec,) * n_states
    out_specs = (spec,) * n_states + (P(), P(), P())
    # donation aliases each packed state input onto the matching state
    # output: a retired batch's tenant-column buffer is handed straight
    # to the next launch of the same shape class instead of allocating
    donate = tuple(range(3, 3 + n_states)) if donate_states else ()
    return jax.jit(shard_map_unchecked(kernel, mesh=mesh,
                                       in_specs=in_specs,
                                       out_specs=out_specs),
                   donate_argnums=donate)


# ---------------------------------------------------------------------------
# the analytic twin: host mirror + TaskEngine replay
# ---------------------------------------------------------------------------

def _bucket_positions(chan, active):
    """Stable per-channel cumcount of the active tasks, in array order —
    the admission order of the shard_map ``bucket``. -1 where inactive."""
    pos = np.full(len(chan), -1, np.int64)
    idx = np.flatnonzero(active)
    if not len(idx):
        return pos
    k = chan[idx]
    order = np.argsort(k, kind="stable")
    ks = k[order]
    starts = np.r_[0, np.flatnonzero(ks[1:] != ks[:-1]) + 1]
    sizes = np.diff(np.r_[starts, len(ks)])
    p = np.arange(len(ks)) - np.repeat(starts, sizes)
    out = np.empty(len(ks), np.int64)
    out[order] = p
    pos[idx] = out
    return pos


def _flat_keep(dev_of, owner, active, cap, n_dev):  # noqa: PLR0917
    pos = _bucket_positions(dev_of * n_dev + owner, active)
    keep = active & (pos < cap)
    return keep, int(active.sum() - keep.sum())


def _hier_keep(dev_of, owner, active, caps, pods):  # noqa: PLR0917
    """Two-stage pod/portal keep rule (mirrors ``owner_route_hier``):
    stage 1 admits per (sender, dest-intra-coordinate) channel at cap1;
    stage 2 admits at the portal per dest pod at cap2, in the receive
    order the tiled all_to_all produces (sender intra rank, then stage-1
    slot)."""
    n_intra, n_pods = pods
    cap1, cap2 = caps
    e_coord = owner % n_intra
    p_coord = owner // n_intra
    pos1 = _bucket_positions(dev_of * n_intra + e_coord, active)
    keep1 = active & (pos1 < cap1)
    drop1 = int(active.sum() - keep1.sum())
    portal = (dev_of // n_intra) * n_intra + e_coord
    idx = np.flatnonzero(keep1)
    arr = idx[np.lexsort((pos1[idx], dev_of[idx] % n_intra, portal[idx]))]
    chan2 = (portal * n_pods + p_coord)[arr]
    pos2 = _bucket_positions(chan2, np.ones(len(arr), bool))
    keep = np.zeros(len(active), bool)
    keep[arr[pos2 < cap2]] = True
    drop2 = int(len(arr) - keep.sum())
    return keep, drop1 + drop2


def program_rounds(prog: TaskProgram, g, n_dev, caps,  # noqa: PLR0917
                   params=None, seed=0,
                   pods=None, max_rounds=None, setup=None):
    """Host mirror of :func:`run_program`'s round loop for a graph
    program: yields, per executable round, the routed task stream
    ``(src_global, dst_global, n_drop)`` — *all* active tasks, with the
    drop count of the first-``cap``-per-channel keep rule — while
    evolving vertex state with kept-only updates, exactly as the
    shard_map path does. Deterministic: shares ``_pack_edges`` (and its
    admission order) with the executable. ``setup`` short-circuits the
    edge packing with a precomputed ``_graph_setup`` result.
    """
    params = dict(params or {})
    n = g.n
    n_local, src_slot, dst, w, E_max = (
        setup if setup is not None
        else _graph_setup(g, n_dev, undirected=prog.undirected, seed=seed))
    dev_of = np.repeat(np.arange(n_dev), E_max)
    evalid = dst >= 0
    dstl = dst.astype(np.int64)
    owner = np.where(evalid, dstl % n_dev, 0)
    src_global = src_slot.astype(np.int64) * n_dev + dev_of
    # the kernel indexes shard-local state with src_slot; the mirror's
    # state is the full device-major packed array, so offset by device
    psrc = dev_of * n_local + src_slot

    ctx = Ctx(xp=np, n=n, n_dev=n_dev, params=params,
              gsum=lambda x: x)
    states0, fills = prog.init(g, params)
    state = tuple(np.asarray(_owner_pack_np(s, n_dev, f)[0], np.float32)
                  for s, f in zip(states0, fills))
    frontier = np.asarray(prog.frontier0(ctx, state), bool)
    if prog.mode == "fixed":
        rounds = int(params["iters"])
    else:
        rounds = int(max_rounds if max_rounds is not None
                     else prog.max_rounds)

    changed, r = True, 0
    while r < rounds and (prog.mode == "fixed" or changed):
        active = (frontier[psrc] & evalid
                  if prog.active == "frontier" else evalid.copy())
        vals = np.asarray(prog.payload(ctx, state, psrc, w), np.float32)
        if pods is None:
            keep, n_drop = _flat_keep(dev_of, owner, active, caps[0], n_dev)
        else:
            keep, n_drop = _hier_keep(dev_of, owner, active, caps, pods)
        kd = dstl[keep]
        kidx = (kd % n_dev) * n_local + kd // n_dev
        if prog.reduce_op == "min":
            upd = np.full(n_dev * n_local, np.inf, np.float32)
            np.minimum.at(upd, kidx, vals[keep])
        else:
            upd = np.zeros(n_dev * n_local, np.float32)
            np.add.at(upd, kidx, vals[keep])
        yield src_global[active], dstl[active], n_drop
        state, frontier = prog.update(ctx, state, frontier, upd)
        frontier = np.asarray(frontier, bool)
        changed = bool(frontier.any())
        r += 1


def program_app_stats(prog: TaskProgram, data, n_dev, *,
                      queues: Optional[QueueConfig] = None,
                      cap: Optional[int] = None,
                      capacity_factor: Optional[float] = None,
                      params=None, seed=0,
                      pods: Optional[Tuple[int, int]] = None,
                      max_rounds=None) -> AppStats:
    """The analytic twin of one program launch.

    Generates the program's task stream (:func:`program_rounds` /
    ``prog.stream``) and replays each flat round through
    ``TaskEngine.route`` on a ``TileGrid(1, n_dev)`` with the capacity
    resolved through the SAME :class:`QueueConfig` path the executable
    uses — the per-(source shard -> owner) channel structure is
    identical, so per-round message/drop counts must match the
    executable's :class:`AppStats` exactly. The pod/portal path is
    counted by the two-stage channel mirror (``TaskEngine`` models a
    single flat channel set).
    """
    params = dict(params or {})
    queues = _resolve_queues(prog, queues, cap, capacity_factor)

    if prog.mode == "single":
        dest, _, n_items = prog.stream(data, params, n_dev, seed)
        e_local = len(dest) // n_dev
        dev_of = np.repeat(np.arange(n_dev), e_local)
        active = dest >= 0
        if pods is None:
            rcap = resolve_flat_cap(queues, prog.task, e_local, n_dev)
            engine = TaskEngine(EngineConfig(
                grid=TileGrid(1, n_dev),
                queues=QueueConfig(default_iq=rcap)), n_items)
            rs = engine.route(prog.task, src_idx=dev_of[active],
                              dst_idx=dest[active].astype(np.int64))
            return AppStats(rounds=1,
                            messages=np.array([rs.tasks_total], np.int64),
                            drops=np.array([rs.drops], np.int64))
        caps = resolve_hier_caps(queues, prog.task, e_local, *pods)
        owner = np.where(active, dest.astype(np.int64) % n_dev, 0)
        _, n_drop = _hier_keep(dev_of, owner, active, caps, pods)
        return AppStats(rounds=1,
                        messages=np.array([int(active.sum())], np.int64),
                        drops=np.array([n_drop], np.int64))

    # graph program: mirror the rounds, replay flat rounds through route()
    setup = _graph_setup(data, n_dev, undirected=prog.undirected, seed=seed)
    caps = _graph_caps(queues, prog.task, setup[-1], n_dev, pods)
    msgs, drops = [], []
    engine = None
    if pods is None:
        engine = TaskEngine(EngineConfig(
            grid=TileGrid(1, n_dev),
            queues=QueueConfig(default_iq=caps[0])), data.n)
    for src, dst, n_drop in program_rounds(prog, data, n_dev, caps,
                                           params=params, seed=seed,
                                           pods=pods, max_rounds=max_rounds,
                                           setup=setup):
        if engine is not None:
            rs = engine.route(prog.task, src_idx=src, dst_idx=dst)
            assert rs.drops == n_drop, (rs.drops, n_drop)  # model coherence
            msgs.append(rs.tasks_total)
            drops.append(rs.drops)
        else:
            msgs.append(len(dst))
            drops.append(n_drop)
    return AppStats(rounds=len(msgs),
                    messages=np.asarray(msgs, np.int64),
                    drops=np.asarray(drops, np.int64))
