from . import apps, csr, datasets, ref                    # noqa: F401
