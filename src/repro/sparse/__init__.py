from . import apps, csr, datasets, ref                    # noqa: F401

# The distributed executables import jax; keep the numpy-only analytic path
# (datasets/oracles/task-engine apps) jax-free by resolving them lazily.
_JAX_APPS = ("AppStats", "PROGRAMS", "TaskProgram", "dcra_bfs",
             "dcra_histogram", "dcra_kcore", "dcra_pagerank",
             "dcra_scatter", "dcra_spmv", "dcra_sssp", "dcra_wcc",
             "histogram_task_stream", "launch_program", "run_program",
             "ProgramLaunch", "spmv_task_stream")

# launch configuration (numpy-only module — no jax import)
_OPTIONS = ("LaunchOptions", "resolve_options")


def __getattr__(name):
    if name in _JAX_APPS:
        from . import jax_apps
        return getattr(jax_apps, name)
    if name in _OPTIONS:
        from . import options
        return getattr(options, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_JAX_APPS) + list(_OPTIONS))
