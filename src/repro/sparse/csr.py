"""CSR sparse container (the paper's dataset format, §IV-A)."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class CSR:
    """Compressed Sparse Row: three arrays, no partitioning (paper §IV-A)."""
    row_ptr: np.ndarray   # [V+1] int64
    col_idx: np.ndarray   # [E] int32
    values: np.ndarray    # [E] float32 (edge weights / nonzeros)

    @property
    def n(self) -> int:
        return len(self.row_ptr) - 1

    @property
    def nnz(self) -> int:
        return len(self.col_idx)

    def degrees(self) -> np.ndarray:
        return np.diff(self.row_ptr)

    def row_of(self) -> np.ndarray:
        """Row index of every nonzero (repeat rows by degree)."""
        return np.repeat(np.arange(self.n, dtype=np.int64), self.degrees())

    def transpose(self) -> "CSR":
        order = np.argsort(self.col_idx, kind="stable")
        rows_t = self.col_idx[order]
        cols_t = self.row_of()[order].astype(np.int32)
        vals_t = self.values[order]
        rp = np.zeros(self.n + 1, np.int64)
        np.add.at(rp, rows_t + 1, 1)
        return CSR(np.cumsum(rp), cols_t, vals_t)

    def memory_bytes(self) -> int:
        return (self.row_ptr.nbytes + self.col_idx.nbytes + self.values.nbytes)


def from_edges(n: int, src: np.ndarray, dst: np.ndarray,
               values: np.ndarray | None = None) -> CSR:
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    if values is None:
        values = np.ones(len(src), np.float32)
    else:
        values = values[order]
    rp = np.zeros(n + 1, np.int64)
    np.add.at(rp, src + 1, 1)
    return CSR(np.cumsum(rp), dst.astype(np.int32), values.astype(np.float32))
