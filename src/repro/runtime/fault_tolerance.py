"""Fault-tolerant runtime primitives: checkpoint-restart, failure
injection, retry bookkeeping, straggler detection (DESIGN.md §6 —
1000-node posture).

On a real multi-host cluster, failures surface as raised exceptions from
collectives (ICI timeouts) or as preemption signals; here the ``FailurePlan``
injects the same exception paths deterministically so the recovery logic is
*tested*, not just written. Straggler mitigation: a per-step wall-clock
watchdog records slow steps and (on real hardware) would trigger the
replacement policy; the hook + accounting are exercised in tests.

This module is ALSO the home of the injection/retry primitives the
serving tier builds on (:mod:`repro.serve.resilience`): the
:class:`InjectionSchedule` base every deterministic chaos plan derives
from, and the :class:`RetryLedger` attempt/backoff bookkeeping shared by
:func:`run_training` restarts and the ``ProgramServer`` retry path — one
implementation, so training and serving count restarts the same way.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from ..checkpoint import checkpoint as ckpt

log = logging.getLogger("repro.runtime")


class InjectedFailure(RuntimeError):
    """Stands in for an ICI timeout / preempted worker / lost host."""


@dataclass
class InjectionSchedule:
    """Deterministic fault schedule ``{index: kind}`` — the house chaos
    primitive.

    ``index`` is whatever the consuming loop counts (training *steps*
    here, fused serving *launches* in
    :class:`repro.serve.resilience.ServeFailurePlan`); each scheduled
    index fires exactly once (popped on :meth:`due`), and every firing
    is appended to ``fired`` so a chaos run can assert its plan actually
    executed — a plan that never fires is a test that never tested.
    """
    at: Dict[int, str] = field(default_factory=dict)
    fired: List[Tuple[int, str]] = field(default_factory=list)

    #: what ``index`` counts, for failure messages (subclasses override)
    noun = "step"

    def peek(self, index: int) -> Optional[str]:
        """The fault scheduled at ``index`` without consuming it."""
        return self.at.get(index)

    def due(self, index: int) -> Optional[str]:
        """Pop-and-record the fault scheduled at ``index`` (None = no
        fault due) — each scheduled index fires exactly once."""
        kind = self.at.pop(index, None)
        if kind is not None:
            self.fired.append((index, kind))
        return kind

    def check(self, index: int):
        """Raise :class:`InjectedFailure` when a fault is due."""
        kind = self.due(index)
        if kind:
            raise InjectedFailure(f"{kind} at {self.noun} {index}")

    @property
    def exhausted(self) -> bool:
        """True once every scheduled fault has fired."""
        return not self.at


class FailurePlan(InjectionSchedule):
    """Deterministic training failure schedule: {step: kind} (the
    historical constructor; the live schedule is ``self.at``)."""

    def __init__(self, at_steps: Optional[Dict[int, str]] = None):
        super().__init__(at=dict(at_steps or {}))

    @property
    def at_steps(self) -> Dict[int, str]:
        return self.at


@dataclass
class RetryLedger:
    """Shared restart/retry bookkeeping — ONE counting rule for the
    training loop and the serving retry path.

    One integer ``key`` names one retriable unit: :func:`run_training`
    uses a single key (the whole loop restarts), the serving tier keys
    by ``req_id``. :meth:`record_failure` counts one failure and answers
    whether the unit still has retry budget; :meth:`backoff_s` derives
    the exponential backoff for the *next* attempt with a deterministic
    per-key jitter — an integer hash of the key, never ``random`` — so a
    replayed chaos run waits identical delays and stays reproducible.
    """
    max_retries: int
    backoff_base_s: float = 0.0
    attempts: Dict[int, int] = field(default_factory=dict)
    total_retries: int = 0               # granted retries, all keys

    def attempt(self, key: int) -> int:
        """Failures recorded for ``key`` so far (0 = never failed)."""
        return self.attempts.get(int(key), 0)

    def record_failure(self, key: int) -> bool:
        """Count one failure of ``key``; True while retry budget remains
        (the failure may be retried), False when exhausted."""
        key = int(key)
        n = self.attempts.get(key, 0) + 1
        self.attempts[key] = n
        if n > self.max_retries:
            return False
        self.total_retries += 1
        return True

    def backoff_s(self, key: int) -> float:
        """Deterministic exponential backoff before retrying ``key``:
        ``base * 2**(attempt-1) * (1 + jitter)`` with ``jitter`` in
        [0, 1) hashed from the key (Knuth multiplicative mix) — spread
        without randomness."""
        if self.backoff_base_s <= 0.0:
            return 0.0
        n = max(1, self.attempts.get(int(key), 1))
        jitter = ((int(key) * 2654435761 + 0x9E3779B9) & 0xFFFFFFFF) / 2**32
        return self.backoff_base_s * 2.0 ** (n - 1) * (1.0 + jitter)

    def clear(self, key: int) -> None:
        """Drop ``key``'s attempt count (the unit reached a terminal
        outcome) — keeps a resident server's ledger O(inflight), while
        ``total_retries`` preserves the aggregate."""
        self.attempts.pop(int(key), None)


@dataclass
class StragglerWatchdog:
    """Flags steps slower than ``factor`` x the trailing-median step time."""
    factor: float = 3.0
    window: int = 16
    history: List[float] = field(default_factory=list)
    steps: List[int] = field(default_factory=list)   # step of each entry
    flagged: List[int] = field(default_factory=list)
    on_straggler: Optional[Callable[[int, float], None]] = None

    def observe(self, step: int, seconds: float):
        hist = self.history[-self.window:]
        if len(hist) >= 4:
            # true median: even windows average the two middle elements
            # (the upper-mid element alone biases the threshold high and
            # can mask stragglers behind one slow outlier in the window)
            s = sorted(hist)
            mid = len(s) // 2
            med = s[mid] if len(s) % 2 else (s[mid - 1] + s[mid]) / 2.0
            if seconds > self.factor * med:
                self.flagged.append(step)
                log.warning("straggler: step %d took %.3fs (median %.3fs)",
                            step, seconds, med)
                if self.on_straggler:
                    self.on_straggler(step, seconds)
        self.history.append(seconds)
        self.steps.append(step)

    def rollback(self, step: int):
        """Forget observations for steps >= ``step``: they roll back on a
        checkpoint restart and will be re-observed on replay — keeping
        them would double-count replayed steps and pollute the median."""
        keep = [i for i, s in enumerate(self.steps) if s < step]
        self.history = [self.history[i] for i in keep]
        self.steps = [self.steps[i] for i in keep]
        self.flagged = [s for s in self.flagged if s < step]


@dataclass
class TrainLoopResult:
    final_step: int
    restarts: int
    metrics_history: List[dict]
    straggler_steps: List[int]


def run_training(step_fn: Callable, init_state: Callable[[], tuple],
                 batch_fn: Callable[[int], Any], total_steps: int,
                 ckpt_dir: str, ckpt_every: int = 10,
                 max_restarts: int = 3,
                 backoff_base_s: float = 0.0,
                 failure_plan: Optional[FailurePlan] = None,
                 watchdog: Optional[StragglerWatchdog] = None,
                 shardings: Optional[tuple] = None) -> TrainLoopResult:
    """Restartable loop: state = (params, opt_state).

    On failure: reload the latest checkpoint and continue — the data
    pipeline is keyed by step so no loader state is needed. Restart
    accounting rides the same :class:`RetryLedger` as the serving retry
    path (one key — the loop is the unit); ``backoff_base_s`` adds the
    ledger's deterministic exponential backoff before each restart
    (real clusters don't restart hot into the fault that just killed
    them).
    """
    watchdog = watchdog or StragglerWatchdog()
    ledger = RetryLedger(max_retries=max_restarts,
                         backoff_base_s=backoff_base_s)
    history: List[tuple] = []          # (step, metrics) — deduped on restart

    def load_or_init():
        last = ckpt.latest_step(ckpt_dir)
        if last is None:
            return 0, init_state()
        state = init_state()
        restored = ckpt.restore(ckpt_dir, last, state, shardings)
        return last + 1, restored

    step, state = load_or_init()
    while step < total_steps:
        try:
            t0 = time.perf_counter()
            if failure_plan:
                failure_plan.check(step)
            params, opt_state = state
            params, opt_state, metrics = step_fn(params, opt_state,
                                                 batch_fn(step))
            state = (params, opt_state)
            dt = time.perf_counter() - t0
            watchdog.observe(step, dt)
            history.append((step, {k: float(v) for k, v in metrics.items()}))
            if (step + 1) % ckpt_every == 0 or step + 1 == total_steps:
                ckpt.save(ckpt_dir, step, state)
            step += 1
        except InjectedFailure as e:
            granted = ledger.record_failure(0)
            log.warning("failure: %s -> restart %d", e, ledger.attempt(0))
            if not granted:
                raise
            delay = ledger.backoff_s(0)
            if delay > 0:
                time.sleep(delay)
            step, state = load_or_init()
            # steps after the restored point re-run: drop their metrics
            # and watchdog observations or the replay double-counts them
            # (duplicate metrics_history entries, polluted straggler
            # median)
            history = [(s, m) for s, m in history if s < step]
            watchdog.rollback(step)
    return TrainLoopResult(step, ledger.total_retries,
                           [m for _, m in history], watchdog.flagged)
