"""Fault-tolerant training runtime: checkpoint-restart, failure injection,
straggler detection (DESIGN.md §6 — 1000-node posture).

On a real multi-host cluster, failures surface as raised exceptions from
collectives (ICI timeouts) or as preemption signals; here the ``FailurePlan``
injects the same exception paths deterministically so the recovery logic is
*tested*, not just written. Straggler mitigation: a per-step wall-clock
watchdog records slow steps and (on real hardware) would trigger the
replacement policy; the hook + accounting are exercised in tests.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from ..checkpoint import checkpoint as ckpt

log = logging.getLogger("repro.runtime")


class InjectedFailure(RuntimeError):
    """Stands in for an ICI timeout / preempted worker."""


@dataclass
class FailurePlan:
    """Deterministic failure schedule: {step: kind}."""
    at_steps: Dict[int, str] = field(default_factory=dict)

    def check(self, step: int):
        kind = self.at_steps.pop(step, None)
        if kind:
            raise InjectedFailure(f"{kind} at step {step}")


@dataclass
class StragglerWatchdog:
    """Flags steps slower than ``factor`` x the trailing-median step time."""
    factor: float = 3.0
    window: int = 16
    history: List[float] = field(default_factory=list)
    steps: List[int] = field(default_factory=list)   # step of each entry
    flagged: List[int] = field(default_factory=list)
    on_straggler: Optional[Callable[[int, float], None]] = None

    def observe(self, step: int, seconds: float):
        hist = self.history[-self.window:]
        if len(hist) >= 4:
            # true median: even windows average the two middle elements
            # (the upper-mid element alone biases the threshold high and
            # can mask stragglers behind one slow outlier in the window)
            s = sorted(hist)
            mid = len(s) // 2
            med = s[mid] if len(s) % 2 else (s[mid - 1] + s[mid]) / 2.0
            if seconds > self.factor * med:
                self.flagged.append(step)
                log.warning("straggler: step %d took %.3fs (median %.3fs)",
                            step, seconds, med)
                if self.on_straggler:
                    self.on_straggler(step, seconds)
        self.history.append(seconds)
        self.steps.append(step)

    def rollback(self, step: int):
        """Forget observations for steps >= ``step``: they roll back on a
        checkpoint restart and will be re-observed on replay — keeping
        them would double-count replayed steps and pollute the median."""
        keep = [i for i, s in enumerate(self.steps) if s < step]
        self.history = [self.history[i] for i in keep]
        self.steps = [self.steps[i] for i in keep]
        self.flagged = [s for s in self.flagged if s < step]


@dataclass
class TrainLoopResult:
    final_step: int
    restarts: int
    metrics_history: List[dict]
    straggler_steps: List[int]


def run_training(step_fn: Callable, init_state: Callable[[], tuple],
                 batch_fn: Callable[[int], Any], total_steps: int,
                 ckpt_dir: str, ckpt_every: int = 10,
                 max_restarts: int = 3,
                 failure_plan: Optional[FailurePlan] = None,
                 watchdog: Optional[StragglerWatchdog] = None,
                 shardings: Optional[tuple] = None) -> TrainLoopResult:
    """Restartable loop: state = (params, opt_state).

    On failure: reload the latest checkpoint and continue — the data
    pipeline is keyed by step so no loader state is needed.
    """
    watchdog = watchdog or StragglerWatchdog()
    restarts = 0
    history: List[tuple] = []          # (step, metrics) — deduped on restart

    def load_or_init():
        last = ckpt.latest_step(ckpt_dir)
        if last is None:
            return 0, init_state()
        state = init_state()
        restored = ckpt.restore(ckpt_dir, last, state, shardings)
        return last + 1, restored

    step, state = load_or_init()
    while step < total_steps:
        try:
            t0 = time.perf_counter()
            if failure_plan:
                failure_plan.check(step)
            params, opt_state = state
            params, opt_state, metrics = step_fn(params, opt_state,
                                                 batch_fn(step))
            state = (params, opt_state)
            dt = time.perf_counter() - t0
            watchdog.observe(step, dt)
            history.append((step, {k: float(v) for k, v in metrics.items()}))
            if (step + 1) % ckpt_every == 0 or step + 1 == total_steps:
                ckpt.save(ckpt_dir, step, state)
            step += 1
        except InjectedFailure as e:
            restarts += 1
            log.warning("failure: %s -> restart %d", e, restarts)
            if restarts > max_restarts:
                raise
            step, state = load_or_init()
            # steps after the restored point re-run: drop their metrics
            # and watchdog observations or the replay double-counts them
            # (duplicate metrics_history entries, polluted straggler
            # median)
            history = [(s, m) for s, m in history if s < step]
            watchdog.rollback(step)
    return TrainLoopResult(step, restarts, [m for _, m in history],
                           watchdog.flagged)
