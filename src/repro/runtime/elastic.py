"""Elastic rescaling: move training state between meshes ("repackaging").

Because checkpoints are mesh-agnostic (host numpy + target shardings), a
rescale is: save on mesh A -> build mesh B + its shardings -> restore. This
module provides the one-call wrapper plus a pure in-memory reshard for
tests (no filesystem round-trip).
"""
from __future__ import annotations

from typing import Any, Optional

import jax

from ..checkpoint import checkpoint as ckpt


def reshard(tree: Any, shardings: Any) -> Any:
    """In-memory mesh-to-mesh move (host round-trip, correct for any pair)."""
    def one(x, sh):
        return jax.device_put(jax.device_get(x), sh)
    return jax.tree.map(one, tree, shardings)


def rescale_from_checkpoint(ckpt_dir: str, step: int, target_state: Any,
                            target_shardings: Optional[Any]) -> Any:
    return ckpt.restore(ckpt_dir, step, target_state, target_shardings)
