"""Elastic rescaling: move training state between fabrics ("repackaging").

Because checkpoints are mesh-agnostic (host numpy + target shardings), a
rescale is: save on fabric A -> ``Fabric.resize()`` to fabric B -> build
B's shardings -> restore. This module provides the one-call wrapper plus a
pure in-memory reshard for tests (no filesystem round-trip), and
:func:`rescale` — the ``Fabric.resize()`` consumer that moves a live tree
onto the resized fabric so a changed host set degrades capacity instead of
killing the run.
"""
from __future__ import annotations

from typing import Any, Optional

import jax

from ..checkpoint import checkpoint as ckpt


def reshard(tree: Any, shardings: Any) -> Any:
    """In-memory mesh-to-mesh move (host round-trip, correct for any pair).

    Leaves whose current sharding already equals the target are returned
    as-is — no ``device_get`` round-trip on the unchanged path (asserted
    in tests/test_fabric.py), which is what makes a mostly-overlapping
    elastic rescale cheap.
    """
    def one(x, sh):
        if getattr(x, "sharding", None) == sh:
            return x
        return jax.device_put(jax.device_get(x), sh)
    return jax.tree.map(one, tree, shardings)


def rescale(tree: Any, fabric, pspecs: Any) -> Any:
    """Move ``tree`` onto ``fabric`` (typically a ``Fabric.resize()``
    result): each leaf's PartitionSpec from ``pspecs`` is bound to the
    fabric's mesh and resharded (no-op leaves skipped)."""
    from jax.sharding import NamedSharding, PartitionSpec
    from ..core.fabric import Fabric
    mesh = Fabric.of(fabric).mesh
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                             is_leaf=lambda s: isinstance(s, PartitionSpec))
    return reshard(tree, shardings)


def rescale_from_checkpoint(ckpt_dir: str, step: int, target_state: Any,
                            target_shardings: Optional[Any]) -> Any:
    return ckpt.restore(ckpt_dir, step, target_state, target_shardings)
