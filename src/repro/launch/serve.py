"""Batched serving driver: prefill then greedy decode with the KV cache.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models.model_zoo import build_model


def serve(cfg, model, params, prompts: jax.Array, gen: int):
    """prompts [B, P] -> generated [B, gen] (greedy)."""
    B, P = prompts.shape
    if gen <= 0:
        # nothing to generate: [B, 0], same dtype as the generated ids
        return jnp.zeros((B, 0), jnp.int32)
    cache = model.init_cache(B, P + gen, jnp.float32)
    decode = jax.jit(model.decode_step)
    # prefill by teacher-forcing the prompt through the decode path (keeps
    # one compiled step; a chunked prefill kernel is the TPU optimization)
    tok = prompts[:, :1]
    out = []
    for t in range(P + gen - 1):
        logits, cache = decode(params, cache, tok, jnp.array(t, jnp.int32))
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        # the last prompt token's logits (t == P-1) emit the first
        # generated id; with P == 1 that is the very first step
        tok = prompts[:, t + 1:t + 2] if t + 1 < P else nxt
        if t >= P - 1:
            out.append(tok)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    out = serve(cfg, model, params, prompts, args.gen)
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.1f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(out[:, :8])


if __name__ == "__main__":
    main()
