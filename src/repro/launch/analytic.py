"""Analytic FLOP / HBM-byte accounting per (arch x shape).

WHY ANALYTIC: XLA's ``compiled.cost_analysis()`` counts each ``while`` body
ONCE, not x trip-count (verified empirically — see EXPERIMENTS.md §Dry-run
calibration), so any scanned-layer or chunked-scan model is undercounted by
the trip count. The roofline compute/memory terms therefore come from the
closed forms below; they are cross-checked against cost_analysis on an
unrolled single-layer calibration cell (agreement ~±10%). Collective bytes
ARE taken from HLO (1-vs-2-layer unrolled extrapolation, launch/dryrun.py)
because XLA chooses the collective schedule and we must not guess it.

Conventions: matmul [M,K]x[K,N] = 2MKN flops. Train = fwd + 2x bwd (+1 fwd
recompute when remat='block'/'full'). MoE einsum dispatch processes padded
capacity (x capacity_factor dead compute); DCRA dispatch processes ~the
routed tokens only — the paper technique's win shows up in the
MODEL_FLOPS/HLO ratio.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..configs.base import ArchConfig, ShapeConfig
from ..models.transformer import padded_vocab


@dataclass
class CostEstimate:
    flops: float            # global, per step
    hbm_bytes: float        # global, per step


def _attn_layer_flops(cfg: ArchConfig, B: int, S: int, kv_len: float) -> float:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    proj = 2 * B * S * d * hd * (H + 2 * Hkv) + 2 * B * S * H * hd * d
    scores = 2 * B * S * kv_len * H * hd * 2          # QK^T + PV
    return proj + scores


def _kv_len(cfg: ArchConfig, S: int, decode: bool) -> float:
    full = S if decode else S / 2.0                    # causal average
    if cfg.sliding_window:
        return min(full, cfg.sliding_window)
    return full


def _ffn_layer_flops(cfg: ArchConfig, B: int, S: int) -> float:
    d = cfg.d_model
    if cfg.moe is not None:
        mc = cfg.moe
        router = 2 * B * S * d * mc.num_experts
        pad = (mc.capacity_factor if mc.dispatch_impl == "einsum" else 1.0)
        return router + 6 * B * S * d * mc.d_expert * mc.top_k * pad
    return 6 * B * S * d * cfg.d_ff


def _rwkv_layer_flops(cfg: ArchConfig, B: int, S: int) -> float:
    d, hd = cfg.d_model, cfg.ssm.head_dim
    proj = 2 * B * S * d * d * 5                       # r,k,v,g,o
    lora = 2 * B * S * d * 64 * 2
    wkv = 4 * B * S * d * hd                           # state update + read
    cmix = 2 * B * S * d * cfg.d_ff * 2 + 2 * B * S * d * d
    return proj + lora + wkv + cmix


def _mamba_layer_flops(cfg: ArchConfig, B: int, S: int) -> float:
    d = cfg.d_model
    ss = cfg.ssm
    d_in = ss.expand * d
    H = d_in // ss.head_dim
    N, P = ss.state_dim, ss.head_dim
    conv_dim = d_in + 2 * N
    proj = 2 * B * S * d * (2 * d_in + 2 * N + H) + 2 * B * S * d_in * d
    conv = 2 * B * S * ss.conv_width * conv_dim
    L = min(ss.chunk_size, S)
    intra = 2 * B * S * L * H * P + 2 * B * S * L * N  # y_intra + CB^T
    state = 4 * B * S * N * H * P                      # carry + inter
    return proj + conv + intra + state


def forward_flops(cfg: ArchConfig, B: int, S: int, decode: bool = False
                  ) -> float:
    kv = _kv_len(cfg, S if not decode else S, decode)
    head = 2 * B * (1 if decode else S) * cfg.d_model * padded_vocab(
        cfg.vocab_size)
    Sq = 1 if decode else S
    total = head
    if cfg.family == "ssm":
        total += cfg.num_layers * _rwkv_layer_flops(cfg, B, Sq)
        return total
    if cfg.family == "hybrid":
        total += cfg.num_layers * _mamba_layer_flops(cfg, B, Sq)
        n_attn = cfg.num_layers // cfg.hybrid_attn_period
        total += n_attn * (_attn_layer_flops(cfg, B, Sq, kv)
                           + 6 * B * Sq * cfg.d_model * cfg.d_ff)
        return total
    if cfg.family == "encdec":
        s_src = min(S // 2, 4096) if not decode else 4096
        s_tgt = (S - s_src) if not decode else 1
        enc = cfg.encoder_layers * (_attn_layer_flops(cfg, B, s_src, s_src)
                                    + _ffn_layer_flops(cfg, B, s_src))
        dec = cfg.num_layers * (
            _attn_layer_flops(cfg, B, s_tgt, _kv_len(cfg, S, decode))
            + _attn_layer_flops(cfg, B, s_tgt, s_src)   # cross
            + _ffn_layer_flops(cfg, B, s_tgt))
        if decode:
            enc = 0.0                                   # encoder ran at prefill
        return enc + dec + 2 * B * s_tgt * cfg.d_model * \
            padded_vocab(cfg.vocab_size)
    # dense / moe / vlm decoder
    total += cfg.num_layers * (_attn_layer_flops(cfg, B, Sq, kv)
                               + _ffn_layer_flops(cfg, B, Sq))
    return total


def step_cost(cfg: ArchConfig, shape: ShapeConfig) -> CostEstimate:
    B, S = shape.global_batch, shape.seq_len
    decode = shape.is_decode
    f_fwd = forward_flops(cfg, B, S, decode=decode)
    n_params = cfg.param_count()

    if shape.kind == "train":
        remat_fwd = 1.0 if cfg.remat != "none" else 0.0
        flops = f_fwd * (3.0 + remat_fwd)
        # HBM: params bf16 fwd+bwd reads + fp32 grads/adam state rw +
        # per-layer saved residuals (write + 2 reads) + logits
        act = cfg.num_layers * B * S * cfg.d_model * 2 * 3
        hbm = n_params * (2 * 2 + 4 * 5) + act + \
            B * S * padded_vocab(cfg.vocab_size) * 4 * 2
        return CostEstimate(flops, hbm)
    if shape.kind == "prefill":
        act = cfg.num_layers * B * S * cfg.d_model * 2 * 2
        return CostEstimate(f_fwd, cfg.active_param_count() * 2 + act)
    # decode: read all active params + the KV cache / states per token
    hd = cfg.resolved_head_dim
    cache_len = min(S, cfg.sliding_window) if cfg.sliding_window else S
    kv_bytes = (cfg.num_layers * B * cache_len * cfg.num_kv_heads * hd
                * 2 * 2) if cfg.num_heads else 0
    if cfg.family == "ssm":
        d = cfg.d_model
        kv_bytes = cfg.num_layers * B * (d // hd) * hd * hd * 4 * 2
    if cfg.family == "hybrid":
        ss = cfg.ssm
        d_in = ss.expand * cfg.d_model
        H = d_in // ss.head_dim
        n_attn = cfg.num_layers // cfg.hybrid_attn_period
        kv_bytes = (cfg.num_layers * B * H * ss.state_dim * ss.head_dim * 4
                    * 2 + n_attn * B * S * cfg.num_kv_heads * hd * 2 * 2)
    return CostEstimate(f_fwd, cfg.active_param_count() * 2 + kv_bytes)
