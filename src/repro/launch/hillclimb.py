import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing driver — hypothesis -> change -> re-lower -> measure.

Each VARIANT is one hypothesis applied to one of the three chosen cells
(EXPERIMENTS.md §Perf). Results are appended (tagged) to
dryrun_results.json; the baseline rows keep tag="". The sweep loop itself
(resume, per-variant error capture, incremental JSON writes) is
:func:`repro.dse.driver.run_sweep` — this module only declares the
variant list.

  PYTHONPATH=src python -m repro.launch.hillclimb [--only CELL]
"""
import argparse      # noqa: E402

from ..dse.driver import SweepTask, run_sweep  # noqa: E402
from .dryrun import DEFAULT_OUT, lower_cell    # noqa: E402

# (cell, tag, kwargs, hypothesis)
VARIANTS = [
    # ---- cell A: mixtral-8x22b decode_32k multi (worst roofline frac) ----
    ("A", ("mixtral-8x22b", "decode_32k", True), "A1-serve-nofsdp",
     dict(fsdp=False),
     "decode re-gathers FSDP-sharded weights every token; serving should "
     "keep weights resident (replicated over data) -> collective ~ -90%"),
    ("A", ("mixtral-8x22b", "decode_32k", True), "A2-serve-nofsdp-einsum",
     dict(fsdp=False, dispatch_impl="einsum"),
     "is the DCRA dispatch or the einsum dispatch cheaper at batch-decode "
     "scale? (einsum moves [G,t,E,C] masks; DCRA moves cap-bounded payload)"),
    # ---- cell B: olmoe-1b-7b train_4k multi (paper technique, top-8) -----
    ("B", ("olmoe-1b-7b", "train_4k", True), "B0-einsum-baseline",
     dict(dispatch_impl="einsum"),
     "PAPER-BASELINE: flat GShard-style dense-mask dispatch (the 'mesh NoC' "
     "equivalent) — expect more collective bytes than DCRA routing"),
    ("B", ("olmoe-1b-7b", "train_4k", True), "B1-flat-dispatch",
     dict(hierarchical=False),
     "hierarchical (2-stage, die-NoC) vs flat single-stage dispatch with "
     "pod-replicated experts: flat avoids stage-2 but doubles expert-weight "
     "gradient reduction across pods"),
    ("B", ("olmoe-1b-7b", "train_4k", True), "B2-cap-1.0",
     dict(capacity_factor=1.0),
     "IQ size (capacity factor) 1.25 -> 1.0: -20% dispatch payload at the "
     "cost of more drops (paper Fig. 10 inverse)"),
    ("B", ("olmoe-1b-7b", "train_4k", True), "B3-no-remat",
     dict(remat="none"),
     "remat recomputes the fwd (incl. its gathers) in bwd; with memory "
     "headroom, dropping remat removes the recompute gathers"),
    # ---- cell C: mixtral-8x22b train_4k multi (representative at scale) --
    ("C", ("mixtral-8x22b", "train_4k", True), "C0-einsum-baseline",
     dict(dispatch_impl="einsum"),
     "PAPER-BASELINE: dense-mask dispatch for the 8x22B config"),
    ("C", ("mixtral-8x22b", "train_4k", True), "C1-no-remat",
     dict(remat="none"),
     "drop remat: -1 fwd recompute of FSDP/SP gathers (memory permitting)"),
    ("C", ("mixtral-8x22b", "train_4k", True), "C2-nofsdp",
     dict(fsdp=False),
     "weights resident (no FSDP): kills per-layer weight all-gathers; "
     "memory_analysis must still fit 16GB/chip"),
    # ---- round 2 (informed by round-1 breakdowns) -------------------------
    ("C", ("mixtral-8x22b", "train_4k", True), "C3-bf16-params",
     dict(param_dtype="bf16"),
     "params at rest in fp32 are gathered BEFORE the bf16 cast; storing "
     "matrices in bf16 (fp32 Adam moments) halves every FSDP/TP gather"),
    ("C", ("mixtral-8x22b", "train_4k", True), "C4-bf16-einsum",
     dict(param_dtype="bf16", dispatch_impl="einsum"),
     "paper-baseline einsum under the bf16-at-rest regime (fair compare)"),
    ("B", ("olmoe-1b-7b", "train_4k", True), "B4-bf16-params",
     dict(param_dtype="bf16"),
     "same bf16-at-rest hypothesis on the top-8 dispatch cell"),
    ("B", ("olmoe-1b-7b", "train_4k", True), "B5-bf16-flat-cap1",
     dict(param_dtype="bf16", hierarchical=False, capacity_factor=1.0),
     "compose the three confirmed wins: bf16 gathers + flat dispatch + "
     "tighter IQ"),
    ("A", ("mixtral-8x22b", "decode_32k", True), "A3-nofsdp-cap1",
     dict(fsdp=False, capacity_factor=1.0),
     "remaining decode collective after A1: dispatch payload; tighter IQ "
     "capacity trims the padded buckets"),
    # ---- round 3: per-kind breakdown showed 487GiB/dev of all-gathers on
    # cell C = the dispatch re-gathering the seq-sharded residual over the
    # expert axis then slicing 1/8. Fix: accept seq sharded over the whole
    # dispatch group (now the default) --------------------------------------
    ("C", ("mixtral-8x22b", "train_4k", True), "C5-seqgroup-dispatch",
     dict(),
     "dispatch consumes the SP seq-sharded residual directly (tokens "
     "distinct per expert-rank): kills the 8x pre-gather + slice"),
    ("B", ("olmoe-1b-7b", "train_4k", True), "B6-seqgroup-dispatch",
     dict(),
     "same fix on the top-8 cell (seq sharded over the fused 16-way group)"),
    ("B", ("olmoe-1b-7b", "train_4k", True), "B7-seqgroup-flat-cap1",
     dict(hierarchical=False, capacity_factor=1.0),
     "compose with the round-1 wins"),
    ("C", ("mixtral-8x22b", "train_4k", True), "C6-seqgroup-einsum",
     dict(dispatch_impl="einsum"),
     "paper-baseline einsum against the optimized DCRA path (fair compare "
     "on the new residual layout)"),
]


def _task(cell_id, cell, tag, kwargs, hypothesis) -> SweepTask:
    arch, shape, mp = cell

    def run():
        print(f"== {tag}: {hypothesis}", flush=True)
        rec = lower_cell(arch, shape, mp, tag=tag, **kwargs)
        rec["variant_kwargs"] = {k: str(v) for k, v in kwargs.items()}
        return rec

    return SweepTask(
        key=tag, run=run,
        meta={"arch": arch, "shape": shape,
              "mesh": "multi" if mp else "single", "tag": tag,
              "hypothesis": hypothesis})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="cell id A/B/C or tag")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--retry-errors", action="store_true",
                    help="re-run previously errored variants on resume")
    args = ap.parse_args()

    tasks = [_task(*variant) for variant in VARIANTS
             if not args.only or args.only in (variant[0], variant[2])]
    run_sweep(tasks, out=args.out, resume=True,
              retry_errors=args.retry_errors,
              key_of=lambda r: r.get("tag"))
    print("hillclimb pass done")


if __name__ == "__main__":
    main()
