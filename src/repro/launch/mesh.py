"""Production meshes — DCRA "packaging-time" composition (Table II #5-7).

``make_production_mesh`` is the contract mesh: a 256-chip pod (16x16) or two
pods (2x16x16). ``make_mesh_for`` refines the 16-way ``model`` axis into
``expert x tp`` (8x2) for MoE architectures — same chips, different
"packaging", exactly the paper's one-chiplet-many-products thesis.

Functions, not module constants: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before any jax init).
"""
from __future__ import annotations

from typing import Optional

from ..configs.base import ArchConfig
from ..core.compat import make_mesh as _mk  # noqa: F401 (re-exported idiom)
from ..core.dispatch import MeshInfo
from ..core.fabric import Fabric


def make_production_fabric(*, multi_pod: bool = False) -> Fabric:
    """The contract fabric: a 256-chip pod (16x16) or two pods
    (2x16x16, ``pod`` = the portal/DCN-crossing axis)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return Fabric.single(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    return make_production_fabric(multi_pod=multi_pod).mesh


def make_moe_fabric(*, multi_pod: bool = False) -> Fabric:
    """model axis split into (expert, tp) for expert-parallel archs."""
    shape = (2, 16, 8, 2) if multi_pod else (16, 8, 2)
    axes = (("pod", "data", "expert", "tp") if multi_pod
            else ("data", "expert", "tp"))
    return Fabric.single(shape, axes)


def make_moe_mesh(*, multi_pod: bool = False):
    return make_moe_fabric(multi_pod=multi_pod).mesh


def fabric_for(cfg: ArchConfig, *, multi_pod: bool = False) -> Fabric:
    if cfg.moe is not None:
        return make_moe_fabric(multi_pod=multi_pod)
    return make_production_fabric(multi_pod=multi_pod)


def make_mesh_for(cfg: ArchConfig, *, multi_pod: bool = False):
    return fabric_for(cfg, multi_pod=multi_pod).mesh


def mesh_info_for(cfg: ArchConfig, mesh, hierarchical: bool = True
                  ) -> Optional[MeshInfo]:
    fab = Fabric.of(mesh)                       # mesh OR Fabric
    if cfg.moe is None:
        return None
    return MeshInfo(
        mesh=fab.mesh,
        data_axis="data",
        expert_axis="expert",
        tp_axis="tp",
        pod_axis="pod" if "pod" in fab.axis_names else None,
        hierarchical=hierarchical,
    )


def model_axes(mesh) -> tuple:
    """The tensor-parallel axis group ('model' or expert+tp); accepts a
    mesh or a Fabric."""
    names = Fabric.of(mesh).axis_names
    return ("model",) if "model" in names else ("expert", "tp")


def batch_axes(mesh) -> tuple:
    names = Fabric.of(mesh).axis_names
    return ("pod", "data") if "pod" in names else ("data",)
