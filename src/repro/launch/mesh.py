"""Production meshes — DCRA "packaging-time" composition (Table II #5-7).

``make_production_mesh`` is the contract mesh: a 256-chip pod (16x16) or two
pods (2x16x16). ``make_mesh_for`` refines the 16-way ``model`` axis into
``expert x tp`` (8x2) for MoE architectures — same chips, different
"packaging", exactly the paper's one-chiplet-many-products thesis.

Functions, not module constants: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before any jax init).
"""
from __future__ import annotations

from typing import Optional

from ..configs.base import ArchConfig
from ..core.compat import make_mesh as _mk  # noqa: F401 (re-exported idiom)
from ..core.dispatch import MeshInfo


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_moe_mesh(*, multi_pod: bool = False):
    """model axis split into (expert, tp) for expert-parallel archs."""
    shape = (2, 16, 8, 2) if multi_pod else (16, 8, 2)
    axes = (("pod", "data", "expert", "tp") if multi_pod
            else ("data", "expert", "tp"))
    return _mk(shape, axes)


def make_mesh_for(cfg: ArchConfig, *, multi_pod: bool = False):
    if cfg.moe is not None:
        return make_moe_mesh(multi_pod=multi_pod)
    return make_production_mesh(multi_pod=multi_pod)


def mesh_info_for(cfg: ArchConfig, mesh, hierarchical: bool = True
                  ) -> Optional[MeshInfo]:
    names = mesh.axis_names
    if cfg.moe is None:
        return None
    return MeshInfo(
        mesh=mesh,
        data_axis="data",
        expert_axis="expert",
        tp_axis="tp",
        pod_axis="pod" if "pod" in names else None,
        hierarchical=hierarchical,
    )


def model_axes(mesh) -> tuple:
    """The tensor-parallel axis group of this mesh ('model' or expert+tp)."""
    return (("model",) if "model" in mesh.axis_names else ("expert", "tp"))


def batch_axes(mesh) -> tuple:
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))
