"""End-to-end training driver.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --reduced \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..configs.base import ShapeConfig
from ..data.pipeline import synth_batch
from ..models.model_zoo import build_model
from ..runtime.fault_tolerance import StragglerWatchdog, run_training
from .steps import default_optimizer, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    full_cfg = get_config(args.arch)
    cfg = full_cfg.reduced() if args.reduced else full_cfg
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    model = build_model(cfg)
    opt = default_optimizer()
    from ..core.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    raw_step = make_train_step(model, opt, mesh, shape)
    step_jit = jax.jit(raw_step, donate_argnums=(0, 1))

    def init_state():
        params = model.init(jax.random.key(0))
        return params, opt.init(params)

    def batch_fn(step):
        raw = synth_batch(full_cfg, shape, step)
        out = {}
        for k, v in raw.items():
            if k in ("tokens", "labels"):
                v = np.minimum(v, cfg.vocab_size - 1)
            if k in ("src_embeds", "patch_embeds") and \
                    v.shape[-1] != cfg.d_model:
                v = np.repeat(v, -(-cfg.d_model // v.shape[-1]),
                              axis=-1)[..., :cfg.d_model]
            out[k] = jnp.asarray(v)
        return out

    t0 = time.time()
    last = {"t": t0, "s": 0}

    def logging_step(params, opt_state, batch):
        params, opt_state, metrics = step_jit(params, opt_state, batch)
        return params, opt_state, metrics

    wd = StragglerWatchdog()
    res = run_training(logging_step, init_state, batch_fn, args.steps,
                       args.ckpt_dir, ckpt_every=args.ckpt_every,
                       watchdog=wd)
    for i, m in enumerate(res.metrics_history):
        if i % args.log_every == 0 or i == len(res.metrics_history) - 1:
            print(f"step {i}: loss={m['loss']:.4f} ce={m['ce']:.4f}")
    dt = time.time() - t0
    tok = args.steps * args.batch * args.seq
    print(f"done: {args.steps} steps, {tok / dt:.0f} tok/s, "
          f"{res.restarts} restarts, stragglers={res.straggler_steps}")


if __name__ == "__main__":
    main()
