"""Generate the EXPERIMENTS.md roofline/dry-run tables from
dryrun_results.json."""
from __future__ import annotations

import json
import sys
from collections import defaultdict


def fmt_row(r):
    if "skipped" in r:
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"skip: quadratic attn (DESIGN.md §5) | — | — |")
    if "error" in r:
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"ERROR | — | — |")
    t = max(r["compute_s"], r["memory_s"], r["collective_s"])
    frac = r["compute_s"] / t if t else 0.0
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.2e} | {r['memory_s']:.2e} | "
            f"{r['collective_s']:.2e} | {r['bottleneck']} | "
            f"{r['model_flops_ratio']:.2f} | "
            f"{r.get('temp_size_in_bytes', 0) / 2**30:.1f} |")


def roofline_table(results):
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "bottleneck | 6ND/HLO | temp GiB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    rs = sorted(results, key=lambda r: (r["arch"], order.get(r["shape"], 9),
                                        r["mesh"]))
    for r in rs:
        if r.get("tag"):
            continue            # variants go to §Perf, not the baseline table
        lines.append(fmt_row(r))
    return "\n".join(lines)


def summary(results):
    base = [r for r in results if "compute_s" in r and not r.get("tag")]
    bn = defaultdict(int)
    for r in base:
        bn[r["bottleneck"]] += 1
    compiled = len(base)
    skipped = sum(1 for r in results if "skipped" in r)
    errors = sum(1 for r in results if "error" in r)
    peak = max((r.get("temp_size_in_bytes", 0) for r in base), default=0)
    return (f"{compiled} cells compiled, {skipped} documented skips, "
            f"{errors} errors; bottlenecks: {dict(bn)}; "
            f"max temp/device {peak / 2**30:.1f} GiB")


def main(path="dryrun_results.json"):
    with open(path) as f:
        results = json.load(f)
    print(summary(results))
    print()
    print(roofline_table(results))


if __name__ == "__main__":
    main(*sys.argv[1:])
