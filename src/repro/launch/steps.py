"""train_step / serve_step builders — the units the dry-run lowers and the
drivers execute."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from ..models.common import logical_axis_rules
from ..models.model_zoo import BaseModel
from ..optim.adamw import AdamW, AdamWState, cosine_schedule
from .sharding import logical_rules


def default_optimizer() -> AdamW:
    return AdamW(lr=cosine_schedule())


def make_train_step(model: BaseModel, opt: AdamW, mesh,
                    shape: Optional[ShapeConfig] = None,
                    accum_steps: int = 1):
    rules = logical_rules(model.cfg, mesh, shape)

    def train_step(params, opt_state: AdamWState, batch):
        with logical_axis_rules(rules):
            if accum_steps == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    model.loss, has_aux=True)(params, batch)
            else:
                def micro(c, mb):
                    (l, m), g = jax.value_and_grad(
                        model.loss, has_aux=True)(params, mb)
                    return jax.tree.map(jnp.add, c, g), (l, m)
                zero = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                mbs = jax.tree.map(
                    lambda x: x.reshape(accum_steps, -1, *x.shape[1:]), batch)
                grads, (ls, ms) = jax.lax.scan(micro, zero, mbs)
                grads = jax.tree.map(lambda g: g / accum_steps, grads)
                loss, metrics = ls.mean(), jax.tree.map(
                    lambda m: m.mean(), ms)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, metrics

    return train_step


def make_serve_step(model: BaseModel, mesh,
                    shape: Optional[ShapeConfig] = None):
    rules = logical_rules(model.cfg, mesh, shape)

    def serve_step(params, cache, tokens, pos):
        """One decode step: greedy next token for the whole batch."""
        with logical_axis_rules(rules):
            logits, new_cache = model.decode_step(params, cache, tokens, pos)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_cache

    return serve_step


def make_prefill_step(model: BaseModel, mesh,
                      shape: Optional[ShapeConfig] = None):
    rules = logical_rules(model.cfg, mesh, shape)

    def prefill_step(params, batch):
        with logical_axis_rules(rules):
            logits, _ = model.forward(params, batch)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    return prefill_step
