import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each architecture and its assigned input shapes, builds the real
train_step (loss + grads + AdamW update) or serve_step (decode with cache),
lowers it with sharded ShapeDtypeStructs (no allocation), compiles for the
single-pod (16x16 = 256 chips) AND multi-pod (2x16x16 = 512 chips) meshes,
prints memory_analysis / cost_analysis, and records roofline terms to
``dryrun_results.json``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch ID] [--shape NAME]
      [--mesh single|multi|both] [--out PATH]
"""
import argparse      # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

import dataclasses  # noqa: E402

from ..configs import ARCH_IDS, get_config           # noqa: E402
from ..core.compat import set_mesh as compat_set_mesh   # noqa: E402
from ..dse.driver import SweepTask, run_sweep, summarize  # noqa: E402
from ..costmodel.params import (TPU_HBM_BW, TPU_ICI_BW,  # noqa: E402
                                TPU_PEAK_BF16_FLOPS)
from ..models.model_zoo import build_model            # noqa: E402
from .analytic import step_cost                        # noqa: E402
from .mesh import make_mesh_for, mesh_info_for        # noqa: E402
from .roofline import analyze, model_flops            # noqa: E402
from .sharding import (batch_struct, cache_shardings,  # noqa: E402
                       param_shardings)
from .steps import default_optimizer, make_serve_step, make_train_step  # noqa: E402

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "dryrun_results.json")


def _struct_with_sharding(shapes, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


def _compile_for(cfg, shape, mesh, fsdp=True, hierarchical=True,
                 param_dtype="f32"):
    """Lower + compile one step function for (cfg, shape, mesh).

    ``param_dtype='bf16'``: matrix params stored bf16 (halves FSDP/TP gather
    bytes); Adam moments stay fp32 (mixed-precision-at-rest)."""
    info = mesh_info_for(cfg, mesh, hierarchical=hierarchical)
    if info is not None and not fsdp:
        info = dataclasses.replace(info, fsdp=False)
    model = build_model(cfg, mesh_info=info, dtype=jnp.bfloat16)

    params_shape = jax.eval_shape(model.init, jax.random.key(0))
    params_f32 = params_shape
    if param_dtype == "bf16":
        params_shape = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if (s.ndim >= 2 and
                                          s.dtype == jnp.float32)
                else s.dtype), params_shape)
    p_shard = param_shardings(cfg, mesh, params_shape, fsdp=fsdp)
    params_in = _struct_with_sharding(params_shape, p_shard)

    with compat_set_mesh(mesh):
        if shape.kind in ("train", "prefill"):
            batch = batch_struct(cfg, shape, mesh)
            if shape.kind == "train":
                opt = default_optimizer()
                opt_shape = jax.eval_shape(opt.init, params_f32)
                from jax.sharding import NamedSharding, PartitionSpec as P
                o_shard = type(opt_shape)(
                    NamedSharding(mesh, P()),
                    param_shardings(cfg, mesh, opt_shape.mu),
                    param_shardings(cfg, mesh, opt_shape.nu))
                opt_in = _struct_with_sharding(opt_shape, o_shard)
                step = make_train_step(model, opt, mesh, shape,
                                       accum_steps=cfg.accum_steps)
                lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                    params_in, opt_in, batch)
            else:
                from .steps import make_prefill_step
                step = make_prefill_step(model, mesh, shape)
                lowered = jax.jit(step).lower(params_in, batch)
        else:  # decode
            B = shape.global_batch
            cache_shape = jax.eval_shape(
                lambda: model.init_cache(B, shape.seq_len, jnp.bfloat16))
            c_shard = cache_shardings(cfg, shape, mesh, cache_shape)
            cache_in = _struct_with_sharding(cache_shape, c_shard)
            from jax.sharding import NamedSharding, PartitionSpec as P
            tok_in = jax.ShapeDtypeStruct(
                (B, 1), jnp.int32, sharding=NamedSharding(mesh, P()))
            pos_in = jax.ShapeDtypeStruct((), jnp.int32,
                                          sharding=NamedSharding(mesh, P()))
            step = make_serve_step(model, mesh, shape)
            lowered = jax.jit(step, donate_argnums=(1,)).lower(
                params_in, cache_in, tok_in, pos_in)

        compiled = lowered.compile()
    return compiled


def _depth_pair(cfg):
    """(k1, k2) reduced unrolled depths for collective extrapolation."""
    unit = cfg.hybrid_attn_period if cfg.family == "hybrid" else 1
    return unit, 2 * unit


def _reduced_depth(cfg, k):
    kw = {"num_layers": k, "scan_layers": False}
    if cfg.encoder_layers:
        kw["encoder_layers"] = k
    return dataclasses.replace(cfg, **kw)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               dispatch_impl=None, remat=None, verbose: bool = True,
               skip_pair: bool = False, fsdp: bool = True,
               hierarchical: bool = True, capacity_factor=None,
               param_dtype: str = "f32", tag: str = ""):
    """Lower + compile one cell; returns result record dict.

    Full-depth scanned compile = the deliverable proof + memory analysis.
    Collective bytes come from a 1-vs-2-layer unrolled pair (XLA counts
    while-bodies once — see analytic.py) extrapolated to full depth;
    compute/memory roofline terms come from launch/analytic.py.
    """
    cfg = get_config(arch)
    if dispatch_impl is not None and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_impl=dispatch_impl))
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    if capacity_factor is not None and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         capacity_factor=capacity_factor))
    shape = {s.name: s for s in cfg.shape_cells()}.get(shape_name)
    if shape is None:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "skipped": "shape not applicable (DESIGN.md §5)"}
    mesh = make_mesh_for(cfg, multi_pod=multi_pod)
    chips = mesh.devices.size

    t0 = time.time()
    kw = dict(fsdp=fsdp, hierarchical=hierarchical,
              param_dtype=param_dtype)
    compiled = _compile_for(cfg, shape, mesh, **kw)
    t1 = time.time()
    mem = compiled.memory_analysis()
    rl_full = analyze(compiled)          # cost_analysis caveat: scan-once

    # --- collective extrapolation pair ---------------------------------
    k1, k2 = _depth_pair(cfg)
    coll_bytes = rl_full.coll_bytes_per_device
    coll_note = "full-hlo (scan bodies once)"
    if not skip_pair and cfg.num_layers > k2:
        try:
            c1 = analyze(_compile_for(_reduced_depth(cfg, k1), shape, mesh,
                                      **kw))
            c2 = analyze(_compile_for(_reduced_depth(cfg, k2), shape, mesh,
                                      **kw))
            per_unit = (c2.coll_bytes_per_device
                        - c1.coll_bytes_per_device) / (k2 - k1)
            coll_bytes = max(
                c1.coll_bytes_per_device
                + per_unit * (cfg.num_layers - k1), 0.0)
            coll_note = f"extrapolated from unrolled depths {k1},{k2}"
            kinds = set(c1.coll_breakdown) | set(c2.coll_breakdown)
            coll_by_kind = {}
            for kind in kinds:
                b1 = c1.coll_breakdown.get(kind, 0)
                b2 = c2.coll_breakdown.get(kind, 0)
                coll_by_kind[kind] = max(
                    b1 + (b2 - b1) / (k2 - k1) * (cfg.num_layers - k1), 0)
            rl_full = dataclasses.replace(rl_full,
                                          coll_breakdown=coll_by_kind)
        except Exception as e:          # fall back to the scanned parse
            coll_note = f"pair failed ({type(e).__name__}); full-hlo"

    est = step_cost(cfg, shape)
    compute_s = est.flops / (chips * TPU_PEAK_BF16_FLOPS)
    memory_s = est.hbm_bytes / (chips * TPU_HBM_BW)
    collective_s = coll_bytes / TPU_ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    rec = {
        "arch": arch, "shape": shape.name,
        "mesh": "multi" if multi_pod else "single",
        "tag": tag,
        "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "analytic_flops": est.flops,
        "analytic_hbm_bytes": est.hbm_bytes,
        "coll_bytes_per_device": coll_bytes,
        "coll_note": coll_note,
        "coll_breakdown": rl_full.coll_breakdown,
        "hlo_flops_per_device_scanbody": rl_full.flops_per_device,
        "model_flops": mf,
        "model_flops_ratio": mf / est.flops if est.flops else 0.0,
        "compile_s": t1 - t0,
    }
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                rec[attr] = int(v)
    if verbose:
        print(f"[{rec['mesh']}|{arch}|{shape.name}|{tag}] chips={chips} "
              f"compile={rec['compile_s']:.0f}s "
              f"C/M/N={compute_s:.2e}/{memory_s:.2e}/{collective_s:.2e}s "
              f"bottleneck={bottleneck} "
              f"6ND/HLO={rec['model_flops_ratio']:.2f} "
              f"temp={rec.get('temp_size_in_bytes', 0) / 2**30:.2f}GiB",
              flush=True)
    return rec


SHAPE_NAMES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=("single", "multi", "both"))
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--append", action="store_true")
    ap.add_argument("--retry-errors", action="store_true",
                    help="re-run previously errored cells on resume")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPE_NAMES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    tasks = [
        SweepTask(
            key=f"{arch}|{shape}|{'multi' if mp else 'single'}",
            run=(lambda arch=arch, shape=shape, mp=mp:
                 lower_cell(arch, shape, mp)),
            meta={"arch": arch, "shape": shape,
                  "mesh": "multi" if mp else "single"})
        for arch in archs for shape in shapes for mp in meshes]
    results = run_sweep(
        tasks, out=args.out, resume=args.append,
        retry_errors=args.retry_errors,
        key_of=lambda r: f"{r.get('arch')}|{r.get('shape')}|"
                         f"{r.get('mesh')}")
    print(f"dry-run complete: {summarize(results, 'compute_s')} "
          f"-> {args.out}")


if __name__ == "__main__":
    main()
