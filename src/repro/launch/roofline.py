"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), per the spec:
  compute_s    = HLO_FLOPs / (chips x 197e12)       [bf16 peak, v5e]
  memory_s     = HLO_bytes / (chips x 819e9)
  collective_s = collective_bytes / (chips x 50e9)

XLA's cost analysis on the SPMD-partitioned module reports *per-device*
numbers, so we treat them as such (global = per_device x chips; the chips
cancel). collective_bytes is parsed from the compiled HLO text: the sum of
result-shape bytes of all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute ops (per-device wire bytes; all-reduce counted twice —
reduce-scatter + all-gather phases).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional

from ..core.compat import cost_analysis as compat_cost_analysis
from ..costmodel.params import TPU_HBM_BW, TPU_ICI_BW, TPU_PEAK_BF16_FLOPS

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^a-z]", re.IGNORECASE)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * b
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device wire bytes per collective kind from compiled HLO."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2).lower()
        b = _shape_bytes(shape_str)
        if kind == "all-reduce":
            b *= 2          # RS + AG phases on the wire
        out[kind] = out.get(kind, 0) + b
    return out


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, int]

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def model_flops_ratio(self, model_flops_global: float, chips: int
                          ) -> float:
        hlo_global = self.flops_per_device * chips
        return model_flops_global / hlo_global if hlo_global else 0.0


def analyze(compiled, hlo_text: Optional[str] = None) -> Roofline:
    cost = compat_cost_analysis(compiled)
    flops = float(cost.get("flops", 0.0))
    mem_bytes = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    cb = float(sum(coll.values()))
    return Roofline(
        compute_s=flops / TPU_PEAK_BF16_FLOPS,
        memory_s=mem_bytes / TPU_HBM_BW,
        collective_s=cb / TPU_ICI_BW,
        flops_per_device=flops,
        bytes_per_device=mem_bytes,
        coll_bytes_per_device=cb,
        coll_breakdown=coll,
    )


def model_flops(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) for train; 2*N_active*B per
    decode token; prefill = forward only (2*N*D)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token
