"""Sharding rules: params (TP + FSDP + EP), activations (logical rules),
batches and decode caches — per architecture x mesh ("packaging").

Everything is divisibility-checked against the actual mesh, with graceful
fallback to replication, so ANY (arch x shape x mesh) cell lowers.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeConfig
from .mesh import batch_axes, model_axes


def _axsize(mesh, axes) -> int:
    from ..core.fabric import Fabric
    return Fabric.of(mesh).axis_size(axes)


def best_spec(mesh, shape, prefs) -> P:
    """Greedy dim->axes assignment honoring divisibility & axis exclusivity.

    prefs: [(dim, axes), ...] in priority order; axes str or tuple.
    """
    spec = [None] * len(shape)
    used = set()
    for dim, axes in prefs:
        if dim >= len(shape) or spec[dim] is not None:
            continue
        ax = (axes,) if isinstance(axes, str) else tuple(axes)
        if any(a not in mesh.axis_names for a in ax):
            continue
        if any(a in used for a in ax):
            continue
        if shape[dim] % _axsize(mesh, ax) == 0 and shape[dim] > 0:
            spec[dim] = axes if isinstance(axes, str) else tuple(axes)
            used.update(ax)
    return P(*spec)


# ---------------------------------------------------------------------------
# Activation logical rules (models/common.shard)
# ---------------------------------------------------------------------------

def logical_rules(cfg: ArchConfig, mesh, shape: Optional[ShapeConfig] = None
                  ) -> Dict[str, object]:
    mdl = model_axes(mesh)
    mdl = mdl[0] if len(mdl) == 1 else tuple(mdl)
    bat = batch_axes(mesh)
    bat = bat[0] if len(bat) == 1 else tuple(bat)
    seq_ax = mdl if (shape is None or not shape.is_decode) else None
    if cfg.family in ("ssm", "hybrid"):
        # recurrent time scans are sequential: seq-sharding would force XLA
        # to gather every chunk on every device (measured: +1.4GiB/layer on
        # zamba2). Keep seq local; heads/channels carry the model axes, and
        # training uses gradient accumulation for activation memory.
        seq_ax = None
    if shape is not None and shape.global_batch < _axsize(
            mesh, bat if isinstance(bat, tuple) else (bat,)):
        bat = None  # tiny-batch decode: replicate batch
    return {
        "act_batch": bat,
        "act_seq": seq_ax,           # SP: sequence over the model axes
        "act_seq_inner": None,       # inner tensors shard ff/heads instead
        "act_embed": None,
        "act_ff": mdl,
        "act_heads": mdl,
        "act_kv": None,
        "act_vocab": mdl,   # logits vocab-sharded (seq gathered at the head)
        "act_group": bat,
        "act_expert": "expert" if "expert" in mesh.axis_names else None,
    }


# ---------------------------------------------------------------------------
# Parameter shardings
# ---------------------------------------------------------------------------

def _param_prefs(name: str, shape: Tuple[int, ...], cfg: ArchConfig, mesh,
                 stacked: bool):
    """Priority list of (dim, axes) for one leaf. Dims are absolute."""
    mdl = model_axes(mesh)
    mdl = mdl[0] if len(mdl) == 1 else tuple(mdl)
    off = 1 if stacked else 0
    nd = len(shape)
    last, prev = nd - 1, nd - 2

    moe_e = "expert"
    if name in ("wg", "wu", "wd") and nd - off == 3 and cfg.moe is not None:
        # expert weights [.., E, D, F] or [.., E, F, D]
        if name == "wd":
            return [(off, moe_e), (off + 1, "tp"), (off + 2, "data")]
        return [(off, moe_e), (off + 2, "tp"), (off + 1, "data")]
    if name == "router":
        return []
    if name in ("embed", "lm_head"):
        return [(0, mdl), (1, "data")]
    if name in ("wq",):  # [.., D, H, hd]
        return [(prev, mdl), (off, "data")]
    if name in ("wk", "wv"):
        prefs = [(prev, mdl)]
        if "expert" in mesh.axis_names:
            prefs.append((prev, "expert"))
        prefs.append((off, "data"))
        return prefs
    if name == "wo":     # [.., F_in, D]
        return [(prev, mdl), (last, "data")]
    if name in ("wg", "wu", "ck"):   # dense [.., D, F]
        return [(last, mdl), (prev, "data")]
    if name in ("wd", "cv"):         # dense [.., F, D]
        return [(prev, mdl), (last, "data")]
    if name in ("wr", "cr", "w_in"):  # [.., D, X]
        return [(last, mdl), (prev, "data")]
    if name == "w_out":
        return [(prev, mdl), (last, "data")]
    if name == "conv_w":             # [.., W, C]
        return [(last, mdl)]
    if name == "bq":                 # [.., H, hd]
        return [(prev, mdl)]
    return []


def param_shardings(cfg: ArchConfig, mesh, params_shape, fsdp: bool = True):
    """params_shape: pytree of ShapeDtypeStruct (jax.eval_shape of init).

    ``fsdp=False`` drops the 'data'-axis param sharding (weights resident,
    replicated across data — the serving configuration)."""
    def leaf_spec(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        name = names[-1]
        stacked = any(n in ("blocks", "enc_blocks") for n in names[:-1])
        prefs = _param_prefs(name, leaf.shape, cfg, mesh, stacked)
        if not fsdp:
            prefs = [(d, a) for d, a in prefs if a != "data"]
        return NamedSharding(mesh, best_spec(mesh, leaf.shape, prefs))

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


# ---------------------------------------------------------------------------
# Batch specs (ShapeDtypeStruct + sharding) per (arch x shape)
# ---------------------------------------------------------------------------

VLM_PATCH_TOKENS = 256
ENCDEC_CROSS_LEN = 4096


def batch_struct(cfg: ArchConfig, shape: ShapeConfig, mesh):
    """Training/prefill batch as sharded ShapeDtypeStructs."""
    B, S = shape.global_batch, shape.seq_len
    bat = batch_axes(mesh)
    bat = bat[0] if len(bat) == 1 else tuple(bat)
    mdl = model_axes(mesh)
    mdl = mdl[0] if len(mdl) == 1 else tuple(mdl)

    def tok(b, s, extra_dim=None):
        shp = (b, s) if extra_dim is None else (b, extra_dim, s)
        prefs = [(0, bat), (len(shp) - 1, mdl)]
        return jax.ShapeDtypeStruct(
            shp, jnp.int32,
            sharding=NamedSharding(mesh, best_spec(mesh, shp, prefs)))

    def emb(b, s, d):
        shp = (b, s, d)
        prefs = [(0, bat), (1, mdl)]
        return jax.ShapeDtypeStruct(
            shp, jnp.float32,
            sharding=NamedSharding(mesh, best_spec(mesh, shp, prefs)))

    if cfg.family == "encdec":
        s_src = min(S // 2, 4096)
        s_tgt = S - s_src
        return {"src_embeds": emb(B, s_src, cfg.d_model),
                "tokens": tok(B, s_tgt), "labels": tok(B, s_tgt)}
    if cfg.family == "vlm":
        s_txt = S - VLM_PATCH_TOKENS
        return {"tokens": tok(B, s_txt), "labels": tok(B, s_txt),
                "patch_embeds": emb(B, VLM_PATCH_TOKENS, cfg.d_model),
                "positions": tok(B, S, extra_dim=3)}
    return {"tokens": tok(B, S), "labels": tok(B, S)}


def cache_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh, cache_shape):
    """Shardings for the decode cache pytree (from jax.eval_shape)."""
    bat = batch_axes(mesh)
    bat_t = tuple(bat)
    mdl = model_axes(mesh)
    mdl = mdl[0] if len(mdl) == 1 else tuple(mdl)
    B = shape.global_batch
    batch_ok = B % _axsize(mesh, bat_t) == 0

    def leaf_spec(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        shp = leaf.shape
        nd = len(shp)
        if nd <= 1:
            return NamedSharding(mesh, P())
        prefs = []
        if batch_ok:
            prefs.append((1, bat_t if len(bat_t) > 1 else bat_t[0]))
        if any("k" == n or "v" == n or "cross" in n for n in names) and nd >= 4:
            # [L, B, C, H, hd]: heads over model/expert; else seq over data
            prefs.append((3, mdl))
            if "expert" in mesh.axis_names:
                prefs.append((3, "expert"))
            prefs.append((2, "data"))
            prefs.append((2, bat_t if len(bat_t) > 1 else bat_t[0]))
        elif nd >= 3:
            # recurrent states [L, B, H, ...] / conv [L, B, W, C]
            prefs.append((2, mdl))
            prefs.append((nd - 1, mdl))
        return NamedSharding(mesh, best_spec(mesh, shp, prefs))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shape)
