"""Energy model (paper §IV-B, Table III): NoC + memory + PU.

Consumes ``RunStats`` from the task engine. Components (paper Fig. 9):
* NoC     — router traversals + wire mm per hop + die-to-die crossings;
* memory  — SRAM at the modeled hit rate, HBM for misses (+ tag checks);
* PU      — instructions executed (clock-gated when idle, §V-D);
SRAM banks and HBM power down when idle (paper §V-D), so idle power is 0.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..core.cache import CacheModel
from ..core.task_engine import EngineConfig, RunStats
from .params import COMPUTE, LINK, MEM


@dataclass
class EnergyBreakdown:
    noc_j: float
    memory_j: float
    pu_j: float

    @property
    def total_j(self) -> float:
        return self.noc_j + self.memory_j + self.pu_j


def run_energy(stats: RunStats, cfg: EngineConfig,
               instr_per_task: float = 7.0,
               dataset_bytes: float = 0.0) -> EnergyBreakdown:
    cache = CacheModel(cfg.sram, cfg.dram)
    noc = 0.0
    mem = 0.0
    pu = 0.0
    foot_tile = dataset_bytes / cfg.grid.n_tiles if dataset_bytes else 0.0
    for r in stats.rounds:
        bits = r.payload_bytes * 8
        if r.messages:
            # dropped tasks are retransmitted (see perf.py): the retried
            # wire traffic burns NoC energy again; same all-channel
            # normalisation as perf.py's retry factor
            retry = 1.0 + r.drops / max(r.messages + r.local_msgs, 1)
            avg_hops = r.hops / r.messages
            per_msg_bits = bits / r.messages
            noc += r.messages * retry * per_msg_bits * (
                avg_hops * (LINK.noc_router_pj_bit
                            + LINK.noc_wire_pj_bit_mm * LINK.tile_pitch_mm))
            noc += r.die_crossings * retry * per_msg_bits * LINK.d2d_pj_bit
        # memory: stream + random access mix
        hit = cache.hit_rate(r.stream_bytes, r.random_bytes, foot_tile)
        total_bits = (r.stream_bytes + r.random_bytes) * 8
        mem += total_bits * (MEM.sram_read_pj_bit * hit
                             + MEM.hbm_pj_bit * (1 - hit))
        if cfg.dram.present:
            mem += (r.stream_bytes + r.random_bytes) / 64.0 * MEM.cache_tag_pj
        pu += r.tasks_total * instr_per_task * COMPUTE.pu_active_pj_instr
    return EnergyBreakdown(noc_j=noc * 1e-12, memory_j=mem * 1e-12,
                           pu_j=pu * 1e-12)
