from .energy import EnergyBreakdown, run_energy          # noqa: F401
from .params import COMPUTE, LINK, MEM, SILICON           # noqa: F401
from .perf import PerfResult, run_perf                    # noqa: F401
from .silicon import (dcra_die_area_mm2, die_cost_usd,     # noqa: F401
                      murphy_yield, package_cost)
