"""Table III constants + silicon-economics parameters (paper §IV-B/C)."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class MemoryModel:
    sram_density_mb_mm2: float = 3.5          # [89]
    sram_rw_latency_ns: float = 0.82
    sram_read_pj_bit: float = 0.18
    sram_write_pj_bit: float = 0.28
    cache_tag_pj: float = 6.3                  # read + compare [89][90]
    hbm_density_gb_mm2: float = 8.0 / 110.0    # 8GB / 110 mm^2 [46]
    hbm_channels: int = 8
    hbm_gbps_per_channel: float = 64.0
    hbm_rw_latency_ns: float = 50.0
    hbm_pj_bit: float = 3.7                    # [36][67]
    refresh_period_ms: float = 32.0
    refresh_pj_bit: float = 0.22


@dataclass(frozen=True)
class LinkModel:
    mcm_phy_areal_gbit_mm2: float = 690.0      # [6]
    mcm_phy_beach_gbit_mm: float = 880.0
    interposer_areal_gbit_mm2: float = 1070.0
    interposer_beach_gbit_mm: float = 1780.0
    d2d_latency_ns: float = 4.0                # <25mm [61]
    d2d_pj_bit: float = 0.55
    noc_wire_ps_mm: float = 50.0               # [38]
    noc_wire_pj_bit_mm: float = 0.15
    noc_router_latency_ps: float = 500.0
    noc_router_pj_bit: float = 0.1
    io_die_rxtx_latency_ns: float = 20.0       # PCIe 6.0 [76]
    off_package_pj_bit: float = 1.17           # up to 80mm [88]
    tile_pitch_mm: float = 0.75                # wire length per NoC hop


@dataclass(frozen=True)
class SiliconModel:
    wafer_cost_usd: float = 6047.0             # 300mm 7nm [32]
    wafer_diameter_mm: float = 300.0
    scribe_mm: float = 0.2
    edge_loss_mm: float = 4.0
    # the paper quotes "0.07 defects per mm^2" — industry convention (and the
    # only value consistent with the paper's own "255mm^2 die still achieves
    # good yield" claim) is per *cm^2*; stored here in per-mm^2 units.
    defects_per_mm2: float = 0.0007            # = 0.07 / cm^2, Murphy model
    interposer_cost_frac: float = 0.20         # of DCRA die price [85]
    substrate_cost_frac: float = 0.10
    bonding_overhead_frac: float = 0.05        # [45][80]
    hbm_usd_per_gb: float = 7.5                # educated guess (§IV-C)
    # area model (7nm): PU tile logic + router + PHY
    pu_area_mm2: float = 0.05                  # tiny in-order core
    router_area_mm2: float = 0.03
    phy_area_mm2_per_die: float = 20.0         # beachfront PHY share


@dataclass(frozen=True)
class ComputeModel:
    pu_freq_ghz: float = 1.0
    instr_per_cycle: float = 1.0               # paper §IV-B assumption
    pu_active_pj_instr: float = 5.0            # in-order RISC-V class [90]
    pu_idle_w: float = 0.0                     # clock-gated when no tasks


MEM = MemoryModel()
LINK = LinkModel()
SILICON = SiliconModel()
COMPUTE = ComputeModel()

# --- TPU v5e roofline constants (for §Roofline, NOT the DCRA model) -------
TPU_PEAK_BF16_FLOPS = 197e12        # per chip
TPU_HBM_BW = 819e9                  # bytes/s
TPU_ICI_BW = 50e9                   # bytes/s per link (~)
