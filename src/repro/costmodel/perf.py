"""Performance model: RunStats -> cycles -> seconds -> TEPS (paper §IV-B).

Bulk-synchronous approximation of the Dalorex cycle-accurate NoC simulator
(documented in DESIGN.md §2): per round, time is the max of
  * compute+memory at the most-loaded tile (peak tasks x (instrs/f + stalls)),
  * injection serialization at the hottest tile,
  * bisection-bandwidth serialization of the remote traffic,
plus a pipelined-latency constant. Queue sizing (Table II #8) enters as a
producer-stall term: a task that fans out more messages than its OQ holds
stalls for the excess (paper Fig. 10 mechanism). Topology enters via
bisection width, hop counts (already topology-aware in RunStats), and a
congestion factor (meshes hotspot under uniform random traffic; tori do
not — paper §V-A / Dalorex observation).
"""
from __future__ import annotations

from dataclasses import dataclass

from ..core.cache import CacheModel
from ..core.task_engine import EngineConfig, RunStats
from .params import LINK

CONGESTION = {"mesh": 0.70, "torus": 1.0, "hier_torus": 1.1}
MSG_BITS = 128  # 2-word payload + header


@dataclass
class PerfResult:
    seconds: float
    cycles: float
    edges_processed: int

    @property
    def teps(self) -> float:
        return self.edges_processed / self.seconds if self.seconds else 0.0


IMBALANCE_WEIGHT = 0.2  # async task model amortizes part of the peak tile


def round_time_ns(r, cfg: EngineConfig, cache: CacheModel,
                  foot_tile: float, oq2: int, fanout: float) -> float:
    g = cfg.grid
    f_pu = cfg.pu_freq_ghz
    f_noc = g.noc_freq_ghz

    # ---- compute + memory at the most loaded tile ----------------------
    instr = 7.0
    bytes_per_task = ((r.stream_bytes + r.random_bytes)
                      / max(r.tasks_total, 1))
    hit = cache.hit_rate(r.stream_bytes, r.random_bytes, foot_tile)
    eff_bw = cache.effective_bw(hit)                  # bytes/ns/tile
    # producer stall: fanout beyond the OQ defers at ~1 msg/cycle
    stall_cyc = max(0.0, fanout - oq2) * 0.5
    per_task_ns = (instr + stall_cyc) / f_pu + bytes_per_task / eff_bw
    avg_tasks = r.tasks_total / g.n_tiles
    # barrier rounds expose the full peak (PageRank's epoch tail, §V-B);
    # otherwise the async task model amortizes stragglers across rounds.
    w = 1.0 if r.barrier else IMBALANCE_WEIGHT
    eff_tasks = avg_tasks + w * max(r.tasks_per_tile_peak - avg_tasks, 0.0)
    compute_ns = eff_tasks * per_task_ns / cfg.pus_per_tile

    # ---- network -------------------------------------------------------
    # IQ-overflow drops are retransmitted by the producer (the routing
    # layer's drop-and-retry semantics), so modeled drops inflate the
    # injection and bisection terms; zero drops leaves them untouched.
    # Drops are counted over ALL (src, dst) channels — local ones too —
    # so normalise by all routed tasks, not just the NoC-crossing ones.
    retry = 1.0 + r.drops / max(r.messages + r.local_msgs, 1)
    inj_hot = avg_tasks + w * max(r.tasks_per_tile_peak - avg_tasks, 0.0)
    inj_ns = inj_hot * retry * MSG_BITS / (g.noc_width_bits * f_noc)
    remote_bytes = r.payload_bytes * retry
    bisec = g.bisection_bytes_per_cycle() * f_noc * CONGESTION[g.topology]
    # hierarchical torus: the die-NoC carries inter-die traffic in parallel
    if g.topology == "hier_torus":
        n_dr, n_dc = g.dies
        die_noc_bpc = min(n_dr, n_dc) * 2 * g.noc_width_bits / 8.0
        bisec += die_noc_bpc * f_noc * 0.5
    bisec_ns = (remote_bytes / 2.0) / max(bisec, 1e-9)
    avg_hops = (r.hops / r.messages) if r.messages else 0.0
    lat_ns = avg_hops * LINK.noc_router_latency_ps / 1e3 + \
        (LINK.d2d_latency_ns if r.die_crossings else 0.0)

    return max(compute_ns, inj_ns, bisec_ns) + lat_ns


def run_perf(stats: RunStats, cfg: EngineConfig, edges: int,
             dataset_bytes: float = 0.0, fanout: float = 16.0) -> PerfResult:
    cache = CacheModel(cfg.sram, cfg.dram)
    foot_tile = dataset_bytes / cfg.grid.n_tiles if dataset_bytes else 0.0
    oq2 = cfg.queues.oq("T3")
    total_ns = 0.0
    for r in stats.rounds:
        total_ns += round_time_ns(r, cfg, cache, foot_tile, oq2, fanout)
    sec = total_ns * 1e-9
    return PerfResult(seconds=sec, cycles=total_ns * cfg.pu_freq_ghz,
                      edges_processed=edges)
