"""Silicon + packaging cost model (paper §IV-C): Murphy yield, die cost,
interposer/substrate/bonding, HBM pricing. Decoupled from simulation so cost
can be re-priced post-run (the paper's stated design)."""
from __future__ import annotations

import math
from dataclasses import dataclass

from .params import MEM, SILICON, SiliconModel


def murphy_yield(area_mm2: float, defects_per_mm2: float) -> float:
    """Murphy's model: Y = ((1 - e^-AD) / (AD))^2."""
    ad = area_mm2 * defects_per_mm2
    if ad <= 0:
        return 1.0
    return ((1 - math.exp(-ad)) / ad) ** 2


def gross_dies_per_wafer(area_mm2: float, s: SiliconModel = SILICON) -> int:
    """Accounting for scribe lines and edge loss."""
    side = math.sqrt(area_mm2) + s.scribe_mm
    d = s.wafer_diameter_mm - 2 * s.edge_loss_mm
    # standard die-per-wafer estimate
    return int(math.pi * (d / 2) ** 2 / (side * side)
               - math.pi * d / math.sqrt(2 * side * side))


def die_cost_usd(area_mm2: float, s: SiliconModel = SILICON) -> float:
    gross = max(gross_dies_per_wafer(area_mm2, s), 1)
    good = max(gross * murphy_yield(area_mm2, s.defects_per_mm2), 1e-6)
    return s.wafer_cost_usd / good


def dcra_die_area_mm2(tiles: int, sram_kb_per_tile: int,
                      pus_per_tile: int = 1, noc_width_bits: int = 64,
                      freq_ghz: float = 1.0, s: SiliconModel = SILICON
                      ) -> float:
    """Area of one DCRA chiplet (tiles x (PU + SRAM + router) + PHY)."""
    sram_mm2 = (sram_kb_per_tile / 1024) / MEM.sram_density_mb_mm2
    pu_mm2 = s.pu_area_mm2 * pus_per_tile * (1.5 if freq_ghz > 1.0 else 1.0)
    router_mm2 = s.router_area_mm2 * (noc_width_bits / 64.0) * \
        (2.0 if freq_ghz > 1.0 else 1.0)
    return tiles * (sram_mm2 + pu_mm2 + router_mm2) + s.phy_area_mm2_per_die


@dataclass
class PackageCost:
    dcra_dies_usd: float
    hbm_usd: float
    interposer_usd: float
    substrate_usd: float
    bonding_usd: float

    @property
    def total(self) -> float:
        return (self.dcra_dies_usd + self.hbm_usd + self.interposer_usd
                + self.substrate_usd + self.bonding_usd)


def package_cost(n_dcra_dies: int, die_area_mm2: float,
                 hbm_gb_total: float, s: SiliconModel = SILICON
                 ) -> PackageCost:
    die_usd = die_cost_usd(die_area_mm2, s)
    dies = n_dcra_dies * die_usd
    hbm = hbm_gb_total * s.hbm_usd_per_gb
    # interposer only where HBM is bonded to a DCRA die (per HBM stack)
    n_hbm_stacks = hbm_gb_total / 8.0
    interposer = n_hbm_stacks * s.interposer_cost_frac * die_usd
    substrate = n_dcra_dies * s.substrate_cost_frac * die_usd
    bonding = s.bonding_overhead_frac * (dies + hbm + interposer + substrate)
    return PackageCost(dies, hbm, interposer, substrate, bonding)


def monolithic_wafer_cost(s: SiliconModel = SILICON) -> float:
    """Dalorex-style wafer-scale: one chip per wafer (paper §V-D)."""
    return s.wafer_cost_usd  # yield-insensitive comparison per the paper
