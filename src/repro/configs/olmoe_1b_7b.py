"""OLMoE-1B-7B — 16L d_model=2048 16H (GQA kv=16) expert d_ff=1024
vocab=50304, MoE 64 experts top-8. [arXiv:2409.02060; hf]"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    rope_theta=10_000.0,
    moe=MoEConfig(num_experts=64, top_k=8, d_expert=1024, dispatch_impl="dcra"),
    source="arXiv:2409.02060",
)
