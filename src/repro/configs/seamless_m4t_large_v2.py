"""SeamlessM4T-large-v2 backbone — 24L enc + 24L dec, d_model=1024 16H
(kv=16) d_ff=8192 vocab=256206. Modality frontend is a STUB: input_specs
provides precomputed audio-frame embeddings. [arXiv:2308.11596; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,           # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    rope_theta=10_000.0,
    frontend="audio_frames",
    source="arXiv:2308.11596",
)
