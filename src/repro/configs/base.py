"""Architecture + shape configuration system.

Every assigned architecture gets one module in this package exporting
``CONFIG: ArchConfig`` with the exact published dimensions. The registry in
``__init__`` resolves ``--arch <id>`` strings.

Design notes
------------
* ``ArchConfig`` is a frozen dataclass so configs are hashable and safe to
  close over in jitted functions.
* ``reduced()`` returns a tiny same-family config for CPU smoke tests; the
  full config is only ever *lowered* (dry-run), never allocated on CPU.
* Shapes are global; the sharding layer divides them across the mesh.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Shapes (assigned per the task spec; identical for the LM family)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                      # per-expert FFN hidden dim
    capacity_factor: float = 1.25      # DCRA: the IQ size knob (Table II #8)
    # 'einsum'   : dense dispatch/combine masks, XLA SPMD partitions (baseline)
    # 'dcra'     : shard_map hierarchical two-level all-to-all (paper technique)
    dispatch_impl: str = "einsum"
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) / RWKV6 recurrence parameters."""
    state_dim: int = 64                # N (mamba2 ssm_state) or head dim (rwkv)
    head_dim: int = 64
    chunk_size: int = 256              # chunked-scan block length
    conv_width: int = 4                # mamba2 depthwise conv
    expand: int = 2                    # mamba2 inner expansion


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int                     # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // num_heads
    qkv_bias: bool = False
    sliding_window: int = 0            # 0 = full attention; >0 = SWA window
    rope_theta: float = 1e4
    mrope: bool = False                # Qwen2-VL multimodal RoPE
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one shared attention block applied every `period` layers
    hybrid_attn_period: int = 0
    # enc-dec (seamless): encoder layer count (decoder = num_layers)
    encoder_layers: int = 0
    frontend: str = "none"             # none | audio_frames | vision_patches
    # source tag from the assignment table
    source: str = ""
    # runtime policy knobs (Table II compile-time analogues)
    remat: str = "block"               # none | block | full | dots
    scan_layers: bool = True
    accum_steps: int = 1               # grad-accumulation microbatches

    # ---- derived -----------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the long_500k decode cell?"""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for roofline's 6ND."""
        d, v = self.d_model, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        hd = self.resolved_head_dim
        total = emb
        n_dec = self.num_layers
        for _ in range(n_dec):
            total += self._block_params(d, hd)
        if self.family == "hybrid":
            # zamba2: the attention+MLP block is WEIGHT-SHARED across its
            # applications -> counted once, not per application.
            q = d * hd * self.num_heads
            kv = 2 * d * hd * self.num_kv_heads
            o = hd * self.num_heads * d
            total += q + kv + o + 3 * d * self.d_ff
        if self.encoder_layers:
            for _ in range(self.encoder_layers):
                total += self._block_params(d, hd, cross=False)
            # decoder cross-attention adds one attention block per layer
            total += n_dec * (d * hd * self.num_heads + 2 * d * hd * self.num_kv_heads
                              + hd * self.num_heads * d)
        return total

    def _block_params(self, d: int, hd: int, cross: bool = False) -> int:
        p = 2 * d  # norms
        if self.family == "ssm":  # rwkv6: tmix (~4 d^2 + decay mlp) + cmix (~3 d*ff)
            p += 4 * d * d + d * 64 * 2 + 3 * d * self.d_ff
            return p
        if self.family == "hybrid":
            # mamba2 block only (shared attn+MLP counted once in param_count)
            ss = self.ssm or SSMConfig()
            d_in = ss.expand * d
            n_heads = d_in // ss.head_dim
            # in_proj -> [z, x, B, C, dt]; conv over (x,B,C); out_proj
            p += d * (2 * d_in + 2 * ss.state_dim + n_heads)
            p += ss.conv_width * (d_in + 2 * ss.state_dim)
            p += d_in * d
            return p
        # attention
        q = d * hd * self.num_heads
        kv = 2 * d * hd * self.num_kv_heads
        o = hd * self.num_heads * d
        p += q + kv + o
        # ffn
        if self.moe is not None:
            p += self.moe.num_experts * 3 * d * self.moe.d_expert + d * self.moe.num_experts
        else:
            p += 3 * d * self.d_ff  # SwiGLU: gate,up,down
        return p

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        inactive = self.num_layers * (self.moe.num_experts - self.moe.top_k) * 3 * d * self.moe.d_expert
        return full - inactive

    # ---- reduced config for smoke tests -------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config: 2 layers, narrow dims, small vocab."""
        kw = {}
        heads = min(self.num_heads, 4) if self.num_heads else 0
        kvh = min(self.num_kv_heads, max(1, heads // 2)) if self.num_kv_heads else 0
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2), d_expert=64)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, state_dim=16, head_dim=16,
                                            chunk_size=32)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=2,
            d_model=64,
            num_heads=heads,
            num_kv_heads=kvh,
            head_dim=16 if heads else 0,
            d_ff=128,
            vocab_size=256,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            hybrid_attn_period=2 if self.hybrid_attn_period else 0,
            scan_layers=False,
            **kw,
        )

    def shape_cells(self) -> Tuple[ShapeConfig, ...]:
        """The shape cells this arch runs (skips documented in DESIGN.md §5)."""
        cells = [TRAIN_4K, PREFILL_32K, DECODE_32K]
        if self.sub_quadratic:
            cells.append(LONG_500K)
        return tuple(cells)
