"""RWKV6-7B (Finch) — 32L d_model=4096 attn-free, d_ff=14336 vocab=65536,
data-dependent decay. [arXiv:2404.05892; hf]"""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=14336,
    vocab_size=65536,
    ssm=SSMConfig(state_dim=64, head_dim=64, chunk_size=256),
    accum_steps=8,
    source="arXiv:2404.05892",
)
