"""Mixtral 8x22B — 56L d_model=6144 48H (GQA kv=8) expert d_ff=16384
vocab=32768, MoE 8 experts top-2, SWA. [arXiv:2401.04088; hf]"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=16384, dispatch_impl="dcra"),
    source="arXiv:2401.04088",
)
