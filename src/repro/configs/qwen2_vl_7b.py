"""Qwen2-VL-7B backbone — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064, M-RoPE. Vision frontend is a STUB: input_specs provides
precomputed patch embeddings + 3D M-RoPE position ids.
[arXiv:2409.12191; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    mrope=True,
    rope_theta=1_000_000.0,
    frontend="vision_patches",
    source="arXiv:2409.12191",
)
