"""Architecture registry: ``get_config("<arch-id>")`` resolves --arch flags."""
from __future__ import annotations

import importlib
from typing import Dict, List

from .base import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, SHAPES,
                   TRAIN_4K, ArchConfig, MoEConfig, ShapeConfig, SSMConfig)

# arch-id -> module name
_ARCH_MODULES: Dict[str, str] = {
    "mixtral-8x22b": "mixtral_8x22b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "granite-8b": "granite_8b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen2-1.5b": "qwen2_1_5b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "rwkv6-7b": "rwkv6_7b",
    "zamba2-7b": "zamba2_7b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def get_config(arch: str) -> ArchConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f".{_ARCH_MODULES[arch]}", __package__)
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = [
    "ArchConfig", "MoEConfig", "SSMConfig", "ShapeConfig",
    "ALL_SHAPES", "SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "ARCH_IDS", "get_config", "all_configs",
]
