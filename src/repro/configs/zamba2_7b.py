"""Zamba2-7B — 81L d_model=3584, Mamba2 blocks + shared attention blocks
(32H GQA kv=32) applied periodically, d_ff=14336 vocab=32000 ssm_state=64.
[arXiv:2411.15242; unverified]"""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, chunk_size=256, expand=2),
    hybrid_attn_period=6,     # shared attn block every 6 mamba layers
    accum_steps=8,
    source="arXiv:2411.15242",
)
