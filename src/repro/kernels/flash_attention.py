"""Causal flash attention Pallas TPU kernel.

VMEM strategy (the DCRA scratchpad/cache split, DESIGN.md §2): the Q tile is
scratchpad-resident across the KV sweep; K/V tiles stream HBM->VMEM like
cache lines, with the BlockSpec index map acting as the hardware prefetcher.
Online softmax keeps the [TQ, TK] logits tile in VMEM; causal tiles beyond
the diagonal are skipped via the grid (no wasted MXU work).

Tile sizes default to MXU-aligned 128x128 with hd lanes; fp32 accumulators.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TQ = 128
DEFAULT_TK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal, tq, tk):
    i = pl.program_id(1)     # q tile
    j = pl.program_id(2)     # kv tile

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_base = i * tq
    k_base = j * tk
    run = (not causal) or (k_base <= q_base + tq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0]                                  # [TQ, hd]
        k = k_ref[0]                                  # [TK, hd]
        v = v_ref[0]
        scale = q.shape[-1] ** -0.5
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qi = q_base + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
            kj = k_base + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
            s = jnp.where(kj <= qi, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           tq: int = DEFAULT_TQ, tk: int = DEFAULT_TK,
                           interpret: bool = True):
    """q,k,v: [BH, S, hd] (batch*heads flattened) -> [BH, S, hd]."""
    BH, S, hd = q.shape
    tq = min(tq, S)
    tk = min(tk, S)
    assert S % tq == 0 and S % tk == 0
    grid = (BH, S // tq, S // tk)
    kern = functools.partial(_flash_kernel, causal=causal, tq=tq, tk=tk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, tk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
