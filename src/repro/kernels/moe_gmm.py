"""Grouped (expert) matmul Pallas TPU kernel — the MoE compute hot-spot.

Layout contract matches the DCRA dispatch output: tokens arrive bucketed
per expert in capacity-padded rows ([E * C, D] with C a multiple of the row
tile), so each row tile belongs to exactly one expert. The expert id per
row tile is *scalar-prefetched* (SMEM) and drives the weight BlockSpec
index map — the TPU analogue of DCRA's TSU prefetching the task's operand
arrays (paper §III-B) before the PU touches them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ROW_TILE = 128
F_TILE = 128


def _gmm_kernel(gid_ref, x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[0],
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


def gmm_pallas(x: jax.Array, w: jax.Array, group_ids: jax.Array,
               rt: int = ROW_TILE, ft: int = F_TILE,
               interpret: bool = True) -> jax.Array:
    """x [T, D] (expert-bucketed rows), w [E, D, F], group_ids [T // rt].

    Returns out [T, F] with out[t] = x[t] @ w[group_ids[t // rt]].
    """
    T, D = x.shape
    E, _, F = w.shape
    rt = min(rt, T)
    ft = min(ft, F)
    assert T % rt == 0 and F % ft == 0
    assert group_ids.shape[0] == T // rt
    grid = (T // rt, F // ft)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rt, D), lambda i, j, gid: (i, 0)),
            pl.BlockSpec((1, D, ft), lambda i, j, gid: (gid[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((rt, ft), lambda i, j, gid: (i, j)),
    )
    return pl.pallas_call(
        _gmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, F), x.dtype),
        interpret=interpret,
    )(group_ids.astype(jnp.int32), x, w)
