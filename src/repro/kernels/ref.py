"""Pure-jnp oracles for every Pallas kernel (shape-for-shape)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def histogram_ref(elements: jax.Array, n_bins: int) -> jax.Array:
    return jnp.bincount(elements.astype(jnp.int32), length=n_bins) \
        .astype(jnp.int32)


def bsr_spmv_ref(block_cols: jax.Array, blocks: jax.Array,
                 x: jax.Array) -> jax.Array:
    """Block-sparse-row SpMV oracle.

    block_cols [R, Kb] int32 (block-column id per stored block; padding
    entries must have zero-valued blocks); blocks [R, Kb, BS, BS];
    x [N] with N = n_col_blocks * BS. Returns y [R * BS].
    """
    R, Kb, BS, _ = blocks.shape
    xb = x.reshape(-1, BS)                       # [n_col_blocks, BS]
    gathered = xb[block_cols]                    # [R, Kb, BS]
    y = jnp.einsum("rkij,rkj->ri", blocks, gathered)
    return y.reshape(-1)


def gmm_ref(x: jax.Array, w: jax.Array, group_ids: jax.Array) -> jax.Array:
    """Grouped matmul oracle: out[t] = x[t] @ w[group_ids[t // BS]].

    x [T, D]; w [E, D, F]; group_ids [T // BS] (expert of each row block).
    """
    T, D = x.shape
    BS = T // group_ids.shape[0]
    xg = x.reshape(-1, BS, D)
    wg = w[group_ids]                            # [T//BS, D, F]
    return jnp.einsum("bsd,bdf->bsf", xg, wg).reshape(T, -1)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """q,k,v: [B, H, S, hd] -> [B, H, S, hd] (fp32 softmax)."""
    S = q.shape[2]
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w.astype(v.dtype), v)
