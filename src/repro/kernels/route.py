"""Pallas routing fast path — the NoC hot loop as kernels.

Every DCRA round funnels through :func:`repro.core.routing.bucket`: rank
each task within its destination bucket, admit the first ``cap`` per
channel, scatter the kept tasks into slot order, and (at the owner)
reduce the received stream into local state. The legacy ranking is a
``one_hot(dest, S)`` + cumsum — O(N*S) memory and FLOPs materialized in
HBM per stage, per round. This module provides the kernel tier of that
loop (the paper's IQ admission is *the* throughput limiter, §III/§VI):

* :func:`bucket_rank` — per-destination running counts live in VMEM and
  elements stream through in tiles: O(N + S*tiles) traffic instead of
  O(N*S). On TPU this is the Mosaic kernel
  (:func:`bucket_rank_pallas`); off-TPU it lowers to the *same tiled
  algorithm* rendered in plain XLA (:func:`bucket_rank_xla` — within-tile
  ranks via an L*L compare, running counts via one scatter-add), never
  the Pallas interpreter, so the deployed fast path is interpreter-free
  on every backend. Tiny bucket counts keep the one-hot rank (it wins
  below :data:`ONEHOT_MAX_BUCKETS` — see the README routing section).
* :func:`bucket_scatter_pallas` — the fused admission kernel: one pass
  over the task stream producing ``(xb, ints, task_slot, n_drop)``
  (rank, capacity test, and slot scatter fused; the XLA paths need a
  rank pass plus a ``segment_sum`` scatter).
* :func:`reduce_received_pallas` — fused receive-side add/min/store into
  local slots.

Drop semantics are bit-identical to the one-hot path (first ``cap`` per
channel, array order), differential-tested in tests/test_route_kernels.py
— which is what keeps the analytic twins (``program_app_stats``,
``dse.shardcheck``) exact no matter which impl a launch resolves.

``impl`` knob (threaded from ``QueueConfig.route_impl`` / ``run_program``
/ ``dcra_scatter``): ``"pallas"`` (the fast path above), ``"sort"``
(argsort-by-dest + segment offsets — the same trick ``_pack_edges`` uses
host-side; pure XLA, selectable everywhere), ``"onehot"`` (legacy).
``None``/``"auto"`` resolve to the fast path, which autodetects the
backend exactly like :mod:`repro.kernels.ops` wrappers do (Mosaic on
TPU, native XLA elsewhere; ``interpret=True`` is for tests only).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ELEM_TILE = 256          # pallas kernels: elements streamed per grid step
SCAN_TILE = 32           # XLA tile-scan: within-tile rank compare width
ONEHOT_MAX_BUCKETS = 32  # below this S the one-hot rank wins off-TPU

ROUTE_IMPLS = ("pallas", "sort", "onehot")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fused_kernels_enabled() -> bool:
    """Opt-in gate for the *per-element* Mosaic kernels
    (:func:`bucket_scatter_pallas`, :func:`reduce_received_pallas`) on
    real TPU. Their dynamic single-row stores inside ``fori_loop`` are
    interpret-tested only (this container has no TPU), and Mosaic
    restricts dynamic scalar-indexed stores — so until a TPU run
    validates them (ROADMAP follow-up), the deployed TPU path keeps the
    vectorized rank kernel + segment-op scatter and these engage only
    under ``DCRA_ROUTE_FUSED=1``."""
    return os.environ.get("DCRA_ROUTE_FUSED") == "1"


def onehot_rank(dest, valid, n_buckets):
    """THE legacy one-hot-cumsum rank — the single copy both
    ``positions_by_dest(impl="onehot")`` and :func:`bucket_rank`'s
    narrow-bucket branch call, so the documented byte-for-byte
    equivalence between them cannot silently drift."""
    onehot = jax.nn.one_hot(dest, n_buckets, dtype=jnp.int32)
    onehot = onehot * valid[:, None].astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    return jnp.take_along_axis(pos, dest[:, None], axis=1)[:, 0]


def resolve_route_impl(impl=None) -> str:
    """``None``/``"auto"`` -> the fast path (``"pallas"``), which itself
    autodetects the backend (Mosaic on TPU, native XLA off-TPU)."""
    if impl in (None, "auto"):
        return "pallas"
    if impl not in ROUTE_IMPLS:
        raise ValueError(f"route_impl {impl!r} not in {ROUTE_IMPLS}")
    return impl


# ---------------------------------------------------------------------------
# bucket-rank: stable cumcount of each element within its destination
# ---------------------------------------------------------------------------

def _rank_kernel(dest_ref, valid_ref, pos_ref, counts_ref, *, n_buckets):
    """One element tile: pos = running count + within-tile exclusive
    cumcount; per-destination running counts persist in VMEM scratch."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    dest = dest_ref[...]                                     # [ET]
    valid = valid_ref[...] != 0
    bins = jax.lax.broadcasted_iota(jnp.int32, (1, n_buckets), 1)
    onehot = ((dest[:, None] == bins) &
              valid[:, None]).astype(jnp.int32)              # [ET, S]
    excl = jnp.cumsum(onehot, axis=0) - onehot               # within-tile
    run = counts_ref[0, :][None, :]                          # [1, S]
    # select this element's column without a dynamic gather: the one-hot
    # row has a single 1 at the destination
    pos_ref[...] = jnp.sum((excl + run) * onehot, axis=1)
    counts_ref[0, :] += jnp.sum(onehot, axis=0)


def bucket_rank_pallas(dest: jax.Array, valid: jax.Array, n_buckets: int,
                       interpret: bool = True) -> jax.Array:
    """Stable position of each *valid* element within its destination
    bucket (invalid positions are 0 — callers mask with ``valid``).

    dest [N] int32 in [0, n_buckets); valid [N] bool. Tail-padded to the
    element tile, so any N works.
    """
    n = dest.shape[0]
    if n == 0:                       # zero-size grid is a pallas error
        return jnp.zeros((0,), jnp.int32)
    et = min(ELEM_TILE, max(8, n))
    n_pad = -(-n // et) * et
    pad = n_pad - n
    dest_p = jnp.pad(dest.astype(jnp.int32), (0, pad))
    valid_p = jnp.pad(valid.astype(jnp.int32), (0, pad))
    pos = pl.pallas_call(
        functools.partial(_rank_kernel, n_buckets=n_buckets),
        grid=(n_pad // et,),
        in_specs=[pl.BlockSpec((et,), lambda i: (i,)),
                  pl.BlockSpec((et,), lambda i: (i,))],
        out_specs=pl.BlockSpec((et,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, n_buckets), jnp.int32)],
        interpret=interpret,
    )(dest_p, valid_p)
    return pos[:n]


def bucket_rank_xla(dest: jax.Array, valid: jax.Array, n_buckets: int,
                    tile: int = SCAN_TILE) -> jax.Array:
    """The tiled-scan rank in plain XLA — the interpreter-free off-TPU
    lowering of :func:`bucket_rank_pallas` (same algorithm: within-tile
    ranks + per-destination running counts across tiles).

    O(N*tile + tiles*S) instead of the one-hot's O(N*S): the within-tile
    rank is an L*L equality compare and the cross-tile running counts are
    one scatter-add + one short cumsum — nothing N*S ever materializes.
    """
    n = dest.shape[0]
    c = -(-n // tile)
    pad = c * tile - n
    # sentinel bucket S for invalid/padding: equal only to other invalid
    key = jnp.where(valid, dest.astype(jnp.int32), n_buckets)
    key = jnp.pad(key, (0, pad), constant_values=n_buckets)
    keyc = key.reshape(c, tile)
    eq = keyc[:, :, None] == keyc[:, None, :]                # [C, L, L]
    lower = jnp.tril(jnp.ones((tile, tile), bool), -1)
    within = jnp.sum((eq & lower).astype(jnp.int32), -1)     # [C, L]
    seg = (jnp.repeat(jnp.arange(c, dtype=jnp.int32), tile)
           * (n_buckets + 1) + key)
    cnt = jax.ops.segment_sum(jnp.ones(c * tile, jnp.int32), seg,
                              num_segments=c * (n_buckets + 1)
                              ).reshape(c, n_buckets + 1)
    run = (jnp.cumsum(cnt, axis=0) - cnt).reshape(-1)        # excl per tile
    return (within.reshape(-1) + run[seg])[:n]


def bucket_rank(dest: jax.Array, valid: jax.Array, n_buckets: int
                ) -> jax.Array:
    """The deployed fast-path rank: Mosaic on TPU, XLA tile-scan off-TPU
    (one-hot for tiny bucket counts, where it wins — see module doc)."""
    if _on_tpu():
        return bucket_rank_pallas(dest, valid, n_buckets, interpret=False)
    if n_buckets < ONEHOT_MAX_BUCKETS:
        # narrow bucket counts: the one-hot cumsum is cheap and beats the
        # scan's fixed costs — the shared legacy formulation, so these
        # shapes are byte-for-byte the baseline path
        return onehot_rank(dest, valid, n_buckets)
    return bucket_rank_xla(dest, valid, n_buckets)


# ---------------------------------------------------------------------------
# sort-impl bucketing: one argsort, then gathers — no segment-sum scatter
# ---------------------------------------------------------------------------

def bucket_sort_gather(x_tasks, dest, valid, aux_ints, n_buckets, cap):
    """The whole ``bucket()`` contract off ONE stable argsort, with ``xb``
    built by *gathering* from the sorted stream instead of scattering.

    The sort path used to rank via argsort and then hand the kept tasks
    to the generic ``segment_sum`` slot scatter — paying a second
    O(N)-segment reduction just to materialize the bucket array. But the
    argsort already placed bucket ``b``'s tasks contiguously: output slot
    ``(b, p)`` is simply the task at sorted position
    ``bucket_start[b] + p`` (when that run is long enough), so ``xb`` and
    every aux column are plain gathers of shape O(n_buckets*cap) — the
    ROADMAP follow-up from the PR 5 kernel tier. Drop semantics are
    bit-identical to the one-hot path (first ``cap`` per channel in array
    order — stable argsort preserves array order within a bucket),
    differential-tested in tests/test_route_kernels.py.

    Returns ``(xb [n_buckets*cap, D] (or [n_buckets*cap] for 1-D input),
    ints, task_slot, n_drop)`` exactly like
    :func:`repro.core.routing.bucket`.
    """
    n = dest.shape[0]
    total = n_buckets * cap
    squeeze = x_tasks.ndim == 1
    x2 = x_tasks[:, None] if squeeze else x_tasks
    if n == 0:
        xb = jnp.zeros((total, x2.shape[1]), x2.dtype)
        return (xb[:, 0] if squeeze else xb,
                [jnp.full((total,), -1, jnp.int32) for _ in aux_ints],
                jnp.zeros((0,), jnp.int32), jnp.int32(0))
    # stable argsort by destination; invalid tasks sort to a sentinel
    key = jnp.where(valid, dest.astype(jnp.int32), n_buckets)
    order = jnp.argsort(key, stable=True)
    ks = key[order]
    run_start = jnp.searchsorted(ks, ks, side="left")
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - run_start.astype(jnp.int32)
    pos = jnp.zeros(n, jnp.int32).at[order].set(pos_sorted)
    # bucket run offsets -> slot (b, p) gathers sorted index start[b] + p
    bins = jnp.arange(n_buckets, dtype=jnp.int32)
    b_start = jnp.searchsorted(ks, bins, side="left")
    b_end = jnp.searchsorted(ks, bins, side="right")
    slot_b = jnp.repeat(bins, cap)                           # [total]
    slot_p = jnp.tile(jnp.arange(cap, dtype=jnp.int32), n_buckets)
    src_sorted = b_start[slot_b] + slot_p
    filled = src_sorted < b_end[slot_b]
    src = order[jnp.minimum(src_sorted, n - 1)]
    xb = jnp.where(filled[:, None], x2[src], 0).astype(x2.dtype)
    ints = [jnp.where(filled, a.astype(jnp.int32)[src], -1)
            for a in aux_ints]
    keep = valid & (pos < cap)
    task_slot = jnp.where(keep, dest * cap + jnp.minimum(pos, cap - 1), -1)
    n_drop = jnp.sum(valid & ~keep)
    return (xb[:, 0] if squeeze else xb), ints, task_slot, n_drop


# ---------------------------------------------------------------------------
# fused bucket-scatter: rank + capacity test + slot scatter in one pass
# ---------------------------------------------------------------------------

def _scatter_kernel(dest_ref, valid_ref, x_ref, aux_ref, xb_ref, ints_ref,
                    slot_ref, counts_ref, *, n_buckets, cap, elem_tile):
    i = pl.program_id(0)
    total = n_buckets * cap

    @pl.when(i == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)
        xb_ref[...] = jnp.zeros_like(xb_ref)
        ints_ref[...] = jnp.full_like(ints_ref, -1)

    def body(e, _):
        d = jnp.clip(dest_ref[e], 0, n_buckets - 1)
        v = valid_ref[e] != 0
        c = counts_ref[0, d]
        keep = v & (c < cap)
        # kept tasks land in their slot; dropped/invalid ones hit the
        # garbage row `total`, sliced off by the wrapper
        w = jnp.where(keep, d * cap + jnp.minimum(c, cap - 1), total)
        xb_ref[w, :] = x_ref[e, :]
        ints_ref[w, :] = aux_ref[e, :]
        slot_ref[e] = jnp.where(keep, w, -1)
        counts_ref[0, d] = c + v.astype(jnp.int32)
        return 0

    jax.lax.fori_loop(0, elem_tile, body, 0)


def bucket_scatter_pallas(x, dest, valid, aux_ints, n_buckets, cap,
                          interpret: bool = True):
    """Fused capacity-bounded bucketing: ONE pass over the task stream.

    Same contract as :func:`repro.core.routing.bucket` — returns
    ``(xb [n_buckets*cap, D], ints (list of [n_buckets*cap] int32, -1 =
    empty), task_slot [N] (-1 = dropped), n_drop)`` with the identical
    first-``cap``-per-channel admission in array order.
    """
    n, d_cols = x.shape
    total = n_buckets * cap
    if n == 0:                       # zero-size grid is a pallas error
        return (jnp.zeros((total, d_cols), x.dtype),
                [jnp.full((total,), -1, jnp.int32) for _ in aux_ints],
                jnp.zeros((0,), jnp.int32), jnp.int32(0))
    k = max(1, len(aux_ints))
    aux = (jnp.stack([a.astype(jnp.int32) for a in aux_ints], axis=1)
           if aux_ints else jnp.zeros((n, 1), jnp.int32))
    et = min(ELEM_TILE, max(8, n))
    n_pad = -(-n // et) * et
    pad = n_pad - n
    dest_p = jnp.pad(dest.astype(jnp.int32), (0, pad))
    valid_p = jnp.pad(valid.astype(jnp.int32), (0, pad))
    x_p = jnp.pad(x, ((0, pad), (0, 0)))
    aux_p = jnp.pad(aux, ((0, pad), (0, 0)))
    xb, ints, slot = pl.pallas_call(
        functools.partial(_scatter_kernel, n_buckets=n_buckets, cap=cap,
                          elem_tile=et),
        grid=(n_pad // et,),
        in_specs=[pl.BlockSpec((et,), lambda i: (i,)),
                  pl.BlockSpec((et,), lambda i: (i,)),
                  pl.BlockSpec((et, d_cols), lambda i: (i, 0)),
                  pl.BlockSpec((et, k), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((total + 1, d_cols), lambda i: (0, 0)),
                   pl.BlockSpec((total + 1, k), lambda i: (0, 0)),
                   pl.BlockSpec((et,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((total + 1, d_cols), x.dtype),
                   jax.ShapeDtypeStruct((total + 1, k), jnp.int32),
                   jax.ShapeDtypeStruct((n_pad,), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((1, n_buckets), jnp.int32)],
        interpret=interpret,
    )(dest_p, valid_p, x_p, aux_p)
    task_slot = slot[:n]
    n_drop = jnp.sum(valid) - jnp.sum(task_slot >= 0)
    ints_out = [ints[:total, j] for j in range(len(aux_ints))]
    return xb[:total], ints_out, task_slot, n_drop


# ---------------------------------------------------------------------------
# fused receive-reduce: apply the received stream at the owner
# ---------------------------------------------------------------------------

_REDUCE_INIT = {"add": 0.0, "min": float("inf"), "store": float("-inf")}


def _reduce_kernel(slot_ref, val_ref, y_ref, *, n_local, op, elem_tile):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        y_ref[...] = jnp.full_like(y_ref, _REDUCE_INIT[op])

    def body(e, _):
        s = slot_ref[e]
        w = jnp.clip(jnp.where(s >= 0, s, n_local), 0, n_local)
        v = val_ref[e]
        if op == "add":
            y_ref[w] += jnp.where(s >= 0, v, 0.0)
        elif op == "min":
            y_ref[w] = jnp.minimum(y_ref[w], jnp.where(s >= 0, v, jnp.inf))
        else:                                                # "store" (max)
            y_ref[w] = jnp.maximum(y_ref[w], jnp.where(s >= 0, v, -jnp.inf))
        return 0

    jax.lax.fori_loop(0, elem_tile, body, 0)


def reduce_received_pallas(recv_slot, recv_val, n_local, op,
                           interpret: bool = True):
    """Fused owner-side reduce — same contract as
    :func:`repro.core.routing.reduce_received` (add/min/store; ``store``
    keeps the deterministic max-value tie-break)."""
    if op not in _REDUCE_INIT:
        raise ValueError(op)
    n = recv_slot.shape[0]
    if n == 0:                       # zero-size grid is a pallas error
        return jnp.full((n_local,), jnp.inf if op == "min" else 0.0,
                        jnp.float32)
    et = min(ELEM_TILE, max(8, n))
    n_pad = -(-n // et) * et
    pad = n_pad - n
    slot_p = jnp.pad(recv_slot.astype(jnp.int32), (0, pad),
                     constant_values=-1)
    val_p = jnp.pad(recv_val.astype(jnp.float32), (0, pad))
    y = pl.pallas_call(
        functools.partial(_reduce_kernel, n_local=n_local, op=op,
                          elem_tile=et),
        grid=(n_pad // et,),
        in_specs=[pl.BlockSpec((et,), lambda i: (i,)),
                  pl.BlockSpec((et,), lambda i: (i,))],
        out_specs=pl.BlockSpec((n_local + 1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_local + 1,), jnp.float32),
        interpret=interpret,
    )(slot_p, val_p)[:n_local]
    if op == "min":
        return jnp.where(jnp.isfinite(y), y, jnp.inf)
    if op == "store":
        return jnp.where(jnp.isfinite(y), y, 0.0)
    return y
