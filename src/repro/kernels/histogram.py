"""Histogram Pallas TPU kernel — the paper's Histogram app, TPU-native.

Hardware adaptation (DESIGN.md §2): DCRA scatters (bin, +1) messages to the
bin's owner tile. A TPU has no scatter unit — the MXU-native rendering is
one-hot compare + matmul-reduce: each element block is compared against the
bin-id lane vector (VPU), and the resulting one-hot matrix is summed down
the element axis. Bins are tiled over the grid's second axis so arbitrarily
many bins stream through VMEM; elements tile over the first axis and
accumulate into the output block (revisited across steps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ELEM_TILE = 1024
BIN_TILE = 256


def _hist_kernel(elems_ref, out_ref, *, bin_tile):
    i = pl.program_id(0)       # element tile
    j = pl.program_id(1)       # bin tile

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    elems = elems_ref[...]                                  # [ET]
    base = j * bin_tile
    bins = base + jax.lax.broadcasted_iota(jnp.int32, (1, bin_tile), 1)
    onehot = (elems[:, None] == bins).astype(jnp.float32)   # [ET, BT]
    out_ref[...] += jnp.sum(onehot, axis=0).astype(out_ref.dtype)


def histogram_pallas(elements: jax.Array, n_bins: int,
                     interpret: bool = True) -> jax.Array:
    """elements: [N] int32 in [0, n_bins). Returns [n_bins] int32 counts.

    Any N / n_bins works: the element tail is padded with a -1 sentinel
    (matches no bin — negative ids are therefore also safe no-ops in the
    input itself, e.g. the task streams' padding entries) and the bin
    axis is padded to the bin tile and sliced off the result.
    """
    n = elements.shape[0]
    if n == 0:                       # zero-size grid is a pallas error
        return jnp.zeros((n_bins,), jnp.int32)
    et = min(ELEM_TILE, max(1, n))
    bt = min(BIN_TILE, n_bins)
    n_pad = -(-n // et) * et
    nb_pad = -(-n_bins // bt) * bt
    elems = jnp.pad(elements.astype(jnp.int32), (0, n_pad - n),
                    constant_values=-1)
    grid = (n_pad // et, nb_pad // bt)
    out = pl.pallas_call(
        functools.partial(_hist_kernel, bin_tile=bt),
        grid=grid,
        in_specs=[pl.BlockSpec((et,), lambda i, j: (i,))],
        out_specs=pl.BlockSpec((bt,), lambda i, j: (j,)),
        out_shape=jax.ShapeDtypeStruct((nb_pad,), jnp.int32),
        interpret=interpret,
    )(elems)
    return out[:n_bins]
