"""Histogram Pallas TPU kernel — the paper's Histogram app, TPU-native.

Hardware adaptation (DESIGN.md §2): DCRA scatters (bin, +1) messages to the
bin's owner tile. A TPU has no scatter unit — the MXU-native rendering is
one-hot compare + matmul-reduce: each element block is compared against the
bin-id lane vector (VPU), and the resulting one-hot matrix is summed down
the element axis. Bins are tiled over the grid's second axis so arbitrarily
many bins stream through VMEM; elements tile over the first axis and
accumulate into the output block (revisited across steps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ELEM_TILE = 1024
BIN_TILE = 256


def _hist_kernel(elems_ref, out_ref, *, bin_tile):
    i = pl.program_id(0)       # element tile
    j = pl.program_id(1)       # bin tile

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    elems = elems_ref[...]                                  # [ET]
    base = j * bin_tile
    bins = base + jax.lax.broadcasted_iota(jnp.int32, (1, bin_tile), 1)
    onehot = (elems[:, None] == bins).astype(jnp.float32)   # [ET, BT]
    out_ref[...] += jnp.sum(onehot, axis=0).astype(out_ref.dtype)


def histogram_pallas(elements: jax.Array, n_bins: int,
                     interpret: bool = True) -> jax.Array:
    """elements: [N] int32 in [0, n_bins). Returns [n_bins] int32 counts."""
    n = elements.shape[0]
    et = min(ELEM_TILE, n)
    bt = min(BIN_TILE, n_bins)
    assert n % et == 0 and n_bins % bt == 0
    grid = (n // et, n_bins // bt)
    out = pl.pallas_call(
        functools.partial(_hist_kernel, bin_tile=bt),
        grid=grid,
        in_specs=[pl.BlockSpec((et,), lambda i, j: (i,))],
        out_specs=pl.BlockSpec((bt,), lambda i, j: (j,)),
        out_shape=jax.ShapeDtypeStruct((n_bins,), jnp.int32),
        interpret=interpret,
    )(elements.astype(jnp.int32))
    return out
