"""Jit'd public wrappers for all Pallas kernels.

``interpret`` defaults to True off-TPU (this container) so kernels execute
via the Pallas interpreter for correctness; on TPU backends they lower to
Mosaic. The wrappers are the only entry points the rest of the framework
uses.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .histogram import histogram_pallas
from .moe_gmm import gmm_pallas
from .spmv import bsr_spmv_pallas, csr_to_bsr, spmv_csr


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("n_bins",))
def histogram(elements: jax.Array, n_bins: int) -> jax.Array:
    return histogram_pallas(elements, n_bins, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("causal",))
def flash_attention(q, k, v, causal: bool = True):
    """q,k,v: [B, H, S, hd] -> [B, H, S, hd]."""
    B, H, S, hd = q.shape
    f = lambda a: a.reshape(B * H, S, hd)
    out = flash_attention_pallas(f(q), f(k), f(v), causal=causal,
                                 interpret=not _on_tpu())
    return out.reshape(B, H, S, hd)


@jax.jit
def gmm(x, w, group_ids):
    return gmm_pallas(x, w, group_ids, interpret=not _on_tpu())


@jax.jit
def bsr_spmv(block_cols, blocks, x):
    return bsr_spmv_pallas(block_cols, blocks, x, interpret=not _on_tpu())


__all__ = ["histogram", "flash_attention", "gmm", "bsr_spmv", "csr_to_bsr",
           "spmv_csr"]
