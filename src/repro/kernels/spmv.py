"""Block-sparse-row SpMV Pallas TPU kernel — the paper's SPMV app.

Hardware adaptation (DESIGN.md §2): DCRA processes CSR nonzeros with
per-element task messages. The MXU equivalent blocks the matrix into
BS x BS dense tiles (BSR); each row-block streams its nonzero tiles through
VMEM and the needed x tile is fetched by a *scalar-prefetched* block-column
index — the data-dependent gather becomes a prefetched BlockSpec index map
(the TSU-prefetch analogue), and the multiply runs on the MXU.

Padding contract: rows of ``block_cols`` are padded with index 0 and
zero-valued blocks, so padded steps contribute nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..sparse.csr import CSR


def _spmv_kernel(bc_ref, blocks_ref, x_ref, y_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    a = blocks_ref[0, 0]                       # [BS, BS]
    xb = x_ref[...]                            # [BS]
    y_ref[...] += jnp.dot(a, xb, preferred_element_type=jnp.float32
                          ).astype(y_ref.dtype)


def bsr_spmv_pallas(block_cols: jax.Array, blocks: jax.Array, x: jax.Array,
                    interpret: bool = True) -> jax.Array:
    """block_cols [R, Kb] int32; blocks [R, Kb, BS, BS]; x [Ncb * BS]."""
    R, Kb, BS, _ = blocks.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(R, Kb),
        in_specs=[
            pl.BlockSpec((1, 1, BS, BS), lambda i, j, bc: (i, j, 0, 0)),
            pl.BlockSpec((BS,), lambda i, j, bc: (bc[i, j],)),
        ],
        out_specs=pl.BlockSpec((BS,), lambda i, j, bc: (i,)),
    )
    return pl.pallas_call(
        _spmv_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R * BS,), x.dtype),
        interpret=interpret,
    )(block_cols.astype(jnp.int32), blocks, x)


# ---------------------------------------------------------------------------
# CSR -> BSR conversion (host-side, numpy)
# ---------------------------------------------------------------------------

def csr_to_bsr(g: CSR, bs: int = 128):
    """Convert CSR to padded BSR arrays for the kernel."""
    n_rb = -(-g.n // bs)
    n_cb = -(-g.n // bs)
    rows = g.row_of()
    rb = rows // bs
    cb = g.col_idx // bs
    key = rb * n_cb + cb
    uniq = np.unique(key)
    # blocks per row-block (padded to the max)
    rb_of_blk = (uniq // n_cb).astype(np.int64)
    counts = np.bincount(rb_of_blk, minlength=n_rb)
    Kb = max(int(counts.max(initial=1)), 1)
    block_cols = np.zeros((n_rb, Kb), np.int32)
    blocks = np.zeros((n_rb, Kb, bs, bs), np.float32)
    slot_of_key = {}
    next_slot = np.zeros(n_rb, np.int64)
    for u in uniq:
        r = u // n_cb
        slot_of_key[u] = next_slot[r]
        block_cols[r, next_slot[r]] = u % n_cb
        next_slot[r] += 1
    slots = np.array([slot_of_key[k] for k in key], np.int64)
    blocks[rb, slots, rows % bs, g.col_idx % bs] = g.values
    return jnp.asarray(block_cols), jnp.asarray(blocks)


def spmv_csr(g: CSR, x: np.ndarray, bs: int = 128,
             interpret: bool = True) -> jax.Array:
    """End-to-end: CSR graph x dense vector via the BSR kernel."""
    bc, blocks = csr_to_bsr(g, bs)
    n_pad = ((g.n + bs - 1) // bs) * bs
    xp = jnp.zeros((n_pad,), jnp.float32).at[:g.n].set(
        jnp.asarray(x, jnp.float32))
    y = bsr_spmv_pallas(bc, blocks, xp, interpret=interpret)
    return y[:g.n]
