"""``python -m repro.dse.serve_compare OLD.json NEW.json`` — serving
trajectory gate (sibling of :mod:`repro.dse.route_compare`, for the
wall-clock ``dcra-serve-bench/v1`` artifact ``BENCH_serve.json``).

Absolute req/s do not transfer across machines (the committed baseline
is produced on a dev box, CI runs on shared runners), so the gate
compares what IS machine-portable — the within-run ratio:

* ``overlap_speedup``: the overlapped drain's throughput over the
  synchronous drain's, measured back-to-back in the same run on the
  same stream. This is the headline win of the inflight launch window
  (``ServeOptions.inflight_depth``); if pipelined serving stops beating
  the synchronous loop, that is a code regression, not runner noise.

The new bench fails the build when its ``overlap_speedup`` falls more
than ``--tol`` (default 15%) below the committed baseline's, and both
benches must carry a sync AND an overlapped row (silent coverage loss
is a failure). Speedups only compare within one backend.

Exit codes: 0 ok; 1 bad input; 2 regression.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Sequence, Tuple

SCHEMA = "dcra-serve-bench/v1"
REQUIRED_MODES = ("sync", "overlapped")


def compare(old: Dict, new: Dict, tol: float = 0.15
            ) -> Tuple[List[str], List[str]]:
    """Returns (failures, notes); empty failures == trajectory ok."""
    failures: List[str] = []
    notes: List[str] = []
    for name, bench in (("old", old), ("new", new)):
        modes = {r.get("mode") for r in bench.get("rows", [])}
        missing = [m for m in REQUIRED_MODES if m not in modes]
        if missing:
            failures.append(f"{name} bench is missing {missing} row(s)")
    if failures:
        return failures, notes
    if old.get("backend") != new.get("backend"):
        return [f"backend mismatch: baseline {old.get('backend')!r} vs "
                f"new {new.get('backend')!r} — regenerate the committed "
                f"baseline on the comparison backend"], notes
    so = float(old["overlap_speedup"])
    sn = float(new["overlap_speedup"])
    line = (f"overlap_speedup: {so:.2f}x -> {sn:.2f}x "
            f"(depth={new.get('config', {}).get('depth')})")
    if sn < so * (1.0 - tol):
        failures.append(f"{line}  REGRESSED beyond tol={tol:.0%}")
    else:
        notes.append(line)
    for row in new["rows"]:
        if row.get("re_traces", 0) != 0:
            failures.append(f"{row['mode']} row re-traced "
                            f"{row['re_traces']} kernels under load")
    return failures, notes


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("old", help="committed baseline BENCH_serve.json")
    ap.add_argument("new", help="freshly-benched BENCH_serve.json")
    ap.add_argument("--tol", type=float, default=0.15,
                    help="relative speedup regression tolerance "
                         "(default 15%%)")
    args = ap.parse_args(argv)
    try:
        with open(args.old) as f:
            old = json.load(f)
        with open(args.new) as f:
            new = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"[dse.serve_compare] bad input: {e}", file=sys.stderr)
        return 1
    for name, bench in (("old", old), ("new", new)):
        if bench.get("schema") != SCHEMA:
            print(f"[dse.serve_compare] bad input: {name} schema "
                  f"{bench.get('schema')!r} != {SCHEMA!r}",
                  file=sys.stderr)
            return 1
    failures, notes = compare(old, new, tol=args.tol)
    for line in notes:
        print(f"[dse.serve_compare] {line}")
    for line in failures:
        print(f"[dse.serve_compare] FAIL: {line}", file=sys.stderr)
    return 2 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
