"""n-dimensional Pareto frontier extraction (paper §V: the sweep's output
is not one winner but the (TEPS, watts, $/package) frontier per app).

Conventions: an *objective spec* is a sequence of (key, direction) pairs,
direction ``"max"`` or ``"min"``. Records may be dicts or objects —
``key`` is looked up with ``record[key]`` / ``getattr``. Ties: a point is
dominated only by a point strictly better in ≥ 1 objective and no worse in
all others; duplicate metric vectors therefore all survive.
"""
from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import numpy as np

ObjectiveSpec = Sequence[Tuple[str, str]]

DEFAULT_OBJECTIVES: ObjectiveSpec = (
    ("teps", "max"), ("watts", "min"), ("package_usd", "min"))


def _get(rec: Any, key: str):
    if isinstance(rec, dict):
        return rec[key]
    return getattr(rec, key)


def _signed_matrix(records: Sequence[Any],
                   objectives: ObjectiveSpec) -> np.ndarray:
    """[n, k] matrix with every objective flipped to maximise."""
    cols = []
    for key, direction in objectives:
        if direction not in ("max", "min"):
            raise ValueError(f"direction must be max|min, got {direction!r}")
        sign = 1.0 if direction == "max" else -1.0
        cols.append(sign * np.asarray([float(_get(r, key))
                                       for r in records]))
    return np.stack(cols, axis=1)


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """True iff maximise-vector ``a`` Pareto-dominates ``b``."""
    a, b = np.asarray(a, float), np.asarray(b, float)
    return bool(np.all(a >= b) and np.any(a > b))


def pareto_indices(values: np.ndarray) -> np.ndarray:
    """Indices of the non-dominated rows of a maximise-matrix [n, k]."""
    v = np.asarray(values, float)
    n = v.shape[0]
    keep = np.ones(n, bool)
    for i in range(n):
        if not keep[i]:
            continue
        ge = np.all(v >= v[i], axis=1)
        gt = np.any(v > v[i], axis=1)
        if np.any(ge & gt):
            keep[i] = False
    return np.flatnonzero(keep)


def pareto_frontier(records: Sequence[Any],
                    objectives: ObjectiveSpec = DEFAULT_OBJECTIVES
                    ) -> List[int]:
    """Indices of the Pareto-optimal records under ``objectives``."""
    if not len(records):
        return []
    return pareto_indices(_signed_matrix(records, objectives)).tolist()
