"""Declarative design space over DCRA's three configuration axes (§V–§VI).

A :class:`DesignPoint` is one fully-specified deployment:

* **pre-silicon** (fixed at die tapeout): tiles per die edge
  (``die_side``), NoC link width / frequency, SRAM per tile, PUs per tile;
* **package-time** (fixed at assembly): memory technology (pure-SRAM
  scratchpad vs HBM-backed cache, constants from
  :data:`repro.costmodel.params.MEM`), DCRA dies per package;
* **compile-time** (free per launch): deployment grid (``grid_side`` —
  how many tiles the dataset is spread over), NoC topology (any of
  :data:`repro.core.topology.TOPOLOGIES` — the software-reconfigurability
  claim), and input/output task-queue capacities (Table II knob #8).

A :class:`ConfigSpace` enumerates the cartesian product of per-axis value
tuples, filtered for geometric validity. Points convert losslessly to the
existing model types (``TileGrid`` / ``EngineConfig``) so the figure
benchmarks, the sweep CLI, and tests all share one code path.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from dataclasses import dataclass
from typing import Dict, Iterator, Tuple

from ..core.cache import DRAMConfig, SRAMConfig
from ..core.queues import QueueConfig
from ..core.task_engine import EngineConfig
from ..core.topology import TOPOLOGIES, TileGrid
from ..costmodel.params import MEM
from ..costmodel.silicon import PackageCost, dcra_die_area_mm2, package_cost

# Package-time memory technologies, parameterised from Table III (MEM).
# "sram": pure scratchpad (Dalorex-style, everything resident);
# "hbm":  per-die HBM device behind the reconfigurable SRAM cache.
MEM_TECHS: Dict[str, DRAMConfig] = {
    "sram": DRAMConfig(present=False),
    "hbm": DRAMConfig(present=True, channels=MEM.hbm_channels,
                      gbps_per_channel=MEM.hbm_gbps_per_channel),
}


@dataclass(frozen=True)
class DesignPoint:
    # ---- pre-silicon -----------------------------------------------------
    die_side: int = 16                 # tiles per die edge (die_side^2/die)
    noc_width_bits: int = 64
    noc_freq_ghz: float = 1.0
    sram_kb_per_tile: int = 512
    pus_per_tile: int = 1
    # ---- package-time ----------------------------------------------------
    mem_tech: str = "hbm"              # key into MEM_TECHS
    dies_per_package: int = 4
    # ---- compile-time ----------------------------------------------------
    grid_side: int = 32                # deployment: grid_side^2 tiles
    topology: str = "hier_torus"
    iq_capacity: int = 12              # per-channel input queue (tasks/round)
    oq_capacity: int = 12              # producer output queue (T3)
    # The MoE dispatch IQ knob (relative sizing; ROADMAP fold-in: the
    # dispatch capacity factor IS the IQ axis, routed via QueueConfig).
    moe_capacity_factor: float = 1.25

    def __post_init__(self):
        if self.topology not in TOPOLOGIES:
            raise ValueError(f"unknown topology {self.topology!r}")
        if self.mem_tech not in MEM_TECHS:
            raise ValueError(f"unknown mem_tech {self.mem_tech!r}")

    # ---- conversions -----------------------------------------------------
    def grid(self) -> TileGrid:
        return TileGrid(self.grid_side, self.grid_side,
                        topology=self.topology,
                        die_rows=self.die_side, die_cols=self.die_side,
                        noc_width_bits=self.noc_width_bits,
                        noc_freq_ghz=self.noc_freq_ghz)

    def engine_config(self) -> EngineConfig:
        """The point as an ``EngineConfig``.

        ``QueueConfig`` is the single IQ source of truth: ``TaskEngine
        .route`` reads ``queues.iq(task)`` per round, so this point's
        ``iq_capacity`` bounds the analytic drop model directly — figure
        baselines are pinned under bounded-IQ physics since PR 3.
        """
        return EngineConfig(
            grid=self.grid(),
            queues=QueueConfig(default_iq=self.iq_capacity,
                               default_oq=self.oq_capacity,
                               oq_sizes={"T3": self.oq_capacity}),
            sram=SRAMConfig(kb_per_tile=self.sram_kb_per_tile),
            dram=MEM_TECHS[self.mem_tech],
            pus_per_tile=self.pus_per_tile)

    # ---- derived geometry / economics ------------------------------------
    @property
    def n_tiles(self) -> int:
        return self.grid_side ** 2

    @property
    def n_dies(self) -> int:
        return max(1, self.grid_side // self.die_side) ** 2

    @property
    def n_packages(self) -> int:
        return math.ceil(self.n_dies / self.dies_per_package)

    def die_area_mm2(self) -> float:
        return dcra_die_area_mm2(self.die_side ** 2, self.sram_kb_per_tile,
                                 self.pus_per_tile, self.noc_width_bits,
                                 self.noc_freq_ghz)

    def package_bill(self) -> PackageCost:
        """Cost of ONE (full) package at this point (the paper's $/package)."""
        dies = min(self.dies_per_package, self.n_dies)
        dram = MEM_TECHS[self.mem_tech]
        hbm_gb = dram.gb_per_die * dies if dram.present else 0.0
        return package_cost(dies, self.die_area_mm2(), hbm_gb)

    def moe_queues(self) -> QueueConfig:
        """The point's MoE dispatch sizing as a ``QueueConfig`` (pass to
        ``moe_dcra(..., queues=...)``) — same resolution path as the graph
        apps, no parallel capacity-factor knob."""
        return QueueConfig.for_moe_dispatch(self.moe_capacity_factor)

    def package_usd(self) -> float:
        return self.package_bill().total

    def system_usd(self) -> float:
        """Whole-deployment cost: packages are bought whole."""
        return self.package_usd() * self.n_packages

    # ---- identity / serialisation ----------------------------------------
    @property
    def stats_key(self) -> Tuple:
        """The sub-key that determines ``RunStats`` (routing is blind to
        link width/frequency, memory tech and OQ size — those only re-price
        the same task stream, the paper's decoupled-cost design)."""
        return (self.grid_side, self.die_side, self.topology,
                self.iq_capacity)

    @property
    def point_id(self) -> str:
        return (f"g{self.grid_side}_d{self.die_side}_{self.topology}"
                f"_w{self.noc_width_bits}_f{self.noc_freq_ghz:g}"
                f"_{self.mem_tech}_p{self.dies_per_package}"
                f"_s{self.sram_kb_per_tile}_iq{self.iq_capacity}"
                f"_oq{self.oq_capacity}_mcf{self.moe_capacity_factor:g}")

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "DesignPoint":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    def with_(self, **kw) -> "DesignPoint":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ConfigSpace:
    """Cartesian product of per-axis value tuples (invalid combos skipped).

    A combo is valid when the deployment grid tiles cleanly into dies
    (``grid_side % die_side == 0``); single-die deployments smaller than a
    die are allowed (``grid_side == die_side`` covers them).
    """
    # pre-silicon
    die_sides: Tuple[int, ...] = (16, 32)
    noc_width_bits: Tuple[int, ...] = (32, 64)
    noc_freq_ghz: Tuple[float, ...] = (1.0, 2.0)
    sram_kb_per_tile: Tuple[int, ...] = (512,)
    pus_per_tile: Tuple[int, ...] = (1,)
    # package-time
    mem_techs: Tuple[str, ...] = ("sram", "hbm")
    dies_per_package: Tuple[int, ...] = (4, 16)
    # compile-time
    grid_sides: Tuple[int, ...] = (32, 64)
    topologies: Tuple[str, ...] = TOPOLOGIES
    iq_capacities: Tuple[int, ...] = (12, 48)
    oq_capacities: Tuple[int, ...] = (12, 48)
    # MoE-only axis: consumed via DesignPoint.moe_queues() -> moe_dcra.
    # The graph-app Evaluator is blind to it, so widen this tuple only in
    # sweeps that actually run MoE cells — for graph-only sweeps extra
    # values just duplicate every record (identical metrics, and Pareto
    # keeps duplicate optima by design).
    moe_capacity_factors: Tuple[float, ...] = (1.25,)

    def points(self) -> Iterator[DesignPoint]:
        for (die, w, f, kb, pus, mem, dpp, side, topo, iq, oq, mcf) in \
                itertools.product(self.die_sides, self.noc_width_bits,
                                  self.noc_freq_ghz, self.sram_kb_per_tile,
                                  self.pus_per_tile, self.mem_techs,
                                  self.dies_per_package, self.grid_sides,
                                  self.topologies, self.iq_capacities,
                                  self.oq_capacities,
                                  self.moe_capacity_factors):
            if side % die != 0:
                continue
            yield DesignPoint(die_side=die, noc_width_bits=w,
                              noc_freq_ghz=f, sram_kb_per_tile=kb,
                              pus_per_tile=pus, mem_tech=mem,
                              dies_per_package=dpp, grid_side=side,
                              topology=topo, iq_capacity=iq, oq_capacity=oq,
                              moe_capacity_factor=mcf)

    def __len__(self) -> int:
        return sum(1 for _ in self.points())

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def quick(cls) -> "ConfigSpace":
        """CI-sized space: 24 points, every axis still exercised by ≥ 2
        values somewhere (topology ×3, width ×2, mem tech ×2, IQ ×2)."""
        return cls(die_sides=(16,), noc_width_bits=(32, 64),
                   noc_freq_ghz=(1.0,), sram_kb_per_tile=(512,),
                   mem_techs=("sram", "hbm"), dies_per_package=(4,),
                   grid_sides=(32,), topologies=TOPOLOGIES,
                   iq_capacities=(12, 48), oq_capacities=(12,))

    @classmethod
    def full(cls) -> "ConfigSpace":
        """The nightly sweep space (paper §V axes)."""
        return cls()
