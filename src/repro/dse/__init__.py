"""Design-space exploration engine (paper §V–§VI).

The paper's central claim is a *framework*: sweep pre-silicon (die grid,
NoC width/frequency), package-time (dies + memory tech per package) and
compile-time (topology, deployment grid, queue sizing) configurations,
evaluate each point through the analytic stack (task engine → perf →
energy → silicon cost), and pick Pareto-optimal deployments per
application. This package composes the ingredients the rest of the repo
already has into that loop:

* :mod:`repro.dse.space`     — declarative ``ConfigSpace`` / ``DesignPoint``
  over the three configuration axes;
* :mod:`repro.dse.evaluate`  — ``Evaluator``: analytic evaluation of a point
  for the seven apps × bundled datasets (stats cached across points that
  share the simulation-relevant sub-key, the paper's decoupled re-pricing);
* :mod:`repro.dse.pareto`    — n-dimensional Pareto frontier extraction;
* :mod:`repro.dse.driver`    — generic resumable sweep driver (also the
  engine behind ``launch/dryrun.py`` and ``launch/hillclimb.py``);
* :mod:`repro.dse.shardcheck`— subprocess worker re-validating analytic
  message/drop counts on the real ``shard_map`` executables;
* :mod:`repro.dse.sweep`     — ``python -m repro.dse.sweep`` CLI emitting
  the tracked ``BENCH_dse.json`` perf trajectory;
* :mod:`repro.dse.autoconfig`— Pareto-guided *launch-time* selection: the
  ``dcra_*`` apps' ``config="auto"`` picks a frontier point for the
  dataset at hand (signature matching + interpolation, mini-sweep
  fallback);
* :mod:`repro.dse.compare`   — ``python -m repro.dse.compare`` trajectory
  regression gate between successive ``BENCH_dse.json`` artifacts.
"""
from .autoconfig import (BASELINE, DatasetSignature,            # noqa: F401
                         DispatchLoadSignature, LaunchConfig,
                         autoconfigure, autoconfigure_moe, launch_for,
                         moe_dispatch_signature, signature_of)
from .evaluate import (APPS, ConfigResult, Evaluator, PointResult,  # noqa: F401
                       config_cost, evaluate, geomean, load_datasets,
                       run_app)
from .pareto import dominates, pareto_frontier, pareto_indices  # noqa: F401
from .space import MEM_TECHS, ConfigSpace, DesignPoint          # noqa: F401
