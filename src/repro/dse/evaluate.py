"""Analytic evaluation of design points (TaskEngine → perf → energy → $).

Two layers:

* the flat helpers (``run_app`` / ``evaluate`` / ``config_cost``) — run any
  ``EngineConfig`` through the analytic stack for one app × dataset; these
  are the primitives the figure benchmarks (``benchmarks/common.py``) have
  always used, now owned here so figure reproduction and DSE share one
  code path;
* :class:`Evaluator` — evaluates :class:`~repro.dse.space.DesignPoint`\\ s
  across apps × datasets with **stats caching**: routing statistics depend
  only on ``DesignPoint.stats_key`` (grid, die size, topology, IQ), so
  points differing only in link width/frequency, memory tech, SRAM or OQ
  re-price a cached task stream instead of re-simulating it — the paper's
  own decoupling of simulation from cost (§IV-C).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.cache import DRAMConfig, SRAMConfig  # noqa: F401  (re-export)
from ..core.task_engine import EngineConfig, RunStats, TaskEngine
from ..costmodel import (dcra_die_area_mm2, package_cost, run_energy,
                         run_perf)
from ..sparse import apps, datasets
from .space import DesignPoint

APPS = ("sssp", "pagerank", "bfs", "wcc", "spmv", "histogram", "kcore")

# the k the analytic sweep peels at (deterministic; chosen so both quick
# datasets peel a real fraction of their vertices — a k no dataset peels
# at would make the kcore cell zero-traffic and its TEPS meaningless)
KCORE_K = 16


def load_datasets(scale: int = 12) -> Dict[str, object]:
    """The bundled dataset pair: RMAT-<scale> + a Wikipedia-like graph."""
    return {
        f"R{scale}": datasets.rmat(scale, edge_factor=16, seed=1),
        "WK": datasets.wiki_like(1 << (scale - 1), avg_degree=25),
    }


def run_app(app: str, engine: TaskEngine, g, rng_seed: int = 0):
    if app == "bfs":
        return apps.bfs(engine, g, root=0)
    if app == "sssp":
        return apps.sssp(engine, g, root=0)
    if app == "pagerank":
        return apps.pagerank(engine, g, iters=5)
    if app == "wcc":
        return apps.wcc(engine, g)
    if app == "spmv":
        x = np.random.default_rng(rng_seed).random(g.n)
        return apps.spmv(engine, g, x)
    if app == "histogram":
        if hasattr(g, "nnz"):      # graph stand-in: synthesize a stream
            els = datasets.histogram_data(g.nnz, max(g.n // 16, 64))
            return apps.histogram(engine, els, max(g.n // 16, 64))
        els = np.asarray(g)        # a raw element stream IS the dataset
        return apps.histogram(engine, els, max(int(els.max()) + 1, 64))
    if app == "kcore":
        if not hasattr(g, "nnz"):
            raise ValueError("kcore needs a graph dataset")
        return apps.kcore(engine, g, k=KCORE_K)
    raise ValueError(app)


@dataclass
class ConfigResult:
    teps: float
    teps_per_watt: float
    teps_per_dollar: float
    seconds: float
    energy_j: float
    cost_usd: float
    hops: int
    drops: int = 0
    messages: int = 0
    breakdown: object = None


def config_cost(cfg: EngineConfig) -> float:
    """One package holding every die of the deployment (legacy figure
    costing; :meth:`DesignPoint.package_bill` adds the dies-per-package
    axis on top of the same silicon model)."""
    g = cfg.grid
    tiles_per_die = g.die_rows * g.die_cols
    n_dies = max(1, g.n_tiles // tiles_per_die)
    area = dcra_die_area_mm2(tiles_per_die, cfg.sram.kb_per_tile,
                             cfg.pus_per_tile, g.noc_width_bits,
                             g.noc_freq_ghz)
    hbm_gb = cfg.dram.gb_per_die * n_dies if cfg.dram.present else 0.0
    return package_cost(n_dies, area, hbm_gb).total


def _dataset_terms(g) -> Tuple[int, float, float]:
    edges = g.nnz if hasattr(g, "nnz") else len(g)
    dbytes = g.memory_bytes() if hasattr(g, "memory_bytes") else edges * 8
    fanout = edges / max(getattr(g, "n", 1), 1)
    return edges, dbytes, fanout


def _price(stats: RunStats, cfg: EngineConfig, g,
           cost_usd: float) -> ConfigResult:
    edges, dbytes, fanout = _dataset_terms(g)
    perf = run_perf(stats, cfg, edges, dataset_bytes=dbytes, fanout=fanout)
    en = run_energy(stats, cfg, dataset_bytes=dbytes)
    watts = en.total_j / max(perf.seconds, 1e-12)
    return ConfigResult(
        teps=perf.teps,
        teps_per_watt=perf.teps / max(watts, 1e-12),
        teps_per_dollar=perf.teps / max(cost_usd, 1e-12),
        seconds=perf.seconds, energy_j=en.total_j, cost_usd=cost_usd,
        hops=stats.total_hops, drops=stats.total_drops,
        messages=stats.total_messages, breakdown=en)


def evaluate(cfg: EngineConfig, g, app: str,
             cost_usd: Optional[float] = None) -> ConfigResult:
    """Run one (config, dataset, app) cell through the analytic stack.

    Queue physics comes from ``cfg.queues`` alone: ``TaskEngine.route``
    bounds every round at ``queues.iq(task)``, so the figure benchmarks
    and the DSE sweep price the same bounded-IQ drop model (baselines
    re-pinned under it in PR 3; ``QueueConfig.unbounded()`` restores the
    legacy stats when needed).
    """
    engine = TaskEngine(cfg, getattr(g, "n", len(np.atleast_1d(g))))
    _, stats = run_app(app, engine, g)
    if cost_usd is None:
        cost_usd = config_cost(cfg)
    return _price(stats, cfg, g, cost_usd)


def geomean(vals: List[float]) -> float:
    vals = [max(v, 1e-12) for v in vals]
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


# ---------------------------------------------------------------------------
# DesignPoint evaluation
# ---------------------------------------------------------------------------

@dataclass
class PointResult:
    """Aggregate metrics of one design point over apps × datasets."""
    point: DesignPoint
    teps: float                     # geomean over cells
    watts: float                    # geomean over cells
    package_usd: float
    system_usd: float
    teps_per_watt: float
    teps_per_usd: float             # vs system cost
    seconds: float                  # geomean
    energy_j: float                 # total
    drops: int                      # total modeled IQ overflow
    messages: int
    per_cell: Dict[str, ConfigResult] = field(default_factory=dict)

    def to_dict(self) -> Dict:
        return {
            "point_id": self.point.point_id,
            "config": self.point.to_dict(),
            "metrics": {
                "teps_geomean": self.teps,
                "watts_geomean": self.watts,
                "package_usd": self.package_usd,
                "system_usd": self.system_usd,
                "teps_per_watt": self.teps_per_watt,
                "teps_per_usd": self.teps_per_usd,
                "seconds_geomean": self.seconds,
                "energy_j_total": self.energy_j,
                "drops_total": self.drops,
                "messages_total": self.messages,
            },
            "per_cell": {
                cell: {"teps": r.teps, "seconds": r.seconds,
                       "energy_j": r.energy_j, "drops": r.drops,
                       "messages": r.messages, "hops": r.hops}
                for cell, r in self.per_cell.items()
            },
        }


class Evaluator:
    """Evaluate design points analytically, caching routed task streams.

    ``datasets``: name → CSR (or element array); ``apps_list``: subset of
    :data:`APPS`. ``stats_for`` is also the hook the revalidation worker
    uses to get the exact analytic stream of a top-K winner.
    """

    def __init__(self, data: Dict[str, object],
                 apps_list: Sequence[str] = APPS):
        self.data = data
        self.apps_list = tuple(apps_list)
        self._stats: Dict[Tuple, RunStats] = {}

    def stats_for(self, point: DesignPoint, app: str,
                  dname: str) -> RunStats:
        key = point.stats_key + (app, dname)
        if key not in self._stats:
            g = self.data[dname]
            # the point's IQ axis flows through engine_config().queues —
            # QueueConfig is the only capacity source
            engine = TaskEngine(point.engine_config(),
                                getattr(g, "n", len(np.atleast_1d(g))))
            run_app(app, engine, g)
            self._stats[key] = engine.stats
        return self._stats[key]

    def evaluate_point(self, point: DesignPoint) -> PointResult:
        cfg = point.engine_config()
        system_usd = point.system_usd()
        per_cell: Dict[str, ConfigResult] = {}
        for dname, g in self.data.items():
            for app in self.apps_list:
                stats = self.stats_for(point, app, dname)
                per_cell[f"{app}:{dname}"] = _price(stats, cfg, g,
                                                    system_usd)
        teps = geomean([r.teps for r in per_cell.values()])
        watts = geomean([r.energy_j / max(r.seconds, 1e-12)
                         for r in per_cell.values()])
        return PointResult(
            point=point,
            teps=teps, watts=watts,
            package_usd=point.package_usd(), system_usd=system_usd,
            teps_per_watt=teps / max(watts, 1e-12),
            teps_per_usd=teps / max(system_usd, 1e-12),
            seconds=geomean([r.seconds for r in per_cell.values()]),
            energy_j=sum(r.energy_j for r in per_cell.values()),
            drops=sum(r.drops for r in per_cell.values()),
            messages=sum(r.messages for r in per_cell.values()),
            per_cell=per_cell)
