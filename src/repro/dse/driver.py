"""Generic resumable sweep driver.

One loop, three clients: the DSE sweep CLI, the dry-run lowering grid
(``launch/dryrun.py``) and the perf hill-climber (``launch/hillclimb.py``)
all iterate "evaluate a config point, record a dict, skip what's done,
never let one failure kill the sweep". This module owns that loop:

* each unit of work is a :class:`SweepTask` — a dedup ``key``, a ``run``
  thunk returning the result record, and static ``meta`` merged into the
  record (also the error record, so failures stay attributable);
* :func:`run_sweep` resumes from an existing JSON list (``key_of`` maps
  previously-written records back to task keys), appends one record per
  task, and rewrites the file after every task so a crash loses at most
  the in-flight point.

Every record — error records included — is stamped with its ``task_key``,
so resume never depends on ``key_of`` being able to reconstruct a key from
a failure payload (the pre-PR-3 bug: error records carried no key, so
errored points were silently re-run on every resume while their stale
error records piled up in the file). Re-running failures is now an
explicit choice: ``retry_errors=True`` drops the matching error records
and runs those tasks again; the default treats them as done.
"""
from __future__ import annotations

import json
import os
import traceback
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence


@dataclass
class SweepTask:
    key: str
    run: Callable[[], Dict]
    meta: Dict = field(default_factory=dict)


def load_results(out: Optional[str]) -> List[Dict]:
    if out and os.path.exists(out):
        with open(out) as f:
            return json.load(f)
    return []


def _write(out: Optional[str], results: List[Dict]) -> None:
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=1)


def record_key(rec: Dict,
               key_of: Optional[Callable[[Dict], Optional[str]]] = None
               ) -> Optional[str]:
    """A record's task key: the stamped ``task_key`` wins, ``key_of`` is
    the fallback for files written before stamping existed."""
    key = rec.get("task_key")
    if key is None and key_of is not None:
        key = key_of(rec)
    return key


def run_sweep(tasks: Iterable[SweepTask], out: Optional[str] = None,
              resume: bool = True,
              key_of: Optional[Callable[[Dict], Optional[str]]] = None,
              verbose: bool = True,
              raise_errors: bool = False,
              retry_errors: bool = False) -> List[Dict]:
    """Run every task not already recorded; returns the full record list.

    ``out=None`` keeps everything in memory (single-shot sweeps that
    post-process before writing, e.g. the BENCH emitter). Every record is
    stamped with its ``task_key`` so errored points resume as *done*;
    ``retry_errors=True`` re-runs them instead (their stale error records
    are dropped, not duplicated).
    """
    tasks = list(tasks)
    results = load_results(out) if resume else []
    if retry_errors:
        keys = {t.key for t in tasks}
        results = [r for r in results
                   if not ("error" in r and record_key(r, key_of) in keys)]
    done = {record_key(r, key_of) for r in results}
    for task in tasks:
        if task.key in done:
            continue
        try:
            rec = dict(task.run())
        except Exception as e:  # record and continue — sweeps must finish
            if raise_errors:
                raise
            traceback.print_exc()
            rec = {"error": f"{type(e).__name__}: {e}"}
        rec.setdefault("task_key", task.key)
        for k, v in task.meta.items():
            rec.setdefault(k, v)
        results.append(rec)
        done.add(task.key)
        _write(out, results)
        if verbose and "error" in rec:
            print(f"[sweep] {task.key}: ERROR {rec['error']}", flush=True)
    return results


def summarize(results: Sequence[Dict], ok_field: str) -> str:
    ok = sum(1 for r in results if ok_field in r)
    skip = sum(1 for r in results if "skipped" in r)
    err = sum(1 for r in results if "error" in r)
    return f"{ok} ok, {skip} skipped, {err} errors"
