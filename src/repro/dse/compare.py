"""``python -m repro.dse.compare OLD.json NEW.json`` — frontier trajectory
regression gate (ROADMAP: "compare successive nightly BENCH_dse.json
artifacts to flag trajectory regressions").

Two ``dcra-dse-bench`` files are compared on what the frontier *delivers*,
not on point identity (point-id formats may evolve across PRs):

* **per-objective bests** over the Pareto set — max TEPS, min watts, min
  $/package, max TEPS/$ — each must not regress beyond ``--tol``
  (relative);
* **common frontier points** (matched by point_id) are reported
  individually; a common point whose TEPS geomean regressed beyond the
  tolerance is a failure too (the same hardware point got slower — a
  model change, not a frontier shift);
* **per-app frontier bests** (schema v2 ``app_frontiers``): when BOTH
  files record app-specific Pareto slices, each common app's best slice
  TEPS must not regress beyond the tolerance either; a file pair mixing
  v1 and v2 skips this leg with a note (the nightly's previous artifact
  may predate the slices);
* structural drift (points only in one file, frontier size change) is
  reported but informational.

Accepts both tracked schemas (``dcra-dse-bench/v1`` and ``/v2``) on
either side.

Exit codes: 0 ok; 1 bad input; 2 frontier regression.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Dict, List, Optional, Sequence, Tuple

SCHEMAS = ("dcra-dse-bench/v1", "dcra-dse-bench/v2")

# (name, metrics key, direction): the sweep's objective axes
OBJECTIVE_BESTS: Tuple[Tuple[str, str, str], ...] = (
    ("teps", "teps_geomean", "max"),
    ("watts", "watts_geomean", "min"),
    ("package_usd", "package_usd", "min"),
    ("teps_per_usd", "teps_per_usd", "max"),
)


def frontier_metrics(bench: Dict) -> Dict[str, Dict]:
    """point_id -> metrics for the Pareto records of a bench file."""
    return {r["point_id"]: r["metrics"] for r in bench.get("points", [])
            if r.get("pareto") and "metrics" in r}


def objective_bests(frontier: Dict[str, Dict]) -> Dict[str, float]:
    out = {}
    for name, key, direction in OBJECTIVE_BESTS:
        vals = [m[key] for m in frontier.values() if key in m]
        if vals:
            out[name] = max(vals) if direction == "max" else min(vals)
    return out


def _regressed(name: str, old: float, new: float, tol: float) -> bool:
    direction = {n: d for n, _, d in OBJECTIVE_BESTS}[name]
    if direction == "max":
        return new < old * (1.0 - tol)
    return new > old * (1.0 + tol)


def app_bests(bench: Dict) -> Dict[str, float]:
    """app -> best per-app TEPS geomean over that app's frontier slice
    (empty when the bench predates schema v2's ``app_frontiers``)."""
    fronts = bench.get("app_frontiers") or {}
    by_id = {r["point_id"]: r for r in bench.get("points", [])
             if "metrics" in r}
    out: Dict[str, float] = {}
    for app, pids in fronts.items():
        vals = []
        for pid in pids:
            rec = by_id.get(pid)
            if rec is None:
                continue
            cells = [c["teps"] for name, c in rec.get("per_cell",
                                                      {}).items()
                     if name.split(":")[0] == app]
            if cells:
                vals.append(math.exp(sum(math.log(max(c, 1e-12))
                                         for c in cells) / len(cells)))
        if vals:
            out[app] = max(vals)
    return out


def compare(old: Dict, new: Dict, tol: float = 0.05
            ) -> Tuple[List[str], List[str]]:
    """Returns (failures, notes); empty failures == trajectory ok."""
    failures: List[str] = []
    notes: List[str] = []
    fo, fn = frontier_metrics(old), frontier_metrics(new)
    if not fo:
        return ["old bench has no frontier points"], notes
    if not fn:
        return ["new bench has no frontier points"], notes

    bo, bn = objective_bests(fo), objective_bests(fn)
    for name in bo:
        if name not in bn:
            failures.append(f"objective {name}: missing from new frontier")
            continue
        line = f"best {name}: {bo[name]:.6g} -> {bn[name]:.6g}"
        if _regressed(name, bo[name], bn[name], tol):
            failures.append(f"{line}  REGRESSED beyond tol={tol:.0%}")
        else:
            notes.append(line)

    ao, an = app_bests(old), app_bests(new)
    if ao and an:
        for app in sorted(set(ao) & set(an)):
            line = f"best {app} teps: {ao[app]:.6g} -> {an[app]:.6g}"
            if an[app] < ao[app] * (1.0 - tol):
                failures.append(f"{line}  REGRESSED beyond tol={tol:.0%}")
            else:
                notes.append(line)
    elif ao or an:
        notes.append("per-app frontier slices present on one side only "
                     "(v1/v2 mix) — per-app leg skipped")

    common = sorted(set(fo) & set(fn))
    for pid in common:
        t_old, t_new = fo[pid]["teps_geomean"], fn[pid]["teps_geomean"]
        if t_new < t_old * (1.0 - tol):
            failures.append(f"point {pid}: teps {t_old:.6g} -> {t_new:.6g} "
                            f"REGRESSED beyond tol={tol:.0%}")
    gone, born = sorted(set(fo) - set(fn)), sorted(set(fn) - set(fo))
    if gone or born:
        notes.append(f"frontier drift: {len(gone)} point(s) left, "
                     f"{len(born)} joined (structural, informational)")
    notes.append(f"frontier size {len(fo)} -> {len(fn)}, "
                 f"{len(common)} common point(s)")
    return failures, notes


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("old", help="previous BENCH_dse.json")
    ap.add_argument("new", help="freshly-swept BENCH_dse.json")
    ap.add_argument("--tol", type=float, default=0.05,
                    help="relative regression tolerance (default 5%%)")
    args = ap.parse_args(argv)
    try:
        with open(args.old) as f:
            old = json.load(f)
        with open(args.new) as f:
            new = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"[dse.compare] bad input: {e}", file=sys.stderr)
        return 1
    for name, bench in (("old", old), ("new", new)):
        schema = bench.get("schema")
        if schema is not None and schema not in SCHEMAS:
            print(f"[dse.compare] bad input: {name} schema {schema!r} "
                  f"not in {SCHEMAS}", file=sys.stderr)
            return 1
    failures, notes = compare(old, new, tol=args.tol)
    for line in notes:
        print(f"[dse.compare] {line}")
    for line in failures:
        print(f"[dse.compare] FAIL: {line}", file=sys.stderr)
    return 2 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
