"""``python -m repro.dse.sweep`` — the design-space exploration CLI.

Enumerates a :class:`~repro.dse.space.ConfigSpace`, evaluates every point
analytically (:class:`~repro.dse.evaluate.Evaluator`), extracts the 3-D
Pareto frontier over (TEPS↑, watts↓, $/package↓), re-validates the top-K
analytic winners on the real ``shard_map`` executables (message/drop
counts must match the analytic model exactly — see
:mod:`repro.dse.shardcheck`), and emits ``BENCH_dse.json`` — the repo's
machine-readable perf trajectory, uploaded as a CI artifact by the
``bench-smoke`` and nightly workflows.

Exit codes: 0 ok; 1 sweep produced no valid points; 3 revalidation
mismatch (the analytic model diverged from the executables — a gating
failure, not a soft warning).

Usage::

    PYTHONPATH=src python -m repro.dse.sweep --quick [--out BENCH_dse.json]
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from typing import Dict, List, Optional, Sequence

from .autoconfig import signature_of
from .driver import SweepTask, run_sweep
from .evaluate import APPS, Evaluator, geomean, load_datasets
from .pareto import DEFAULT_OBJECTIVES, pareto_frontier
from .shardcheck import RESULT_PREFIX
from .space import ConfigSpace

SCHEMA = "dcra-dse-bench/v2"
QUICK_APPS = ("bfs", "pagerank", "spmv", "histogram", "kcore")
# every app is revalidated on shard_map — the one-round scatters AND the
# iterative TaskPrograms (per-round trajectory agreement, see shardcheck)
REVALIDATION_APPS = ("spmv", "histogram", "bfs", "sssp", "wcc",
                     "pagerank", "kcore")


def revalidate(results: Sequence[Dict], top_k: int, n_dev: int,
               scale: int, timeout: float = 1800.0) -> List[Dict]:
    """Re-run the top-K points' queue model on the shard_map executables
    (subprocess: the fake-device count must be set before jax imports)."""
    ranked = sorted((r for r in results if r.get("pareto")),
                    key=lambda r: -r["metrics"]["teps_geomean"])
    checks = [{"point_id": r["point_id"],
               "iq_capacity": r["config"]["iq_capacity"],
               "apps": list(REVALIDATION_APPS)}
              for r in ranked[:top_k]]
    if not checks:
        return []
    spec = {"n_dev": n_dev, "scale": scale, "seed": 0, "checks": checks}
    proc = subprocess.run(
        [sys.executable, "-m", "repro.dse.shardcheck"],
        input=json.dumps(spec), capture_output=True, text=True,
        timeout=timeout)
    lines = [ln for ln in proc.stdout.splitlines()
             if ln.startswith(RESULT_PREFIX)]
    if proc.returncode not in (0, 3) or not lines:
        raise RuntimeError(
            f"shardcheck failed (rc={proc.returncode}):\n"
            f"{proc.stderr[-2000:]}")
    return json.loads(lines[-1][len(RESULT_PREFIX):])


def per_app_frontiers(valid: Sequence[Dict], apps_list: Sequence[str]
                      ) -> Dict[str, List[str]]:
    """App-specific Pareto slices: the (TEPS↑, watts↓, $/pkg↓) frontier
    recomputed from each record's per-``app`` cells alone. A point that is
    globally dominated can still be optimal *for one app* (and vice
    versa) — ``autoconfig.select_from_frontier`` ranks on these."""
    out: Dict[str, List[str]] = {}
    for app in apps_list:
        recs, pids = [], []
        for r in valid:
            cells = [c for name, c in r.get("per_cell", {}).items()
                     if name.split(":")[0] == app]
            if not cells:
                continue
            recs.append({
                "teps": geomean([c["teps"] for c in cells]),
                "watts": geomean([c["energy_j"] / max(c["seconds"], 1e-12)
                                  for c in cells]),
                "package_usd": r["metrics"]["package_usd"],
            })
            pids.append(r["point_id"])
        idx = pareto_frontier(recs, DEFAULT_OBJECTIVES)
        out[app] = sorted(pids[i] for i in idx)
    return out


def run(space: ConfigSpace, apps_list: Sequence[str], scale: int,
        top_k: int, n_dev: int, out: Optional[str],
        quick: bool, skip_revalidation: bool = False) -> Dict:
    t0 = time.time()
    data = load_datasets(scale)
    ev = Evaluator(data, apps_list)
    points = list(space.points())
    print(f"[dse] sweeping {len(points)} points x {len(apps_list)} apps x "
          f"{len(data)} datasets (scale={scale})", flush=True)

    tasks = [SweepTask(key=p.point_id,
                       run=(lambda p=p: ev.evaluate_point(p).to_dict()),
                       meta={"point_id": p.point_id})
             for p in points]
    records = run_sweep(tasks, out=None, resume=False)
    valid = [r for r in records if "metrics" in r]

    frontier = pareto_frontier([r["metrics"] | {"teps": r["metrics"]
                                                ["teps_geomean"],
                                                "watts": r["metrics"]
                                                ["watts_geomean"]}
                                for r in valid], DEFAULT_OBJECTIVES)
    frontier_ids = {valid[i]["point_id"] for i in frontier}
    for r in valid:
        r["pareto"] = r["point_id"] in frontier_ids
    app_frontiers = per_app_frontiers(valid, apps_list)

    reval: List[Dict] = []
    if not skip_revalidation:
        reval = revalidate(valid, top_k=top_k, n_dev=n_dev,
                           scale=min(scale, 8))

    bench = {
        "schema": SCHEMA,
        "quick": quick,
        "space": space.to_dict(),
        "apps": list(apps_list),
        "datasets": sorted(data),
        "dataset_scale": scale,
        # what launch-time auto-configuration matches against (additive to
        # schema v1; autoconfig recomputes from dataset_scale when absent)
        "dataset_signatures": {name: signature_of(g).to_dict()
                               for name, g in data.items()},
        "points": records,
        "pareto": sorted(frontier_ids),
        # schema v2: app-specific Pareto slices so launch auto-config can
        # rank on the frontier of the app actually being launched
        "app_frontiers": app_frontiers,
        "revalidation": reval,
        "elapsed_s": time.time() - t0,
    }
    if out:
        with open(out, "w") as f:
            json.dump(bench, f, indent=1)
        print(f"[dse] wrote {out}: {len(valid)} points, "
              f"{len(frontier_ids)} on the frontier, "
              f"{sum(1 for r in reval if r['ok'])}/{len(reval)} "
              f"revalidations ok, {bench['elapsed_s']:.1f}s", flush=True)
    return bench


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized space + small datasets")
    ap.add_argument("--scale", type=int, default=None,
                    help="dataset scale (default: 8 quick / 12 full)")
    ap.add_argument("--out", default="BENCH_dse.json")
    ap.add_argument("--top-k", type=int, default=2,
                    help="analytic winners to revalidate on shard_map")
    ap.add_argument("--n-dev", type=int, default=8)
    ap.add_argument("--apps", default=None,
                    help="comma-separated subset of " + ",".join(APPS))
    ap.add_argument("--skip-revalidation", action="store_true")
    args = ap.parse_args(argv)

    space = ConfigSpace.quick() if args.quick else ConfigSpace.full()
    scale = args.scale if args.scale is not None else (8 if args.quick
                                                      else 12)
    apps_list = (tuple(args.apps.split(",")) if args.apps
                 else (QUICK_APPS if args.quick else APPS))
    bench = run(space, apps_list, scale, args.top_k, args.n_dev,
                args.out, quick=args.quick,
                skip_revalidation=args.skip_revalidation)

    valid = [r for r in bench["points"] if "metrics" in r]
    if not valid or not bench["pareto"]:
        print("[dse] FAIL: no valid points / empty frontier",
              file=sys.stderr)
        return 1
    if not args.skip_revalidation and (
            not bench["revalidation"]
            or not all(r["ok"] for r in bench["revalidation"])):
        print("[dse] FAIL: shard_map revalidation mismatch "
              f"{bench['revalidation']}", file=sys.stderr)
        return 3
    return 0


if __name__ == "__main__":
    sys.exit(main())
