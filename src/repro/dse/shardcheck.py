"""Subprocess worker: re-validate analytic winners on the real shard_map
executables — for EVERY app, iterative ones included.

The DSE sweep's analytic stack models the bounded input queue of the
distributed routing layer (:mod:`repro.core.routing`); this worker proves
the model on a top-K point by routing the *same* task stream through both
paths at the same parallelism and comparing message / drop counts:

* executable: the ``dcra_*`` apps from :mod:`repro.sparse.jax_apps` under
  ``shard_map`` on ``n_dev`` host devices, with the point's IQ capacity
  pinned via ``cap=`` (a ``QueueConfig.from_cap`` override under the
  hood);
* analytic: each app's **TaskProgram twin**
  (:func:`repro.sparse.program.program_app_stats`) — the program's
  generated task stream replayed round by round through
  ``TaskEngine.route`` with ``QueueConfig(default_iq=cap)`` on a
  ``TileGrid(1, n_dev)`` — one tile per shard, so the per-(source shard →
  owner) channel structure is identical (the property
  ``tests/test_routing.py`` pins). For the iterative apps the twin
  evolves vertex state under the executable's own kept/dropped admission
  order, so the per-round streams (and therefore drop counts) agree
  exactly even when tight queues lose updates mid-run.

The ``histogram_self`` app is the heavy self-traffic case: every shard's
element stream targets mostly bins the shard itself owns, so overflow lands
on the (d -> d) channels — proving the analytic model's same-tile drop
charging matches the executable ``bucket``'s treatment of self-owned tasks.

Must run in its own process: the fake-device count has to be set before
jax imports (same pattern as ``benchmarks/noc_routing.py``). Protocol:
spec JSON on stdin, one ``RESULT <json>`` line on stdout.

Spec::

    {"n_dev": 8, "scale": 8, "seed": 0,
     "checks": [{"point_id": "...", "iq_capacity": 12,
                 "apps": ["spmv", "histogram", "bfs", "sssp", "wcc",
                          "pagerank", "kcore"]}]}
"""
from __future__ import annotations

import os

if "XLA_FLAGS" not in os.environ:  # must precede any jax import
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json     # noqa: E402
import sys      # noqa: E402

import numpy as np  # noqa: E402

RESULT_PREFIX = "RESULT "

# the iterative (graph-program) apps and their revalidation parameters
PROGRAM_PARAMS = {
    "bfs": {"root": 0},
    "sssp": {"root": 0},
    "wcc": {},
    "pagerank": {"damping": 0.85, "iters": 5},
    "kcore": {"k": 8.0},
}


def _analytic_counts(dest: np.ndarray, n: int, fab, cap: int):
    """The same stream through the analytic twin at shard parallelism
    (``fab.tile_grid()`` — one tile per shard)."""
    from ..core.queues import QueueConfig
    from ..core.task_engine import EngineConfig, TaskEngine
    n_dev = fab.n_devices
    engine = TaskEngine(EngineConfig(grid=fab.tile_grid(),
                                     queues=QueueConfig(default_iq=cap)), n)
    e_local = len(dest) // n_dev
    shard_of = np.repeat(np.arange(n_dev), e_local)
    valid = dest >= 0
    rs = engine.route("T3", src_idx=shard_of[valid],
                      dst_idx=dest[valid].astype(np.int64))
    return rs.tasks_total, rs.drops


def check_point(check: dict, n_dev: int, scale: int, seed: int) -> list:
    import jax.numpy as jnp
    from ..core.fabric import Fabric
    from ..sparse import datasets
    from ..sparse.jax_apps import (dcra_histogram, dcra_scatter, dcra_spmv,
                                   histogram_task_stream, spmv_task_stream)

    fab = Fabric.fake(n_dev)
    mesh = fab             # every launch below goes through the Fabric path
    cap = max(1, int(check["iq_capacity"]))  # honored exactly, no rounding
    g = datasets.rmat(scale, edge_factor=8, seed=1)
    out = []
    for app in check.get("apps", ("spmv", "histogram")):
        if app == "spmv":
            x = np.random.default_rng(seed).random(g.n)
            dest, _ = spmv_task_stream(g, x, n_dev, seed)
            _, dropped = dcra_spmv(g, x, mesh, seed=seed, cap=cap)
            n_items = g.n
            # measure delivered-task count END TO END: route unit payloads
            # through the same collective so kept+dropped is observed at
            # the owners, not recomputed from the host-side stream
            ones = np.ones(len(dest), np.float32)
            y1, drop1 = dcra_scatter(jnp.asarray(dest), jnp.asarray(ones),
                                     n_items, mesh, "data", op="add",
                                     cap=cap)
            kept = int(round(float(np.asarray(y1).sum())))
            assert int(drop1) == int(dropped)   # same stream, same cap
        elif app == "histogram":
            els = datasets.histogram_data(g.nnz, max(g.n // 16, 64),
                                          seed=seed + 3)
            n_items = max(g.n // 16, 64)
            dest, _ = histogram_task_stream(els, n_dev)
            y, dropped = dcra_histogram(els, n_items, mesh, cap=cap)
            # the histogram IS a unit-payload scatter: its own output
            # counts the delivered tasks
            kept = int(round(float(np.asarray(y).sum())))
        elif app == "histogram_self":
            # heavy self-traffic: ~90% of each shard's elements hash to
            # bins the shard itself owns (bin % n_dev == shard), so IQ
            # overflow concentrates on the same-tile (d -> d) channels
            n_items = max(g.n // 16, 64)
            e_local = max(g.nnz // n_dev, 32)
            rng = np.random.default_rng(seed + 7)
            shard_of = np.repeat(np.arange(n_dev), e_local)
            bins = rng.integers(0, max(n_items // n_dev, 1),
                                n_dev * e_local) * n_dev
            self_mask = rng.random(n_dev * e_local) < 0.9
            owner = np.where(self_mask, shard_of,
                             rng.integers(0, n_dev, n_dev * e_local))
            els = np.minimum(bins + owner, n_items - 1)
            dest, _ = histogram_task_stream(els, n_dev)
            y, dropped = dcra_histogram(els, n_items, mesh, cap=cap)
            kept = int(round(float(np.asarray(y).sum())))
        elif app in PROGRAM_PARAMS:
            # iterative app: run the whole program, compare the per-round
            # message/drop trajectories against the TaskProgram twin
            from ..sparse.jax_apps import PROGRAMS
            from ..sparse.program import program_app_stats, run_program
            params = PROGRAM_PARAMS[app]
            _, stats = run_program(PROGRAMS[app], g, mesh, cap=cap,
                                   params=params, seed=seed)
            twin = program_app_stats(PROGRAMS[app], g, n_dev, cap=cap,
                                     params=params, seed=seed)
            ok = (stats.rounds == twin.rounds
                  and np.array_equal(stats.messages, twin.messages)
                  and np.array_equal(stats.drops, twin.drops))
            out.append({
                "point_id": check.get("point_id", ""),
                "app": app, "n_dev": n_dev, "cap": cap,
                "executable": {"messages": stats.total_messages,
                               "drops": stats.total_drops,
                               "rounds": stats.rounds},
                "analytic": {"messages": twin.total_messages,
                             "drops": twin.total_drops,
                             "rounds": twin.rounds},
                "ok": ok,
            })
            continue
        else:
            raise ValueError(f"unsupported revalidation app {app!r}")
        exe_drops = int(dropped)
        exe_msgs = kept + exe_drops
        ana_msgs, ana_drops = _analytic_counts(dest, n_items, fab, cap)
        ok = (exe_msgs == ana_msgs) and (exe_drops == ana_drops)
        out.append({
            "point_id": check.get("point_id", ""),
            "app": app, "n_dev": n_dev, "cap": cap,
            "executable": {"messages": exe_msgs, "drops": exe_drops},
            "analytic": {"messages": ana_msgs, "drops": ana_drops},
            "ok": ok,
        })
    return out


def main() -> int:
    spec = json.load(sys.stdin)
    n_dev = int(spec.get("n_dev", 8))
    scale = int(spec.get("scale", 8))
    seed = int(spec.get("seed", 0))
    results = []
    for check in spec["checks"]:
        results.extend(check_point(check, n_dev, scale, seed))
    print(RESULT_PREFIX + json.dumps(results), flush=True)
    return 0 if all(r["ok"] for r in results) else 3


if __name__ == "__main__":
    sys.exit(main())
