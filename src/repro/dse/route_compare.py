"""``python -m repro.dse.route_compare OLD.json NEW.json`` — routing
hot-path trajectory gate (sibling of :mod:`repro.dse.compare`, for the
wall-clock ``dcra-route-bench/v2`` artifact ``BENCH_route.json``).

Absolute milliseconds do not transfer across machines (the committed
baseline is produced on a dev box, CI runs on shared runners), so the
gate compares what IS machine-portable — the within-run ratios:

* op-level ``cells``: each impl's **speedup vs the onehot baseline
  measured in the same run**;
* round-level ``round_cells``: each impl's **pipelined-vs-lockstep round
  speedup** — the headline win of ``round_mode="pipelined"``. If the
  fused round shape stops beating the two-pass shape, that is a code
  regression, not runner noise.

A cell+impl whose ratio falls more than ``--tol`` (default 20%) below
the committed baseline fails the build. Cells are matched by (n, s); a
cell or impl present in the baseline but missing from the new bench is a
failure (silent coverage loss); new cells are informational.

Exit codes: 0 ok; 1 bad input; 2 regression.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Sequence, Tuple

SCHEMA = "dcra-route-bench/v2"
# schemas this gate still understands as a *baseline* — a v1 baseline
# (no round_cells) gates only the op-level ratios until regenerated
COMPAT_SCHEMAS = ("dcra-route-bench/v1", SCHEMA)


def _cells(bench: Dict, kind: str) -> Dict[Tuple[int, int], Dict]:
    return {(c["n"], c["s"]): c for c in bench.get(kind, [])}


def _gate_ratios(co: Dict, cn: Dict, field: str, label: str, tol: float,
                 failures: List[str], notes: List[str]) -> None:
    """Gate one ratio dict (impl -> ratio) across matched (n, s) cells."""
    for key in sorted(co):
        if key not in cn:
            failures.append(f"{label} cell N={key[0]} S={key[1]}: missing "
                            f"from new bench")
            continue
        so = co[key].get(field, {})
        sn = cn[key].get(field, {})
        for impl in sorted(so):
            if impl not in sn:
                failures.append(f"{label} cell N={key[0]} S={key[1]} "
                                f"{impl}: missing from new bench")
                continue
            line = (f"{label} N={key[0]} S={key[1]} {impl}: "
                    f"{so[impl]:.2f}x -> {sn[impl]:.2f}x")
            if sn[impl] < so[impl] * (1.0 - tol):
                failures.append(f"{line}  REGRESSED beyond tol={tol:.0%}")
            else:
                notes.append(line)
    born = sorted(set(cn) - set(co))
    if born:
        notes.append(f"{len(born)} new {label} cell(s): {born} "
                     f"(informational)")


def compare(old: Dict, new: Dict, tol: float = 0.2
            ) -> Tuple[List[str], List[str]]:
    """Returns (failures, notes); empty failures == trajectory ok."""
    failures: List[str] = []
    notes: List[str] = []
    co, cn = _cells(old, "cells"), _cells(new, "cells")
    if not co:
        return ["old bench has no cells"], notes
    if not cn:
        return ["new bench has no cells"], notes
    # speedups only compare within one lowering: a baseline regenerated
    # on TPU (mosaic) is meaningless against a CPU (xla) re-measure
    for field in ("backend", "pallas_lowering"):
        if old.get(field) != new.get(field):
            return [f"{field} mismatch: baseline {old.get(field)!r} vs "
                    f"new {new.get(field)!r} — regenerate the committed "
                    f"baseline on the comparison backend"], notes
    _gate_ratios(co, cn, "speedup_vs_onehot", "op", tol, failures, notes)
    ro = _cells(old, "round_cells")
    rn = _cells(new, "round_cells")
    if ro and not rn:
        failures.append("baseline has round_cells but new bench has none")
    elif not ro and rn:
        notes.append("baseline has no round_cells (v1?) — round-level "
                     "ratios reported but not gated; regenerate the "
                     "baseline to gate them")
    _gate_ratios(ro, rn, "round_speedup", "round", tol, failures, notes)
    return failures, notes


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("old", help="committed baseline BENCH_route.json")
    ap.add_argument("new", help="freshly-benched BENCH_route.json")
    ap.add_argument("--tol", type=float, default=0.2,
                    help="relative speedup regression tolerance "
                         "(default 20%%)")
    args = ap.parse_args(argv)
    try:
        with open(args.old) as f:
            old = json.load(f)
        with open(args.new) as f:
            new = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"[dse.route_compare] bad input: {e}", file=sys.stderr)
        return 1
    for name, bench in (("old", old), ("new", new)):
        allowed = COMPAT_SCHEMAS if name == "old" else (SCHEMA,)
        if bench.get("schema") not in allowed:
            print(f"[dse.route_compare] bad input: {name} schema "
                  f"{bench.get('schema')!r} not in {allowed!r}",
                  file=sys.stderr)
            return 1
    failures, notes = compare(old, new, tol=args.tol)
    for line in notes:
        print(f"[dse.route_compare] {line}")
    for line in failures:
        print(f"[dse.route_compare] FAIL: {line}", file=sys.stderr)
    return 2 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
