"""``python -m repro.dse.route_compare OLD.json NEW.json`` — routing
hot-path trajectory gate (sibling of :mod:`repro.dse.compare`, for the
wall-clock ``dcra-route-bench/v1`` artifact ``BENCH_route.json``).

Absolute milliseconds do not transfer across machines (the committed
baseline is produced on a dev box, CI runs on shared runners), so the
gate compares what IS machine-portable: each impl's **speedup vs the
onehot baseline measured in the same run**. A cell+impl whose relative
speedup falls more than ``--tol`` (default 20%) below the committed
baseline fails the build — the fast path got slower relative to the
legacy path, which is a code regression, not runner noise.

Cells are matched by (n, s); a cell or impl present in the baseline but
missing from the new bench is a failure (silent coverage loss); new
cells are informational.

Exit codes: 0 ok; 1 bad input; 2 regression.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Sequence, Tuple

SCHEMA = "dcra-route-bench/v1"


def _cells(bench: Dict) -> Dict[Tuple[int, int], Dict]:
    return {(c["n"], c["s"]): c for c in bench.get("cells", [])}


def compare(old: Dict, new: Dict, tol: float = 0.2
            ) -> Tuple[List[str], List[str]]:
    """Returns (failures, notes); empty failures == trajectory ok."""
    failures: List[str] = []
    notes: List[str] = []
    co, cn = _cells(old), _cells(new)
    if not co:
        return ["old bench has no cells"], notes
    if not cn:
        return ["new bench has no cells"], notes
    # speedups only compare within one lowering: a baseline regenerated
    # on TPU (mosaic) is meaningless against a CPU (xla) re-measure
    for field in ("backend", "pallas_lowering"):
        if old.get(field) != new.get(field):
            return [f"{field} mismatch: baseline {old.get(field)!r} vs "
                    f"new {new.get(field)!r} — regenerate the committed "
                    f"baseline on the comparison backend"], notes
    for key in sorted(co):
        if key not in cn:
            failures.append(f"cell N={key[0]} S={key[1]}: missing from "
                            f"new bench")
            continue
        so = co[key].get("speedup_vs_onehot", {})
        sn = cn[key].get("speedup_vs_onehot", {})
        for impl in sorted(so):
            if impl not in sn:
                failures.append(f"cell N={key[0]} S={key[1]} {impl}: "
                                f"missing from new bench")
                continue
            line = (f"N={key[0]} S={key[1]} {impl}: "
                    f"{so[impl]:.2f}x -> {sn[impl]:.2f}x vs onehot")
            if sn[impl] < so[impl] * (1.0 - tol):
                failures.append(f"{line}  REGRESSED beyond tol={tol:.0%}")
            else:
                notes.append(line)
    born = sorted(set(cn) - set(co))
    if born:
        notes.append(f"{len(born)} new cell(s): {born} (informational)")
    return failures, notes


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("old", help="committed baseline BENCH_route.json")
    ap.add_argument("new", help="freshly-benched BENCH_route.json")
    ap.add_argument("--tol", type=float, default=0.2,
                    help="relative speedup regression tolerance "
                         "(default 20%%)")
    args = ap.parse_args(argv)
    try:
        with open(args.old) as f:
            old = json.load(f)
        with open(args.new) as f:
            new = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"[dse.route_compare] bad input: {e}", file=sys.stderr)
        return 1
    for name, bench in (("old", old), ("new", new)):
        if bench.get("schema") != SCHEMA:
            print(f"[dse.route_compare] bad input: {name} schema "
                  f"{bench.get('schema')!r} != {SCHEMA!r}", file=sys.stderr)
            return 1
    failures, notes = compare(old, new, tol=args.tol)
    for line in notes:
        print(f"[dse.route_compare] {line}")
    for line in failures:
        print(f"[dse.route_compare] FAIL: {line}", file=sys.stderr)
    return 2 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
