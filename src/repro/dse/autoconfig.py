"""Pareto-guided launch auto-configuration (ROADMAP: "launch fast by
default on any dataset").

The DSE sweep (PR 2) finds good deployments offline and commits them to
``BENCH_dse.json``; this module closes the loop at *launch time*: given a
dataset, an app and an objective, pick a :class:`~repro.dse.space
.DesignPoint` from the tracked Pareto frontier — the paper's §V–§VI claim
that DCRA's pre-silicon / package-time / compile-time knobs are configured
*per deployment*, automated.

Selection pipeline:

1. **signature** the dataset — ``(n, nnz, degree skew)`` in log space;
2. **match** it against the frontier's benchmark datasets and
   **interpolate** each frontier point's per-cell metrics with
   inverse-distance weights (nearest-signature matching — a point that is
   great on the power-law Wikipedia graph and mediocre on uniform RMAT is
   scored mostly by the cell that resembles the user's graph);
3. **score** frontier points under the objective (``"teps"`` | ``"watts"``
   | ``"usd"`` | a weighted blend) and take the argmax;
4. **guard**: the winner must beat the all-defaults baseline on the user's
   actual dataset (one analytic evaluation each); if it does not — or if
   no frontier dataset is close — fall back to a quick on-the-fly
   **mini-sweep** over the frontier + a handful of baseline variants. The
   baseline is always a mini-sweep candidate, so the selected point is
   never worse than it under the chosen objective.

The resulting :class:`LaunchConfig` resolves everything the executables
need — deployment grid, pod/portal topology, and per-task IQ capacities as
:class:`~repro.core.queues.QueueConfig` overrides (the single source of
queue truth). All seven ``dcra_*`` apps accept ``config="auto"`` (the
TaskProgram runtime resolves it), ranked on the app-specific Pareto
slice when the bench records one (schema v2 ``app_frontiers``); and
:func:`autoconfigure_moe` picks the MoE dispatch capacity factor from a
dispatch-load signature.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.queues import QueueConfig
from .space import DesignPoint

# All-defaults deployment: what the hand-written benchmarks launch with.
BASELINE = DesignPoint()

# Signature distance beyond which the frontier's datasets say nothing
# about this one (one unit ~ a 16x size mismatch on every axis).
MINISWEEP_THRESHOLD = 0.75

ObjectiveT = Union[str, Dict[str, float]]


def default_bench_path() -> str:
    """The committed trajectory at the repo root (env-overridable)."""
    env = os.environ.get("DCRA_BENCH_PATH")
    if env:
        return env
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.join(here, "..", "..", "..", "BENCH_dse.json")


# ---------------------------------------------------------------------------
# dataset signatures
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DatasetSignature:
    """What the frontier is matched on: size, density, degree skew."""
    n: int
    nnz: int
    skew: float          # coefficient of variation of the degrees

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "DatasetSignature":
        return cls(n=int(d["n"]), nnz=int(d["nnz"]),
                   skew=float(d["skew"]))


def signature_of(g) -> DatasetSignature:
    if hasattr(g, "degrees"):
        deg = np.asarray(g.degrees(), np.float64)
        n, nnz = int(g.n), int(g.nnz)
    else:                                   # raw element stream (histogram)
        # bins are the owned items: signature in (bins, tasks) space, the
        # same shape the sweep's histogram cells have — n == nnz == len
        # would put every stream >= one full size-axis unit from every
        # recorded graph and make the frontier path unreachable
        els = np.atleast_1d(np.asarray(g))
        deg = (np.bincount(els - els.min()).astype(np.float64)
               if els.size else np.zeros(1))
        n = int(els.max() - els.min()) + 1 if els.size else 1
        nnz = int(els.size)
    mean = float(deg.mean()) if deg.size else 1.0
    skew = float(deg.std() / mean) if mean > 0 else 0.0
    return DatasetSignature(n=n, nnz=nnz, skew=skew)


_LOG16 = math.log(16.0)


def signature_distance(a: DatasetSignature, b: DatasetSignature) -> float:
    """0 = identical; 1 = a 16x mismatch on the worst size axis (or an
    e-fold skew mismatch) — the worst axis decides whether the frontier's
    measurements transfer."""
    dn = abs(math.log(max(a.n, 1) / max(b.n, 1))) / _LOG16
    de = abs(math.log(max(a.nnz, 1) / max(b.nnz, 1))) / _LOG16
    ds = abs(math.log((1.0 + a.skew) / (1.0 + b.skew)))
    return max(dn, de, ds)


# ---------------------------------------------------------------------------
# objectives
# ---------------------------------------------------------------------------

def objective_weights(objective: ObjectiveT) -> Tuple[Tuple[str, float], ...]:
    """Normalise an objective to ((metric, weight), ...) over teps/watts/usd.

    Positive weights mean "improve this axis"; the score is a signed
    log-space sum, so ``"usd"`` is TEPS-per-dollar and a blend like
    ``{"teps": 0.5, "watts": 0.5}`` trades throughput against power.
    """
    if isinstance(objective, str):
        named = {"teps": {"teps": 1.0},
                 "watts": {"watts": 1.0},
                 "usd": {"teps": 1.0, "usd": 1.0}}
        if objective not in named:
            raise ValueError(f"unknown objective {objective!r} "
                             f"(expected teps|watts|usd or a weight dict)")
        objective = named[objective]
    bad = set(objective) - {"teps", "watts", "usd"}
    if bad or not objective:
        raise ValueError(f"objective keys must be teps|watts|usd, got {bad}")
    return tuple(sorted(objective.items()))


def objective_score(weights: Sequence[Tuple[str, float]], teps: float,
                    watts: float, usd: float) -> float:
    """Signed log-space score: higher is better under the objective."""
    sign = {"teps": 1.0, "watts": -1.0, "usd": -1.0}
    vals = {"teps": teps, "watts": watts, "usd": usd}
    return sum(w * sign[k] * math.log(max(vals[k], 1e-12))
               for k, w in weights)


# ---------------------------------------------------------------------------
# frontier loading + interpolation
# ---------------------------------------------------------------------------

def load_bench(path: Optional[str] = None) -> Optional[Dict]:
    path = path or default_bench_path()
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def bench_signatures(bench: Dict) -> Dict[str, DatasetSignature]:
    """Signatures of the sweep's datasets — from the bench file when the
    sweep recorded them, else recomputed from the (deterministic)
    generators at the recorded scale."""
    recorded = bench.get("dataset_signatures")
    if recorded:
        return {k: DatasetSignature.from_dict(v) for k, v in recorded.items()}
    from .evaluate import load_datasets
    data = load_datasets(int(bench.get("dataset_scale", 8)))
    return {k: signature_of(g) for k, g in data.items()
            if k in set(bench.get("datasets", data))}


def frontier_records(bench: Dict, app: Optional[str] = None) -> List[Dict]:
    """Frontier candidates for ``app``: the app-specific Pareto slice when
    the bench records one (schema v2 ``app_frontiers``), else the global
    (TEPS, watts, $/pkg) frontier — v1 files and un-swept apps fall back
    gracefully."""
    slice_ids = set(bench.get("app_frontiers", {}).get(app or "", ()))
    if slice_ids:
        return [r for r in bench.get("points", [])
                if r.get("point_id") in slice_ids and "metrics" in r]
    return [r for r in bench.get("points", [])
            if r.get("pareto") and "metrics" in r]


def _cell_metrics(rec: Dict, app: str, dname: str
                  ) -> Optional[Tuple[float, float]]:
    cell = rec.get("per_cell", {}).get(f"{app}:{dname}")
    if not cell:
        return None
    teps = float(cell["teps"])
    watts = float(cell["energy_j"]) / max(float(cell["seconds"]), 1e-12)
    return teps, watts


def interpolate_record(rec: Dict, app: str,
                       dist_by_dataset: Dict[str, float]
                       ) -> Tuple[float, float, float]:
    """(teps, watts, usd) of one frontier record for the user's dataset:
    inverse-distance-weighted geometric interpolation of the record's
    per-dataset cells for ``app`` (falls back to the record's geomeans
    when the app wasn't swept)."""
    lt, lw, ws = 0.0, 0.0, 0.0
    for dname, dist in dist_by_dataset.items():
        cell = _cell_metrics(rec, app, dname)
        if cell is None:
            continue
        w = 1.0 / (dist + 0.05)
        lt += w * math.log(max(cell[0], 1e-12))
        lw += w * math.log(max(cell[1], 1e-12))
        ws += w
    m = rec["metrics"]
    if ws == 0.0:
        teps, watts = m["teps_geomean"], m["watts_geomean"]
    else:
        teps, watts = math.exp(lt / ws), math.exp(lw / ws)
    return teps, watts, float(m["system_usd"])


# ---------------------------------------------------------------------------
# the resolved launch configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LaunchConfig:
    """A fully-resolved deployment for one (dataset, app, objective)."""
    point: DesignPoint
    source: str                                # frontier|mini-sweep|explicit
    objective: Tuple[Tuple[str, float], ...] = (("teps", 1.0),)
    signature: Optional[DatasetSignature] = None
    score: float = 0.0

    def engine_config(self):
        """Analytic deployment: grid shape, topology, bounded queues."""
        return self.point.engine_config()

    @property
    def queues(self) -> QueueConfig:
        """The point's tile-level queue sizing (single source of truth)."""
        return self.point.engine_config().queues

    def pod_axis_for(self, fabric) -> Optional[str]:
        """Hierarchical pod/portal routing when the point asks for it AND
        the fabric actually has a multi-pod axis to route over (the
        mesh-introspection half now lives on
        :attr:`repro.core.fabric.Fabric.pod_axis`; raw meshes accepted)."""
        if self.point.topology != "hier_torus":
            return None
        from ..core.fabric import Fabric
        return Fabric.of(fabric).pod_axis

    def device_queues(self, n_dev: int, e_local: int, task: str = "T3",
                      pod: bool = False) -> QueueConfig:
        """Fold the tile-level IQ capacity onto ``n_dev`` executable shards.

        One shard emulates ``n_tiles / n_dev`` tiles, so a shard-level
        ingress channel aggregates that many tile channels on each side —
        capacity scales by the fold squared, clamped at ``e_local`` (a
        shard can never send more than its whole slice to one owner, so
        the clamp only trims allocation, never admission). The two-stage
        pod path sizes by factor instead (stage caps are relative); the
        analytic model still prices the point's tile-level drops.
        """
        if pod:
            return QueueConfig.from_factor(float(max(n_dev, 1)), task)
        fold = max(1, self.point.n_tiles // max(n_dev, 1))
        cap = min(self.point.iq_capacity * fold * fold, max(1, e_local))
        return QueueConfig.from_cap(max(1, cap), task)


def launch_for(point: DesignPoint, g=None,
               objective: ObjectiveT = "teps") -> LaunchConfig:
    """Wrap an explicitly-chosen point (no frontier selection)."""
    return LaunchConfig(point=point, source="explicit",
                        objective=objective_weights(objective),
                        signature=signature_of(g) if g is not None else None)


# ---------------------------------------------------------------------------
# selection
# ---------------------------------------------------------------------------

def select_from_frontier(bench: Dict, sig: DatasetSignature, app: str,
                         weights: Sequence[Tuple[str, float]]
                         ) -> Optional[Tuple[DesignPoint, float, float]]:
    """Best frontier point under the interpolated objective, ranked on the
    app-specific Pareto slice when the bench carries one (v2
    ``app_frontiers``; the cross-app frontier otherwise).

    Returns (point, score, min_signature_distance) or None when the bench
    has no frontier. Deterministic: ties break on point_id.
    """
    records = frontier_records(bench, app)
    if not records:
        return None
    sigs = bench_signatures(bench)
    if not sigs:
        return None
    dists = {d: signature_distance(sig, s) for d, s in sigs.items()}
    scored = []
    for rec in records:
        teps, watts, usd = interpolate_record(rec, app, dists)
        score = objective_score(weights, teps, watts, usd)
        scored.append((-score, rec["point_id"], rec))
    scored.sort()
    _, _, best = scored[0]
    point = DesignPoint.from_dict(best["config"])
    return point, -scored[0][0], min(dists.values())


def _mini_candidates(frontier: Sequence[DesignPoint]) -> List[DesignPoint]:
    # baseline variants FIRST: the truncation below must never cut
    # BASELINE, or the never-below-baseline guarantee breaks on a large
    # frontier (the full-space sweep can carry 10+ Pareto points)
    cands = [
        BASELINE,
        BASELINE.with_(iq_capacity=48),
        BASELINE.with_(topology="torus"),
        BASELINE.with_(grid_side=16, die_side=16),
        BASELINE.with_(mem_tech="sram"),
    ] + list(frontier)
    seen, out = set(), []
    for p in cands:
        if p.point_id not in seen:
            seen.add(p.point_id)
            out.append(p)
    return out[:10]          # the mini-sweep stays mini


def _score_point(ev, point: DesignPoint,
                 weights: Sequence[Tuple[str, float]]) -> float:
    r = ev.evaluate_point(point)
    return objective_score(weights, r.teps, r.watts, r.system_usd)


def autoconfigure(g, app: str, objective: ObjectiveT = "teps",
                  bench: Optional[Dict] = None,
                  bench_path: Optional[str] = None,
                  threshold: float = MINISWEEP_THRESHOLD) -> LaunchConfig:
    """Resolve the launch configuration for (dataset, app, objective).

    Deterministic for a fixed ``BENCH_dse.json``; never selects a point
    that scores below :data:`BASELINE` under the objective on the user's
    dataset (so with ``objective="teps"`` the pick is TEPS-no-worse than
    the all-defaults deployment).
    """
    from .evaluate import Evaluator
    sig = signature_of(g)
    weights = objective_weights(objective)
    if bench is None:
        bench = load_bench(bench_path)
    ev = Evaluator({"user": g}, (app,))

    frontier_pts: List[DesignPoint] = []
    picked: Optional[Tuple[DesignPoint, float, float]] = None
    if bench is not None:
        frontier_pts = [DesignPoint.from_dict(r["config"])
                        for r in frontier_records(bench, app)]
        picked = select_from_frontier(bench, sig, app, weights)

    if picked is not None and picked[2] <= threshold:
        point, _, _ = picked
        score = _score_point(ev, point, weights)
        if score >= _score_point(ev, BASELINE, weights):
            return LaunchConfig(point=point, source="frontier",
                                objective=weights, signature=sig,
                                score=score)

    # no close frontier entry (or the pick lost to the baseline on the
    # real dataset): quick on-the-fly mini-sweep, baseline included
    best_point, best_score = BASELINE, -math.inf
    for cand in _mini_candidates(frontier_pts):
        s = _score_point(ev, cand, weights)
        if s > best_score + 1e-12 or (
                abs(s - best_score) <= 1e-12
                and cand.point_id < best_point.point_id):
            best_point, best_score = cand, s
    return LaunchConfig(point=best_point, source="mini-sweep",
                        objective=weights, signature=sig, score=best_score)


# ---------------------------------------------------------------------------
# MoE dispatch auto-configuration (ROADMAP: pick moe_capacity_factor from
# a dispatch-load signature)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DispatchLoadSignature:
    """What the MoE capacity choice keys on: how skewed the router's
    expert assignment is for a representative token batch."""
    tokens: int
    num_experts: int
    peak_frac: float     # hottest expert's share of the assignments
    cv: float            # coefficient of variation of per-expert load

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def moe_dispatch_signature(expert_ids, num_experts: int
                           ) -> DispatchLoadSignature:
    """Signature a sample of router assignments (flattened top-k expert
    ids, e.g. ``eids.reshape(-1)`` from one batch)."""
    ids = np.atleast_1d(np.asarray(expert_ids)).reshape(-1)
    load = np.bincount(ids, minlength=num_experts).astype(np.float64)
    total = max(load.sum(), 1.0)
    mean = total / max(num_experts, 1)
    return DispatchLoadSignature(
        tokens=int(ids.size), num_experts=int(num_experts),
        peak_frac=float(load.max(initial=0.0) / total),
        cv=float(load.std() / mean) if mean > 0 else 0.0)


# the swept moe_capacity_factor ladder (ConfigSpace values + headroom)
MOE_FACTOR_LADDER = (1.0, 1.25, 1.5, 2.0, 3.0, 4.0)


def autoconfigure_moe(expert_ids, num_experts: int, n_shards: int,
                      ladder: Sequence[float] = MOE_FACTOR_LADDER
                      ) -> Tuple[float, QueueConfig]:
    """Pick ``moe_capacity_factor`` from a dispatch-load signature.

    Simulates the stage-1 dispatch bucket on the sample — tokens sharded
    as contiguous blocks over ``n_shards`` sender shards and experts
    owned in contiguous blocks, both matching the ``moe_dcra`` layout
    (``P(batch, seq)`` keeps neighbouring tokens on one shard, so
    locally-correlated assignments concentrate on one sender's channel —
    a round-robin model would hide exactly that hotspot) — and returns
    the smallest ladder factor whose ``QueueConfig.for_moe_dispatch``
    channel capacity admits every (sender → owner) channel without
    overflow; if even the largest factor drops (pathological skew), the
    drop-minimising factor wins. Deterministic; the returned
    ``QueueConfig`` plugs straight into ``moe_dcra(..., queues=...)``.
    """
    ids = np.atleast_1d(np.asarray(expert_ids)).reshape(-1)
    if not ids.size:
        f = float(ladder[0])
        return f, QueueConfig.for_moe_dispatch(f)
    e_local = -(-num_experts // max(n_shards, 1))
    block = -(-ids.size // n_shards)
    sender = np.arange(ids.size) // block
    owner = np.minimum(ids // e_local, n_shards - 1)
    chan = np.bincount(sender * n_shards + owner,
                       minlength=n_shards * n_shards)
    t_local = -(-ids.size // n_shards)
    best_f, best_drops = float(ladder[-1]), None
    for f in ladder:
        cap = QueueConfig.for_moe_dispatch(float(f)).channel_cap(
            "dispatch", t_local, n_shards)
        drops = int(np.maximum(chan - cap, 0).sum())
        if best_drops is None or drops < best_drops:
            best_f, best_drops = float(f), drops
        if drops == 0:
            break
    return best_f, QueueConfig.for_moe_dispatch(best_f)
