"""Resident serving runtime — TaskPrograms as a service.

DCRA's pitch is a compute node for scale-out sparse data processing under
*sustained* irregular traffic (Nexus Machine frames the same workloads as
continuously-arriving active messages, arXiv 2502.12380). This package is
that tier: a :class:`~repro.serve.engine.ProgramServer` keeps jitted
program callables warm (the PR 4 compile cache plus an explicit pre-warm
API), fuses many tenants' graph queries into one tenant-column frontier so
a single shard_map round serves N tenants, applies admission control
through :class:`~repro.core.queues.QueueConfig` per-tenant round budgets
(overflow -> graceful retriable rejection, never a silent drop), and
exports per-tenant serving stats (queue depth, cache hit rate, drops,
p50/p99 round latency). The failure posture is first-class
(:mod:`repro.serve.resilience`): deterministic fault injection by launch
index (:class:`ServeFailurePlan`), retry/backoff/deadlines
(``ServeOptions``), per-shape-class circuit breakers, and elastic
degrade on host loss.
"""
from ..sparse.options import LaunchOptions
from .batching import (DrrFormer, FifoFormer, TenantBatch, batched_program,
                       split_tenant_states, tenant_graph)
from .engine import (ADMISSION_TASK, MoEService, ProgramServer, Request,
                     Response, STATUS_FAILED, STATUS_OK, STATUS_REJECTED)
from .options import ServeOptions
from .resilience import (CircuitBreaker, FAULT_DEVICE, FAULT_HOST_LOSS,
                         FAULT_KINDS, FAULT_LAUNCH, FAULT_MOE,
                         ServeFailurePlan, seeded_chaos_plan)
from .stats import STATS_WINDOW, ServingStats, TenantStats

__all__ = [
    "ADMISSION_TASK", "CircuitBreaker", "DrrFormer", "FAULT_DEVICE",
    "FAULT_HOST_LOSS", "FAULT_KINDS", "FAULT_LAUNCH", "FAULT_MOE",
    "FifoFormer", "LaunchOptions", "MoEService", "ProgramServer", "Request",
    "Response", "ServeFailurePlan", "ServeOptions", "ServingStats",
    "STATS_WINDOW", "STATUS_FAILED", "STATUS_OK", "STATUS_REJECTED",
    "TenantBatch", "TenantStats", "batched_program", "seeded_chaos_plan",
    "split_tenant_states", "tenant_graph",
]
