"""ServeOptions — the serving-loop knobs, LaunchOptions' counterpart.

:class:`~repro.sparse.options.LaunchOptions` configures one *launch*
(queue sizing, route impl, round mode); :class:`ServeOptions` configures
the *loop* that issues launches: how many fused batches may be in flight
at once, how batches are formed across tenants, and whether retired
state buffers are donated back to the allocator. The defaults
(``inflight_depth=1``, FIFO formation, no donation) reproduce the
synchronous drain loop bit-for-bit — responses, cache keys, ledger.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

#: batch-formation disciplines (see repro.serve.batching formers)
FAIRNESS_MODES = ("fifo", "drr")


@dataclass(frozen=True)
class ServeOptions:
    """Immutable serving-loop configuration.

    * ``inflight_depth`` — size of the launch window: batch k+1 is
      formed, admitted and dispatched while batch k's arrays are still
      computing on device; harvesting is lazy (poll ``jax.Array``
      readiness, block only at the window boundary or in ``drain``).
      Depth 1 = today's launch-then-block loop.
    * ``fairness`` — ``"fifo"`` is head-of-line batch formation (today's
      behavior, byte-compatible cache keys); ``"drr"`` is deficit
      round-robin across tenants: per-tenant FIFO queues, deficit
      counters charged by each request's admission demand, starvation-
      free (a pending tenant becomes the batch setter within
      ``n_tenants`` formations), order preserved within a tenant.
    * ``drr_quantum`` — deficit refill per formation pass; ``None``
      (default) adapts to the largest demand seen so every head fits on
      its first visit. A smaller fixed quantum makes heavyweight
      requests wait extra passes banking deficit — classic DRR.
    * ``donate_buffers`` — thread ``donate_argnums`` through the batched
      jit so each launch's packed tenant-column state input is donated
      to its output; a retired batch's device buffer is recycled rather
      than freshly allocated. Donation changes lowering, so it joins the
      compile-cache key ONLY when set — default keys stay byte-identical
      (pre-warm compiles the donated shape class when enabled).
    """
    inflight_depth: int = 1
    fairness: str = "fifo"
    drr_quantum: Optional[int] = None
    donate_buffers: bool = False

    def resolve(self) -> "ServeOptions":
        """Validate and return self (mirrors LaunchOptions.resolve)."""
        if int(self.inflight_depth) < 1:
            raise ValueError(
                f"inflight_depth must be >= 1, got {self.inflight_depth}")
        if self.fairness not in FAIRNESS_MODES:
            raise ValueError(f"fairness must be one of {FAIRNESS_MODES}, "
                             f"got {self.fairness!r}")
        if self.drr_quantum is not None and int(self.drr_quantum) < 1:
            raise ValueError(
                f"drr_quantum must be >= 1 or None, got {self.drr_quantum}")
        return self
