"""ServeOptions — the serving-loop knobs, LaunchOptions' counterpart.

:class:`~repro.sparse.options.LaunchOptions` configures one *launch*
(queue sizing, route impl, round mode); :class:`ServeOptions` configures
the *loop* that issues launches: how many fused batches may be in flight
at once, how batches are formed across tenants, and whether retired
state buffers are donated back to the allocator — plus the failure
posture: how many times a transiently-failed request is retried, how
long it backs off, when it is past its deadline, and when a shape
class's circuit breaker opens. The defaults (``inflight_depth=1``, FIFO
formation, no donation, no retries, no deadline, no breaker) reproduce
the synchronous drain loop bit-for-bit — responses, cache keys, ledger.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

#: batch-formation disciplines (see repro.serve.batching formers)
FAIRNESS_MODES = ("fifo", "drr")


@dataclass(frozen=True)
class ServeOptions:
    """Immutable serving-loop configuration.

    * ``inflight_depth`` — size of the launch window: batch k+1 is
      formed, admitted and dispatched while batch k's arrays are still
      computing on device; harvesting is lazy (poll ``jax.Array``
      readiness, block only at the window boundary or in ``drain``).
      Depth 1 = today's launch-then-block loop.
    * ``fairness`` — ``"fifo"`` is head-of-line batch formation (today's
      behavior, byte-compatible cache keys); ``"drr"`` is deficit
      round-robin across tenants: per-tenant FIFO queues, deficit
      counters charged by each request's admission demand, starvation-
      free (a pending tenant becomes the batch setter within
      ``n_tenants`` formations), order preserved within a tenant.
    * ``drr_quantum`` — deficit refill per formation pass; ``None``
      (default) adapts to the largest demand seen so every head fits on
      its first visit. A smaller fixed quantum makes heavyweight
      requests wait extra passes banking deficit — classic DRR.
    * ``donate_buffers`` — thread ``donate_argnums`` through the batched
      jit so each launch's packed tenant-column state input is donated
      to its output; a retired batch's device buffer is recycled rather
      than freshly allocated. Donation changes lowering, so it joins the
      compile-cache key ONLY when set — default keys stay byte-identical
      (pre-warm compiles the donated shape class when enabled).
    * ``max_retries`` — transient failures (launch exceptions, device
      errors at harvest, MoE dispatch faults, host loss) requeue the
      failed batch's riders at the **head of their tenant's queue** up
      to this many times per request before the request fails
      non-retriably; 0 (default) keeps every failure terminal on first
      strike, the historical behavior.
    * ``backoff_base_s`` — exponential backoff before a retry relaunch:
      attempt n waits ``base * 2**(n-1) * (1 + jitter)`` where the
      jitter is a deterministic hash of ``req_id`` (no ``random`` — a
      replayed chaos run waits identical delays). 0 (default) retries
      immediately.
    * ``deadline_s`` — per-request end-to-end budget measured from
      ``submit()``: a request past its deadline at batch formation or
      after a failed launch fails non-retriably with a distinct
      ``deadline ... exceeded`` reason, never silently retried forever.
      ``None`` (default) = no deadline.
    * ``breaker_threshold`` — per-(program, graph) circuit breaker:
      this many *consecutive* failed launches of one shape class open
      it (new submissions of the class fail fast with a retriable
      rejection naming the breaker); the next formed batch is the
      half-open probe, whose success closes it. ``None`` (default)
      disables breakers.
    """
    inflight_depth: int = 1
    fairness: str = "fifo"
    drr_quantum: Optional[int] = None
    donate_buffers: bool = False
    max_retries: int = 0
    backoff_base_s: float = 0.0
    deadline_s: Optional[float] = None
    breaker_threshold: Optional[int] = None

    def resolve(self) -> "ServeOptions":
        """Validate and return self (mirrors LaunchOptions.resolve)."""
        if int(self.inflight_depth) < 1:
            raise ValueError(
                f"inflight_depth must be >= 1, got {self.inflight_depth}")
        if self.fairness not in FAIRNESS_MODES:
            raise ValueError(f"fairness must be one of {FAIRNESS_MODES}, "
                             f"got {self.fairness!r}")
        if self.drr_quantum is not None and int(self.drr_quantum) < 1:
            raise ValueError(
                f"drr_quantum must be >= 1 or None, got {self.drr_quantum}")
        if int(self.max_retries) < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {self.max_retries}")
        if float(self.backoff_base_s) < 0.0:
            raise ValueError(
                f"backoff_base_s must be >= 0, got {self.backoff_base_s}")
        if self.deadline_s is not None and float(self.deadline_s) <= 0.0:
            raise ValueError(
                f"deadline_s must be > 0 or None, got {self.deadline_s}")
        if self.breaker_threshold is not None \
                and int(self.breaker_threshold) < 1:
            raise ValueError(f"breaker_threshold must be >= 1 or None, "
                             f"got {self.breaker_threshold}")
        return self
