"""Serving observability: per-tenant / per-round counters and latency
quantiles — the repo's first serving-stats layer.

Everything is plain counters + a latency reservoir; ``snapshot()``
renders one JSON-able dict (the CI smoke leg and ``serve_bench`` assert
on it). Accounting invariant (asserted by :meth:`ServingStats.verify`):
every submitted request is exactly one of served / rejected / failed —
nothing is silently dropped — and every NoC-level task drop the engine
observed is attributed to a response (``noc_drops``), never swallowed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List


def _quantile(xs: List[float], q: float) -> float:
    """Nearest-rank quantile (no numpy dependency for the hot path)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    i = min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))
    return s[i]


@dataclass
class TenantStats:
    submitted: int = 0
    served: int = 0
    rejected: int = 0                 # admission-control rejections
    failed: int = 0
    # launch-level attribution: drops/messages/rounds of every fused
    # launch this tenant rode (columns share one NoC, so per-column
    # splits don't exist at the engine level)
    noc_drops: int = 0                # IQ-overflow task drops
    messages: int = 0                 # routed tasks
    rounds: int = 0                   # NoC rounds
    latencies: List[float] = field(default_factory=list)

    def snapshot(self) -> Dict:
        return {
            "submitted": self.submitted, "served": self.served,
            "rejected": self.rejected, "failed": self.failed,
            "noc_drops": self.noc_drops, "messages": self.messages,
            "rounds": self.rounds,
            "p50_latency_s": _quantile(self.latencies, 0.50),
            "p99_latency_s": _quantile(self.latencies, 0.99),
        }


@dataclass
class ServingStats:
    """Aggregate + per-tenant serving counters."""
    tenants: Dict[str, TenantStats] = field(default_factory=dict)
    noc_drops: int = 0                # aggregate IQ-overflow task drops
    launches: int = 0                 # fused shard_map launches
    batched_requests: int = 0         # real (non-padding) requests served
    pad_columns: int = 0              # dummy columns burned on padding
    cache_hits: int = 0               # TaskProgram compile-cache hits
    cache_misses: int = 0
    prewarmed_keys: int = 0
    queue_depth_samples: List[int] = field(default_factory=list)
    round_latencies: List[float] = field(default_factory=list)

    def tenant(self, name: str) -> TenantStats:
        ts = self.tenants.get(name)
        if ts is None:
            ts = self.tenants[name] = TenantStats()
        return ts

    @property
    def cache_hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    def observe_queue_depth(self, depth: int) -> None:
        self.queue_depth_samples.append(int(depth))

    def verify(self) -> None:
        """The no-silent-drop ledger: submitted == served + rejected +
        failed, per tenant (in-flight requests must be drained first)."""
        for name, ts in self.tenants.items():
            acc = ts.served + ts.rejected + ts.failed
            if ts.submitted != acc:
                raise AssertionError(
                    f"tenant {name!r}: {ts.submitted} submitted but only "
                    f"{acc} accounted (served {ts.served} + rejected "
                    f"{ts.rejected} + failed {ts.failed})")

    def snapshot(self) -> Dict:
        return {
            "noc_drops": self.noc_drops,
            "launches": self.launches,
            "batched_requests": self.batched_requests,
            "pad_columns": self.pad_columns,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "cache_hit_rate": self.cache_hit_rate,
            "prewarmed_keys": self.prewarmed_keys,
            "max_queue_depth": max(self.queue_depth_samples, default=0),
            "p50_round_latency_s": _quantile(self.round_latencies, 0.50),
            "p99_round_latency_s": _quantile(self.round_latencies, 0.99),
            "tenants": {t: s.snapshot() for t, s in self.tenants.items()},
        }
