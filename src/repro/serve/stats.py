"""Serving observability: per-tenant / per-round counters and latency
quantiles — the repo's first serving-stats layer.

Everything is plain counters + **bounded** latency reservoirs;
``snapshot()`` renders one JSON-able dict (the CI smoke leg and
``serve_bench`` assert on it). A resident server runs for days, so every
per-event list is a ``deque(maxlen=STATS_WINDOW)``: quantiles are
computed over the most recent window and host memory stays O(window) no
matter how long the server lives (tests/test_serve.py pins the cap).
Accounting invariant (asserted by :meth:`ServingStats.verify`): every
submitted request is exactly one of served / rejected / failed — nothing
is silently dropped — and every NoC-level task drop the engine observed
is attributed to a response (``noc_drops``), never swallowed.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict

#: bound on every per-event reservoir (latencies, queue-depth samples);
#: quantiles are over the most recent STATS_WINDOW events
STATS_WINDOW = 4096


def _window() -> Deque:
    return deque(maxlen=STATS_WINDOW)


def _quantile(xs, q: float) -> float:
    """Nearest-rank quantile (no numpy dependency for the hot path)."""
    s = sorted(xs)
    if not s:
        return 0.0
    i = min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))
    return s[i]


@dataclass
class TenantStats:
    submitted: int = 0
    served: int = 0
    rejected: int = 0                 # admission-control rejections
    failed: int = 0
    retries: int = 0                  # failed-launch requeues (a retry is
                                      # NOT a resubmission: the request
                                      # stays admitted, the ledger's
                                      # submitted count is untouched)
    # launch-level attribution: drops/messages/rounds of every fused
    # launch this tenant rode (columns share one NoC, so per-column
    # splits don't exist at the engine level)
    noc_drops: int = 0                # IQ-overflow task drops
    messages: int = 0                 # routed tasks
    rounds: int = 0                   # NoC rounds
    latencies: Deque[float] = field(default_factory=_window)
    # end-to-end latency decomposed: time queued before launch vs time
    # the fused launch spent computing (submit -> launch -> harvest)
    queue_waits: Deque[float] = field(default_factory=_window)
    device_times: Deque[float] = field(default_factory=_window)

    def snapshot(self) -> Dict:
        return {
            "submitted": self.submitted, "served": self.served,
            "rejected": self.rejected, "failed": self.failed,
            "retries": self.retries,
            "noc_drops": self.noc_drops, "messages": self.messages,
            "rounds": self.rounds,
            "p50_latency_s": _quantile(self.latencies, 0.50),
            "p99_latency_s": _quantile(self.latencies, 0.99),
            "p50_queue_wait_s": _quantile(self.queue_waits, 0.50),
            "p99_queue_wait_s": _quantile(self.queue_waits, 0.99),
            "p50_device_s": _quantile(self.device_times, 0.50),
            "p99_device_s": _quantile(self.device_times, 0.99),
        }


@dataclass
class ServingStats:
    """Aggregate + per-tenant serving counters."""
    tenants: Dict[str, TenantStats] = field(default_factory=dict)
    noc_drops: int = 0                # aggregate IQ-overflow task drops
    launches: int = 0                 # fused shard_map launches
    batched_requests: int = 0         # real (non-padding) requests served
    pad_columns: int = 0              # dummy columns burned on padding
    cache_hits: int = 0               # TaskProgram compile-cache hits
    cache_misses: int = 0
    prewarmed_keys: int = 0
    # resilience counters (repro.serve.resilience): how often the
    # recovery machinery actually engaged — a chaos test asserts these
    retries: int = 0                  # failed-launch rider requeues
    breaker_opens: int = 0            # circuit-breaker open transitions
    breaker_closes: int = 0           # half-open probe successes
    host_losses: int = 0              # fabric shrinks survived
    max_queue_depth: int = 0          # running max (survives the window)
    queue_depth_samples: Deque[int] = field(default_factory=_window)
    round_latencies: Deque[float] = field(default_factory=_window)

    def tenant(self, name: str) -> TenantStats:
        ts = self.tenants.get(name)
        if ts is None:
            ts = self.tenants[name] = TenantStats()
        return ts

    @property
    def cache_hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    def observe_queue_depth(self, depth: int) -> None:
        depth = int(depth)
        self.queue_depth_samples.append(depth)
        if depth > self.max_queue_depth:
            self.max_queue_depth = depth

    def verify(self) -> None:
        """The no-silent-drop ledger: submitted == served + rejected +
        failed, per tenant (in-flight requests must be drained first).
        Retries deliberately do NOT enter the equation — a retried
        request is still one submission with one eventual outcome; the
        per-tenant ``retries`` counter tracks the extra attempts."""
        for name, ts in self.tenants.items():
            acc = ts.served + ts.rejected + ts.failed
            if ts.submitted != acc:
                raise AssertionError(
                    f"tenant {name!r}: {ts.submitted} submitted but only "
                    f"{acc} accounted (served {ts.served} + rejected "
                    f"{ts.rejected} + failed {ts.failed})")

    def snapshot(self) -> Dict:
        return {
            "noc_drops": self.noc_drops,
            "launches": self.launches,
            "batched_requests": self.batched_requests,
            "pad_columns": self.pad_columns,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "cache_hit_rate": self.cache_hit_rate,
            "prewarmed_keys": self.prewarmed_keys,
            "retries": self.retries,
            "breaker_opens": self.breaker_opens,
            "breaker_closes": self.breaker_closes,
            "host_losses": self.host_losses,
            "max_queue_depth": self.max_queue_depth,
            "p50_round_latency_s": _quantile(self.round_latencies, 0.50),
            "p99_round_latency_s": _quantile(self.round_latencies, 0.99),
            "tenants": {t: s.snapshot() for t, s in self.tenants.items()},
        }
