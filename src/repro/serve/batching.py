"""Tenant-column batching: fuse N tenants' graph queries into ONE launch.

A graph query (BFS/SSSP from a root) is a frontier computation over a
fixed topology. To serve N tenants in one shard_map round, the base graph
is expanded by a *tenant column*: base vertex ``v`` becomes the T virtual
vertices ``t * n + v`` (tenant-blocked), every base edge is replicated
once per tenant inside its own column, and the batched program's init rule
(:func:`repro.sparse.jax_apps._multi_root_init`) seeds one root per
tenant. Columns never interact — edge ``(t*n+u, t*n+v)`` stays inside
tenant ``t`` — so each tenant's result is exactly its standalone run:

* min-reduce programs (BFS/SSSP/WCC) are **bit-identical** to the
  standalone ``run_program`` launch when no task drops: every final
  distance is the same left-fold of f32 adds along the winning path, and
  ``min`` is exact in f32 (asserted in tests/test_serve.py);
* the cyclic owner layout stripes each column across all devices
  (virtual vertex ``t*n+v`` lives on device ``(t*n+v) % n_dev``, uniform
  over ``v``), so one tenant's hot frontier can't capsize a single
  shard. The blocked id — NOT the interleaved ``v*T+t`` — matters: when
  ``n_dev`` divides T, interleaving would pin every vertex of tenant t
  to device ``t % n_dev``, serialising the whole column's traffic.

The fused batch always has width ``T`` (short batches are padded with
dummy root-0 columns, results discarded): one (program, graph, T) shape
class -> ONE compile-cache entry, which is what the server pre-warms.
"""
from __future__ import annotations

import weakref
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..sparse.csr import CSR, from_edges
from ..sparse.jax_apps import BATCHED_BFS, BATCHED_SSSP, TaskProgram

# base program name -> tenant-batched variant (same payload/update rules,
# multi-root init). Only min-reduce programs batch exactly — float adds
# commute per-column here because columns are disjoint, but an add-reduce
# program (pagerank) still sums in a different global order, so it is
# deliberately NOT in this registry.
BATCHED_PROGRAMS: Dict[str, TaskProgram] = {
    "bfs": BATCHED_BFS,
    "sssp": BATCHED_SSSP,
}


def batched_program(base_name: str) -> TaskProgram:
    """The tenant-batched variant of a base program (KeyError for
    programs that have none — add-reduce programs don't batch exactly)."""
    return BATCHED_PROGRAMS[base_name]


# (graph id, T) -> (weakref to the base CSR, expanded CSR); the expansion
# is pure topology, shared by every program and every request batch of the
# same width. The weakref guards against id() reuse: a lookup only counts
# as a hit when the recorded referent IS the argument, and a dead
# referent's entry is purged by the weakref callback, so the memo can't
# serve a stale expansion of a garbage-collected graph and can't grow
# past the set of live (graph, width) pairs.
_TENANT_GRAPHS: Dict[Tuple[int, int], Tuple["weakref.ref[CSR]", CSR]] = {}


def tenant_graph(g: CSR, n_tenants: int) -> CSR:
    """Tenant-expand ``g``: ``n * T`` virtual vertices, ``nnz * T`` edges,
    edge (u, v, w) -> (t*n+u, t*n+v, w) for every tenant column t.

    Memoized by CSR object identity + T — the server's graph registry is
    resident, so each (graph, batch width) expands exactly once.
    """
    T = int(n_tenants)
    if T < 1:
        raise ValueError(f"need at least one tenant column, got {T}")
    key = (id(g), T)
    got = _TENANT_GRAPHS.get(key)
    if got is not None and got[0]() is g:
        return got[1]
    rows = g.row_of()
    cols = g.col_idx.astype(np.int64)
    off = np.arange(T, dtype=np.int64) * g.n
    src = (rows[None, :] + off[:, None]).ravel()
    dst = (cols[None, :] + off[:, None]).ravel()
    w = np.tile(g.values, T)
    out = from_edges(g.n * T, src, dst, w)
    ref = weakref.ref(g, lambda _r, _k=key: _TENANT_GRAPHS.pop(_k, None))
    _TENANT_GRAPHS[key] = (ref, out)
    return out


def split_tenant_states(state: np.ndarray, n: int, n_tenants: int
                        ) -> List[np.ndarray]:
    """Undo the tenant column: one [n*T] state array -> T per-tenant [n]
    arrays (tenant t's value for base vertex v sits at slot t*n + v)."""
    return [np.ascontiguousarray(state.reshape(n_tenants, n)[t])
            for t in range(n_tenants)]


@dataclass
class TenantBatch:
    """One fused launch: up to T tenants' requests for the same
    (program, graph) shape class, padded to exactly width T with dummy
    root-0 columns (``req_ids[t] is None`` marks padding)."""
    program: str                     # base program name ("bfs" | "sssp")
    graph: str                       # server graph-registry key
    width: int                       # T, the fixed tenant-column count
    roots: Tuple[int, ...] = ()
    tenants: List[str] = field(default_factory=list)
    req_ids: List[Optional[int]] = field(default_factory=list)

    @property
    def n_real(self) -> int:
        return sum(1 for r in self.req_ids if r is not None)

    def padded(self) -> "TenantBatch":
        pad = self.width - len(self.req_ids)
        if pad < 0:
            raise ValueError(f"batch overflows width {self.width}")
        if pad == 0:
            return self
        return TenantBatch(
            program=self.program, graph=self.graph, width=self.width,
            roots=tuple(self.roots) + (0,) * pad,
            tenants=list(self.tenants) + ["_pad"] * pad,
            req_ids=list(self.req_ids) + [None] * pad)
