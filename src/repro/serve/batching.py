"""Tenant-column batching: fuse N tenants' graph queries into ONE launch.

A graph query (BFS/SSSP from a root) is a frontier computation over a
fixed topology. To serve N tenants in one shard_map round, the base graph
is expanded by a *tenant column*: base vertex ``v`` becomes the T virtual
vertices ``t * n + v`` (tenant-blocked), every base edge is replicated
once per tenant inside its own column, and the batched program's init rule
(:func:`repro.sparse.jax_apps._multi_root_init`) seeds one root per
tenant. Columns never interact — edge ``(t*n+u, t*n+v)`` stays inside
tenant ``t`` — so each tenant's result is exactly its standalone run:

* min-reduce programs (BFS/SSSP/WCC) are **bit-identical** to the
  standalone ``run_program`` launch when no task drops: every final
  distance is the same left-fold of f32 adds along the winning path, and
  ``min`` is exact in f32 (asserted in tests/test_serve.py);
* the cyclic owner layout stripes each column across all devices
  (virtual vertex ``t*n+v`` lives on device ``(t*n+v) % n_dev``, uniform
  over ``v``), so one tenant's hot frontier can't capsize a single
  shard. The blocked id — NOT the interleaved ``v*T+t`` — matters: when
  ``n_dev`` divides T, interleaving would pin every vertex of tenant t
  to device ``t % n_dev``, serialising the whole column's traffic.

The fused batch always has width ``T`` (short batches are padded with
dummy root-0 columns, results discarded): one (program, graph, T) shape
class -> ONE compile-cache entry, which is what the server pre-warms.
"""
from __future__ import annotations

import weakref
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from ..sparse.csr import CSR, from_edges
from ..sparse.jax_apps import BATCHED_BFS, BATCHED_SSSP, TaskProgram

# base program name -> tenant-batched variant (same payload/update rules,
# multi-root init). Only min-reduce programs batch exactly — float adds
# commute per-column here because columns are disjoint, but an add-reduce
# program (pagerank) still sums in a different global order, so it is
# deliberately NOT in this registry.
BATCHED_PROGRAMS: Dict[str, TaskProgram] = {
    "bfs": BATCHED_BFS,
    "sssp": BATCHED_SSSP,
}


def batched_program(base_name: str) -> TaskProgram:
    """The tenant-batched variant of a base program (KeyError for
    programs that have none — add-reduce programs don't batch exactly)."""
    return BATCHED_PROGRAMS[base_name]


# (graph id, T) -> (weakref to the base CSR, expanded CSR); the expansion
# is pure topology, shared by every program and every request batch of the
# same width. The weakref guards against id() reuse: a lookup only counts
# as a hit when the recorded referent IS the argument, and a dead
# referent's entry is purged by the weakref callback, so the memo can't
# serve a stale expansion of a garbage-collected graph and can't grow
# past the set of live (graph, width) pairs.
_TENANT_GRAPHS: Dict[Tuple[int, int], Tuple["weakref.ref[CSR]", CSR]] = {}


def tenant_graph(g: CSR, n_tenants: int) -> CSR:
    """Tenant-expand ``g``: ``n * T`` virtual vertices, ``nnz * T`` edges,
    edge (u, v, w) -> (t*n+u, t*n+v, w) for every tenant column t.

    Memoized by CSR object identity + T — the server's graph registry is
    resident, so each (graph, batch width) expands exactly once.
    """
    T = int(n_tenants)
    if T < 1:
        raise ValueError(f"need at least one tenant column, got {T}")
    key = (id(g), T)
    got = _TENANT_GRAPHS.get(key)
    if got is not None and got[0]() is g:
        return got[1]
    rows = g.row_of()
    cols = g.col_idx.astype(np.int64)
    off = np.arange(T, dtype=np.int64) * g.n
    src = (rows[None, :] + off[:, None]).ravel()
    dst = (cols[None, :] + off[:, None]).ravel()
    w = np.tile(g.values, T)
    out = from_edges(g.n * T, src, dst, w)
    ref = weakref.ref(g, lambda _r, _k=key: _TENANT_GRAPHS.pop(_k, None))
    _TENANT_GRAPHS[key] = (ref, out)
    return out


def split_tenant_states(state: np.ndarray, n: int, n_tenants: int
                        ) -> List[np.ndarray]:
    """Undo the tenant column: one [n*T] state array -> T per-tenant [n]
    arrays (tenant t's value for base vertex v sits at slot t*n + v)."""
    return [np.ascontiguousarray(state.reshape(n_tenants, n)[t])
            for t in range(n_tenants)]


# ---------------------------------------------------------------------------
# batch formation: which queued requests ride the next fused launch
# ---------------------------------------------------------------------------
#
# A *former* owns the server's pending queue. Entries are any objects
# exposing three read-only attributes: ``tenant`` (str), ``klass`` (the
# (program, graph) shape-class key — one fused launch serves exactly one
# class) and ``demand`` (the admission-time per-round task estimate, the
# same number QueueConfig budgets are charged with). The engine pushes on
# admission and calls ``form(width_for)`` to pop the next batch; at most
# one entry per tenant rides a batch (each tenant owns whole columns) and
# only queue *heads* are ever popped, so intra-tenant FIFO order is
# preserved by construction in every discipline.

class FifoFormer:
    """Head-of-line batch formation — the original ``_next_batch``.

    One global FIFO: the next batch's class is whatever the oldest
    pending request wants, filled by scanning the whole queue for
    same-class requests from distinct tenants (arrival order of the
    rest preserved). A heavy tenant that keeps the head occupied can
    starve light tenants — that is the trade :class:`DrrFormer` fixes.
    """

    def __init__(self) -> None:
        self._q: Deque = deque()

    def push(self, entry) -> None:
        self._q.append(entry)

    def push_front(self, entry) -> None:
        """Requeue at the head of the line — a retried request must not
        re-pay the whole queue (it already waited once); push a failed
        batch's riders in reverse so their relative order is preserved."""
        self._q.appendleft(entry)

    def __len__(self) -> int:
        return len(self._q)

    def pending_tenants(self) -> List[str]:
        return list({e.tenant: None for e in self._q})

    def pending_classes(self) -> List:
        """Distinct (program, graph) classes still queued, head-first —
        what the engine re-prewarms after an elastic fabric shrink."""
        return list({e.klass: None for e in self._q})

    def form(self, width_for: Callable) -> List:
        """Pop the next batch (``[]`` when idle) — bit-identical to the
        pre-former serving loop's head-of-line scan."""
        if not self._q:
            return []
        head = self._q[0]
        key = head.klass
        width = int(width_for(head))
        taken: List = []
        seen_tenants = set()
        rest: Deque = deque()
        while self._q:
            e = self._q.popleft()
            if (len(taken) < width and e.klass == key
                    and e.tenant not in seen_tenants):
                taken.append(e)
                seen_tenants.add(e.tenant)
            else:
                rest.append(e)
        self._q = rest
        return taken


class DrrFormer:
    """Deficit-round-robin batch formation across tenants.

    Classic DRR adapted to fused tenant-column launches: one FIFO queue
    per tenant, a round-robin ring over tenants in first-seen order, and
    a per-tenant *deficit* counter. Each formation pass grants every
    pending tenant one ``quantum`` of deficit; the first tenant (in ring
    order from the RR pointer) whose head request's ``demand`` fits its
    deficit becomes the batch **setter** — its head fixes the batch's
    (program, graph) class — and is charged that demand. The remaining
    width is filled by one ring cycle of *riders*: other tenants whose
    heads match the class and fit their deficit (charged the same way).
    The pointer then advances past the setter.

    Properties (tests/test_serve.py pins them):

    * **starvation-free** — with the default adaptive quantum (max
      demand seen) every pending head fits on its first visit, so the
      setter is always the first pending tenant at/after the pointer
      and every pending tenant sets a batch within ``n_tenants``
      formations; a request admitted behind ``d`` same-tenant requests
      launches within ``d * n_tenants`` formations.
    * **FIFO within a tenant** — only heads are popped.
    * **no banking while idle** — a tenant's deficit resets to zero
      when its queue empties, so bursts don't inherit credit.
    """

    def __init__(self, quantum: Optional[int] = None) -> None:
        self._by_tenant: Dict[str, Deque] = {}
        self._ring: List[str] = []          # tenants, first-seen order
        self._rr = 0                        # ring index of the next setter
        self._deficit: Dict[str, int] = {}
        self._quantum = None if quantum is None else int(quantum)
        self._max_demand = 1                # adaptive-quantum floor

    def push(self, entry) -> None:
        t = entry.tenant
        q = self._by_tenant.get(t)
        if q is None:
            q = self._by_tenant[t] = deque()
            self._ring.append(t)
            self._deficit[t] = 0
        q.append(entry)
        self._max_demand = max(self._max_demand, int(entry.demand))

    def push_front(self, entry) -> None:
        """Requeue at the head of the entry's tenant queue (see
        :meth:`FifoFormer.push_front`) — intra-tenant FIFO order is
        restored, the ring/deficit discipline is untouched."""
        t = entry.tenant
        q = self._by_tenant.get(t)
        if q is None:
            q = self._by_tenant[t] = deque()
            self._ring.append(t)
            self._deficit[t] = 0
        q.appendleft(entry)
        self._max_demand = max(self._max_demand, int(entry.demand))

    def __len__(self) -> int:
        return sum(len(q) for q in self._by_tenant.values())

    def pending_tenants(self) -> List[str]:
        return [t for t in self._ring if self._by_tenant[t]]

    def pending_classes(self) -> List:
        """Distinct (program, graph) classes still queued (ring order) —
        what the engine re-prewarms after an elastic fabric shrink."""
        return list({e.klass: None for t in self._ring
                     for e in self._by_tenant[t]})

    def _charge(self, tenant: str, demand: int) -> None:
        self._deficit[tenant] -= int(demand)
        if not self._by_tenant[tenant]:
            self._deficit[tenant] = 0       # no banking while idle

    def form(self, width_for: Callable) -> List:
        """Pop the next batch (``[]`` when idle)."""
        order = [self._ring[(self._rr + i) % len(self._ring)]
                 for i in range(len(self._ring))] if self._ring else []
        order = [t for t in order if self._by_tenant[t]]
        if not order:
            return []
        quantum = (self._max_demand if self._quantum is None
                   else self._quantum)
        setter = None
        while setter is None:               # each pass grants EVERY
            for t in order:                 # pending tenant one quantum
                self._deficit[t] += quantum
                if (setter is None and
                        self._by_tenant[t][0].demand <= self._deficit[t]):
                    setter = t              # keep granting to the rest
        e0 = self._by_tenant[setter].popleft()
        self._charge(setter, e0.demand)
        key = e0.klass
        width = int(width_for(e0))
        taken = [e0]
        si = order.index(setter)
        for t in order[si + 1:] + order[:si]:   # one rider cycle
            if len(taken) >= width:
                break
            q = self._by_tenant[t]
            if q and q[0].klass == key and q[0].demand <= self._deficit[t]:
                e = q.popleft()
                self._charge(t, e.demand)
                taken.append(e)
        self._rr = (self._ring.index(setter) + 1) % len(self._ring)
        return taken


@dataclass
class TenantBatch:
    """One fused launch: up to T tenants' requests for the same
    (program, graph) shape class, padded to exactly width T with dummy
    root-0 columns (``req_ids[t] is None`` marks padding)."""
    program: str                     # base program name ("bfs" | "sssp")
    graph: str                       # server graph-registry key
    width: int                       # T, the fixed tenant-column count
    roots: Tuple[int, ...] = ()
    tenants: List[str] = field(default_factory=list)
    req_ids: List[Optional[int]] = field(default_factory=list)

    @property
    def n_real(self) -> int:
        return sum(1 for r in self.req_ids if r is not None)

    def padded(self) -> "TenantBatch":
        pad = self.width - len(self.req_ids)
        if pad < 0:
            raise ValueError(f"batch overflows width {self.width}")
        if pad == 0:
            return self
        return TenantBatch(
            program=self.program, graph=self.graph, width=self.width,
            roots=tuple(self.roots) + (0,) * pad,
            tenants=list(self.tenants) + ["_pad"] * pad,
            req_ids=list(self.req_ids) + [None] * pad)
