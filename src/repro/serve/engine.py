"""The resident ProgramServer: warm jitted TaskPrograms serving a stream.

One server owns a mesh, a registry of resident graphs, and the TaskProgram
compile cache. Life of a request:

1. **Admission** — the tenant's :class:`~repro.core.queues.QueueConfig`
   resolves a per-round task *budget* (:meth:`QueueConfig.round_budget`,
   task class ``"serve"``). A request whose estimated per-round demand
   (its graph's edge count / its token block's task count) does not fit
   the tenant's remaining budget is rejected **before launch** —
   retriable when draining queued work could admit it, non-retriable
   when its demand alone exceeds the budget — admission replaces silent
   in-flight IQ drops.
2. **Batching** — admitted graph queries of one (program, graph) shape
   class are fused into a fixed-width tenant-column batch
   (:mod:`repro.serve.batching`): one shard_map launch serves up to
   ``batch_width`` tenants; short batches are padded so every launch hits
   the SAME compile-cache entry.
3. **Execution** — :func:`repro.sparse.program.launch_program` on the
   batched program: launches are *device futures* (JAX async dispatch),
   held in an inflight window of up to ``ServeOptions.inflight_depth``
   batches so batch k+1 forms and launches while batch k computes;
   results are harvested lazily, oldest-first, and per-request results
   are the unpacked tenant columns, bit-identical to standalone launches
   for the min-reduce programs under ANY depth.
4. **Observability** — per-tenant and aggregate counters
   (:mod:`repro.serve.stats`): queue depth, compile-cache hit rate,
   NoC drops (always attributed, never swallowed), p50/p99 latency.
5. **Resilience** — the failure posture is a first-class contract
   (:mod:`repro.serve.resilience`): a failed launch (at dispatch, from
   the device at harvest, in the MoE lane, or an injected host loss)
   never takes the server down. With ``ServeOptions.max_retries`` set,
   the poisoned batch's riders are requeued at the head of their
   tenant's queue with deterministic exponential backoff; past the
   retry budget or a ``deadline_s``, the request fails with a distinct
   reason. A per-shape-class :class:`~repro.serve.resilience.
   CircuitBreaker` fails persistent offenders fast, and a ``host_loss``
   fault shrinks the :class:`~repro.core.fabric.Fabric` to the
   survivors, re-prewarms only the classes with queued traffic, and
   relaunches — min-reduce survivors stay bit-identical to a fault-free
   run. Every fault is injectable deterministically by launch index via
   :class:`~repro.serve.resilience.ServeFailurePlan`.

MoE dispatch rides the same loop through :class:`MoEService`: token
blocks are batched to a fixed [B, S, D] shape class and dispatched
through one warm jitted ``moe_dcra`` callable.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Mapping, Optional, Tuple

import numpy as np

from ..core.queues import QueueConfig
from ..runtime.fault_tolerance import InjectedFailure, RetryLedger
from ..sparse import program as program_mod
from ..sparse.csr import CSR
from ..sparse.options import LaunchOptions
from ..sparse.program import prewarm_program
from .batching import (BATCHED_PROGRAMS, DrrFormer, FifoFormer, TenantBatch,
                       batched_program, split_tenant_states, tenant_graph)
from .options import ServeOptions
from .resilience import (BREAKER_CLOSED, FAULT_DEVICE, FAULT_HOST_LOSS,
                         CircuitBreaker, ServeFailurePlan)
from .stats import ServingStats

STATUS_OK = "ok"
STATUS_REJECTED = "rejected"          # admission control; retriable unless
                                      # the request can never fit the budget
STATUS_FAILED = "failed"

#: the QueueConfig task class admission budgets resolve through
ADMISSION_TASK = "serve"


@dataclass(frozen=True)
class Request:
    """One unit of tenant traffic.

    Graph queries name a resident ``graph`` and a ``root``; MoE dispatch
    requests carry a ``payload`` token block [S, D] instead.
    """
    req_id: int
    tenant: str
    program: str                       # "bfs" | "sssp" | "moe"
    graph: Optional[str] = None
    root: int = 0
    payload: Optional[np.ndarray] = None
    params: Mapping = field(default_factory=dict)


@dataclass(frozen=True)
class Response:
    """One request's outcome — immutable once issued (like
    :class:`Request`, part of the stable ``repro.serve`` surface)."""
    req_id: int
    tenant: str
    status: str                        # STATUS_OK | _REJECTED | _FAILED
    retriable: bool = False
    reason: str = ""
    result: Optional[np.ndarray] = None
    batch_drops: int = 0               # NoC drops of the fused launch
    batch_messages: int = 0            # routed tasks of the fused launch
    rounds: int = 0
    batch_width: int = 0               # real tenants in the launch
    latency_s: float = 0.0             # end-to-end: submit -> harvest
    queue_wait_s: float = 0.0          # submit -> launch (formation wait)
    device_s: float = 0.0              # launch -> harvest (compute + xfer)
    retries: int = 0                   # failed launches this request rode
                                       # before this terminal outcome


@dataclass
class _Pending:
    """One admitted request waiting in a batch former (the former only
    reads ``tenant`` / ``klass`` / ``demand``). A retried entry keeps
    its original ``t_enq`` (latency and the deadline both span the whole
    life of the request, retries included); ``not_before`` parks it out
    of the former until its backoff elapses."""
    req: Request
    t_enq: float                       # submit() wall-clock
    demand: int                        # admission-time task estimate
    deadline: Optional[float] = None   # absolute perf_counter deadline
    not_before: float = 0.0            # backoff gate for retried entries

    @property
    def tenant(self) -> str:
        return self.req.tenant

    @property
    def klass(self) -> Tuple[str, Optional[str]]:
        return (self.req.program, self.req.graph)


@dataclass
class _InflightBatch:
    """One launched-but-unharvested fused batch in the window.

    ``launch`` is the :class:`~repro.sparse.program.ProgramLaunch`
    device future; ``error`` is set instead when the launch itself threw
    (the batch then 'completes' instantly at harvest with every rider
    failed, keeping response order identical to the synchronous loop).
    Launch-time cache-delta and padding stats are stashed here and
    applied only on successful harvest, matching the synchronous loop's
    accounting on the failure path.
    """
    entries: List[_Pending]
    batch: TenantBatch
    g_n: int                           # base-graph vertex count
    t_launch: float
    launch: Optional[object] = None    # ProgramLaunch
    error: Optional[str] = None
    cache_hits: int = 0
    cache_misses: int = 0
    index: int = 0                     # server-wide launch index
    inject_device: bool = False        # ServeFailurePlan device fault:
                                       # surface an error at harvest

    @property
    def klass(self) -> Tuple[str, Optional[str]]:
        return (self.batch.program, self.batch.graph)

    def ready(self) -> bool:
        return self.error is not None or self.launch.is_ready()


class ProgramServer:
    """Resident serving engine over one fabric + graph registry.

    ``fabric`` is a :class:`repro.core.fabric.Fabric`; raw meshes keep
    working through the warn-once shim (identical compile-cache keys).

    ``tenant_queues`` maps tenant -> :class:`QueueConfig` admission
    budget (``default_queues`` covers the rest; ``None`` = unbounded
    admission). ``options`` is the :class:`LaunchOptions` default applied
    to EVERY launch the server issues (pre-warm included) — queue sizing,
    ``route_impl``, ``round_mode="pipelined"``, all of it; the legacy
    ``axis=`` / ``launch_queues=`` kwargs keep working when ``options``
    is not given (mixing the two raises). The default factor-4 sizing is
    drop-free for the serving graphs, which is what keeps batched results
    bit-identical to standalone runs.

    ``serve_options`` is the :class:`~repro.serve.options.ServeOptions`
    for the loop itself — inflight window depth, batch-formation
    fairness (FIFO vs deficit round-robin), state-buffer donation. The
    default reproduces the synchronous drain loop bit-for-bit.

    **The serving-loop contract** (one place, the three methods below are
    thin entries into it):

    * :meth:`step` advances the pipeline by one batch: it launches
      fused batches (the batch former pops up to ``batch_width``
      requests of one (program, graph) class, one per tenant; each
      becomes a single padded tenant-column
      :func:`~repro.sparse.program.launch_program` device future) until
      the inflight window holds ``ServeOptions.inflight_depth`` of
      them, then harvests every *completed* batch oldest-first —
      blocking on the oldest only when nothing is ready — and returns
      the harvested responses, ``[]`` when idle. Responses always
      stream in launch order; with ``inflight_depth=1`` this is exactly
      the old launch-then-block step. An MoE batch is a synchronous
      barrier: the window settles first, then the one MoE dispatch
      runs. A failed launch — at dispatch or surfacing from the device
      at harvest — never takes the server down and poisons only its
      own batch; earlier and later inflight batches complete normally.
      With the default ``ServeOptions`` every rider of a poisoned
      batch gets a non-retriable :data:`STATUS_FAILED` response (the
      historical behavior, byte-identical reasons); with
      ``max_retries > 0`` riders with remaining retry budget AND
      deadline are requeued at the head of their tenant's queue (with
      deterministic backoff) instead, and only budget/deadline
      exhaustion is terminal. ``breaker_threshold`` consecutive
      failures of one (program, graph) class open that class's circuit
      breaker: submissions fail fast retriably, formed batches hold,
      one half-open probe decides. An injected ``host_loss`` fault
      shrinks the fabric (:meth:`~repro.core.fabric.Fabric.shrink`),
      requeues the poisoned window's riders and re-prewarms ONLY the
      classes with queued traffic — unaffected classes are never
      re-traced.
    * :meth:`drain` calls :meth:`step` until the queue, the inflight
      window AND the backoff park are empty, concatenating responses
      (launch order across batches).
    * :meth:`run` is submit-then-drain for a whole request list:
      admission rejections are collected (never dropped), the queue is
      drained, and ALL responses come back sorted by ``req_id``.

    Responses are one-to-one with submitted requests in every path, and
    (for the deterministic min-reduce programs) bit-identical across
    every ``inflight_depth`` and to standalone launches.
    """

    def __init__(self, fabric, graphs: Dict[str, CSR], *,
                 axis: str = "data",
                 batch_width: int = 4,
                 tenant_queues: Optional[Dict[str, QueueConfig]] = None,
                 default_queues: Optional[QueueConfig] = None,
                 launch_queues: Optional[QueueConfig] = None,
                 max_rounds: Optional[int] = None,
                 moe: Optional["MoEService"] = None,
                 options: Optional[LaunchOptions] = None,
                 serve_options: Optional[ServeOptions] = None,
                 failure_plan: Optional[ServeFailurePlan] = None):
        if options is not None:
            if axis != "data" or launch_queues is not None:
                raise ValueError("options= conflicts with explicit axis=/"
                                 "launch_queues=: fold them into the "
                                 "LaunchOptions")
            self.options = options.resolve()
        else:
            self.options = LaunchOptions(axis=axis,
                                         queues=launch_queues).resolve()
        from ..core.fabric import as_fabric
        self.fabric = as_fabric(fabric)     # raw Mesh -> warn-once shim
        self.mesh = self.fabric.mesh        # kept for the MoE lane
        self.axis = self.options.axis
        self.graphs = dict(graphs)
        self.batch_width = int(batch_width)
        self.tenant_queues = dict(tenant_queues or {})
        self.default_queues = default_queues
        self.launch_queues = self.options.queues
        self.max_rounds = max_rounds
        self.moe = moe
        self.serve_options = (serve_options or ServeOptions()).resolve()
        self.stats = ServingStats()
        self._former = (DrrFormer(self.serve_options.drr_quantum)
                        if self.serve_options.fairness == "drr"
                        else FifoFormer())
        self._window: Deque[_InflightBatch] = deque()
        self._inflight_demand: Dict[str, int] = {}
        self._n_dev = self.fabric.n_devices
        # resilience state (repro.serve.resilience): deterministic fault
        # schedule, per-request retry ledger, per-shape-class breakers,
        # and the backoff park (retried entries waiting out not_before)
        self.failure_plan = failure_plan
        self._retry = RetryLedger(
            max_retries=self.serve_options.max_retries,
            backoff_base_s=self.serve_options.backoff_base_s)
        self._breakers: Dict[Tuple[str, Optional[str]], CircuitBreaker] = {}
        self._parked: List[_Pending] = []
        self._launch_index = 0

    # ---- admission -------------------------------------------------------

    def _demand(self, req: Request) -> int:
        """Estimated per-round task injection of one request: worst case,
        every edge of the tenant's column emits (graph queries), or every
        token spawns top-k expert tasks (MoE)."""
        if req.program == "moe":
            if self.moe is None:
                raise ValueError("server has no MoEService configured")
            return self.moe.demand(req.payload)
        prog = batched_program(req.program)
        g = self.graphs[req.graph]
        return g.nnz * (2 if prog.undirected else 1)

    def _budget(self, tenant: str, demand: int) -> Optional[int]:
        q = self.tenant_queues.get(tenant, self.default_queues)
        if q is None:
            return None
        return q.round_budget(ADMISSION_TASK, demand, self._n_dev)

    def submit(self, req: Request) -> Optional[Response]:
        """Admit ``req`` into the serving queue, or reject it.

        Returns ``None`` on admission; a :data:`STATUS_REJECTED` response
        when the tenant's per-round budget is exhausted —
        ``retriable=True`` when the request would fit an idle budget (the
        tenant may resubmit once its queued work drains),
        ``retriable=False`` when its demand alone exceeds the budget, so
        no amount of draining could ever admit it. A non-closed circuit
        breaker for the request's (program, graph) class also rejects —
        always retriably, naming the breaker — before any budget is
        charged. Unknown programs/graphs and out-of-range roots fail
        loudly at submit time.
        """
        ts = self.stats.tenant(req.tenant)
        ts.submitted += 1
        if req.program == "moe":
            if self.moe is None:
                ts.failed += 1
                return Response(req.req_id, req.tenant, STATUS_FAILED,
                                reason="server has no MoEService configured")
        else:
            if req.program not in BATCHED_PROGRAMS:
                ts.failed += 1
                return Response(req.req_id, req.tenant, STATUS_FAILED,
                                reason=f"no batched program {req.program!r}")
            if req.graph not in self.graphs:
                ts.failed += 1
                return Response(req.req_id, req.tenant, STATUS_FAILED,
                                reason=f"unknown graph {req.graph!r}")
            n = self.graphs[req.graph].n
            if not 0 <= int(req.root) < n:
                # an unchecked root would seed distance 0 inside ANOTHER
                # tenant's column (_multi_root_init writes dist[t*n+root])
                ts.failed += 1
                return Response(
                    req.req_id, req.tenant, STATUS_FAILED,
                    reason=(f"root {req.root} out of range [0, {n}) "
                            f"for graph {req.graph!r}"))
        br = self._breakers.get((req.program, req.graph))
        if br is not None and br.state != BREAKER_CLOSED:
            # fail fast: the class keeps failing on device — reject
            # retriably at admission instead of burning a launch slot
            ts.rejected += 1
            return Response(req.req_id, req.tenant, STATUS_REJECTED,
                            retriable=True, reason=br.reject_reason())
        demand = self._demand(req)
        budget = self._budget(req.tenant, demand)
        pending = self._inflight_demand.get(req.tenant, 0)
        if budget is not None and pending + demand > budget:
            ts.rejected += 1
            if demand > budget:
                return Response(
                    req.req_id, req.tenant, STATUS_REJECTED,
                    retriable=False,
                    reason=(f"demand {demand} exceeds tenant budget "
                            f"{budget} tasks/round — can never be "
                            f"admitted; resubmission is futile"))
            return Response(
                req.req_id, req.tenant, STATUS_REJECTED, retriable=True,
                reason=(f"tenant budget {budget} tasks/round: "
                        f"{pending} pending + {demand} requested"))
        self._inflight_demand[req.tenant] = pending + demand
        now = time.perf_counter()
        deadline = (None if self.serve_options.deadline_s is None
                    else now + self.serve_options.deadline_s)
        self._former.push(_Pending(req, now, demand, deadline=deadline))
        self.stats.observe_queue_depth(len(self._former))
        return None

    # ---- pre-warm --------------------------------------------------------

    def prewarm(self, programs: Tuple[str, ...] = ("bfs", "sssp"),
                graphs: Optional[Tuple[str, ...]] = None) -> Dict:
        """Trace + compile every (program, graph, batch_width) shape
        class before traffic arrives; returns {(program, graph): keys}.

        Init-only roots are outside the compile-cache key, so one
        pre-warm per shape class covers every later request batch.
        """
        out = {}
        for name in programs:
            if name == "moe":
                if self.moe is not None:
                    self.moe.prewarm(self.mesh)
                continue
            for gname in (graphs if graphs is not None else self.graphs):
                out[(name, gname)] = self._prewarm_class(name, gname)
        return out

    def _prewarm_class(self, name: str, gname: str):
        """Trace + compile ONE (program, graph, batch_width) shape class
        on the server's *current* fabric — the unit :meth:`prewarm`
        iterates and the host-loss path re-runs for exactly the classes
        with queued traffic (never the whole registry: an unaffected
        class must not re-trace)."""
        keys = prewarm_program(
            batched_program(name),
            tenant_graph(self.graphs[gname], self.batch_width),
            self.fabric, options=self.options, max_rounds=self.max_rounds,
            donate_states=self.serve_options.donate_buffers,
            params={"roots": (0,) * self.batch_width})
        self.stats.prewarmed_keys += len(keys)
        return keys

    # ---- the serving loop ------------------------------------------------

    def _width_for(self, entry: _Pending) -> int:
        return (self.moe.batch if entry.req.program == "moe"
                else self.batch_width)

    def _finish(self, entry: _Pending, resp: Response) -> Response:
        req = entry.req
        left = self._inflight_demand.get(req.tenant, 0) - entry.demand
        if left < 0:                   # would mask a double-_finish bug
            raise AssertionError(
                f"tenant {req.tenant!r} inflight demand went negative "
                f"({left}) finishing req {req.req_id} — double _finish?")
        if left:
            self._inflight_demand[req.tenant] = left
        else:
            # drop zeroed keys: a resident server must not leak one dict
            # slot per tenant ever seen
            del self._inflight_demand[req.tenant]
        self._retry.clear(req.req_id)  # terminal outcome: O(inflight) ledger
        ts = self.stats.tenant(req.tenant)
        if resp.status == STATUS_OK:
            ts.served += 1
        else:
            ts.failed += 1
        ts.noc_drops += resp.batch_drops
        ts.messages += resp.batch_messages
        ts.rounds += resp.rounds
        ts.latencies.append(resp.latency_s)
        ts.queue_waits.append(resp.queue_wait_s)
        ts.device_times.append(resp.device_s)
        return resp

    # ---- resilience helpers ----------------------------------------------

    def _next_launch_slot(self) -> Tuple[int, Optional[str]]:
        """Claim the next launch index and pop any fault the plan
        scheduled there — the ONE place the index advances, so graph and
        MoE launches share a single deterministic counter."""
        idx = self._launch_index
        self._launch_index += 1
        kind = (self.failure_plan.due(idx)
                if self.failure_plan is not None else None)
        return idx, kind

    def _breaker(self, klass: Tuple[str, Optional[str]]
                 ) -> Optional[CircuitBreaker]:
        if self.serve_options.breaker_threshold is None:
            return None
        br = self._breakers.get(klass)
        if br is None:
            br = self._breakers[klass] = CircuitBreaker(
                threshold=self.serve_options.breaker_threshold,
                klass=klass)
        return br

    def _breaker_observe(self, klass, *, ok: bool) -> None:
        """Feed one launch outcome to the class's breaker and count the
        open/close transitions."""
        br = self._breaker(klass)
        if br is None:
            return
        if ok:
            if br.record_success():
                self.stats.breaker_closes += 1
        elif br.record_failure():
            self.stats.breaker_opens += 1

    def _requeue(self, entries: List[_Pending]) -> None:
        """Head-of-queue requeue for a failed batch's riders: reverse
        push_front keeps their relative order; entries still backing off
        go to the park instead (step() readmits them once ``not_before``
        passes)."""
        now = time.perf_counter()
        for e in reversed(entries):
            if e.not_before > now:
                self._parked.append(e)
            else:
                self._former.push_front(e)
        if self._parked:
            self._parked.sort(key=lambda e: e.not_before)

    def _unpark(self) -> None:
        """Move parked entries whose backoff elapsed back to the head of
        their queues."""
        if not self._parked:
            return
        now = time.perf_counter()
        ready = [e for e in self._parked if e.not_before <= now]
        if ready:
            self._parked = [e for e in self._parked if e.not_before > now]
            for e in reversed(ready):
                self._former.push_front(e)

    def _expire(self, entries: List[_Pending]
                ) -> Tuple[List[_Pending], List[Response]]:
        """Split formed entries into (still live, deadline-failed): a
        request past ``deadline_s`` fails non-retriably with a distinct
        reason BEFORE spending a launch on it."""
        if self.serve_options.deadline_s is None:
            return entries, []
        now = time.perf_counter()
        live, dead = [], []
        for e in entries:
            if e.deadline is not None and now >= e.deadline:
                dead.append(self._finish(e, Response(
                    e.req.req_id, e.req.tenant, STATUS_FAILED,
                    retriable=False,
                    reason=(f"deadline {self.serve_options.deadline_s:.6g}s "
                            f"exceeded before launch"),
                    latency_s=now - e.t_enq, queue_wait_s=now - e.t_enq,
                    retries=self._retry.attempt(e.req.req_id))))
            else:
                live.append(e)
        return live, dead

    def _settle_failed(self, entries: List[_Pending], err: str,
                       t_launch: float,
                       requeue_to: Optional[List[_Pending]] = None
                       ) -> List[Response]:
        """Disposition of a poisoned batch's riders: requeue those with
        retry budget and deadline remaining (head-of-queue, backoff via
        ``not_before``); fail the rest non-retriably — past-deadline
        riders and exhausted riders each with a distinct reason. With
        ``max_retries=0`` (default) this is byte-identical to the
        historical every-rider-fails path. ``requeue_to`` collects the
        retried riders instead of requeueing them now (the host-loss
        path settles several batches before one combined requeue that
        restores launch order)."""
        so = self.serve_options
        t1 = time.perf_counter()
        dt = t1 - t_launch
        out: List[Response] = []
        requeue: List[_Pending] = (requeue_to if requeue_to is not None
                                   else [])
        for e in entries:
            rid = e.req.req_id
            if e.deadline is not None and t1 >= e.deadline:
                out.append(self._finish(e, Response(
                    e.req.req_id, e.req.tenant, STATUS_FAILED,
                    retriable=False,
                    reason=(f"deadline {so.deadline_s:.6g}s exceeded "
                            f"({err})"),
                    latency_s=t1 - e.t_enq, device_s=dt,
                    queue_wait_s=t_launch - e.t_enq,
                    retries=self._retry.attempt(rid))))
            elif so.max_retries > 0 and self._retry.record_failure(rid):
                e.not_before = t1 + self._retry.backoff_s(rid)
                self.stats.tenant(e.req.tenant).retries += 1
                self.stats.retries += 1
                requeue.append(e)
            else:
                n = self._retry.attempt(rid)
                reason = (err if n == 0 else
                          f"{err} [failed after {n - 1} retries]")
                out.append(self._finish(e, Response(
                    e.req.req_id, e.req.tenant, STATUS_FAILED, reason=reason,
                    latency_s=t1 - e.t_enq, device_s=dt,
                    queue_wait_s=t_launch - e.t_enq, retries=max(0, n - 1))))
        if requeue_to is None:
            self._requeue(requeue)
        return out

    def _lose_hosts(self, entries: List[_Pending]) -> List[Response]:
        """The elastic-degrade path for an injected ``host_loss``:
        shrink the fabric to the survivors, poison every inflight batch
        (their launches ran on lost devices) AND the batch that was
        about to launch — all riders go through the normal retry
        disposition — then re-prewarm ONLY the shape classes that still
        have queued traffic. Min-reduce results on the shrunken fabric
        are bit-identical under drop-free sizing, so retried riders
        match a fault-free run."""
        plan = self.failure_plan
        old_n = self._n_dev
        keep = (plan.keep_devices if plan is not None
                and plan.keep_devices else max(1, old_n // 2))
        self.fabric = self.fabric.shrink(keep)
        self.mesh = self.fabric.mesh
        self._n_dev = self.fabric.n_devices
        self.stats.host_losses += 1
        err = (f"InjectedFailure: host loss at launch "
               f"{self._launch_index} (fabric {old_n} -> "
               f"{self._n_dev} devices)")
        out: List[Response] = []
        riders: List[_Pending] = []    # combined requeue: one reversed
        lost, self._window = list(self._window), deque()
        for ib in lost:                # poisoned window, oldest first
            out.extend(self._settle_failed(ib.entries, err, ib.t_launch,
                                           requeue_to=riders))
        out.extend(self._settle_failed(entries, err, time.perf_counter(),
                                       requeue_to=riders))
        self._requeue(riders)          # push_front puts riders[0] (the
        # oldest poisoned batch's first rider) back at the very head, so
        # relaunches replay in the original launch order
        classes = set(self._former.pending_classes())
        classes.update(e.klass for e in self._parked)
        for name, gname in sorted(c for c in classes if c[0] != "moe"):
            self._prewarm_class(name, gname)
        return out

    # ---- launch / harvest ------------------------------------------------

    def _launch_batch(self, entries: List[_Pending]) -> _InflightBatch:
        """Dispatch one fused batch WITHOUT waiting on the result: the
        returned record enters the inflight window. A launch-time
        exception (or an injected launch fault) is captured in ``error``
        (harvest settles the riders in window order) — it never takes
        the server down."""
        reqs = [e.req for e in entries]
        gname = reqs[0].graph
        g = self.graphs[gname]
        batch = TenantBatch(
            program=reqs[0].program, graph=gname, width=self.batch_width,
            roots=tuple(int(r.root) for r in reqs),
            tenants=[r.tenant for r in reqs],
            req_ids=[r.req_id for r in reqs]).padded()
        tg = tenant_graph(g, self.batch_width)
        c0 = program_mod.cache_stats()
        t0 = time.perf_counter()
        idx, kind = self._next_launch_slot()
        ib = _InflightBatch(entries=entries, batch=batch, g_n=g.n,
                            t_launch=t0, index=idx)
        if kind == FAULT_DEVICE:
            # dispatch normally; the error surfaces at harvest, like an
            # ICI timeout mid-collective would
            ib.inject_device = True
            kind = None
        try:
            if kind is not None:
                raise InjectedFailure(f"{kind} fault at launch {idx}")
            ib.launch = program_mod.launch_program(
                batched_program(reqs[0].program), tg, self.fabric,
                options=self.options, max_rounds=self.max_rounds,
                donate_states=self.serve_options.donate_buffers,
                params={"roots": batch.roots})
        except Exception as e:  # noqa: BLE001 — a failed launch must not
            # take the server down; its riders are settled at harvest
            # (retried when budget remains, failed otherwise)
            ib.error = f"{type(e).__name__}: {e}"
            return ib
        c1 = program_mod.cache_stats()
        ib.cache_hits = c1["hits"] - c0["hits"]
        ib.cache_misses = c1["misses"] - c0["misses"]
        return ib

    def _harvest(self, ib: _InflightBatch) -> List[Response]:
        """Materialize one inflight batch: block, transfer, split tenant
        columns, settle the ledger. Failures (captured at launch OR
        surfacing from the device at harvest) poison only this batch's
        riders — settled through the retry disposition
        (:meth:`_settle_failed`) and fed to the class's breaker."""
        err = ib.error
        app_stats = state = None
        if err is None and ib.inject_device:
            # the launch ran; the injected device error stands in for
            # its result surfacing as an ICI failure
            err = f"InjectedFailure: device fault at launch {ib.index}"
        elif err is None:
            try:
                (state,), app_stats = ib.launch.result()
            except Exception as e:  # noqa: BLE001 — device-side failure
                err = f"{type(e).__name__}: {e}"
        if err is not None:
            self._breaker_observe(ib.klass, ok=False)
            return self._settle_failed(ib.entries, err, ib.t_launch)
        t1 = time.perf_counter()
        dt = t1 - ib.t_launch
        self._breaker_observe(ib.klass, ok=True)
        self.stats.cache_hits += ib.cache_hits
        self.stats.cache_misses += ib.cache_misses
        self.stats.launches += 1
        self.stats.batched_requests += ib.batch.n_real
        self.stats.pad_columns += self.batch_width - ib.batch.n_real
        self.stats.noc_drops += app_stats.total_drops
        self.stats.round_latencies.append(dt / max(1, app_stats.rounds))
        per_tenant = split_tenant_states(state, ib.g_n, self.batch_width)
        return [self._finish(e, Response(
            e.req.req_id, e.req.tenant, STATUS_OK, result=per_tenant[i],
            batch_drops=app_stats.total_drops,
            batch_messages=app_stats.total_messages,
            rounds=app_stats.rounds, batch_width=ib.batch.n_real,
            latency_s=t1 - e.t_enq, device_s=dt,
            queue_wait_s=ib.t_launch - e.t_enq,
            retries=self._retry.attempt(e.req.req_id)))
            for i, e in enumerate(ib.entries)]

    def _harvest_window(self, *, block: bool) -> List[Response]:
        """Harvest completed batches oldest-first — NEVER out of order,
        so responses stream in launch order under any depth. Non-blocking
        unless ``block`` (then the whole window settles)."""
        out: List[Response] = []
        while self._window and (block or self._window[0].ready()):
            out.extend(self._harvest(self._window.popleft()))
        return out

    def step(self) -> List[Response]:
        """Advance the pipeline by one batch (see the class docstring's
        serving-loop contract); ``[]`` when idle."""
        out: List[Response] = []
        self._unpark()
        depth = self.serve_options.inflight_depth
        while len(self._former) and len(self._window) < depth:
            entries = self._former.form(self._width_for)
            self.stats.observe_queue_depth(len(self._former))
            live, dead = self._expire(entries)
            out.extend(dead)
            if not live:
                continue
            if (self.failure_plan is not None
                    and live[0].req.program != "moe"
                    and self.failure_plan.peek(self._launch_index)
                    == FAULT_HOST_LOSS):
                # the loss consumes this launch's index WITHOUT
                # advancing it: the relaunch on the survivors claims the
                # same slot, keeping later scheduled faults aligned
                self.failure_plan.due(self._launch_index)
                out.extend(self._lose_hosts(live))
                continue
            br = self._breaker(live[0].klass)
            if br is not None and not br.allows_launch():
                # half-open probe in flight: hold the class (requeued in
                # order); harvesting below settles the probe
                self._requeue(live)
                break
            if live[0].req.program == "moe":
                # the MoE lane is synchronous — settle the window first
                # so responses keep streaming in launch order
                out.extend(self._harvest_window(block=True))
                out.extend(self._step_moe(live))
                return out
            self._window.append(self._launch_batch(live))
        out.extend(self._harvest_window(block=False))
        if not out and self._window:
            # window full (or queue empty) and nothing ready: the oldest
            # launch is the one the loop must wait on
            out.extend(self._harvest(self._window.popleft()))
        if not out and not self._window and not len(self._former) \
                and self._parked:
            # everything is backing off: sleep to the earliest retry
            # gate instead of busy-spinning drain()
            wait = self._parked[0].not_before - time.perf_counter()
            if wait > 0:
                time.sleep(wait)
        return out

    def _step_moe(self, entries: List[_Pending]) -> List[Response]:
        reqs = [e.req for e in entries]
        t0 = time.perf_counter()
        idx, kind = self._next_launch_slot()
        try:
            if kind is not None:
                # the MoE lane is synchronous with no separate harvest
                # seam and no elastic path: every scheduled kind
                # degrades to a dispatch exception here
                raise InjectedFailure(f"{kind} fault at launch {idx} (moe)")
            outs, hit = self.moe.dispatch([r.payload for r in reqs],
                                          self.mesh)
        except Exception as e:  # noqa: BLE001
            self._breaker_observe(entries[0].klass, ok=False)
            return self._settle_failed(entries, f"{type(e).__name__}: {e}",
                                       t0)
        self._breaker_observe(entries[0].klass, ok=True)
        t1 = time.perf_counter()
        dt = t1 - t0
        self.stats.cache_hits += int(hit)
        self.stats.cache_misses += int(not hit)
        self.stats.launches += 1
        self.stats.batched_requests += len(reqs)
        self.stats.pad_columns += self.moe.batch - len(reqs)
        self.stats.round_latencies.append(dt)
        return [self._finish(en, Response(
            en.req.req_id, en.req.tenant, STATUS_OK, result=outs[i],
            rounds=1, batch_width=len(reqs), latency_s=t1 - en.t_enq,
            device_s=dt, queue_wait_s=t0 - en.t_enq,
            retries=self._retry.attempt(en.req.req_id)))
            for i, en in enumerate(entries)]

    def drain(self) -> List[Response]:
        """:meth:`step` until idle, then settle the whole inflight
        window (see the class docstring); entries parked on retry
        backoff count as pending — drain outlives every backoff."""
        out: List[Response] = []
        while len(self._former) or self._window or self._parked:
            out.extend(self.step())
        return out

    def run(self, requests: List[Request]) -> List[Response]:
        """Submit a whole stream, drain, return responses in ``req_id``
        order (see the class docstring)."""
        responses: List[Response] = []
        for req in requests:
            rej = self.submit(req)
            if rej is not None:
                responses.append(rej)
        responses.extend(self.drain())
        return sorted(responses, key=lambda r: r.req_id)

    @property
    def queue_depth(self) -> int:
        """Admitted requests not yet launched (inflight batches have
        left the queue; retried entries parked on backoff count)."""
        return len(self._former) + len(self._parked)

    @property
    def inflight_depth(self) -> int:
        """Launched-but-unharvested fused batches in the window."""
        return len(self._window)


class MoEService:
    """MoE dispatch as a serving lane: one warm jitted ``moe_dcra`` over a
    fixed [batch, seq, d_model] shape class; short batches zero-pad.

    ``traces`` counts actual jit traces (incremented inside the traced
    function, so a warm call leaves it unchanged) — the MoE analogue of
    the TaskProgram compile cache's no-re-trace assertion.
    """

    def __init__(self, cfg, params, info, *, batch: int = 4, seq: int = 16):
        if cfg.moe is None:
            raise ValueError("MoEService needs a config with cfg.moe set")
        self.cfg, self.params, self.info = cfg, params, info
        self.batch, self.seq = int(batch), int(seq)
        self.calls = 0
        self.traces = 0
        self._fn = None

    def demand(self, payload: Optional[np.ndarray]) -> int:
        seq = self.seq if payload is None else int(payload.shape[0])
        return seq * self.cfg.moe.top_k

    def _build(self):
        import jax

        from ..core.dispatch import moe_dcra

        def f(params, x):
            self.traces += 1
            return moe_dcra(params, x, self.cfg, self.info)

        return jax.jit(f)

    def prewarm(self, mesh) -> None:
        x = np.zeros((self.batch, self.seq, self.cfg.d_model), np.float32)
        self._dispatch_block(x, mesh)

    def _dispatch_block(self, x: np.ndarray, mesh):
        from ..core.compat import set_mesh
        from ..core.fabric import Fabric
        if self._fn is None:
            self._fn = self._build()
        before = self.traces
        with set_mesh(Fabric.of(mesh).mesh):   # mesh OR Fabric
            out, _aux = self._fn(self.params, x)
        self.calls += 1
        return np.asarray(out), self.traces == before

    def dispatch(self, payloads: List[np.ndarray], mesh
                 ) -> Tuple[List[np.ndarray], bool]:
        """Fuse up to ``batch`` [seq, d_model] token blocks into one
        dispatch; returns (per-request outputs, warm-cache hit)."""
        for p in payloads:
            if p is None or p.shape != (self.seq, self.cfg.d_model):
                raise ValueError(
                    f"MoE payload must be [{self.seq}, {self.cfg.d_model}]")
        x = np.zeros((self.batch, self.seq, self.cfg.d_model), np.float32)
        for i, p in enumerate(payloads):
            x[i] = p
        out, hit = self._dispatch_block(x, mesh)
        return [out[i] for i in range(len(payloads))], hit
