"""The resident ProgramServer: warm jitted TaskPrograms serving a stream.

One server owns a mesh, a registry of resident graphs, and the TaskProgram
compile cache. Life of a request:

1. **Admission** — the tenant's :class:`~repro.core.queues.QueueConfig`
   resolves a per-round task *budget* (:meth:`QueueConfig.round_budget`,
   task class ``"serve"``). A request whose estimated per-round demand
   (its graph's edge count / its token block's task count) does not fit
   the tenant's remaining budget is rejected **before launch** —
   retriable when draining queued work could admit it, non-retriable
   when its demand alone exceeds the budget — admission replaces silent
   in-flight IQ drops.
2. **Batching** — admitted graph queries of one (program, graph) shape
   class are fused into a fixed-width tenant-column batch
   (:mod:`repro.serve.batching`): one shard_map launch serves up to
   ``batch_width`` tenants; short batches are padded so every launch hits
   the SAME compile-cache entry.
3. **Execution** — :func:`repro.sparse.program.run_program` on the
   batched program; per-request results are the unpacked tenant columns,
   bit-identical to standalone launches for the min-reduce programs.
4. **Observability** — per-tenant and aggregate counters
   (:mod:`repro.serve.stats`): queue depth, compile-cache hit rate,
   NoC drops (always attributed, never swallowed), p50/p99 latency.

MoE dispatch rides the same loop through :class:`MoEService`: token
blocks are batched to a fixed [B, S, D] shape class and dispatched
through one warm jitted ``moe_dcra`` callable.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Mapping, Optional, Tuple

import numpy as np

from ..core.queues import QueueConfig
from ..sparse import program as program_mod
from ..sparse.csr import CSR
from ..sparse.options import LaunchOptions
from ..sparse.program import prewarm_program, run_program
from .batching import (BATCHED_PROGRAMS, TenantBatch, batched_program,
                       split_tenant_states, tenant_graph)
from .stats import ServingStats

STATUS_OK = "ok"
STATUS_REJECTED = "rejected"          # admission control; retriable unless
                                      # the request can never fit the budget
STATUS_FAILED = "failed"

#: the QueueConfig task class admission budgets resolve through
ADMISSION_TASK = "serve"


@dataclass(frozen=True)
class Request:
    """One unit of tenant traffic.

    Graph queries name a resident ``graph`` and a ``root``; MoE dispatch
    requests carry a ``payload`` token block [S, D] instead.
    """
    req_id: int
    tenant: str
    program: str                       # "bfs" | "sssp" | "moe"
    graph: Optional[str] = None
    root: int = 0
    payload: Optional[np.ndarray] = None
    params: Mapping = field(default_factory=dict)


@dataclass(frozen=True)
class Response:
    """One request's outcome — immutable once issued (like
    :class:`Request`, part of the stable ``repro.serve`` surface)."""
    req_id: int
    tenant: str
    status: str                        # STATUS_OK | _REJECTED | _FAILED
    retriable: bool = False
    reason: str = ""
    result: Optional[np.ndarray] = None
    batch_drops: int = 0               # NoC drops of the fused launch
    batch_messages: int = 0            # routed tasks of the fused launch
    rounds: int = 0
    batch_width: int = 0               # real tenants in the launch
    latency_s: float = 0.0


class ProgramServer:
    """Resident serving engine over one fabric + graph registry.

    ``fabric`` is a :class:`repro.core.fabric.Fabric`; raw meshes keep
    working through the warn-once shim (identical compile-cache keys).

    ``tenant_queues`` maps tenant -> :class:`QueueConfig` admission
    budget (``default_queues`` covers the rest; ``None`` = unbounded
    admission). ``options`` is the :class:`LaunchOptions` default applied
    to EVERY launch the server issues (pre-warm included) — queue sizing,
    ``route_impl``, ``round_mode="pipelined"``, all of it; the legacy
    ``axis=`` / ``launch_queues=`` kwargs keep working when ``options``
    is not given (mixing the two raises). The default factor-4 sizing is
    drop-free for the serving graphs, which is what keeps batched results
    bit-identical to standalone runs.

    **The serving-loop contract** (one place, the three methods below are
    thin entries into it):

    * :meth:`step` serves exactly ONE fused batch — it pops up to
      ``batch_width`` queued requests of the head-of-line (program,
      graph) class (one request per tenant), launches them as a single
      padded tenant-column ``run_program`` (or one MoE dispatch), and
      returns that batch's responses, ``[]`` when the queue is idle. A
      failed launch never takes the server down: every rider gets a
      non-retriable :data:`STATUS_FAILED` response.
    * :meth:`drain` calls :meth:`step` until the queue is empty and
      concatenates the responses (arrival order across batches).
    * :meth:`run` is submit-then-drain for a whole request list:
      admission rejections are collected (never dropped), the queue is
      drained, and ALL responses come back sorted by ``req_id``.

    Responses are one-to-one with submitted requests in every path.
    """

    def __init__(self, fabric, graphs: Dict[str, CSR], *,
                 axis: str = "data",
                 batch_width: int = 4,
                 tenant_queues: Optional[Dict[str, QueueConfig]] = None,
                 default_queues: Optional[QueueConfig] = None,
                 launch_queues: Optional[QueueConfig] = None,
                 max_rounds: Optional[int] = None,
                 moe: Optional["MoEService"] = None,
                 options: Optional[LaunchOptions] = None):
        if options is not None:
            if axis != "data" or launch_queues is not None:
                raise ValueError("options= conflicts with explicit axis=/"
                                 "launch_queues=: fold them into the "
                                 "LaunchOptions")
            self.options = options.resolve()
        else:
            self.options = LaunchOptions(axis=axis,
                                         queues=launch_queues).resolve()
        from ..core.fabric import as_fabric
        self.fabric = as_fabric(fabric)     # raw Mesh -> warn-once shim
        self.mesh = self.fabric.mesh        # kept for the MoE lane
        self.axis = self.options.axis
        self.graphs = dict(graphs)
        self.batch_width = int(batch_width)
        self.tenant_queues = dict(tenant_queues or {})
        self.default_queues = default_queues
        self.launch_queues = self.options.queues
        self.max_rounds = max_rounds
        self.moe = moe
        self.stats = ServingStats()
        self._queue: Deque[Request] = deque()
        self._inflight_demand: Dict[str, int] = {}
        self._n_dev = self.fabric.n_devices

    # ---- admission -------------------------------------------------------

    def _demand(self, req: Request) -> int:
        """Estimated per-round task injection of one request: worst case,
        every edge of the tenant's column emits (graph queries), or every
        token spawns top-k expert tasks (MoE)."""
        if req.program == "moe":
            if self.moe is None:
                raise ValueError("server has no MoEService configured")
            return self.moe.demand(req.payload)
        prog = batched_program(req.program)
        g = self.graphs[req.graph]
        return g.nnz * (2 if prog.undirected else 1)

    def _budget(self, tenant: str, demand: int) -> Optional[int]:
        q = self.tenant_queues.get(tenant, self.default_queues)
        if q is None:
            return None
        return q.round_budget(ADMISSION_TASK, demand, self._n_dev)

    def submit(self, req: Request) -> Optional[Response]:
        """Admit ``req`` into the serving queue, or reject it.

        Returns ``None`` on admission; a :data:`STATUS_REJECTED` response
        when the tenant's per-round budget is exhausted —
        ``retriable=True`` when the request would fit an idle budget (the
        tenant may resubmit once its queued work drains),
        ``retriable=False`` when its demand alone exceeds the budget, so
        no amount of draining could ever admit it. Unknown
        programs/graphs and out-of-range roots fail loudly at submit
        time.
        """
        ts = self.stats.tenant(req.tenant)
        ts.submitted += 1
        if req.program == "moe":
            if self.moe is None:
                ts.failed += 1
                return Response(req.req_id, req.tenant, STATUS_FAILED,
                                reason="server has no MoEService configured")
        else:
            if req.program not in BATCHED_PROGRAMS:
                ts.failed += 1
                return Response(req.req_id, req.tenant, STATUS_FAILED,
                                reason=f"no batched program {req.program!r}")
            if req.graph not in self.graphs:
                ts.failed += 1
                return Response(req.req_id, req.tenant, STATUS_FAILED,
                                reason=f"unknown graph {req.graph!r}")
            n = self.graphs[req.graph].n
            if not 0 <= int(req.root) < n:
                # an unchecked root would seed distance 0 inside ANOTHER
                # tenant's column (_multi_root_init writes dist[t*n+root])
                ts.failed += 1
                return Response(
                    req.req_id, req.tenant, STATUS_FAILED,
                    reason=(f"root {req.root} out of range [0, {n}) "
                            f"for graph {req.graph!r}"))
        demand = self._demand(req)
        budget = self._budget(req.tenant, demand)
        pending = self._inflight_demand.get(req.tenant, 0)
        if budget is not None and pending + demand > budget:
            ts.rejected += 1
            if demand > budget:
                return Response(
                    req.req_id, req.tenant, STATUS_REJECTED,
                    retriable=False,
                    reason=(f"demand {demand} exceeds tenant budget "
                            f"{budget} tasks/round — can never be "
                            f"admitted; resubmission is futile"))
            return Response(
                req.req_id, req.tenant, STATUS_REJECTED, retriable=True,
                reason=(f"tenant budget {budget} tasks/round: "
                        f"{pending} pending + {demand} requested"))
        self._inflight_demand[req.tenant] = pending + demand
        self._queue.append(req)
        self.stats.observe_queue_depth(len(self._queue))
        return None

    # ---- pre-warm --------------------------------------------------------

    def prewarm(self, programs: Tuple[str, ...] = ("bfs", "sssp"),
                graphs: Optional[Tuple[str, ...]] = None) -> Dict:
        """Trace + compile every (program, graph, batch_width) shape
        class before traffic arrives; returns {(program, graph): keys}.

        Init-only roots are outside the compile-cache key, so one
        pre-warm per shape class covers every later request batch.
        """
        out = {}
        for name in programs:
            if name == "moe":
                if self.moe is not None:
                    self.moe.prewarm(self.mesh)
                continue
            prog = batched_program(name)
            for gname in (graphs if graphs is not None else self.graphs):
                tg = tenant_graph(self.graphs[gname], self.batch_width)
                keys = prewarm_program(
                    prog, tg, self.fabric, options=self.options,
                    max_rounds=self.max_rounds,
                    params={"roots": (0,) * self.batch_width})
                out[(name, gname)] = keys
                self.stats.prewarmed_keys += len(keys)
        return out

    # ---- the serving loop ------------------------------------------------

    def _next_batch(self) -> List[Request]:
        """Pop up to ``batch_width`` queued requests of the head-of-line
        (program, graph) class, preserving arrival order of the rest.
        At most one request per tenant rides a batch — each tenant owns
        whole columns, so per-tenant results stay per-tenant."""
        head = self._queue[0]
        key = (head.program, head.graph)
        width = (self.moe.batch if head.program == "moe"
                 else self.batch_width)
        taken: List[Request] = []
        seen_tenants = set()
        rest: Deque[Request] = deque()
        while self._queue:
            r = self._queue.popleft()
            if (len(taken) < width and (r.program, r.graph) == key
                    and r.tenant not in seen_tenants):
                taken.append(r)
                seen_tenants.add(r.tenant)
            else:
                rest.append(r)
        self._queue = rest
        return taken

    def _finish(self, req: Request, resp: Response) -> Response:
        self._inflight_demand[req.tenant] -= self._demand(req)
        ts = self.stats.tenant(req.tenant)
        if resp.status == STATUS_OK:
            ts.served += 1
        else:
            ts.failed += 1
        ts.noc_drops += resp.batch_drops
        ts.messages += resp.batch_messages
        ts.rounds += resp.rounds
        ts.latencies.append(resp.latency_s)
        return resp

    def step(self) -> List[Response]:
        """Serve ONE fused batch (see the class docstring's serving-loop
        contract); ``[]`` when idle."""
        if not self._queue:
            return []
        batch_reqs = self._next_batch()
        if batch_reqs[0].program == "moe":
            return self._step_moe(batch_reqs)
        return self._step_graph(batch_reqs)

    def _step_graph(self, reqs: List[Request]) -> List[Response]:
        prog = batched_program(reqs[0].program)
        gname = reqs[0].graph
        g = self.graphs[gname]
        batch = TenantBatch(
            program=reqs[0].program, graph=gname, width=self.batch_width,
            roots=tuple(int(r.root) for r in reqs),
            tenants=[r.tenant for r in reqs],
            req_ids=[r.req_id for r in reqs]).padded()
        tg = tenant_graph(g, self.batch_width)
        c0 = program_mod.cache_stats()
        t0 = time.perf_counter()
        try:
            (state,), app_stats = run_program(
                prog, tg, self.fabric, options=self.options,
                max_rounds=self.max_rounds,
                params={"roots": batch.roots})
        except Exception as e:  # noqa: BLE001 — a failed launch must not
            # take the server down; every rider gets a non-retriable
            # failure (the request itself is suspect, not the capacity)
            dt = time.perf_counter() - t0
            return [self._finish(r, Response(
                r.req_id, r.tenant, STATUS_FAILED, latency_s=dt,
                reason=f"{type(e).__name__}: {e}")) for r in reqs]
        dt = time.perf_counter() - t0
        c1 = program_mod.cache_stats()
        self.stats.cache_hits += c1["hits"] - c0["hits"]
        self.stats.cache_misses += c1["misses"] - c0["misses"]
        self.stats.launches += 1
        self.stats.batched_requests += batch.n_real
        self.stats.pad_columns += self.batch_width - batch.n_real
        self.stats.noc_drops += app_stats.total_drops
        self.stats.round_latencies.append(dt / max(1, app_stats.rounds))
        per_tenant = split_tenant_states(state, g.n, self.batch_width)
        return [self._finish(r, Response(
            r.req_id, r.tenant, STATUS_OK, result=per_tenant[i],
            batch_drops=app_stats.total_drops,
            batch_messages=app_stats.total_messages,
            rounds=app_stats.rounds,
            batch_width=batch.n_real, latency_s=dt))
            for i, r in enumerate(reqs)]

    def _step_moe(self, reqs: List[Request]) -> List[Response]:
        t0 = time.perf_counter()
        try:
            outs, hit = self.moe.dispatch([r.payload for r in reqs],
                                          self.mesh)
        except Exception as e:  # noqa: BLE001
            dt = time.perf_counter() - t0
            return [self._finish(r, Response(
                r.req_id, r.tenant, STATUS_FAILED, latency_s=dt,
                reason=f"{type(e).__name__}: {e}")) for r in reqs]
        dt = time.perf_counter() - t0
        self.stats.cache_hits += int(hit)
        self.stats.cache_misses += int(not hit)
        self.stats.launches += 1
        self.stats.batched_requests += len(reqs)
        self.stats.pad_columns += self.moe.batch - len(reqs)
        self.stats.round_latencies.append(dt)
        return [self._finish(r, Response(
            r.req_id, r.tenant, STATUS_OK, result=outs[i], rounds=1,
            batch_width=len(reqs), latency_s=dt))
            for i, r in enumerate(reqs)]

    def drain(self) -> List[Response]:
        """:meth:`step` until idle (see the class docstring)."""
        out: List[Response] = []
        while self._queue:
            out.extend(self.step())
        return out

    def run(self, requests: List[Request]) -> List[Response]:
        """Submit a whole stream, drain, return responses in ``req_id``
        order (see the class docstring)."""
        responses: List[Response] = []
        for req in requests:
            rej = self.submit(req)
            if rej is not None:
                responses.append(rej)
        responses.extend(self.drain())
        return sorted(responses, key=lambda r: r.req_id)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)


class MoEService:
    """MoE dispatch as a serving lane: one warm jitted ``moe_dcra`` over a
    fixed [batch, seq, d_model] shape class; short batches zero-pad.

    ``traces`` counts actual jit traces (incremented inside the traced
    function, so a warm call leaves it unchanged) — the MoE analogue of
    the TaskProgram compile cache's no-re-trace assertion.
    """

    def __init__(self, cfg, params, info, *, batch: int = 4, seq: int = 16):
        if cfg.moe is None:
            raise ValueError("MoEService needs a config with cfg.moe set")
        self.cfg, self.params, self.info = cfg, params, info
        self.batch, self.seq = int(batch), int(seq)
        self.calls = 0
        self.traces = 0
        self._fn = None

    def demand(self, payload: Optional[np.ndarray]) -> int:
        seq = self.seq if payload is None else int(payload.shape[0])
        return seq * self.cfg.moe.top_k

    def _build(self):
        import jax

        from ..core.dispatch import moe_dcra

        def f(params, x):
            self.traces += 1
            return moe_dcra(params, x, self.cfg, self.info)

        return jax.jit(f)

    def prewarm(self, mesh) -> None:
        x = np.zeros((self.batch, self.seq, self.cfg.d_model), np.float32)
        self._dispatch_block(x, mesh)

    def _dispatch_block(self, x: np.ndarray, mesh):
        from ..core.compat import set_mesh
        from ..core.fabric import Fabric
        if self._fn is None:
            self._fn = self._build()
        before = self.traces
        with set_mesh(Fabric.of(mesh).mesh):   # mesh OR Fabric
            out, _aux = self._fn(self.params, x)
        self.calls += 1
        return np.asarray(out), self.traces == before

    def dispatch(self, payloads: List[np.ndarray], mesh
                 ) -> Tuple[List[np.ndarray], bool]:
        """Fuse up to ``batch`` [seq, d_model] token blocks into one
        dispatch; returns (per-request outputs, warm-cache hit)."""
        for p in payloads:
            if p is None or p.shape != (self.seq, self.cfg.d_model):
                raise ValueError(
                    f"MoE payload must be [{self.seq}, {self.cfg.d_model}]")
        x = np.zeros((self.batch, self.seq, self.cfg.d_model), np.float32)
        for i, p in enumerate(payloads):
            x[i] = p
        out, hit = self._dispatch_block(x, mesh)
        return [out[i] for i in range(len(payloads))], hit
