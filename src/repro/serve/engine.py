"""The resident ProgramServer: warm jitted TaskPrograms serving a stream.

One server owns a mesh, a registry of resident graphs, and the TaskProgram
compile cache. Life of a request:

1. **Admission** — the tenant's :class:`~repro.core.queues.QueueConfig`
   resolves a per-round task *budget* (:meth:`QueueConfig.round_budget`,
   task class ``"serve"``). A request whose estimated per-round demand
   (its graph's edge count / its token block's task count) does not fit
   the tenant's remaining budget is rejected **before launch** —
   retriable when draining queued work could admit it, non-retriable
   when its demand alone exceeds the budget — admission replaces silent
   in-flight IQ drops.
2. **Batching** — admitted graph queries of one (program, graph) shape
   class are fused into a fixed-width tenant-column batch
   (:mod:`repro.serve.batching`): one shard_map launch serves up to
   ``batch_width`` tenants; short batches are padded so every launch hits
   the SAME compile-cache entry.
3. **Execution** — :func:`repro.sparse.program.launch_program` on the
   batched program: launches are *device futures* (JAX async dispatch),
   held in an inflight window of up to ``ServeOptions.inflight_depth``
   batches so batch k+1 forms and launches while batch k computes;
   results are harvested lazily, oldest-first, and per-request results
   are the unpacked tenant columns, bit-identical to standalone launches
   for the min-reduce programs under ANY depth.
4. **Observability** — per-tenant and aggregate counters
   (:mod:`repro.serve.stats`): queue depth, compile-cache hit rate,
   NoC drops (always attributed, never swallowed), p50/p99 latency.

MoE dispatch rides the same loop through :class:`MoEService`: token
blocks are batched to a fixed [B, S, D] shape class and dispatched
through one warm jitted ``moe_dcra`` callable.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Mapping, Optional, Tuple

import numpy as np

from ..core.queues import QueueConfig
from ..sparse import program as program_mod
from ..sparse.csr import CSR
from ..sparse.options import LaunchOptions
from ..sparse.program import prewarm_program
from .batching import (BATCHED_PROGRAMS, DrrFormer, FifoFormer, TenantBatch,
                       batched_program, split_tenant_states, tenant_graph)
from .options import ServeOptions
from .stats import ServingStats

STATUS_OK = "ok"
STATUS_REJECTED = "rejected"          # admission control; retriable unless
                                      # the request can never fit the budget
STATUS_FAILED = "failed"

#: the QueueConfig task class admission budgets resolve through
ADMISSION_TASK = "serve"


@dataclass(frozen=True)
class Request:
    """One unit of tenant traffic.

    Graph queries name a resident ``graph`` and a ``root``; MoE dispatch
    requests carry a ``payload`` token block [S, D] instead.
    """
    req_id: int
    tenant: str
    program: str                       # "bfs" | "sssp" | "moe"
    graph: Optional[str] = None
    root: int = 0
    payload: Optional[np.ndarray] = None
    params: Mapping = field(default_factory=dict)


@dataclass(frozen=True)
class Response:
    """One request's outcome — immutable once issued (like
    :class:`Request`, part of the stable ``repro.serve`` surface)."""
    req_id: int
    tenant: str
    status: str                        # STATUS_OK | _REJECTED | _FAILED
    retriable: bool = False
    reason: str = ""
    result: Optional[np.ndarray] = None
    batch_drops: int = 0               # NoC drops of the fused launch
    batch_messages: int = 0            # routed tasks of the fused launch
    rounds: int = 0
    batch_width: int = 0               # real tenants in the launch
    latency_s: float = 0.0             # end-to-end: submit -> harvest
    queue_wait_s: float = 0.0          # submit -> launch (formation wait)
    device_s: float = 0.0              # launch -> harvest (compute + xfer)


@dataclass
class _Pending:
    """One admitted request waiting in a batch former (the former only
    reads ``tenant`` / ``klass`` / ``demand``)."""
    req: Request
    t_enq: float                       # submit() wall-clock
    demand: int                        # admission-time task estimate

    @property
    def tenant(self) -> str:
        return self.req.tenant

    @property
    def klass(self) -> Tuple[str, Optional[str]]:
        return (self.req.program, self.req.graph)


@dataclass
class _InflightBatch:
    """One launched-but-unharvested fused batch in the window.

    ``launch`` is the :class:`~repro.sparse.program.ProgramLaunch`
    device future; ``error`` is set instead when the launch itself threw
    (the batch then 'completes' instantly at harvest with every rider
    failed, keeping response order identical to the synchronous loop).
    Launch-time cache-delta and padding stats are stashed here and
    applied only on successful harvest, matching the synchronous loop's
    accounting on the failure path.
    """
    entries: List[_Pending]
    batch: TenantBatch
    g_n: int                           # base-graph vertex count
    t_launch: float
    launch: Optional[object] = None    # ProgramLaunch
    error: Optional[str] = None
    cache_hits: int = 0
    cache_misses: int = 0

    def ready(self) -> bool:
        return self.error is not None or self.launch.is_ready()


class ProgramServer:
    """Resident serving engine over one fabric + graph registry.

    ``fabric`` is a :class:`repro.core.fabric.Fabric`; raw meshes keep
    working through the warn-once shim (identical compile-cache keys).

    ``tenant_queues`` maps tenant -> :class:`QueueConfig` admission
    budget (``default_queues`` covers the rest; ``None`` = unbounded
    admission). ``options`` is the :class:`LaunchOptions` default applied
    to EVERY launch the server issues (pre-warm included) — queue sizing,
    ``route_impl``, ``round_mode="pipelined"``, all of it; the legacy
    ``axis=`` / ``launch_queues=`` kwargs keep working when ``options``
    is not given (mixing the two raises). The default factor-4 sizing is
    drop-free for the serving graphs, which is what keeps batched results
    bit-identical to standalone runs.

    ``serve_options`` is the :class:`~repro.serve.options.ServeOptions`
    for the loop itself — inflight window depth, batch-formation
    fairness (FIFO vs deficit round-robin), state-buffer donation. The
    default reproduces the synchronous drain loop bit-for-bit.

    **The serving-loop contract** (one place, the three methods below are
    thin entries into it):

    * :meth:`step` advances the pipeline by one batch: it launches
      fused batches (the batch former pops up to ``batch_width``
      requests of one (program, graph) class, one per tenant; each
      becomes a single padded tenant-column
      :func:`~repro.sparse.program.launch_program` device future) until
      the inflight window holds ``ServeOptions.inflight_depth`` of
      them, then harvests every *completed* batch oldest-first —
      blocking on the oldest only when nothing is ready — and returns
      the harvested responses, ``[]`` when idle. Responses always
      stream in launch order; with ``inflight_depth=1`` this is exactly
      the old launch-then-block step. An MoE batch is a synchronous
      barrier: the window settles first, then the one MoE dispatch
      runs. A failed launch — at dispatch or surfacing from the device
      at harvest — never takes the server down and poisons only its
      own batch: every rider gets a non-retriable
      :data:`STATUS_FAILED` response; earlier and later inflight
      batches complete normally.
    * :meth:`drain` calls :meth:`step` until the queue AND the inflight
      window are empty, concatenating responses (launch order across
      batches).
    * :meth:`run` is submit-then-drain for a whole request list:
      admission rejections are collected (never dropped), the queue is
      drained, and ALL responses come back sorted by ``req_id``.

    Responses are one-to-one with submitted requests in every path, and
    (for the deterministic min-reduce programs) bit-identical across
    every ``inflight_depth`` and to standalone launches.
    """

    def __init__(self, fabric, graphs: Dict[str, CSR], *,
                 axis: str = "data",
                 batch_width: int = 4,
                 tenant_queues: Optional[Dict[str, QueueConfig]] = None,
                 default_queues: Optional[QueueConfig] = None,
                 launch_queues: Optional[QueueConfig] = None,
                 max_rounds: Optional[int] = None,
                 moe: Optional["MoEService"] = None,
                 options: Optional[LaunchOptions] = None,
                 serve_options: Optional[ServeOptions] = None):
        if options is not None:
            if axis != "data" or launch_queues is not None:
                raise ValueError("options= conflicts with explicit axis=/"
                                 "launch_queues=: fold them into the "
                                 "LaunchOptions")
            self.options = options.resolve()
        else:
            self.options = LaunchOptions(axis=axis,
                                         queues=launch_queues).resolve()
        from ..core.fabric import as_fabric
        self.fabric = as_fabric(fabric)     # raw Mesh -> warn-once shim
        self.mesh = self.fabric.mesh        # kept for the MoE lane
        self.axis = self.options.axis
        self.graphs = dict(graphs)
        self.batch_width = int(batch_width)
        self.tenant_queues = dict(tenant_queues or {})
        self.default_queues = default_queues
        self.launch_queues = self.options.queues
        self.max_rounds = max_rounds
        self.moe = moe
        self.serve_options = (serve_options or ServeOptions()).resolve()
        self.stats = ServingStats()
        self._former = (DrrFormer(self.serve_options.drr_quantum)
                        if self.serve_options.fairness == "drr"
                        else FifoFormer())
        self._window: Deque[_InflightBatch] = deque()
        self._inflight_demand: Dict[str, int] = {}
        self._n_dev = self.fabric.n_devices

    # ---- admission -------------------------------------------------------

    def _demand(self, req: Request) -> int:
        """Estimated per-round task injection of one request: worst case,
        every edge of the tenant's column emits (graph queries), or every
        token spawns top-k expert tasks (MoE)."""
        if req.program == "moe":
            if self.moe is None:
                raise ValueError("server has no MoEService configured")
            return self.moe.demand(req.payload)
        prog = batched_program(req.program)
        g = self.graphs[req.graph]
        return g.nnz * (2 if prog.undirected else 1)

    def _budget(self, tenant: str, demand: int) -> Optional[int]:
        q = self.tenant_queues.get(tenant, self.default_queues)
        if q is None:
            return None
        return q.round_budget(ADMISSION_TASK, demand, self._n_dev)

    def submit(self, req: Request) -> Optional[Response]:
        """Admit ``req`` into the serving queue, or reject it.

        Returns ``None`` on admission; a :data:`STATUS_REJECTED` response
        when the tenant's per-round budget is exhausted —
        ``retriable=True`` when the request would fit an idle budget (the
        tenant may resubmit once its queued work drains),
        ``retriable=False`` when its demand alone exceeds the budget, so
        no amount of draining could ever admit it. Unknown
        programs/graphs and out-of-range roots fail loudly at submit
        time.
        """
        ts = self.stats.tenant(req.tenant)
        ts.submitted += 1
        if req.program == "moe":
            if self.moe is None:
                ts.failed += 1
                return Response(req.req_id, req.tenant, STATUS_FAILED,
                                reason="server has no MoEService configured")
        else:
            if req.program not in BATCHED_PROGRAMS:
                ts.failed += 1
                return Response(req.req_id, req.tenant, STATUS_FAILED,
                                reason=f"no batched program {req.program!r}")
            if req.graph not in self.graphs:
                ts.failed += 1
                return Response(req.req_id, req.tenant, STATUS_FAILED,
                                reason=f"unknown graph {req.graph!r}")
            n = self.graphs[req.graph].n
            if not 0 <= int(req.root) < n:
                # an unchecked root would seed distance 0 inside ANOTHER
                # tenant's column (_multi_root_init writes dist[t*n+root])
                ts.failed += 1
                return Response(
                    req.req_id, req.tenant, STATUS_FAILED,
                    reason=(f"root {req.root} out of range [0, {n}) "
                            f"for graph {req.graph!r}"))
        demand = self._demand(req)
        budget = self._budget(req.tenant, demand)
        pending = self._inflight_demand.get(req.tenant, 0)
        if budget is not None and pending + demand > budget:
            ts.rejected += 1
            if demand > budget:
                return Response(
                    req.req_id, req.tenant, STATUS_REJECTED,
                    retriable=False,
                    reason=(f"demand {demand} exceeds tenant budget "
                            f"{budget} tasks/round — can never be "
                            f"admitted; resubmission is futile"))
            return Response(
                req.req_id, req.tenant, STATUS_REJECTED, retriable=True,
                reason=(f"tenant budget {budget} tasks/round: "
                        f"{pending} pending + {demand} requested"))
        self._inflight_demand[req.tenant] = pending + demand
        self._former.push(_Pending(req, time.perf_counter(), demand))
        self.stats.observe_queue_depth(len(self._former))
        return None

    # ---- pre-warm --------------------------------------------------------

    def prewarm(self, programs: Tuple[str, ...] = ("bfs", "sssp"),
                graphs: Optional[Tuple[str, ...]] = None) -> Dict:
        """Trace + compile every (program, graph, batch_width) shape
        class before traffic arrives; returns {(program, graph): keys}.

        Init-only roots are outside the compile-cache key, so one
        pre-warm per shape class covers every later request batch.
        """
        out = {}
        for name in programs:
            if name == "moe":
                if self.moe is not None:
                    self.moe.prewarm(self.mesh)
                continue
            prog = batched_program(name)
            for gname in (graphs if graphs is not None else self.graphs):
                tg = tenant_graph(self.graphs[gname], self.batch_width)
                keys = prewarm_program(
                    prog, tg, self.fabric, options=self.options,
                    max_rounds=self.max_rounds,
                    donate_states=self.serve_options.donate_buffers,
                    params={"roots": (0,) * self.batch_width})
                out[(name, gname)] = keys
                self.stats.prewarmed_keys += len(keys)
        return out

    # ---- the serving loop ------------------------------------------------

    def _width_for(self, entry: _Pending) -> int:
        return (self.moe.batch if entry.req.program == "moe"
                else self.batch_width)

    def _finish(self, entry: _Pending, resp: Response) -> Response:
        req = entry.req
        self._inflight_demand[req.tenant] -= entry.demand
        ts = self.stats.tenant(req.tenant)
        if resp.status == STATUS_OK:
            ts.served += 1
        else:
            ts.failed += 1
        ts.noc_drops += resp.batch_drops
        ts.messages += resp.batch_messages
        ts.rounds += resp.rounds
        ts.latencies.append(resp.latency_s)
        ts.queue_waits.append(resp.queue_wait_s)
        ts.device_times.append(resp.device_s)
        return resp

    def _launch_batch(self, entries: List[_Pending]) -> _InflightBatch:
        """Dispatch one fused batch WITHOUT waiting on the result: the
        returned record enters the inflight window. A launch-time
        exception is captured in ``error`` (harvest fails the riders in
        window order) — it never takes the server down."""
        reqs = [e.req for e in entries]
        gname = reqs[0].graph
        g = self.graphs[gname]
        batch = TenantBatch(
            program=reqs[0].program, graph=gname, width=self.batch_width,
            roots=tuple(int(r.root) for r in reqs),
            tenants=[r.tenant for r in reqs],
            req_ids=[r.req_id for r in reqs]).padded()
        tg = tenant_graph(g, self.batch_width)
        c0 = program_mod.cache_stats()
        t0 = time.perf_counter()
        ib = _InflightBatch(entries=entries, batch=batch, g_n=g.n,
                            t_launch=t0)
        try:
            ib.launch = program_mod.launch_program(
                batched_program(reqs[0].program), tg, self.fabric,
                options=self.options, max_rounds=self.max_rounds,
                donate_states=self.serve_options.donate_buffers,
                params={"roots": batch.roots})
        except Exception as e:  # noqa: BLE001 — a failed launch must not
            # take the server down; every rider gets a non-retriable
            # failure (the request itself is suspect, not the capacity)
            ib.error = f"{type(e).__name__}: {e}"
            return ib
        c1 = program_mod.cache_stats()
        ib.cache_hits = c1["hits"] - c0["hits"]
        ib.cache_misses = c1["misses"] - c0["misses"]
        return ib

    def _harvest(self, ib: _InflightBatch) -> List[Response]:
        """Materialize one inflight batch: block, transfer, split tenant
        columns, settle the ledger. Failures (captured at launch OR
        surfacing from the device at harvest) poison only this batch's
        riders, non-retriably."""
        err = ib.error
        app_stats = state = None
        if err is None:
            try:
                (state,), app_stats = ib.launch.result()
            except Exception as e:  # noqa: BLE001 — device-side failure
                err = f"{type(e).__name__}: {e}"
        t1 = time.perf_counter()
        dt = t1 - ib.t_launch
        if err is not None:
            return [self._finish(e, Response(
                e.req.req_id, e.req.tenant, STATUS_FAILED, reason=err,
                latency_s=t1 - e.t_enq, device_s=dt,
                queue_wait_s=ib.t_launch - e.t_enq))
                for e in ib.entries]
        self.stats.cache_hits += ib.cache_hits
        self.stats.cache_misses += ib.cache_misses
        self.stats.launches += 1
        self.stats.batched_requests += ib.batch.n_real
        self.stats.pad_columns += self.batch_width - ib.batch.n_real
        self.stats.noc_drops += app_stats.total_drops
        self.stats.round_latencies.append(dt / max(1, app_stats.rounds))
        per_tenant = split_tenant_states(state, ib.g_n, self.batch_width)
        return [self._finish(e, Response(
            e.req.req_id, e.req.tenant, STATUS_OK, result=per_tenant[i],
            batch_drops=app_stats.total_drops,
            batch_messages=app_stats.total_messages,
            rounds=app_stats.rounds, batch_width=ib.batch.n_real,
            latency_s=t1 - e.t_enq, device_s=dt,
            queue_wait_s=ib.t_launch - e.t_enq))
            for i, e in enumerate(ib.entries)]

    def _harvest_window(self, *, block: bool) -> List[Response]:
        """Harvest completed batches oldest-first — NEVER out of order,
        so responses stream in launch order under any depth. Non-blocking
        unless ``block`` (then the whole window settles)."""
        out: List[Response] = []
        while self._window and (block or self._window[0].ready()):
            out.extend(self._harvest(self._window.popleft()))
        return out

    def step(self) -> List[Response]:
        """Advance the pipeline by one batch (see the class docstring's
        serving-loop contract); ``[]`` when idle."""
        out: List[Response] = []
        depth = self.serve_options.inflight_depth
        while len(self._former) and len(self._window) < depth:
            entries = self._former.form(self._width_for)
            if entries[0].req.program == "moe":
                # the MoE lane is synchronous — settle the window first
                # so responses keep streaming in launch order
                out.extend(self._harvest_window(block=True))
                out.extend(self._step_moe(entries))
                return out
            self._window.append(self._launch_batch(entries))
        out.extend(self._harvest_window(block=False))
        if not out and self._window:
            # window full (or queue empty) and nothing ready: the oldest
            # launch is the one the loop must wait on
            out.extend(self._harvest(self._window.popleft()))
        return out

    def _step_moe(self, entries: List[_Pending]) -> List[Response]:
        reqs = [e.req for e in entries]
        t0 = time.perf_counter()
        try:
            outs, hit = self.moe.dispatch([r.payload for r in reqs],
                                          self.mesh)
        except Exception as e:  # noqa: BLE001
            t1 = time.perf_counter()
            return [self._finish(en, Response(
                en.req.req_id, en.req.tenant, STATUS_FAILED,
                reason=f"{type(e).__name__}: {e}",
                latency_s=t1 - en.t_enq, device_s=t1 - t0,
                queue_wait_s=t0 - en.t_enq)) for en in entries]
        t1 = time.perf_counter()
        dt = t1 - t0
        self.stats.cache_hits += int(hit)
        self.stats.cache_misses += int(not hit)
        self.stats.launches += 1
        self.stats.batched_requests += len(reqs)
        self.stats.pad_columns += self.moe.batch - len(reqs)
        self.stats.round_latencies.append(dt)
        return [self._finish(en, Response(
            en.req.req_id, en.req.tenant, STATUS_OK, result=outs[i],
            rounds=1, batch_width=len(reqs), latency_s=t1 - en.t_enq,
            device_s=dt, queue_wait_s=t0 - en.t_enq))
            for i, en in enumerate(entries)]

    def drain(self) -> List[Response]:
        """:meth:`step` until idle, then settle the whole inflight
        window (see the class docstring)."""
        out: List[Response] = []
        while len(self._former) or self._window:
            out.extend(self.step())
        return out

    def run(self, requests: List[Request]) -> List[Response]:
        """Submit a whole stream, drain, return responses in ``req_id``
        order (see the class docstring)."""
        responses: List[Response] = []
        for req in requests:
            rej = self.submit(req)
            if rej is not None:
                responses.append(rej)
        responses.extend(self.drain())
        return sorted(responses, key=lambda r: r.req_id)

    @property
    def queue_depth(self) -> int:
        """Admitted requests not yet launched (inflight batches have
        left the queue)."""
        return len(self._former)

    @property
    def inflight_depth(self) -> int:
        """Launched-but-unharvested fused batches in the window."""
        return len(self._window)


class MoEService:
    """MoE dispatch as a serving lane: one warm jitted ``moe_dcra`` over a
    fixed [batch, seq, d_model] shape class; short batches zero-pad.

    ``traces`` counts actual jit traces (incremented inside the traced
    function, so a warm call leaves it unchanged) — the MoE analogue of
    the TaskProgram compile cache's no-re-trace assertion.
    """

    def __init__(self, cfg, params, info, *, batch: int = 4, seq: int = 16):
        if cfg.moe is None:
            raise ValueError("MoEService needs a config with cfg.moe set")
        self.cfg, self.params, self.info = cfg, params, info
        self.batch, self.seq = int(batch), int(seq)
        self.calls = 0
        self.traces = 0
        self._fn = None

    def demand(self, payload: Optional[np.ndarray]) -> int:
        seq = self.seq if payload is None else int(payload.shape[0])
        return seq * self.cfg.moe.top_k

    def _build(self):
        import jax

        from ..core.dispatch import moe_dcra

        def f(params, x):
            self.traces += 1
            return moe_dcra(params, x, self.cfg, self.info)

        return jax.jit(f)

    def prewarm(self, mesh) -> None:
        x = np.zeros((self.batch, self.seq, self.cfg.d_model), np.float32)
        self._dispatch_block(x, mesh)

    def _dispatch_block(self, x: np.ndarray, mesh):
        from ..core.compat import set_mesh
        from ..core.fabric import Fabric
        if self._fn is None:
            self._fn = self._build()
        before = self.traces
        with set_mesh(Fabric.of(mesh).mesh):   # mesh OR Fabric
            out, _aux = self._fn(self.params, x)
        self.calls += 1
        return np.asarray(out), self.traces == before

    def dispatch(self, payloads: List[np.ndarray], mesh
                 ) -> Tuple[List[np.ndarray], bool]:
        """Fuse up to ``batch`` [seq, d_model] token blocks into one
        dispatch; returns (per-request outputs, warm-cache hit)."""
        for p in payloads:
            if p is None or p.shape != (self.seq, self.cfg.d_model):
                raise ValueError(
                    f"MoE payload must be [{self.seq}, {self.cfg.d_model}]")
        x = np.zeros((self.batch, self.seq, self.cfg.d_model), np.float32)
        for i, p in enumerate(payloads):
            x[i] = p
        out, hit = self._dispatch_block(x, mesh)
        return [out[i] for i in range(len(payloads))], hit
