"""Serving resilience: deterministic chaos plans and circuit breakers.

The serving loop has four failure seams, and every one of them can be
exercised deterministically from here (the
:class:`~repro.runtime.fault_tolerance.InjectionSchedule` house style:
inject the failure so the recovery is *tested*, not just written):

* ``FAULT_LAUNCH`` — the fused launch itself raises at dispatch time
  (a flaky host's tracing/dispatch path);
* ``FAULT_DEVICE`` — the launch dispatches but the device future
  surfaces an error at harvest (an ICI timeout mid-collective);
* ``FAULT_MOE`` — the MoE lane's synchronous dispatch raises;
* ``FAULT_HOST_LOSS`` — a host disappears: the server shrinks its
  :class:`~repro.core.fabric.Fabric` to the surviving devices,
  re-prewarms the shape classes that still have queued traffic,
  requeues the poisoned window's riders, and keeps serving.

A :class:`ServeFailurePlan` keys faults by **launch index** (the
server's monotone count of fused launches, graph + MoE), so a chaos run
replays bit-for-bit: same plan, same stream -> same faults at the same
launches, and min-reduce survivors land bit-identical to a fault-free
run (drop-free sizing is device-count independent, so even the
post-shrink relaunches reproduce the exact distances).

The :class:`CircuitBreaker` is the fail-fast half of the story: one
breaker per (program, graph) shape class, opened by
``ServeOptions.breaker_threshold`` consecutive launch failures. An open
breaker rejects new submissions of its class retriably (naming itself in
the reason) instead of burning device time on a class that keeps
failing; the next formed batch of the class is admitted as a single
half-open *probe* — success closes the breaker, failure re-opens it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from ..runtime.fault_tolerance import (FailurePlan, InjectedFailure,
                                       InjectionSchedule, RetryLedger)

__all__ = [
    "BREAKER_CLOSED", "BREAKER_HALF_OPEN", "BREAKER_OPEN", "CircuitBreaker",
    "FAULT_DEVICE", "FAULT_HOST_LOSS", "FAULT_KINDS", "FAULT_LAUNCH",
    "FAULT_MOE", "FailurePlan", "InjectedFailure", "InjectionSchedule",
    "RetryLedger", "ServeFailurePlan", "seeded_chaos_plan",
]

#: the four serving failure seams a plan may target (see module docstring)
FAULT_LAUNCH = "launch"
FAULT_DEVICE = "device"
FAULT_MOE = "moe"
FAULT_HOST_LOSS = "host_loss"
FAULT_KINDS = (FAULT_LAUNCH, FAULT_DEVICE, FAULT_MOE, FAULT_HOST_LOSS)


@dataclass
class ServeFailurePlan(InjectionSchedule):
    """Deterministic serving fault schedule ``{launch index: kind}``.

    ``kind`` is one of :data:`FAULT_KINDS`. Seam mapping at fire time:

    * at a graph launch, ``launch`` (and ``moe``, which has no graph
      seam) raises at dispatch; ``device`` lets the launch dispatch and
      surfaces as an error from the device future at harvest;
      ``host_loss`` shrinks the fabric to ``keep_devices`` *instead of*
      launching — the batch (and any poisoned inflight riders) is
      requeued and relaunched on the survivors, consuming the same
      launch index.
    * at an MoE launch, every kind degrades to a dispatch exception —
      the MoE lane is synchronous and its fabric does not shrink.

    Each scheduled index fires exactly once; ``fired`` records the
    history and :attr:`~InjectionSchedule.exhausted` lets a chaos test
    assert the plan actually ran.
    """
    #: surviving device count after a ``host_loss`` fault (None = keep
    #: the first half of the current fabric)
    keep_devices: Optional[int] = None

    noun = "launch"

    def __post_init__(self):
        bad = {k for k in self.at.values()} - set(FAULT_KINDS)
        if bad:
            raise ValueError(
                f"unknown fault kinds {sorted(bad)}; pick from {FAULT_KINDS}")


def seeded_chaos_plan(seed: int, n_launches: int, *,
                      keep_devices: Optional[int] = None
                      ) -> ServeFailurePlan:
    """One launch fault, one device fault, one host loss at three
    distinct launch indices derived deterministically from ``seed`` —
    the canonical chaos-smoke plan (CI and the hypothesis tier replay
    the same seeds).

    Pure integer mixing (splitmix-style), no ``random``: the same seed
    always yields the same plan, in any process, under any hash seed.
    The host loss is placed last so the shrunken fabric serves the tail
    of the stream, and indices stay within the fault-free launch count
    ``n_launches`` so every fault is guaranteed to fire.
    """
    if n_launches < 3:
        raise ValueError(f"need >= 3 launches to place 3 faults, "
                         f"got {n_launches}")

    def mix(x: int) -> int:
        x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
        x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
        return x ^ (x >> 31)

    picks = []
    i = 0
    while len(picks) < 3:
        cand = mix(seed * 1_000_003 + i) % n_launches
        if cand not in picks:
            picks.append(cand)
        i += 1
    picks.sort()
    return ServeFailurePlan(
        at={picks[0]: FAULT_LAUNCH, picks[1]: FAULT_DEVICE,
            picks[2]: FAULT_HOST_LOSS},
        keep_devices=keep_devices)


BREAKER_CLOSED = "closed"
BREAKER_OPEN = "open"
BREAKER_HALF_OPEN = "half_open"


@dataclass
class CircuitBreaker:
    """Per-shape-class fail-fast: CLOSED -> (``threshold`` consecutive
    launch failures) -> OPEN -> (one probe batch) -> HALF_OPEN ->
    success closes / failure re-opens.

    While not CLOSED, new submissions of the class are rejected
    *retriably* at admission (fail fast, spend no device time); queued
    work is held except for the single half-open probe the engine admits
    via :meth:`allows_launch`. ``record_failure`` / ``record_success``
    return True exactly on the open/close **transition**, so the engine
    can count ``breaker_opens`` / ``breaker_closes`` without re-deriving
    state edges.
    """
    threshold: int
    klass: Tuple[str, Optional[str]] = ("?", None)
    state: str = BREAKER_CLOSED
    failures: int = 0                 # consecutive failed launches
    opens: int = 0
    closes: int = 0

    def allows_launch(self) -> bool:
        """May a formed batch of this class launch now? CLOSED: yes.
        OPEN: yes, once — the batch becomes the half-open probe.
        HALF_OPEN: no — the probe is still in flight; hold the queue."""
        if self.state == BREAKER_CLOSED:
            return True
        if self.state == BREAKER_OPEN:
            self.state = BREAKER_HALF_OPEN
            return True
        return False

    def record_failure(self) -> bool:
        """Count one failed launch; True when this failure OPENED the
        breaker (a half-open probe failing re-opens immediately)."""
        self.failures += 1
        if self.state == BREAKER_HALF_OPEN or self.failures >= self.threshold:
            was = self.state
            self.state = BREAKER_OPEN
            if was != BREAKER_OPEN:
                self.opens += 1
                return True
        return False

    def record_success(self) -> bool:
        """Count one successful launch; True when it CLOSED the breaker
        (the half-open probe succeeded)."""
        self.failures = 0
        was = self.state
        self.state = BREAKER_CLOSED
        if was != BREAKER_CLOSED:
            self.closes += 1
            return True
        return False

    def reject_reason(self) -> str:
        prog, graph = self.klass
        name = prog if graph is None else f"{prog}/{graph}"
        return (f"circuit breaker {self.state} for shape class {name}: "
                f"{self.failures} consecutive launch failures "
                f"(threshold {self.threshold}); resubmit after the "
                f"half-open probe closes it")
