"""Dense SwiGLU FFN."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, shard, swiglu


def init_mlp(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wg": dense_init(k1, d_model, (d_ff,)),
        "wu": dense_init(k2, d_model, (d_ff,)),
        "wd": dense_init(k3, d_ff, (d_model,)),
    }


def mlp_block(params, x):
    dt = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, params["wg"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, params["wu"].astype(dt))
    h = swiglu(g, u)
    h = shard(h, "act_batch", "act_seq_inner", "act_ff")
    out = jnp.einsum("bsf,fd->bsd", h, params["wd"].astype(dt))
    return shard(out, "act_batch", "act_seq", "act_embed")
