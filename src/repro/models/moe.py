"""Mixture-of-Experts layer.

Two dispatch implementations (selected by ``MoEConfig.dispatch_impl``):

* ``einsum`` — dense dispatch/combine masks over token groups
  (Mesh-TensorFlow / GShard style). XLA SPMD partitions the einsums; this is
  the *flat-NoC baseline* in DCRA terms.
* ``dcra``  — the paper's technique: owner-routed task dispatch with bounded
  queues and a hierarchical (tile-NoC / die-NoC) all-to-all, implemented with
  ``shard_map`` in :mod:`repro.core.dispatch`. Falls back to ``einsum`` when
  no mesh is active (single-device smoke tests still exercise it via a
  trivial mesh).

Expert capacity == DCRA input-queue size: tokens beyond capacity are dropped
(counted) exactly like NoC queue overflow; the residual connection carries
them through — the standard capacity-factor semantics.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, MoEConfig
from .common import dense_init, shard, swiglu

GROUP_SIZE = 1024  # tokens per dispatch group (DCRA: per-tile task batch)


def init_moe(key, cfg: ArchConfig):
    mc = cfg.moe
    assert mc is not None
    d, e, f = cfg.d_model, mc.num_experts, mc.d_expert
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], d, (e,), scale=0.1),
        "wg": _expert_init(ks[1], e, d, f),
        "wu": _expert_init(ks[2], e, d, f),
        "wd": _expert_init(ks[3], e, f, d),
    }


def _expert_init(key, e, din, dout):
    return jax.random.normal(key, (e, din, dout)) * (din ** -0.5)


def router_probs(params, x, mc: MoEConfig):
    """x [G, T, D] -> probs [G, T, E] (fp32)."""
    logits = jnp.einsum("gtd,de->gte", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1), logits


def _topk_mask(probs, k):
    """-> gates [G,T,K], expert one-hot [G,T,K,E]."""
    vals, idx = jax.lax.top_k(probs, k)
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)  # renorm
    onehot = jax.nn.one_hot(idx, probs.shape[-1], dtype=jnp.float32)
    return vals, onehot


def capacity(group_tokens: int, mc: MoEConfig) -> int:
    c = int(group_tokens * mc.top_k * mc.capacity_factor / mc.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU lane alignment


def moe_einsum(params, x, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """Dense-mask dispatch. x [B, S, D] -> (out [B,S,D], aux loss [])."""
    mc = cfg.moe
    B, S, D = x.shape
    T = B * S
    g_size = min(GROUP_SIZE, T)
    G = T // g_size
    xg = x.reshape(G, g_size, D)
    xg = shard(xg, "act_group", None, "act_embed")

    probs, logits = router_probs(params, xg, mc)            # [G,T,E]
    gates, onehot = _topk_mask(probs, mc.top_k)             # [G,T,K],[G,T,K,E]
    C = capacity(g_size, mc)

    # queue position of each (token, k) task within its expert queue
    flat = onehot.reshape(G, g_size * mc.top_k, mc.num_experts)
    pos = jnp.cumsum(flat, axis=1) * flat - flat            # 0-based [G,TK,E]
    keep = (pos < C).astype(jnp.float32) * flat             # drop = IQ overflow
    pos_k = pos.reshape(G, g_size, mc.top_k, mc.num_experts).astype(jnp.int32)
    keep_k = keep.reshape(G, g_size, mc.top_k, mc.num_experts)
    pos_oh = jax.nn.one_hot(pos_k, C, dtype=jnp.float32) * keep_k[..., None]
    # dispatch/combine [G, T, E, C] (k summed; a token goes to k distinct experts)
    dispatch = pos_oh.sum(2)
    combine = (pos_oh * gates[..., None, None]).sum(2)

    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), xg)
    xe = shard(xe, "act_group", "act_expert", None, "act_embed")
    h = swiglu(jnp.einsum("gecd,edf->gecf", xe, params["wg"].astype(x.dtype)),
               jnp.einsum("gecd,edf->gecf", xe, params["wu"].astype(x.dtype)))
    ye = jnp.einsum("gecf,efd->gecd", h, params["wd"].astype(x.dtype))
    ye = shard(ye, "act_group", "act_expert", None, "act_embed")
    out = jnp.einsum("gecd,gtec->gtd", ye, combine.astype(x.dtype))

    aux = load_balance_loss(probs, onehot)
    return out.reshape(B, S, D), aux


def load_balance_loss(probs, onehot) -> jax.Array:
    """Switch-style aux loss: E * sum_e(frac_tokens_e * mean_prob_e)."""
    E = probs.shape[-1]
    frac = onehot.sum(2).mean(axis=(0, 1))      # [E] fraction routed (pre-drop)
    mp = probs.mean(axis=(0, 1))                # [E]
    return E * jnp.sum(frac * mp)


def moe_block(params, x, cfg: ArchConfig,
              mesh_info: Optional[object] = None) -> Tuple[jax.Array, jax.Array]:
    mc = cfg.moe
    assert mc is not None
    if mc.dispatch_impl == "dcra" and mesh_info is not None:
        from ..core.dispatch import moe_dcra
        return moe_dcra(params, x, cfg, mesh_info)
    return moe_einsum(params, x, cfg)
