from .model_zoo import BaseModel, build_model  # noqa: F401
