"""Unified Model interface over all assigned architecture families.

Contracts
---------
``batch`` (train/prefill):
  * decoder LMs : {"tokens": [B,S] i32, "labels": [B,S] i32}
  * vlm         : + {"patch_embeds": [B,P,D] (stub frontend), "positions": [B,3,S]}
  * encdec      : {"src_embeds": [B,Ssrc,D] (stub frontend), "tokens": [B,Stgt],
                   "labels": [B,Stgt]}
``decode_step(params, cache, tokens [B,1], pos [])`` -> (logits [B,1,V], cache)
  ``pos`` is the absolute position of the new token (cache holds positions
  < pos). Cache pytrees are stacked over layers for scan compatibility.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import init_kv_cache, project_cross_kv
from .common import rms_norm, shard, softmax_cross_entropy
from .mamba2 import init_mamba_block, init_mamba_state, mamba_block
from .rwkv6 import init_rwkv_block, init_rwkv_state, rwkv_block
from .transformer import (decoder_block, embed_tokens, init_decoder_block,
                          init_embed, lm_logits, run_stack, run_stack_decode,
                          tree_slice, tree_stack, _remat)

VLM_PATCHES = 256  # stub vision frontend: 16x16 patch grid


def _stack_init(init_fn, key, n):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def _positions(B, S, offset=0):
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32) + offset, (B, S))


class BaseModel:
    family: str

    def __init__(self, cfg: ArchConfig, mesh_info=None, dtype=jnp.float32):
        self.cfg = cfg
        self.mesh_info = mesh_info
        self.dtype = dtype

    # -- interface ------------------------------------------------------
    def init(self, key):
        raise NotImplementedError

    def forward(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        raise NotImplementedError

    def init_cache(self, batch_size: int, cache_len: int, dtype=jnp.bfloat16):
        raise NotImplementedError

    def decode_step(self, params, cache, tokens, pos):
        raise NotImplementedError

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        ce = softmax_cross_entropy(logits[:, :-1], labels[:, 1:]).mean()
        total = ce + 0.01 * aux
        return total, {"loss": total, "ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Decoder-only (dense / MoE / VLM)
# ---------------------------------------------------------------------------

class DecoderLM(BaseModel):
    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {
            **init_embed(k1, self.cfg),
            "blocks": _stack_init(
                lambda k: init_decoder_block(k, self.cfg), k2,
                self.cfg.num_layers),
        }

    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        tok_e = embed_tokens(params, batch["tokens"], cfg, self.dtype)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            x = jnp.concatenate(
                [batch["patch_embeds"].astype(self.dtype), tok_e], axis=1)
            positions = batch["positions"]            # [B,3,S]
        else:
            x = tok_e
            B, S = batch["tokens"].shape
            positions = _positions(B, S)
        return x, positions

    def forward(self, params, batch):
        x, positions = self._embed_inputs(params, batch)
        x, aux = run_stack(params["blocks"], x, self.cfg, positions,
                           mesh_info=self.mesh_info)
        x = rms_norm(x, params["ln_f"].astype(x.dtype), self.cfg.norm_eps)
        if self.cfg.family == "vlm" and "patch_embeds" in batch:
            x = x[:, batch["patch_embeds"].shape[1]:]   # logits over text tail
        return lm_logits(params, x, self.cfg), aux

    def init_cache(self, batch_size, cache_len, dtype=jnp.bfloat16):
        one = lambda _: init_kv_cache(self.cfg, batch_size, cache_len, dtype)
        return jax.vmap(one)(jnp.arange(self.cfg.num_layers))

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        B = tokens.shape[0]
        x = embed_tokens(params, tokens, cfg, self.dtype)
        if cfg.mrope:
            positions = jnp.broadcast_to(pos.astype(jnp.int32), (B, 3, 1))
        else:
            positions = jnp.broadcast_to(pos.astype(jnp.int32), (B, 1))
        x, new_cache = run_stack_decode(params["blocks"], x, cfg, positions,
                                        cache, pos, mesh_info=self.mesh_info)
        x = rms_norm(x, params["ln_f"].astype(x.dtype), cfg.norm_eps)
        return lm_logits(params, x, cfg), new_cache


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------

class RWKVLM(BaseModel):
    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {
            **init_embed(k1, self.cfg),
            "blocks": _stack_init(
                lambda k: init_rwkv_block(k, self.cfg), k2,
                self.cfg.num_layers),
        }

    def _run(self, params, x, states, impl):
        cfg = self.cfg

        def body(xc, layer):
            layer_params, layer_state = layer
            out, new_state = rwkv_block(layer_params, xc, cfg, layer_state,
                                        impl=impl)
            return out, new_state

        if cfg.scan_layers:
            body_r = _remat(body, cfg)
            x, new_states = jax.lax.scan(body_r, x, (params["blocks"], states))
        else:
            body_r = _remat(body, cfg)
            outs = []
            for i in range(cfg.num_layers):
                x, ns = body_r(x, (tree_slice(params["blocks"], i),
                                   tree_slice(states, i)))
                outs.append(ns)
            new_states = tree_stack(outs)
        return x, new_states

    def forward(self, params, batch):
        cfg = self.cfg
        B, S = batch["tokens"].shape
        x = embed_tokens(params, batch["tokens"], cfg, self.dtype)
        states = self.init_cache(B, 0, jnp.float32)
        impl = "chunked" if S % 32 == 0 and S > 32 else "scan"
        x, _ = self._run(params, x, states, impl)
        x = rms_norm(x, params["ln_f"].astype(x.dtype), cfg.norm_eps)
        return lm_logits(params, x, cfg), jnp.zeros((), jnp.float32)

    def init_cache(self, batch_size, cache_len, dtype=jnp.float32):
        one = lambda _: init_rwkv_state(self.cfg, batch_size, jnp.float32)
        return jax.vmap(one)(jnp.arange(self.cfg.num_layers))

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        x = embed_tokens(params, tokens, cfg, self.dtype)
        x, new_states = self._run(params, x, cache, "scan")
        x = rms_norm(x, params["ln_f"].astype(x.dtype), cfg.norm_eps)
        return lm_logits(params, x, cfg), new_states


# ---------------------------------------------------------------------------
# Zamba2-style hybrid: Mamba2 stack + one weight-shared attention block
# ---------------------------------------------------------------------------

class HybridLM(BaseModel):
    """Mamba2 layers; after every ``hybrid_attn_period`` layers the SHARED
    attention+MLP block is applied (weight-shared across applications, each
    application has its own KV cache)."""

    def _segments(self):
        cfg = self.cfg
        p = cfg.hybrid_attn_period
        full, rem = divmod(cfg.num_layers, p)
        segs = [p] * full + ([rem] if rem else [])
        n_attn = full  # shared block after each full segment
        return segs, n_attn

    def init(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            **init_embed(k1, self.cfg),
            "blocks": _stack_init(
                lambda k: init_mamba_block(k, self.cfg), k2,
                self.cfg.num_layers),
            "shared_attn": init_decoder_block(k3, self.cfg),
        }

    def forward(self, params, batch):
        cfg = self.cfg
        B, S = batch["tokens"].shape
        x = embed_tokens(params, batch["tokens"], cfg, self.dtype)
        positions = _positions(B, S)
        segs, _ = self._segments()
        impl = ("chunked" if S % cfg.ssm.chunk_size == 0
                and S > cfg.ssm.chunk_size else "scan")
        start = 0
        for si, seg in enumerate(segs):
            blocks = jax.tree.map(lambda p: p[start:start + seg],
                                  params["blocks"])
            states = jax.vmap(
                lambda _: init_mamba_state(cfg, B, jnp.float32))(
                    jnp.arange(seg))

            def body(xc, layer):
                lp, ls = layer
                out, ns = mamba_block(lp, xc, cfg, ls, impl=impl)
                return xc + out, ns

            body_r = _remat(body, cfg)
            if cfg.scan_layers:
                x, _ = jax.lax.scan(body_r, x, (blocks, states))
            else:
                for li in range(seg):
                    x, _ = body_r(x, (tree_slice(blocks, li),
                                      tree_slice(states, li)))
            if si < len(segs) and seg == cfg.hybrid_attn_period:
                def attn_body(xc, ap):
                    out, _, _ = decoder_block(ap, xc, cfg, positions,
                                              mesh_info=self.mesh_info)
                    return out
                attn_r = (jax.checkpoint(attn_body) if cfg.remat != "none"
                          else attn_body)
                x = attn_r(x, params["shared_attn"])
            start += seg
        x = rms_norm(x, params["ln_f"].astype(x.dtype), cfg.norm_eps)
        return lm_logits(params, x, cfg), jnp.zeros((), jnp.float32)

    def init_cache(self, batch_size, cache_len, dtype=jnp.bfloat16):
        cfg = self.cfg
        segs, n_attn = self._segments()
        mamba = jax.vmap(lambda _: init_mamba_state(cfg, batch_size,
                                                    jnp.float32))(
            jnp.arange(cfg.num_layers))
        kv = jax.vmap(lambda _: init_kv_cache(cfg, batch_size, cache_len,
                                              dtype))(jnp.arange(max(n_attn, 1)))
        return {"mamba": mamba, "kv": kv}

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        B = tokens.shape[0]
        x = embed_tokens(params, tokens, cfg, self.dtype)
        positions = jnp.broadcast_to(pos.astype(jnp.int32), (B, 1))
        segs, _ = self._segments()
        start, attn_i = 0, 0
        new_mamba, new_kv = [], []
        for si, seg in enumerate(segs):
            for li in range(start, start + seg):
                lp = tree_slice(params["blocks"], li)
                ls = tree_slice(cache["mamba"], li)
                out, ns = mamba_block(lp, x, cfg, ls, impl="scan")
                x = x + out
                new_mamba.append(ns)
            if seg == cfg.hybrid_attn_period:
                kv_i = tree_slice(cache["kv"], attn_i)
                x, nkv, _ = decoder_block(params["shared_attn"], x, cfg,
                                          positions, cache=kv_i, cache_pos=pos,
                                          mesh_info=self.mesh_info)
                new_kv.append(nkv)
                attn_i += 1
            start += seg
        x = rms_norm(x, params["ln_f"].astype(x.dtype), cfg.norm_eps)
        new_cache = {"mamba": tree_stack(new_mamba),
                     "kv": tree_stack(new_kv) if new_kv else cache["kv"]}
        return lm_logits(params, x, cfg), new_cache


# ---------------------------------------------------------------------------
# Encoder-decoder (seamless backbone)
# ---------------------------------------------------------------------------

class EncDecLM(BaseModel):
    def init(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            **init_embed(k1, self.cfg),
            "enc_blocks": _stack_init(
                lambda k: init_decoder_block(k, self.cfg), k2,
                self.cfg.encoder_layers),
            "blocks": _stack_init(
                lambda k: init_decoder_block(k, self.cfg, cross=True), k3,
                self.cfg.num_layers),
        }

    def encode(self, params, src_embeds):
        B, S, _ = src_embeds.shape
        positions = _positions(B, S)
        x, _ = run_stack(params["enc_blocks"], src_embeds.astype(self.dtype),
                         self.cfg, positions, causal=False,
                         mesh_info=self.mesh_info)
        return x

    def forward(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["src_embeds"])
        B, S = batch["tokens"].shape
        x = embed_tokens(params, batch["tokens"], cfg, self.dtype)
        positions = _positions(B, S)
        x, aux = run_stack(params["blocks"], x, cfg, positions,
                           enc_out=enc_out, mesh_info=self.mesh_info)
        x = rms_norm(x, params["ln_f"].astype(x.dtype), cfg.norm_eps)
        return lm_logits(params, x, cfg), aux

    def precompute_cross_kv(self, params, enc_out):
        def per_layer(layer_params):
            return project_cross_kv(layer_params["xattn"], enc_out, self.cfg)
        return jax.vmap(per_layer, in_axes=0)(params["blocks"])

    def init_cache(self, batch_size, cache_len, dtype=jnp.bfloat16,
                   cross_len: int = 4096):
        cfg = self.cfg
        kv = jax.vmap(lambda _: init_kv_cache(cfg, batch_size, cache_len,
                                              dtype))(jnp.arange(cfg.num_layers))
        hd = cfg.resolved_head_dim
        xk = jnp.zeros((cfg.num_layers, batch_size, cross_len,
                        cfg.num_kv_heads, hd), dtype)
        return {"kv": kv, "cross_k": xk, "cross_v": xk}

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        B = tokens.shape[0]
        x = embed_tokens(params, tokens, cfg, self.dtype)
        positions = jnp.broadcast_to(pos.astype(jnp.int32), (B, 1))
        x, new_kv = run_stack_decode(
            params["blocks"], x, cfg, positions, cache["kv"], pos,
            enc_kv=(cache["cross_k"], cache["cross_v"]),
            mesh_info=self.mesh_info)
        x = rms_norm(x, params["ln_f"].astype(x.dtype), cfg.norm_eps)
        new_cache = {"kv": new_kv, "cross_k": cache["cross_k"],
                     "cross_v": cache["cross_v"]}
        return lm_logits(params, x, cfg), new_cache


# ---------------------------------------------------------------------------

_FAMILIES = {
    "dense": DecoderLM,
    "moe": DecoderLM,
    "vlm": DecoderLM,
    "ssm": RWKVLM,
    "hybrid": HybridLM,
    "encdec": EncDecLM,
}


def build_model(cfg: ArchConfig, mesh_info=None, dtype=jnp.float32) -> BaseModel:
    return _FAMILIES[cfg.family](cfg, mesh_info=mesh_info, dtype=dtype)
