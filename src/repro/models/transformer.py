"""Decoder-only transformer (dense / MoE / VLM), enc-dec, and hybrid stacks.

All families share one storage convention: ``params["blocks"]`` is a
*stacked* pytree (leading axis = layer). ``scan_layers=True`` runs layers
under ``jax.lax.scan`` (small HLO, FSDP-friendly); ``False`` unrolls a python
loop over sliced subtrees — identical checkpoints either way.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import (KVCache, attention_block, init_attention,
                        init_kv_cache)
from .common import embed_init, rms_norm, shard
from .mamba2 import MambaState, init_mamba_block, init_mamba_state, mamba_block
from .mlp import init_mlp, mlp_block
from .moe import init_moe, moe_block
from .rwkv6 import RWKVState, init_rwkv_block, init_rwkv_state, rwkv_block


def tree_slice(tree, i):
    return jax.tree.map(lambda p: p[i], tree)


def tree_stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# ---------------------------------------------------------------------------
# Attention-family decoder block
# ---------------------------------------------------------------------------

def init_decoder_block(key, cfg: ArchConfig, cross: bool = False):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {
        "ln1": jnp.ones((cfg.d_model,)),
        "ln2": jnp.ones((cfg.d_model,)),
        "attn": init_attention(ks[0], cfg),
    }
    if cross:
        p["ln_x"] = jnp.ones((cfg.d_model,))
        p["xattn"] = init_attention(ks[1], cfg)
    if cfg.moe is not None:
        p["moe"] = init_moe(ks[2], cfg)
    else:
        p["mlp"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff)
    return p


def decoder_block(params, x, cfg: ArchConfig, positions, *,
                  causal: bool = True,
                  cache: Optional[KVCache] = None,
                  cache_pos=None,
                  enc_out: Optional[jax.Array] = None,
                  enc_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                  mesh_info=None):
    """-> (x, new_cache, aux_loss)."""
    h = rms_norm(x, params["ln1"].astype(x.dtype), cfg.norm_eps)
    attn_out, new_cache = attention_block(
        params["attn"], h, cfg, positions, causal=causal,
        cache=cache, cache_pos=cache_pos)
    x = x + attn_out
    if enc_out is not None or enc_kv is not None:
        h = rms_norm(x, params["ln_x"].astype(x.dtype), cfg.norm_eps)
        xo, _ = attention_block(params["xattn"], h, cfg, positions,
                                causal=False, kv_source=enc_out,
                                kv_precomputed=enc_kv)
        x = x + xo
    h = rms_norm(x, params["ln2"].astype(x.dtype), cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        out, aux = moe_block(params["moe"], h, cfg, mesh_info)
    else:
        out = mlp_block(params["mlp"], h)
    x = x + out
    return shard(x, "act_batch", "act_seq", "act_embed"), new_cache, aux


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

VOCAB_PAD = 128  # pad vocab so embedding/logits shard over any mesh axis


def padded_vocab(vocab_size: int) -> int:
    return -(-vocab_size // VOCAB_PAD) * VOCAB_PAD


def init_embed(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    vp = padded_vocab(cfg.vocab_size)
    p = {"embed": embed_init(k1, vp, cfg.d_model),
         "ln_f": jnp.ones((cfg.d_model,))}
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(k2, vp, cfg.d_model)
    return p


def embed_tokens(params, tokens, cfg: ArchConfig, dtype=jnp.float32):
    e = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    return shard(e, "act_batch", "act_seq", "act_embed")


def lm_logits(params, x, cfg: ArchConfig):
    # gather seq, shard vocab: the CE reductions then stay vocab-local and
    # only [B,S]-sized partials cross the network (vs gathering [B,S,V]).
    head = params.get("lm_head", params["embed"])
    x = shard(x, "act_batch", "act_seq_inner", "act_embed")
    logits = jnp.einsum("bsd,vd->bsv", x, head.astype(x.dtype))
    return shard(logits, "act_batch", "act_seq_inner", "act_vocab")


# ---------------------------------------------------------------------------
# Layer-stack runners
# ---------------------------------------------------------------------------

def _remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":   # save matmul outputs (hillclimb knob)
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)  # "block"/"full": recompute block internals


def run_stack(blocks, x, cfg: ArchConfig, positions, *, causal=True,
              enc_out=None, mesh_info=None):
    """Run all layers (train/prefill). blocks = stacked pytree."""
    def body(xc, layer_params):
        out, _, aux = decoder_block(layer_params, xc, cfg, positions,
                                    causal=causal, enc_out=enc_out,
                                    mesh_info=mesh_info)
        return out, aux

    if cfg.scan_layers:
        body_r = _remat(body, cfg)
        x, auxs = jax.lax.scan(body_r, x, blocks)
        return x, auxs.sum()
    body_r = _remat(body, cfg)
    aux_total = jnp.zeros((), jnp.float32)
    n = jax.tree.leaves(blocks)[0].shape[0] if blocks is not None else 0
    for i in range(n):
        x, aux = body_r(x, tree_slice(blocks, i))
        aux_total += aux
    return x, aux_total


def run_stack_decode(blocks, x, cfg: ArchConfig, positions, caches,
                     cache_pos, *, enc_kv=None, mesh_info=None):
    """One decode step through all layers.

    ``caches`` stacked [L, ...]; ``enc_kv`` (optional) stacked per-layer
    precomputed cross K/V.
    """
    def body(xc, layer):
        layer_params, layer_cache, layer_enc = layer
        out, new_cache, _ = decoder_block(
            layer_params, xc, cfg, positions, cache=layer_cache,
            cache_pos=cache_pos, enc_kv=layer_enc, mesh_info=mesh_info)
        return out, new_cache

    n_layers = cfg.num_layers
    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(
            lambda xc, layer: body(xc, layer), x, (blocks, caches, enc_kv))
        return x, new_caches
    new_list = []
    for i in range(n_layers):
        enc_i = tree_slice(enc_kv, i) if enc_kv is not None else None
        x, nc = body(x, (tree_slice(blocks, i), tree_slice(caches, i), enc_i))
        new_list.append(nc)
    return x, tree_stack(new_list)
