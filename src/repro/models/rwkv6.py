"""RWKV6 "Finch" — attention-free time-mix with data-dependent decay.

Faithful pieces: per-channel data-dependent decay ``w_t = exp(-exp(d_t))``
with a low-rank (LoRA) d_t, bonus ``u``, token-shift, WKV state recurrence,
per-head group-norm, gated output, squared-ReLU channel-mix.
Simplification (noted in DESIGN.md): token-shift mixing coefficients are
static learned vectors (the paper also LoRA-modulates them).

Two evaluation paths:
* ``wkv_scan``    — exact sequential recurrence (oracle; also the decode step)
* ``wkv_chunked`` — chunked parallel form with pairwise log-space decays
                    (the TPU-efficient path; validated against the scan)
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import dense_init, shard

CHUNK = 32  # pairwise-decay chunk (kept small: decays are per-channel)


class RWKVState(NamedTuple):
    wkv: jax.Array      # [B, H, hd, hd] per-layer recurrent state
    x_tmix: jax.Array   # [B, D] previous token (time-mix shift)
    x_cmix: jax.Array   # [B, D] previous token (channel-mix shift)


def init_rwkv_block(key, cfg: ArchConfig):
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    H = d // hd
    ks = jax.random.split(key, 10)
    lora_r = 64
    return {
        "mix_r": jnp.full((d,), 0.5), "mix_k": jnp.full((d,), 0.5),
        "mix_v": jnp.full((d,), 0.5), "mix_w": jnp.full((d,), 0.5),
        "mix_g": jnp.full((d,), 0.5), "mix_ck": jnp.full((d,), 0.5),
        "mix_cr": jnp.full((d,), 0.5),
        "wr": dense_init(ks[0], d, (d,)), "wk": dense_init(ks[1], d, (d,)),
        "wv": dense_init(ks[2], d, (d,)), "wg": dense_init(ks[3], d, (d,)),
        "wo": dense_init(ks[4], d, (d,)),
        # data-dependent decay LoRA: d_t = base + W2 tanh(W1 x)
        "w_base": jnp.full((d,), -4.0),
        "w_lora1": dense_init(ks[5], d, (lora_r,), scale=0.1),
        "w_lora2": dense_init(ks[6], lora_r, (d,), scale=0.1),
        "u": jnp.zeros((H, hd)),                       # bonus
        "ln_x": jnp.ones((d,)),                        # per-head group norm
        # channel mix
        "ck": dense_init(ks[7], d, (cfg.d_ff,)),
        "cv": dense_init(ks[8], cfg.d_ff, (d,)),
        "cr": dense_init(ks[9], d, (d,)),
    }


def _token_shift(x, x_prev, mix):
    """lerp(x_{t-1}, x_t, mix); x [B,T,D], x_prev [B,D] (state)."""
    prev = jnp.concatenate([x_prev[:, None].astype(x.dtype), x[:, :-1]],
                           axis=1)
    m = mix.astype(x.dtype)
    return x * m + prev * (1.0 - m)


def _decay(params, xw):
    d_t = params["w_base"] + jnp.einsum(
        "btd,dr->btr", jnp.tanh(jnp.einsum("btd,dr->btr", xw, params["w_lora1"])),
        params["w_lora2"])
    return jnp.exp(-jnp.exp(d_t.astype(jnp.float32)))     # w in (0,1), [B,T,D]


def wkv_scan(r, k, v, w, u, s0):
    """Exact recurrence. r,k,v,w: [B,T,H,hd]; u: [H,hd]; s0: [B,H,hd,hd].

    y_t = r_t · (S_{t-1} + diag(u) k_t^T v_t);  S_t = diag(w_t) S_{t-1} + k_t^T v_t
    Returns y [B,T,H,hd], s_end.
    """
    def step(s, xs):
        rt, kt, vt, wt = xs                              # [B,H,hd]
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    s_end, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_end


def wkv_chunked(r, k, v, w, u, s0, chunk: int = CHUNK):
    """Chunked parallel WKV — pairwise log-space decays (stable).

    Per chunk c with local decays w_t: logcum a_t = sum_{i<=t} log w_i.
    intra: y_t += sum_{s<t} (r_t * exp(a_{t-1}-a_s)) · k_s  v_s + r_t·(u k_t) v_t
    inter: y_t += (r_t * exp(a_{t-1})) · S_0
    carry: S' = diag(exp(a_L)) S_0 + sum_s exp(a_L - a_s) k_s^T v_s
    """
    B, T, H, hd = r.shape
    n = T // chunk
    assert n * chunk == T, "sequence must be divisible by chunk"
    rs = r.reshape(B, n, chunk, H, hd)
    ks_ = k.reshape(B, n, chunk, H, hd)
    vs = v.reshape(B, n, chunk, H, hd)
    logw = jnp.log(jnp.clip(w, 1e-38, 1.0)).reshape(B, n, chunk, H, hd)

    def chunk_step(s, xs):
        rc, kc, vc, lw = xs                              # [B,chunk,H,hd]
        a = jnp.cumsum(lw, axis=1)                       # [B,L,H,hd]
        a_prev = a - lw                                  # a_{t-1}
        # pairwise per-channel decays: exp(a_prev[t] - a[s]) for s < t
        diff = a_prev[:, :, None] - a[:, None, :]        # [B,L,L,H,hd]
        tmask = jnp.tril(jnp.ones((chunk, chunk), bool), -1)[None, :, :, None, None]
        gamma = jnp.where(tmask, jnp.exp(diff), 0.0)
        att = jnp.einsum("bthc,bshc,btshc->btsh", rc, kc, gamma.astype(rc.dtype))
        y = jnp.einsum("btsh,bshv->bthv", att, vc)
        # diagonal bonus term: (sum_c r_tc u_c k_tc) * v_t
        y += jnp.einsum("bthc,bthc->bth", rc, u[None, None] * kc)[..., None] * vc
        # inter-chunk
        y += jnp.einsum("bthc,bhcv->bthv", rc * jnp.exp(a_prev).astype(rc.dtype), s)
        # carry
        aL = a[:, -1]                                    # [B,H,hd]
        kdec = kc * jnp.exp(aL[:, None] - a).astype(kc.dtype)
        s = jnp.exp(aL)[..., None].astype(s.dtype) * s + jnp.einsum(
            "bthc,bthv->bhcv", kdec, vc)
        return s, y

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (rs, ks_, vs, logw))
    s_end, ys = jax.lax.scan(chunk_step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, hd)
    return y, s_end


def rwkv_block(params, x, cfg: ArchConfig, state: RWKVState,
               impl: str = "chunked") -> Tuple[jax.Array, RWKVState]:
    """Full RWKV6 block (time-mix + channel-mix). x [B,T,D]."""
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    H = d // hd
    B, T, _ = x.shape
    dt = x.dtype
    x_in_last = x[:, -1]

    # ---- time mix -----------------------------------------------------
    xr = _token_shift(x, state.x_tmix, params["mix_r"])
    xk = _token_shift(x, state.x_tmix, params["mix_k"])
    xv = _token_shift(x, state.x_tmix, params["mix_v"])
    xw = _token_shift(x, state.x_tmix, params["mix_w"])
    xg = _token_shift(x, state.x_tmix, params["mix_g"])
    r = jnp.einsum("btd,de->bte", xr, params["wr"].astype(dt)).reshape(B, T, H, hd)
    k = jnp.einsum("btd,de->bte", xk, params["wk"].astype(dt)).reshape(B, T, H, hd)
    v = jnp.einsum("btd,de->bte", xv, params["wv"].astype(dt)).reshape(B, T, H, hd)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, params["wg"].astype(dt)))
    w = _decay(params, xw).reshape(B, T, H, hd)

    if impl == "scan" or T == 1 or T % CHUNK != 0:
        y, s_end = wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), w,
                            params["u"].astype(jnp.float32),
                            state.wkv.astype(jnp.float32))
    else:
        y, s_end = wkv_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                               v.astype(jnp.float32), w,
                               params["u"].astype(jnp.float32),
                               state.wkv.astype(jnp.float32))
    # per-head group norm
    y32 = y.astype(jnp.float32)
    mu = y32.mean(-1, keepdims=True)
    var = y32.var(-1, keepdims=True)
    y = ((y32 - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, T, d).astype(dt)
    y = y * params["ln_x"].astype(dt) * g
    y = jnp.einsum("btd,de->bte", y, params["wo"].astype(dt))
    x = x + shard(y, "act_batch", "act_seq", "act_embed")
    x_mid_last = x[:, -1]

    # ---- channel mix ---------------------------------------------------
    xck = _token_shift(x, state.x_cmix, params["mix_ck"])
    xcr = _token_shift(x, state.x_cmix, params["mix_cr"])
    kk = jnp.einsum("btd,df->btf", xck, params["ck"].astype(dt))
    kk = jnp.square(jax.nn.relu(kk))
    cv = jnp.einsum("btf,fd->btd", kk, params["cv"].astype(dt))
    cr = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xcr, params["cr"].astype(dt)))
    x = x + shard(cr * cv, "act_batch", "act_seq", "act_embed")

    new_state = RWKVState(s_end.astype(state.wkv.dtype),
                          x_in_last.astype(state.x_tmix.dtype),
                          x_mid_last.astype(state.x_cmix.dtype))
    return x, new_state


def init_rwkv_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> RWKVState:
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    H = d // hd
    return RWKVState(jnp.zeros((batch, H, hd, hd), dtype),
                     jnp.zeros((batch, d), dtype),
                     jnp.zeros((batch, d), dtype))
