"""Shared model utilities: norms, RoPE/M-RoPE, inits, logical sharding.

Logical-axis sharding (MaxText-style): model code annotates tensors with
*logical* axis names; a rules table (set by the launcher per mesh/"packaging")
maps logical names -> mesh axes. This is DCRA's reconfigurability knob: the
same model definition is "re-packaged" onto different meshes by swapping the
rules, never by editing model code.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical sharding rules
# ---------------------------------------------------------------------------

_state = threading.local()


def _rules() -> Optional[Dict[str, Union[str, Tuple[str, ...], None]]]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def logical_axis_rules(rules: Dict[str, Union[str, Tuple[str, ...], None]]):
    """Install logical->mesh axis rules for the enclosed trace."""
    prev = _rules()
    _state.rules = dict(rules)
    try:
        yield
    finally:
        _state.rules = prev


def logical_spec(*names: Optional[str]) -> P:
    rules = _rules() or {}
    return P(*(rules.get(n) if n is not None else None for n in names))


def shard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Constrain ``x`` to the mesh axes the active rules map ``names`` to."""
    rules = _rules()
    if rules is None:
        return x
    spec = logical_spec(*names)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * gamma


def layer_norm(x, gamma, beta, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(dt) * gamma + beta


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Stable CE over the (possibly sharded) vocab axis. logits [..., V]."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - ll


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs   # [..., S, hd/2]
    angles = angles[..., None, :]                      # [..., S, 1, hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Sequence[int] = (16, 24, 24)) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: [B, S, H, hd]; positions: [B, 3, S] (temporal, height, width streams).
    ``sections`` partitions the hd/2 rotary frequencies among the 3 streams.
    """
    hd = x.shape[-1]
    half = hd // 2
    secs = list(sections)
    if sum(secs) != half:  # rescale sections for reduced head dims
        base = [max(1, s * half // sum(secs)) for s in secs]
        base[0] += half - sum(base)
        secs = base
    freqs = rope_freqs(hd, theta)                      # [half]
    # angles per stream then select per-frequency stream by section
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, 3, S, half]
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(secs), total_repeat_length=half)
    angles = jnp.take_along_axis(
        ang, sec_id[None, None, None, :].repeat(ang.shape[2], axis=2), axis=1
    )[:, 0]                                            # [B, S, half]
    angles = angles[..., None, :]                      # [B, S, 1, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_shape, scale: float = 1.0,
               dtype=jnp.float32) -> jax.Array:
    shape = (in_dim,) + tuple(out_shape)
    std = scale / (in_dim ** 0.5)
    return jax.random.normal(key, shape, dtype) * std


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32) -> jax.Array:
    return jax.random.normal(key, (vocab, dim), dtype) * 0.02
