"""Mamba2 (SSD) block — chunked scan, plus exact sequential oracle/decode.

State-space recurrence per head (P = head dim, N = state dim):
  h_t = a_t * h_{t-1} + dt_t * (B_t ⊗ x_t)      h: [N, P], a_t = exp(dt_t * A)
  y_t = C_t · h_t + D * x_t

The chunked (SSD) form computes intra-chunk contributions with a pairwise
decay matrix (scalar per head — numerically safe in log space) and carries
the state across chunks; validated against the sequential scan in tests.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import dense_init, shard


class MambaState(NamedTuple):
    h: jax.Array       # [B, H, N, P] ssm state
    conv: jax.Array    # [B, W-1, conv_dim] depthwise-conv tail


def _dims(cfg: ArchConfig):
    ss = cfg.ssm
    d_in = ss.expand * cfg.d_model
    H = d_in // ss.head_dim
    return d_in, H, ss.head_dim, ss.state_dim, ss.conv_width


def init_mamba_block(key, cfg: ArchConfig):
    d = cfg.d_model
    d_in, H, P, N, W = _dims(cfg)
    conv_dim = d_in + 2 * N
    ks = jax.random.split(key, 4)
    return {
        # in_proj -> [z (gate), xBC, dt]
        "w_in": dense_init(ks[0], d, (d_in + conv_dim + H,)),
        "conv_w": jax.random.normal(ks[1], (W, conv_dim)) * (W ** -0.5),
        "conv_b": jnp.zeros((conv_dim,)),
        "dt_bias": jnp.full((H,), -2.0),
        "A_log": jnp.zeros((H,)),                 # A = -exp(A_log)
        "D": jnp.ones((H,)),
        "norm_g": jnp.ones((d_in,)),              # gated RMSNorm pre-out
        "w_out": dense_init(ks[2], d_in, (d,)),
    }


def _conv1d(xBC, conv_w, conv_b, conv_state):
    """Causal depthwise conv. xBC [B,T,C]; conv_state [B,W-1,C]."""
    W = conv_w.shape[0]
    full = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
    out = sum(full[:, i:i + xBC.shape[1]] * conv_w[i] for i in range(W))
    new_state = full[:, -(W - 1):] if W > 1 else conv_state
    return jax.nn.silu(out + conv_b.astype(xBC.dtype)), new_state


def ssd_scan(x, dt, A, Bm, Cm, h0):
    """Exact recurrence. x [B,T,H,P]; dt [B,T,H]; A [H]; Bm,Cm [B,T,N].

    Returns y [B,T,H,P], h_end [B,H,N,P].
    """
    def step(h, xs):
        xt, dtt, bt, ct = xs
        a = jnp.exp(dtt * A)                               # [B,H]
        upd = jnp.einsum("bn,bhp,bh->bhnp", bt, xt, dtt)
        h = a[..., None, None] * h + upd
        y = jnp.einsum("bn,bhnp->bhp", ct, h)
        return h, y

    xs = tuple(jnp.moveaxis(v, 1, 0) for v in (x, dt, Bm, Cm))
    h_end, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_end


def ssd_chunked(x, dt, A, Bm, Cm, h0, chunk: int):
    """SSD chunked form. Shapes as in ssd_scan."""
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    n = T // chunk
    assert n * chunk == T
    xs = x.reshape(B, n, chunk, H, P)
    dts = dt.reshape(B, n, chunk, H)
    Bs = Bm.reshape(B, n, chunk, N)
    Cs = Cm.reshape(B, n, chunk, N)

    def chunk_step(h_in, xs_):
        xc, dtc, bc, cc = xs_                              # [B,L,...]
        la = dtc * A                                       # log a_t [B,L,H]
        cum = jnp.cumsum(la, axis=1)                       # alpha_t
        # pairwise decay exp(alpha_t - alpha_s) for s <= t  (scalar per head)
        diff = cum[:, :, None] - cum[:, None, :]           # [B,L,L,H]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, :, :, None]
        gamma = jnp.where(mask, jnp.exp(diff), 0.0)
        cb = jnp.einsum("btn,bsn->bts", cc, bc)            # [B,L,L]
        att = cb[..., None] * gamma                        # [B,L,L,H]
        y = jnp.einsum("btsh,bsh,bshp->bthp", att, dtc, xc)
        # inter: y_t += C_t exp(alpha_t) h_in
        y += jnp.einsum("btn,bth,bhnp->bthp", cc, jnp.exp(cum), h_in)
        # carry: h' = exp(alpha_L) h_in + sum_s exp(alpha_L - alpha_s) dt_s B_s x_s
        aL = cum[:, -1]                                    # [B,H]
        dec = jnp.exp(aL[:, None] - cum)                   # [B,L,H]
        upd = jnp.einsum("bsn,bsh,bshp->bhnp", bc, dec * dtc, xc)
        h_out = jnp.exp(aL)[..., None, None] * h_in + upd
        return h_out, y

    xs_stack = tuple(jnp.moveaxis(v, 1, 0) for v in (xs, dts, Bs, Cs))
    h_end, ys = jax.lax.scan(chunk_step, h0, xs_stack)
    return jnp.moveaxis(ys, 0, 1).reshape(B, T, H, P), h_end


def mamba_block(params, x, cfg: ArchConfig, state: MambaState,
                impl: str = "chunked") -> Tuple[jax.Array, MambaState]:
    """x [B,T,D] -> (out [B,T,D], new state)."""
    d = cfg.d_model
    d_in, H, P, N, W = _dims(cfg)
    B, T, _ = x.shape
    dt_ = x.dtype

    proj = jnp.einsum("btd,de->bte", x, params["w_in"].astype(dt_))
    z, xBC, dt_raw = jnp.split(proj, [d_in, d_in + d_in + 2 * N], axis=-1)
    xBC, conv_state = _conv1d(xBC, params["conv_w"], params["conv_b"],
                              state.conv)
    xs, Bm, Cm = jnp.split(xBC, [d_in, d_in + N], axis=-1)
    xs = xs.reshape(B, T, H, P)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    args = (xs.astype(jnp.float32), dtv, A, Bm.astype(jnp.float32),
            Cm.astype(jnp.float32), state.h.astype(jnp.float32))
    if impl == "scan" or T == 1 or T % cfg.ssm.chunk_size != 0:
        y, h_end = ssd_scan(*args)
    else:
        y, h_end = ssd_chunked(*args, chunk=cfg.ssm.chunk_size)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, T, d_in).astype(dt_)
    # gated RMSNorm
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)).astype(dt_)
    y = y * params["norm_g"].astype(dt_)
    out = jnp.einsum("bte,ed->btd", y, params["w_out"].astype(dt_))
    out = shard(out, "act_batch", "act_seq", "act_embed")
    return out, MambaState(h_end.astype(state.h.dtype), conv_state)


def init_mamba_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> MambaState:
    d_in, H, P, N, W = _dims(cfg)
    return MambaState(jnp.zeros((batch, H, N, P), dtype),
                      jnp.zeros((batch, W - 1, d_in + 2 * N), dtype))
