"""GQA attention: full/SWA masks, chunked online-softmax, KV cache decode.

Memory strategy (maps DCRA's scratchpad/cache split onto TPU):
* short sequences (<= DIRECT_KV_LIMIT) use direct masked softmax — the
  "scratchpad" regime where the whole working set is resident;
* long sequences stream KV in chunks with an online softmax (flash-style) —
  the "cache" regime where data is staged through fast memory in lines.
* decode (Sq == 1) computes directly over the (possibly sequence-sharded)
  cache; XLA's partitioner turns the softmax reductions into the
  flash-decoding partial-max/sum combine across shards.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import apply_mrope, apply_rope, dense_init, shard

DIRECT_KV_LIMIT = 4096
KV_CHUNK = 1024


class KVCache(NamedTuple):
    k: jax.Array        # [B, C, Hkv, hd]
    v: jax.Array        # [B, C, Hkv, hd]
    length: jax.Array   # [] int32 — tokens currently valid (ring for SWA)


def init_attention(key, cfg: ArchConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, (cfg.num_heads, hd)),
        "wk": dense_init(ks[1], d, (cfg.num_kv_heads, hd)),
        "wv": dense_init(ks[2], d, (cfg.num_kv_heads, hd)),
        "wo": dense_init(ks[3], cfg.num_heads * hd, (d,)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads, hd))
        p["bk"] = jnp.zeros((cfg.num_kv_heads, hd))
        p["bv"] = jnp.zeros((cfg.num_kv_heads, hd))
    return p


def _qkv(params, x, cfg: ArchConfig, kv_source=None):
    src = x if kv_source is None else kv_source
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"].astype(src.dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"].astype(src.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    return q, k, v


def project_cross_kv(params, enc_out, cfg: ArchConfig):
    """Precompute cross-attention K/V from encoder output (serving prefill)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(enc_out.dtype))
    if cfg.qkv_bias:
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    return k, v


def _apply_pos(q, k, cfg: ArchConfig, positions):
    """positions: [B, S] (standard) or [B, 3, S] (M-RoPE)."""
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def _mask(q_pos, kv_pos, causal: bool, window: int):
    """[..., Sq, Skv] boolean validity mask from position vectors."""
    m = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], kv_pos.shape[-1]), bool)
    if causal:
        m &= kv_pos[..., None, :] <= q_pos[..., :, None]
    if window > 0:
        m &= kv_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


def _direct_attend(q, k, v, q_pos, kv_pos, causal, window):
    """q [B,Sq,Hq,hd]; k,v [B,Skv,Hkv,hd] -> [B,Sq,Hq,hd]."""
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    scale = hd ** -0.5
    logits = jnp.einsum("bshgk,bthk->bhgst", qg, k) * scale   # [B,Hkv,G,Sq,Skv]
    mask = _mask(q_pos, kv_pos, causal, window)                # [B?,Sq,Skv]
    if mask.ndim == 2:
        mask = mask[None]
    logits = jnp.where(mask[:, None, None], logits.astype(jnp.float32), -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgst,bthk->bshgk", w, v)
    return out.reshape(B, Sq, Hq, hd)


def _chunked_attend(q, k, v, q_pos, kv_pos, causal, window, chunk=KV_CHUNK):
    """Online-softmax over KV chunks; exact; O(Sq * chunk) live memory."""
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = hd ** -0.5
    n_chunks = -(-Skv // chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, pad),), constant_values=jnp.iinfo(jnp.int32).max)
    kc = k.reshape(B, n_chunks, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(n_chunks, chunk)
    qg = q.reshape(B, Sq, Hkv, G, hd)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, pb = xs
        logits = jnp.einsum("bshgk,bthk->bhgst", qg, kb).astype(jnp.float32) * scale
        msk = _mask(q_pos, pb, causal, window)
        msk &= (pb != jnp.iinfo(jnp.int32).max)[..., None, :]  # pad sentinel
        if msk.ndim == 2:
            msk = msk[None]
        logits = jnp.where(msk[:, None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgst,bthk->bhgsk", p.astype(vb.dtype), vb).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, hd)
    return out.astype(q.dtype)


def attend(q, k, v, q_pos, kv_pos, *, causal: bool, window: int = 0):
    if k.shape[1] <= DIRECT_KV_LIMIT or q.shape[1] == 1:
        return _direct_attend(q, k, v, q_pos, kv_pos, causal, window)
    return _chunked_attend(q, k, v, q_pos, kv_pos, causal, window)


def attention_block(params, x, cfg: ArchConfig, positions, *,
                    causal: bool = True,
                    cache: Optional[KVCache] = None,
                    cache_pos: Optional[jax.Array] = None,
                    kv_source: Optional[jax.Array] = None,
                    kv_precomputed: Optional[Tuple[jax.Array, jax.Array]] = None,
                    ) -> Tuple[jax.Array, Optional[KVCache]]:
    """One attention layer.

    * training/prefill: ``cache is None`` -> self-attention over ``x``.
    * decode: ``cache`` given, ``x`` is [B, 1, D]; writes K/V at ``cache_pos``
      (ring position for SWA) and attends over the cache.
    * cross-attention: ``kv_source`` (encoder output, train) or
      ``kv_precomputed`` (projected K/V, decode) — no rope, no causal mask.
    """
    window = cfg.sliding_window
    if kv_precomputed is not None:
        q, _, _ = _qkv(params, x, cfg, kv_source=x)
        k, v = kv_precomputed
        kv_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
        qp = positions if positions.ndim == 2 else positions[:, 0]
        return _finish(params, attend(q, k, v, qp, kv_pos, causal=False,
                                      window=0), x), None
    q, k, v = _qkv(params, x, cfg, kv_source=kv_source)
    new_cache = None
    if kv_source is not None:
        # cross-attention (train/prefill): no rope, no causal mask
        kv_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
        qp = positions if positions.ndim == 2 else positions[:, 0]
        out = attend(q, k, v, qp, kv_pos, causal=False, window=0)
    elif cache is None:
        q, k = _apply_pos(q, k, cfg, positions)
        k = shard(k, "act_batch", "act_seq_inner", "act_kv", None)
        v = shard(v, "act_batch", "act_seq_inner", "act_kv", None)
        qp = positions if not cfg.mrope else positions[:, 0]
        out = attend(q, k, v, qp, qp[0] if qp.ndim == 2 else qp,
                     causal=causal, window=window)
    else:
        # decode: x [B,1,D]; positions [B,1] (or [B,3,1] mrope) absolute
        q, k = _apply_pos(q, k, cfg, positions)
        C = cache.k.shape[1]
        slot = (cache_pos % C).astype(jnp.int32)
        k_cache = _scatter_slot(cache.k, k, slot)
        v_cache = _scatter_slot(cache.v, v, slot)
        # absolute positions of cache slots (ring-aware)
        qp = positions if not cfg.mrope else positions[:, 0]
        abs_pos = _cache_positions(cache_pos, C)
        out = attend(q, k_cache, v_cache, qp, abs_pos, causal=True, window=window)
        new_cache = KVCache(k_cache, v_cache, cache.length + 1)
    return _finish(params, out, x), new_cache


def _finish(params, out, x):
    B, S = out.shape[:2]
    out = out.reshape(B, S, -1)
    out = jnp.einsum("bsf,fd->bsd", out, params["wo"].astype(x.dtype))
    return shard(out, "act_batch", "act_seq", "act_embed")


def _scatter_slot(cache_arr, kv, slot):
    """Write kv [B,1,H,hd] into cache [B,C,H,hd] at ring index ``slot``."""
    C = cache_arr.shape[1]
    onehot = jax.nn.one_hot(slot, C, dtype=kv.dtype)            # [C]
    upd = onehot[None, :, None, None] * kv.astype(cache_arr.dtype)
    keep = (1 - onehot)[None, :, None, None].astype(cache_arr.dtype)
    return cache_arr * keep + upd.astype(cache_arr.dtype)


def _cache_positions(cache_pos, C):
    """Absolute position of each ring slot given next-write pos ``cache_pos``.

    Slots hold the last C tokens: slot i holds absolute position
    p where p ≡ i (mod C) and p in [cache_pos - C, cache_pos - 1] —
    plus the just-written token at slot cache_pos % C (position cache_pos).
    """
    idx = jnp.arange(C, dtype=jnp.int32)
    wrap = (cache_pos % C).astype(jnp.int32)
    base = (cache_pos // C).astype(jnp.int32)
    pos = jnp.where(idx <= wrap, base * C + idx, (base - 1) * C + idx)
    # never-written slots (first lap) -> sentinel masked by the causal check
    return jnp.where(pos < 0, jnp.iinfo(jnp.int32).max, pos)


def init_kv_cache(cfg: ArchConfig, batch: int, seq_len: int,
                  dtype=jnp.bfloat16) -> KVCache:
    """Cache for one layer. SWA bounds capacity by the window (ring)."""
    C = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    hd = cfg.resolved_head_dim
    shape = (batch, C, cfg.num_kv_heads, hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))
